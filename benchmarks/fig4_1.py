"""Figure 4.1 reproduction: normalized spectral error + runtime vs (k, q) on
a VGG19-classifier-sized layer.

The original layer is 4096 x 25088 with the slow-decay spectrum of Fig 1.1.
Ground truth s_{k+1} comes from *constructing* the test matrix with a known
spectrum (synth_spectrum_matrix) matched to the published decay profile —
this avoids a full exact SVD on CPU while keeping the normalized-error
metric exact.  ``--full`` uses the paper's exact dimensions; the default is
a 1/4-scale matrix (same spectrum shape) so the whole suite runs in minutes
on this container.  Runtimes are CPU wall-clock — RELATIVE speedups (RSI vs
exact SVD, q vs q) are the reproduction target, not A100 absolute numbers.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    normalized_error,
    rsi,
    synth_spectrum_matrix,
    vgg_like_spectrum,
)
from repro.core.rsi import rsi_flops


def run(full: bool = False, trials: int = 3, ks=(50, 100, 200), qs=(1, 2, 3, 4)):
    C, D = (4096, 25088) if full else (1024, 6272)
    s = vgg_like_spectrum(C)
    W = synth_spectrum_matrix(jax.random.PRNGKey(0), C, D, s)
    rows = []
    for k in ks:
        for q in qs:
            errs, times = [], []
            fn = jax.jit(lambda key, W=W, k=k, q=q: rsi(W, k, q, key))
            fn(jax.random.PRNGKey(0)).S.block_until_ready()  # warm
            for t in range(trials):
                key = jax.random.PRNGKey(100 + t)
                t0 = time.perf_counter()
                res = fn(key)
                res.S.block_until_ready()
                times.append(time.perf_counter() - t0)
                ne = normalized_error(
                    W, res.U, res.S, res.Vt, float(s[k]), jax.random.PRNGKey(7)
                )
                errs.append(float(ne))
            rows.append(
                dict(
                    k=k,
                    q=q,
                    normalized_error=float(np.mean(errs)),
                    err_std=float(np.std(errs)),
                    seconds=float(np.mean(times)),
                    flops=rsi_flops(C, D, k, q),
                )
            )
    return dict(C=C, D=D, rows=rows)


def emit_csv(result):
    for r in result["rows"]:
        print(
            f"fig4_1/k={r['k']}/q={r['q']},{r['seconds']*1e6:.0f},"
            f"normalized_error={r['normalized_error']:.4f}"
        )


if __name__ == "__main__":
    emit_csv(run())
