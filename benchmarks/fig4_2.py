"""Figure 4.2 reproduction: ViT-B/32 encoder FFN layer (768 x 3072), full
size, RSI vs exact SVD — normalized error and wall-clock.

The ViT layer's spectrum decays even more slowly than VGG's (paper: RSVD
normalized error > 4 at k=500); we synthesize that regime with a flatter
tail.  Exact-SVD runtime is measured for the speedup comparison (the paper's
Fig 4.2(b)) — both run on the same CPU so the ratio is meaningful.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import normalized_error, rsi, synth_spectrum_matrix


def vit_like_spectrum(r: int):
    """Flatter tail than VGG: fast drop over ~10 directions then near-plateau."""
    i = jnp.arange(1, r + 1, dtype=jnp.float32)
    return 20.0 * (i ** (-0.9) + 0.15 * (i / r) ** (-0.15)) / 1.15


def run(trials: int = 3, ks=(100, 300, 500), qs=(1, 2, 3, 4)):
    C, D = 768, 3072
    s = vit_like_spectrum(C)
    W = synth_spectrum_matrix(jax.random.PRNGKey(1), C, D, s)

    # exact SVD baseline (one timing; the decomposition serves all k)
    t0 = time.perf_counter()
    _svd = jnp.linalg.svd(W, compute_uv=True)
    jax.block_until_ready(_svd)
    svd_seconds = time.perf_counter() - t0

    rows = []
    for k in ks:
        for q in qs:
            errs, times = [], []
            fn = jax.jit(lambda key, k=k, q=q: rsi(W, k, q, key))
            fn(jax.random.PRNGKey(0)).S.block_until_ready()
            for t in range(trials):
                t0 = time.perf_counter()
                res = fn(jax.random.PRNGKey(200 + t))
                res.S.block_until_ready()
                times.append(time.perf_counter() - t0)
                errs.append(
                    float(
                        normalized_error(
                            W, res.U, res.S, res.Vt, float(s[k]), jax.random.PRNGKey(8)
                        )
                    )
                )
            rows.append(
                dict(
                    k=k,
                    q=q,
                    normalized_error=float(np.mean(errs)),
                    seconds=float(np.mean(times)),
                    svd_speedup=svd_seconds / float(np.mean(times)),
                )
            )
    return dict(C=C, D=D, svd_seconds=svd_seconds, rows=rows)


def emit_csv(result):
    print(f"fig4_2/exact_svd,{result['svd_seconds']*1e6:.0f},baseline=1.0")
    for r in result["rows"]:
        print(
            f"fig4_2/k={r['k']}/q={r['q']},{r['seconds']*1e6:.0f},"
            f"normalized_error={r['normalized_error']:.4f};svd_speedup={r['svd_speedup']:.1f}x"
        )


if __name__ == "__main__":
    emit_csv(run())
