"""Table 4.1 reproduction: end-to-end compression vs predictive accuracy.

No ImageNet/Imagenette offline, so the experiment runs at two levels
(DESIGN.md §7 documents the deviation):

  (a) TRAINED-MODEL level — a small MLP classifier (VGG-classifier-shaped:
      three wide FC layers) trained in-framework on a synthetic 10-class
      dataset to ~99% accuracy, then compressed with the paper's alpha x q
      grid WITHOUT retraining.  Reports time / ratio / top-1 / top-5.
  (b) CONTROLLED-SPECTRUM level — the same grid applied to a classifier
      whose hidden weights are replaced by matrices with the published
      slow-decay spectrum (Fig 1.1), isolating the spectral mechanism the
      paper attributes the q-effect to.

The validation target is the TREND STRUCTURE of Table 4.1: (i) q=1 collapses
under aggressive compression (small alpha), (ii) q>=2 recovers most accuracy,
(iii) accuracy is monotone-ish in q, (iv) ratio depends only on alpha.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CompressionPolicy, compress_tree, apply_linear
from repro.core import synth_spectrum_matrix, vgg_like_spectrum
from repro.data.synthetic import classification_dataset
from repro.train import optimizer as opt_mod

DIMS = (256, 512, 512, 10)  # "VGG classifier"-shaped FC stack (scaled)
MARGIN = 0.18  # class-mean scale: tuned so the uncompressed model sits ~80% top-1


def _init_mlp(key, dims=DIMS):
    params = {}
    ks = jax.random.split(key, len(dims) - 1)
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        params[f"fc{i}"] = {
            "w": jax.random.normal(ks[i], (a, b)) * (a**-0.5),
            "b": jnp.zeros((b,)),
        }
    return params


def _mlp_forward(params, x):
    n = len(params)
    for i in range(n):
        p = params[f"fc{i}"]
        x = apply_linear(p["w"], x) + p["b"]
        if i < n - 1:
            x = jax.nn.gelu(x)
    return x


def _train_mlp(params, X, y, *, steps=400, lr=3e-3):
    opt = opt_mod.adamw(opt_mod.cosine_schedule(lr, 20, steps), weight_decay=0.0)
    state = opt.init(params)

    @jax.jit
    def step(params, state, i, xb, yb):
        def loss_fn(p):
            logits = _mlp_forward(p, xb)
            return -jnp.mean(
                jnp.take_along_axis(jax.nn.log_softmax(logits), yb[:, None], axis=1)
            )

        loss, grads = jax.value_and_grad(loss_fn)(params)
        updates, state2 = opt.update(grads, state, params, i)
        return opt_mod.apply_updates(params, updates), state2, loss

    n = X.shape[0]
    rng = np.random.default_rng(0)
    for i in range(steps):
        idx = rng.integers(0, n, size=256)
        params, state, loss = step(params, state, jnp.int32(i), X[idx], y[idx])
    return params


def _accuracy(params, X, y, topk=(1, 5)):
    logits = _mlp_forward(params, X)
    order = jnp.argsort(-logits, axis=-1)
    out = {}
    for k in topk:
        hit = jnp.any(order[:, :k] == y[:, None], axis=1)
        out[f"top{k}"] = float(jnp.mean(hit))
    return out


def run(alphas=(0.8, 0.6, 0.4, 0.2), qs=(1, 2, 3, 4), synthetic_spectrum=True):
    Xtr, ytr, _ = classification_dataset(0, 8192, DIMS[0], DIMS[-1], margin=MARGIN)
    Xte, yte, _ = classification_dataset(1, 2048, DIMS[0], DIMS[-1], margin=MARGIN)
    # same cluster means across train/test:
    Xtr, ytr, means = classification_dataset(0, 8192, DIMS[0], DIMS[-1], margin=MARGIN)
    rng = np.random.default_rng(123)
    yte = rng.integers(0, DIMS[-1], size=2048).astype(np.int32)
    Xte = (means[yte] + rng.standard_normal((2048, DIMS[0])).astype(np.float32))

    Xtr, ytr = jnp.asarray(Xtr), jnp.asarray(ytr)
    Xte, yte = jnp.asarray(Xte), jnp.asarray(yte)
    params = _train_mlp(_init_mlp(jax.random.PRNGKey(0)), Xtr, ytr)

    if synthetic_spectrum:
        # (b): swap hidden weights for slow-decay-spectrum matrices, then
        # refit ONLY the final layer so the model is accurate again.
        for i in range(1, len(DIMS) - 2):
            a, b = DIMS[i], DIMS[i + 1]
            W = synth_spectrum_matrix(
                jax.random.PRNGKey(40 + i), a, b, vgg_like_spectrum(min(a, b))
            )
            # blend: keep trained directions + heavy slow tail
            params[f"fc{i}"]["w"] = (
                0.5 * params[f"fc{i}"]["w"] + 0.5 * W / jnp.linalg.norm(W) * jnp.linalg.norm(params[f"fc{i}"]["w"])
            )
        params = _train_mlp(params, Xtr, ytr, steps=200)

    base = _accuracy(params, Xte, yte)
    rows = []
    for alpha in alphas:
        for q in qs:
            policy = CompressionPolicy(alpha=alpha, q=q, min_dim=64, break_even_only=False)
            t0 = time.perf_counter()
            newp, _, rep = compress_tree(params, policy, jax.random.PRNGKey(7))
            jax.block_until_ready(jax.tree_util.tree_leaves(newp))
            dt = time.perf_counter() - t0
            acc = _accuracy(newp, Xte, yte)
            rows.append(
                dict(
                    alpha=alpha,
                    q=q,
                    seconds=dt,
                    ratio=rep.ratio,
                    top1=acc["top1"],
                    top5=acc["top5"],
                )
            )
    return dict(baseline=base, rows=rows)


def emit_csv(result):
    b = result["baseline"]
    print(f"table4_1/baseline,0,top1={b['top1']:.4f};top5={b['top5']:.4f}")
    for r in result["rows"]:
        print(
            f"table4_1/alpha={r['alpha']}/q={r['q']},{r['seconds']*1e6:.0f},"
            f"ratio={r['ratio']:.3f};top1={r['top1']:.4f};top5={r['top5']:.4f}"
        )


if __name__ == "__main__":
    emit_csv(run())
