"""Roofline table: aggregate dryrun_results JSONs into the §Roofline report.

Usage:
    PYTHONPATH=src python -m benchmarks.roofline_table [--mesh single_pod_16x16]
Emits a markdown table + CSV rows (name,us_per_call,derived).
"""

from __future__ import annotations

import argparse
import json
import os

HERE = os.path.dirname(os.path.abspath(__file__))
RESULTS = os.path.join(HERE, "dryrun_results")


def load(mesh_tag: str):
    d = os.path.join(RESULTS, mesh_tag)
    rows = []
    if not os.path.isdir(d):
        return rows
    for f in sorted(os.listdir(d)):
        if f.endswith(".json"):
            with open(os.path.join(d, f)) as fh:
                rows.append(json.load(fh))
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    rows.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    return rows


def markdown(rows):
    hdr = (
        "| arch | shape | t_comp(s) | t_mem(s) | t_coll(s) | bottleneck | "
        "useful | roofline-frac | HBM GiB/chip |\n|---|---|---|---|---|---|---|---|---|"
    )
    out = [hdr]
    for r in rows:
        mem = r["memory"].get("total_bytes", 0) / 2**30
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.4f} | {r['t_memory']:.4f} "
            f"| {r['t_collective']:.4f} | {r['bottleneck']} | {r['useful_flops_ratio']:.3f} "
            f"| {r['roofline_fraction']:.4f} | {mem:.2f} |"
        )
    return "\n".join(out)


def emit_csv(rows, mesh_tag):
    for r in rows:
        t_total = max(r["t_compute"], r["t_memory"], r["t_collective"])
        print(
            f"roofline/{mesh_tag}/{r['arch']}/{r['shape']},{t_total*1e6:.0f},"
            f"bottleneck={r['bottleneck']};frac={r['roofline_fraction']:.4f};"
            f"useful={r['useful_flops_ratio']:.3f}"
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single_pod_16x16")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = load(args.mesh)
    if args.markdown:
        print(markdown(rows))
    else:
        emit_csv(rows, args.mesh)


if __name__ == "__main__":
    main()
