"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV:
  * fig4_1   — normalized error + runtime vs (k, q), VGG-sized layer
  * fig4_2   — same on the ViT layer + exact-SVD speedups
  * table4_1 — end-to-end compression grid (time/ratio/top-1/top-5)
  * powersgd — RSI gradient-compression comm-volume table
  * roofline — dry-run roofline terms per (arch x shape), if dry-run ran
"""

from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import fig4_1, fig4_2, serving, table4_1, roofline_table

    print("name,us_per_call,derived")
    fig4_1.emit_csv(fig4_1.run())
    sys.stdout.flush()
    fig4_2.emit_csv(fig4_2.run())
    sys.stdout.flush()
    table4_1.emit_csv(table4_1.run())
    sys.stdout.flush()
    serving.emit_csv(serving.run())
    sys.stdout.flush()

    # PowerSGD comm-volume (beyond-paper distributed-optimization feature)
    import jax
    import jax.numpy as jnp

    from repro.core.gradient_compression import PowerSGDConfig, comm_bytes

    grads = {
        "w1": jnp.zeros((2048, 8192)),
        "w2": jnp.zeros((8192, 2048)),
        "norm": jnp.zeros((2048,)),
    }
    for rank in (2, 4, 8):
        dense, comp = comm_bytes(grads, PowerSGDConfig(rank=rank))
        print(f"powersgd/rank={rank},0,dense_MB={dense/1e6:.1f};compressed_MB={comp/1e6:.2f};reduction={dense/comp:.0f}x")

    for mesh in ("single_pod_16x16", "multi_pod_2x16x16"):
        rows = roofline_table.load(mesh)
        roofline_table.emit_csv(rows, mesh)


if __name__ == "__main__":
    main()
