"""Serving-throughput benchmark: dense vs RSI-compressed decode (measured).

CPU wall-clock, reduced llama config — the RELATIVE throughput and agreement
numbers support EXPERIMENTS.md §Perf C2 (weight compression as a serving
lever).  Emits name,us_per_call,derived CSV rows.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.core import CompressionPolicy, compress_tree, spectralize_params
from repro.data.synthetic import SyntheticLM
from repro.models.model import build_model


def run(alphas=(0.4, 0.2), q: int = 4, batch: int = 8, prompt: int = 16, gen: int = 16):
    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = spectralize_params(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(9))
    data = SyntheticLM(cfg, batch=batch, seq=prompt, kind="serve")
    bt = {k: jnp.asarray(v) for k, v in data.at_step(0).items()}
    max_len = prompt + gen

    def bench(p):
        logits, cache = jax.jit(lambda p, b: model.prefill(p, b, max_len))(p, bt)
        step = jax.jit(model.decode_step)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        # warm
        l2, c2 = step(p, cache, tok, jnp.int32(prompt))
        jax.block_until_ready(l2)
        t0 = time.perf_counter()
        toks = [tok]
        c = cache
        for i in range(gen):
            logits, c = step(p, c, toks[-1], jnp.int32(prompt + i))
            toks.append(jnp.argmax(logits, -1)[:, None].astype(jnp.int32))
        jax.block_until_ready(toks[-1])
        dt = time.perf_counter() - t0
        return np.concatenate([np.asarray(t) for t in toks[1:]], axis=1), dt

    ref, t_dense = bench(params)
    rows = [dict(name="dense", alpha=0.0, seconds=t_dense, tok_s=batch * gen / t_dense, agree=1.0, ratio=1.0)]
    for alpha in alphas:
        cp, _, rep = compress_tree(
            params, CompressionPolicy(alpha=alpha, q=q, min_dim=32), jax.random.PRNGKey(1)
        )
        out, dt = bench(cp)
        rows.append(
            dict(
                name=f"alpha={alpha}",
                alpha=alpha,
                seconds=dt,
                tok_s=batch * gen / dt,
                agree=float((out == ref).mean()),
                ratio=rep.ratio,
            )
        )
    return rows


def emit_csv(rows):
    for r in rows:
        print(
            f"serving/{r['name']},{r['seconds']*1e6:.0f},"
            f"tok_s={r['tok_s']:.1f};agree={r['agree']:.3f};ratio={r['ratio']:.3f}"
        )


if __name__ == "__main__":
    emit_csv(run())
