"""Serving-throughput benchmark: dense vs RSI-compressed decode (measured).

CPU wall-clock, reduced llama config — the RELATIVE throughput and agreement
numbers support EXPERIMENTS.md §Perf C2 (weight compression as a serving
lever).  Emits name,us_per_call,derived CSV rows.

``--sweep-backends`` additionally runs the compressed model once per kernel
backend (auto / xla / pallas / reference) through the unified dispatch
runtime and emits one CSV row per backend, annotated with the dispatcher's
hit counters — i.e. which execution path (fused / fused_batched / two_gemm /
dense) every linear in the compiled program actually took.

``--trace poisson`` replays a Poisson arrival trace through the continuous-
batching engine (repro.serving.Engine): requests with random prompt/output
lengths arrive at ``--rate`` req/s, queue for cache slots, and share FUSED
decode blocks (``--decode-block`` tokens per host round-trip); the row
reports tok/s, p50/p95 request latency, mean ttft, tokens-per-host-sync,
and decode-batch utilization (emitted tokens / executed decode-step rows) —
the two columns that make the fused-loop win visible in the CI artifact.
``--arch`` takes a comma list so one invocation can cover several reduced
archs.

``--page-size`` runs the trace on the PAGED engine (``--kv-pages`` sizes
the pool, ``--prefill-chunk`` enables chunked prefill); ``--long-frac``
mixes long prompts into the trace and adds a TTFT-p95-over-short-requests
column; ``--compare-paged`` runs each arch twice at equal KV bytes — flat
pool, then a paged pool backing twice the slots — and gates the paged row
against the flat one in the same run (more admitted concurrency, no
throughput loss, bounded short-request TTFT).

``--shared-prefix`` replays a common-system-prompt trace (every request =
``--sys-prompt-len`` shared tokens + a random suffix) TWICE on the paged
engine at equal KV bytes — prefix sharing off, then on (``+shared`` row)
— and gates the same-run contract: strictly fewer peak pages (shared
prefix pages counted once), strictly more peak-admitted concurrency
(page-gated admission banks the savings), throughput within tolerance,
and bit-identical greedy tokens per request.

``--trace sessions`` replays MULTI-TURN conversations (each turn's prompt
is the whole conversation so far plus new user tokens; per-tenant shared
system prompts) TWICE on the paged engine — prefix sharing off, then on
(``+shared`` row) — and gates the same-run session-cache contract:
follow-up turns re-prefill strictly fewer prompt tokens and see strictly
lower TTFT (decode-filled pages registered at slot release are matched
read-only), greedy tokens are bit-identical, and pages stay within the
pool under the ``--warm-cache-pages`` LRU eviction budget.  New columns:
re-prefilled / skipped prompt tokens, follow-up TTFT, evictions, cached
pages.

``--json BENCH_serving.json`` additionally writes the trace rows as a JSON
result document, and ``--check-baseline benchmarks/baselines/
BENCH_serving.json --tolerance 0.5`` compares tok/s and utilization against
a checked-in baseline, exiting non-zero on regression (the CI perf-smoke
step).

    PYTHONPATH=src python benchmarks/serving.py [--sweep-backends]
    PYTHONPATH=src python benchmarks/serving.py --trace poisson \
        --arch llama3.2-1b,mamba2-130m --rate 20 --n-requests 16 \
        [--csv serving_trace.csv] [--json BENCH_serving.json] \
        [--check-baseline benchmarks/baselines/BENCH_serving.json]
"""

from __future__ import annotations

import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.core import CompressionPolicy, compress_tree, spectralize_params
from repro.data.synthetic import SyntheticLM
from repro.models.model import build_model
from repro.runtime import dispatch
from repro.runtime.dispatch import BACKENDS, DispatchConfig, use_dispatch


def _setup(batch: int, prompt: int):
    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = spectralize_params(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(9))
    data = SyntheticLM(cfg, batch=batch, seq=prompt, kind="serve")
    bt = {k: jnp.asarray(v) for k, v in data.at_step(0).items()}
    return cfg, model, params, bt


def _bench(model, p, bt, prompt: int, gen: int):
    max_len = prompt + gen

    # Fresh closures per bench run: pjit's global jaxpr cache is keyed on the
    # function object, and the dispatch policy is ambient trace-time state —
    # reusing `model.decode_step` across backends would silently reuse the
    # FIRST backend's traced program (same idiom as serve_step.make_*_step).
    def prefill_fn(p, b):
        return model.prefill(p, b, max_len)

    def decode_fn(p, c, t, pos):
        return model.decode_step(p, c, t, pos)

    logits, cache = jax.jit(prefill_fn)(p, bt)
    step = jax.jit(decode_fn)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    # warm
    l2, c2 = step(p, cache, tok, jnp.int32(prompt))
    jax.block_until_ready(l2)
    t0 = time.perf_counter()
    toks = [tok]
    c = cache
    for i in range(gen):
        logits, c = step(p, c, toks[-1], jnp.int32(prompt + i))
        toks.append(jnp.argmax(logits, -1)[:, None].astype(jnp.int32))
    jax.block_until_ready(toks[-1])
    dt = time.perf_counter() - t0
    return np.concatenate([np.asarray(t) for t in toks[1:]], axis=1), dt


def run(alphas=(0.4, 0.2), q: int = 4, batch: int = 8, prompt: int = 16, gen: int = 16):
    cfg, model, params, bt = _setup(batch, prompt)

    ref, t_dense = _bench(model, params, bt, prompt, gen)
    rows = [dict(name="dense", alpha=0.0, seconds=t_dense, tok_s=batch * gen / t_dense, agree=1.0, ratio=1.0)]
    for alpha in alphas:
        cp, _, rep = compress_tree(
            params, CompressionPolicy(alpha=alpha, q=q, min_dim=32), jax.random.PRNGKey(1)
        )
        out, dt = _bench(model, cp, bt, prompt, gen)
        rows.append(
            dict(
                name=f"alpha={alpha}",
                alpha=alpha,
                seconds=dt,
                tok_s=batch * gen / dt,
                agree=float((out == ref).mean()),
                ratio=rep.ratio,
            )
        )
    return rows


def _hits_summary() -> str:
    """'path=count' pairs for the lowrank op, plus dense-linear sites."""
    agg = dispatch.counters_by_path()
    parts = [
        f"{path}={n}" for (op, path), n in sorted(agg.items()) if op == "lowrank_matmul"
    ]
    dense_n = sum(n for (op, _), n in agg.items() if op == "dense")
    if dense_n:
        parts.append(f"dense_linear={dense_n}")
    return "|".join(parts) if parts else "none"


def run_backend_sweep(
    alpha: float = 0.4, q: int = 4, batch: int = 4, prompt: int = 16, gen: int = 8
):
    """One row per dispatch backend for the SAME compressed checkpoint.

    Each backend gets a fresh trace (fresh jit closures), so the dispatcher's
    trace-time counters describe exactly the paths in that backend's program.
    """
    cfg, model, params, bt = _setup(batch, prompt)
    cp, _, rep = compress_tree(
        params, CompressionPolicy(alpha=alpha, q=q, min_dim=32), jax.random.PRNGKey(1)
    )
    rows = []
    ref = None
    for backend in BACKENDS:
        dispatch.reset_counters()
        with use_dispatch(DispatchConfig(backend=backend)):
            out, dt = _bench(model, cp, bt, prompt, gen)
        if ref is None:
            ref = out
        rows.append(
            dict(
                name=f"backend={backend}",
                alpha=alpha,
                seconds=dt,
                tok_s=batch * gen / dt,
                agree=float((out == ref).mean()),
                ratio=rep.ratio,
                hits=_hits_summary(),
            )
        )
    return rows


def run_trace(
    archs=("llama3.2-1b",),
    *,
    rate: float = 20.0,
    n_requests: int = 16,
    n_slots: int = 4,
    prompt_range=(4, 16),
    gen_range=(4, 16),
    temperature: float = 0.0,
    top_k: int = 0,
    seed: int = 0,
    alpha: float = 0.0,
    q: int = 4,
    decode_block: int = 8,
    warmup: bool = True,
    page_size: int = 0,
    kv_pages: int = 0,
    prefill_chunk: int = 0,
    long_frac: float = 0.0,
    long_prompt_range=(48, 64),
    max_len: int = 0,
    share_prefix: bool = False,
    sys_prompt_len: int = 0,
    row_suffix: str = "",
):
    """Replay a Poisson arrival trace through the continuous engine.

    One row per arch: tok/s over the busy window plus p50/p95 request
    latency (submit -> final token), mean time-to-first-token, tokens per
    host sync (``decode_block`` amortization), decode-batch utilization
    (emitted tokens / executed decode-step rows), peak admitted concurrency,
    and KV-memory accounting — capacity vs PEAK BYTES ACTUALLY RESIDENT
    (allocated pages in paged mode; a flat pool is fully committed up
    front).  Arrival times are exponential inter-arrivals at ``rate``
    req/s; prompt and output lengths are uniform over the given ranges —
    so the trace exercises ragged admission, exhaustion queueing, and
    mid-stream slot reuse rather than one synchronized batch.

    ``page_size`` switches the engine to the paged KV pool (``kv_pages``
    sizes it; 0 = flat-equivalent capacity) and ``prefill_chunk`` enables
    chunked prefill.  ``long_frac`` > 0 makes that fraction of requests
    draw prompts from ``long_prompt_range`` instead (the long-prompt mixed
    trace): the row then also reports TTFT p95 over the SHORT requests
    alone — the queue-behind-a-long-prefill number chunked prefill bounds.

    ``sys_prompt_len`` > 0 prepends the SAME ``sys_prompt_len`` random
    tokens to every prompt (the system-prompt traffic pattern);
    ``share_prefix`` turns on the engine's refcounted copy-on-write
    prefix sharing over that trace.  Rows then also report
    ``shared_hits`` (pages mapped read-only instead of re-allocated) and
    ``cow_forks``, and greedy rows stash per-request tokens for the
    same-run parity gate (:func:`check_shared_rows`).

    ``warmup`` (default on) replays throwaway requests through the SAME
    engine before the clock starts, so the row measures steady-state
    serving throughput rather than jit compile time (which on the reduced
    CPU configs is seconds — an order of magnitude more than the decode
    work itself, and identical across engine designs).  The prefix cache
    is cleared between warmup sub-runs (so every prefill bucket actually
    compiles instead of being skipped by a warm match) and once more at
    the warmup boundary, so the timed run starts cold and deterministic.
    """
    from repro.data.synthetic import modality_extras
    from repro.serving import Engine, Request, SamplingParams
    from repro.serving.engine import percentile

    rows = []
    for arch in archs:
        cfg = get_arch(arch, reduced=True)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(seed))
        if alpha > 0:
            params, _, _ = compress_tree(
                params, CompressionPolicy(alpha=alpha, q=q, min_dim=32), jax.random.PRNGKey(1)
            )
        rng = np.random.default_rng(seed)
        arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_requests)).tolist()
        top_prompt = max(prompt_range[1], long_prompt_range[1] if long_frac > 0 else 0)
        eff_max_len = max_len or (sys_prompt_len + top_prompt + gen_range[1])
        # the shared system prompt is drawn ONCE (same seed path whether
        # sharing is on or off, so paired rows replay identical traffic)
        sys_tokens = (
            rng.integers(0, cfg.vocab, size=(sys_prompt_len,)).astype(np.int32)
            if sys_prompt_len
            else None
        )
        reqs, is_long = [], []
        for i in range(n_requests):
            sp = SamplingParams(temperature=temperature, top_k=top_k, seed=seed + i)
            long = long_frac > 0 and rng.random() < long_frac
            rng_range = long_prompt_range if long else prompt_range
            is_long.append(long)
            tail = rng.integers(
                0, cfg.vocab, size=(int(rng.integers(*rng_range)),)
            ).astype(np.int32)
            reqs.append(
                Request(
                    prompt=tail if sys_tokens is None else np.concatenate([sys_tokens, tail]),
                    max_new_tokens=int(rng.integers(*gen_range)),
                    sampling=sp,
                    extras=modality_extras(cfg, rng),
                )
            )
        eng = Engine(
            model, params, n_slots=n_slots, max_len=eff_max_len,
            decode_block=decode_block,
            page_size=page_size or None,
            kv_pages=kv_pages or None,
            prefill_chunk=prefill_chunk or None,
            share_prefix=share_prefix,
        )
        if warmup:
            # Compile OUTSIDE the clock.  Admission buckets micro-batch
            # shapes (rows to the next power of two capped at n_slots,
            # prompt lengths to power-of-two buckets — or the EXACT length
            # for recurrent families), so replaying every distinct prompt
            # length the trace will actually use, at every reachable group
            # size (powers of two below n_slots, plus the n_slots cap
            # itself, which is the admitted group size under saturation
            # even when n_slots is not a power of two), hits every prefill
            # program plus the fused decode block.  The timed replay then
            # measures serving, not XLA.
            #
            # Paged engine: the paged decode block and page-pool prefill
            # scatter compile once per (group, prompt-bucket) shape exactly
            # like the flat ones — the bucket sweep below covers them, and
            # page-COUNT enumeration collapses into it because every paged
            # program is block-table-steered at a single static shape
            # (ceil(max_len / page) table entries; page count is runtime
            # data, not a compile-time shape).  Chunked prefill adds ONE
            # more program — the fixed (1, prefill_chunk) chunk — which any
            # single long warmup prompt compiles; chunk count is again
            # runtime data.  Prompts longer than the chunk bypass grouped
            # prefill, so those lengths warm up as singletons.
            wrng = np.random.default_rng(seed + 1)
            wsp = SamplingParams(temperature=temperature, top_k=top_k, seed=seed)
            chunking = prefill_chunk and eng.model.prefill_chunk is not None
            all_lens = sorted({r.prompt.size for r in reqs})
            lens = [n for n in all_lens if not (chunking and n > prefill_chunk)]
            chunk_lens = [n for n in all_lens if chunking and n > prefill_chunk]
            gs, g = [], 1
            while g < n_slots:
                gs.append(g)
                g *= 2
            gs.append(n_slots)
            for g in gs:
                for n in lens:
                    # cleared per sub-run: a warm prefix match would SKIP
                    # the grouped prefill this bucket exists to compile
                    eng.reset_prefix_cache()
                    eng.run(
                        [
                            Request(
                                prompt=wrng.integers(0, cfg.vocab, size=(int(n),)),
                                max_new_tokens=2,
                                sampling=wsp,
                                extras=modality_extras(cfg, wrng),
                            )
                            for _ in range(g)
                        ]
                    )
            if chunk_lens:  # one ragged-tail chunked prompt compiles the rest
                eng.reset_prefix_cache()
                eng.run(
                    [
                        Request(
                            prompt=wrng.integers(0, cfg.vocab, size=(int(chunk_lens[-1]),)),
                            max_new_tokens=2,
                            sampling=wsp,
                            extras=modality_extras(cfg, wrng),
                        )
                    ]
                )
            if share_prefix and sys_prompt_len and eng._share:
                # mid-prompt prefill shapes: a donor/follower pair compiles
                # the shared-tail chunk program, and an exact-page-boundary
                # pair (identical prompts, length a page multiple) compiles
                # the COW fork copy — both are runtime-steered after that
                eng.reset_prefix_cache()
                wsys = wrng.integers(0, cfg.vocab, size=(sys_prompt_len,)).astype(np.int32)

                def sysreq(extra: int):
                    tail = wrng.integers(0, cfg.vocab, size=(extra,)).astype(np.int32)
                    return Request(
                        prompt=np.concatenate([wsys, tail]),
                        max_new_tokens=2,
                        sampling=wsp,
                        extras=modality_extras(cfg, wrng),
                    )

                eng.run([sysreq(2)])
                eng.run([sysreq(3)])  # matches -> shared-tail chunk program
                page = eng.page_size
                blen = -(-(sys_prompt_len + 1) // page) * page
                bprompt = wrng.integers(0, cfg.vocab, size=(blen,)).astype(np.int32)
                for _ in range(2):  # second run fully matches -> COW program
                    eng.run(
                        [Request(prompt=bprompt.copy(), max_new_tokens=2,
                                 sampling=wsp, extras=modality_extras(cfg, wrng))]
                    )
            # timed run starts with a COLD prefix cache either way: the
            # sharing row's wins come from the trace itself, not warmup
            eng.reset_prefix_cache()
            eng.reset_counters()
        t0 = time.perf_counter()
        done = eng.run(reqs, arrivals=arrivals)
        dt = time.perf_counter() - t0
        assert len(done) == n_requests, (len(done), n_requests)
        n_tok = sum(len(r.tokens) for r in done)
        lats = sorted(r.latency for r in done)
        p50, p95 = percentile(lats, 0.5), percentile(lats, 0.95)
        ttft = float(np.mean([r.ttft for r in done]))
        uid_long = {r.uid for r, lg in zip(reqs, is_long) if lg}
        short_ttfts = sorted(r.ttft for r in done if r.uid not in uid_long)
        row = dict(
            name=f"trace={arch}{row_suffix}",
            arch=f"{arch}{row_suffix}",
            seconds=dt,
            tok_s=n_tok / dt,
            p50_ms=p50 * 1e3,
            p95_ms=p95 * 1e3,
            ttft_ms=ttft * 1e3,
            n_requests=n_requests,
            decode_steps=eng.steps,
            host_syncs=eng.host_syncs,
            tok_per_sync=eng.tokens_per_sync,
            util=eng.batch_utilization,
            peak_active=eng.peak_active,
            kv_bytes_cap=eng.kv_bytes_capacity,
            kv_bytes_peak=eng.kv_bytes_peak,
            pages_peak=eng.peak_pages_in_use,
            prefill_chunks=eng.prefill_chunks,
            shared_hits=eng.shared_page_hits,
            cow_forks=eng.cow_forks,
            # whether sharing was EFFECTIVE for this arch (paged leaves +
            # a mid-prompt prefill entry) — the gate skips inert archs
            # (mamba/SWA/vlm/audio) instead of failing their zero hits
            share_supported=int(getattr(eng, "_share", False)),
        )
        if short_ttfts:
            row["ttft_p95_short_ms"] = percentile(short_ttfts, 0.95) * 1e3
        if temperature == 0.0:
            # per-request emitted tokens, in submission order: the same-run
            # shared-vs-unshared parity gate (underscore keys never reach
            # the CSV/JSON outputs)
            row["_tokens"] = [list(r.tokens) for r in reqs]
        rows.append(row)
    return rows


def run_sessions_trace(
    archs=("llama3.2-1b",),
    *,
    n_sessions: int = 4,
    turns_range=(3, 5),
    user_range=(3, 6),
    gen_range=(3, 6),
    sys_prompt_len: int = 8,
    rate: float = 8.0,
    think_time: float = 0.01,
    n_slots: int = 4,
    seed: int = 0,
    alpha: float = 0.0,
    q: int = 4,
    decode_block: int = 8,
    page_size: int = 4,
    kv_pages: int = 0,
    prefill_chunk: int = 8,
    warm_cache_pages: int = 0,
    share_prefix: bool = False,
    temperature: float = 0.0,
    top_k: int = 0,
    warmup: bool = True,
    row_suffix: str = "",
):
    """Replay MULTI-TURN conversations through the continuous engine.

    Each session is ``turns_range`` chat turns: turn t's prompt is the
    ENTIRE conversation so far (per-tenant system prompt, then every
    earlier user turn and model reply) plus ``user_range`` new user
    tokens — so follow-up prompts strictly extend the previous turn's
    prompt + reply, which is exactly the traffic shape session-cache
    registration (decode-filled pages indexed at slot release) exists
    for.  Sessions arrive Poisson at ``rate``/s; a follow-up turn is
    submitted ``think_time`` seconds after its reply lands.  Half the
    sessions share each tenant's system prompt (``n_sessions // 2``
    tenants), so cross-session prefix sharing engages too.

    Because each turn's prompt embeds the previous reply, the trace
    cannot be pre-built — the drive loop below submits turns online as
    replies complete.  With greedy sampling the replies (and therefore
    the full trace) are IDENTICAL whether sharing is on or off, which is
    what makes the same-run gate (:func:`check_sessions_rows`) able to
    demand bit-identical tokens between the two rows.

    Row columns beyond the Poisson trace's: ``reprefill_tok`` (prompt
    tokens follow-up turns actually re-prefilled — the number session
    caching exists to shrink), ``skipped_tok`` (prompt tokens skipped
    because their K/V was already resident), ``followup_ttft_ms`` (mean
    TTFT over turns >= 2), ``evictions`` and ``cached_pages`` (the
    allocator's warm-cache policy at work).

    ``prefill_chunk`` should stay BELOW ``sys_prompt_len + user_range[0]``
    so every prompt routes through the single fixed-shape chunk program —
    that bounds compiles to one prefill program no matter how long the
    conversations grow.
    """
    from repro.serving import Engine, Request, SamplingParams
    from repro.serving.engine import percentile

    rows = []
    for arch in archs:
        cfg = get_arch(arch, reduced=True)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(seed))
        if alpha > 0:
            params, _, _ = compress_tree(
                params, CompressionPolicy(alpha=alpha, q=q, min_dim=32), jax.random.PRNGKey(1)
            )
        # trace material is drawn ONCE per row from the same seed path, so
        # paired rows (sharing off/on) replay identical traffic: session
        # arrivals, per-turn user tokens and reply budgets, tenant prompts
        rng = np.random.default_rng(seed)
        arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_sessions)).tolist()
        n_turns = [int(rng.integers(turns_range[0], turns_range[1] + 1))
                   for _ in range(n_sessions)]
        user_toks = [
            [rng.integers(0, cfg.vocab,
                          size=(int(rng.integers(user_range[0], user_range[1] + 1)),)
                          ).astype(np.int32) for _ in range(n_turns[s])]
            for s in range(n_sessions)
        ]
        gen_lens = [
            [int(rng.integers(gen_range[0], gen_range[1] + 1))
             for _ in range(n_turns[s])]
            for s in range(n_sessions)
        ]
        n_tenants = max(1, n_sessions // 2)
        tenant_sys = [
            rng.integers(0, cfg.vocab, size=(sys_prompt_len,)).astype(np.int32)
            for _ in range(n_tenants)
        ]
        # the longest conversation bounds max_len (prompt + reply of its
        # final turn = the whole session transcript)
        max_len = max(
            sys_prompt_len
            + sum(u.size for u in user_toks[s]) + sum(gen_lens[s])
            for s in range(n_sessions)
        )
        max_pages = -(-max_len // page_size)
        eff_kv_pages = kv_pages or n_slots * max_pages
        eng = Engine(
            model, params, n_slots=n_slots, max_len=max_len,
            decode_block=decode_block, page_size=page_size,
            kv_pages=eff_kv_pages,
            prefill_chunk=prefill_chunk or None,
            share_prefix=share_prefix,
            warm_cache_pages=warm_cache_pages or None,
        )
        supported = bool(getattr(eng, "_share", share_prefix)) if share_prefix else (
            eng.model.prefill_chunk is not None and eng._has_pages
        )
        if warmup:
            # every prompt is longer than the chunk (see docstring), so ONE
            # long chunked prompt compiles the only prefill program; the
            # shared pair + page-boundary pair compile the shared-tail
            # entry and the COW fork copy (run_trace's warmup idiom)
            wrng = np.random.default_rng(seed + 1)
            wsp = SamplingParams(temperature=temperature, top_k=top_k, seed=seed)
            eng.run([Request(
                prompt=wrng.integers(0, cfg.vocab, size=(max_len - 4,)).astype(np.int32),
                max_new_tokens=2, sampling=wsp,
            )])
            if share_prefix and getattr(eng, "_share", False):
                eng.reset_prefix_cache()
                wsys = wrng.integers(0, cfg.vocab, size=(sys_prompt_len + 4,)).astype(np.int32)
                for extra in (2, 3):
                    tail = wrng.integers(0, cfg.vocab, size=(extra,)).astype(np.int32)
                    eng.run([Request(prompt=np.concatenate([wsys, tail]),
                                     max_new_tokens=2, sampling=wsp)])
                blen = -(-(sys_prompt_len + 5) // page_size) * page_size
                bprompt = wrng.integers(0, cfg.vocab, size=(blen,)).astype(np.int32)
                for _ in range(2):  # second run fully matches -> COW program
                    eng.run([Request(prompt=bprompt.copy(), max_new_tokens=2,
                                     sampling=wsp)])
            eng.reset_prefix_cache()
            eng.reset_counters()

        # ---- online drive loop: turn t+1's prompt embeds turn t's reply
        ready_at = list(arrivals)  # next submit time per session (None = done)
        turn = [0] * n_sessions
        ctx = [tenant_sys[s % n_tenants].copy() for s in range(n_sessions)]
        in_flight: dict = {}  # uid -> session
        finished: list = [[None] * n_turns[s] for s in range(n_sessions)]
        t0 = time.perf_counter()
        while any(r is not None for r in ready_at) or eng.has_work:
            now = time.perf_counter() - t0
            for s in range(n_sessions):
                if ready_at[s] is not None and ready_at[s] <= now and s not in in_flight.values():
                    prompt = np.concatenate([ctx[s], user_toks[s][turn[s]]])
                    req = Request(
                        prompt=prompt,
                        max_new_tokens=gen_lens[s][turn[s]],
                        sampling=SamplingParams(
                            temperature=temperature, top_k=top_k,
                            seed=seed + 131 * s + turn[s],
                        ),
                    )
                    eng.submit(req)
                    in_flight[req.uid] = s
                    ready_at[s] = None  # waiting on the reply
            if eng.has_work:
                for r in eng.step():
                    s = in_flight.pop(r.uid)
                    finished[s][turn[s]] = r
                    ctx[s] = np.concatenate([r.prompt, np.asarray(r.tokens, np.int32)])
                    turn[s] += 1
                    if turn[s] < n_turns[s]:
                        ready_at[s] = (time.perf_counter() - t0) + think_time
                continue
            nxt = min((t for t in ready_at if t is not None), default=None)
            if nxt is not None:
                wait = nxt - (time.perf_counter() - t0)
                if wait > 0:
                    time.sleep(min(wait, 0.05))
        dt = time.perf_counter() - t0
        done = [r for per in finished for r in per]
        assert all(r is not None for r in done)
        followups = [r for s in range(n_sessions) for r in finished[s][1:]]
        n_tok = sum(len(r.tokens) for r in done)
        lats = sorted(r.latency for r in done)
        ttfts = sorted(r.ttft for r in followups)
        row = dict(
            name=f"sessions={arch}{row_suffix}",
            arch=f"{arch}{row_suffix}",
            seconds=dt,
            tok_s=n_tok / dt,
            p50_ms=percentile(lats, 0.5) * 1e3,
            p95_ms=percentile(lats, 0.95) * 1e3,
            ttft_ms=float(np.mean([r.ttft for r in done])) * 1e3,
            followup_ttft_ms=float(np.mean(ttfts)) * 1e3 if ttfts else 0.0,
            # prompt tokens follow-up turns actually RE-PREFILLED: their
            # whole context minus what matched resident pages
            reprefill_tok=sum(r.prompt.size - r.prefill_skipped for r in followups),
            skipped_tok=eng.skipped_prefill_tokens,
            evictions=eng.prefix_evictions,
            cached_pages=eng.prefix_cached_pages,
            n_requests=len(done),
            n_sessions=n_sessions,
            decode_steps=eng.steps,
            host_syncs=eng.host_syncs,
            tok_per_sync=eng.tokens_per_sync,
            util=eng.batch_utilization,
            peak_active=eng.peak_active,
            kv_bytes_cap=eng.kv_bytes_capacity,
            kv_bytes_peak=eng.kv_bytes_peak,
            pages_peak=eng.peak_pages_in_use,
            kv_pages=eff_kv_pages if eng.paged else 0,
            prefill_chunks=eng.prefill_chunks,
            shared_hits=eng.shared_page_hits,
            cow_forks=eng.cow_forks,
            share_supported=int(supported),
        )
        if temperature == 0.0:
            # (session, turn)-ordered emitted tokens: the same-run parity
            # gate currency (underscore keys never reach CSV/JSON)
            row["_tokens"] = [list(r.tokens) for r in done]
        rows.append(row)
    return rows


def check_sessions_rows(rows, *, tolerance: float = 0.3) -> int:
    """Same-run sharing-off-vs-on gates for the sessions trace.

    Pairs ``X`` with ``X+shared``; both replayed the IDENTICAL multi-turn
    trace (greedy replies make the traffic deterministic).  Deterministic
    counters gate with NO slack: follow-up turns must re-prefill strictly
    FEWER prompt tokens (decode-filled pages matched read-only), sharing
    must have skipped something, pages_peak must respect the pool, and
    greedy tokens must be bit-identical (sharing relocates bytes, never
    changes what is attended).  Follow-up TTFT — a timing number, but the
    one the mechanism exists to cut, and on the same machine the
    avoided re-prefill work dwarfs scheduler noise — must be strictly
    lower.  Throughput holds within ``tolerance``.  Returns #violations.
    """
    by_arch = {r["arch"]: r for r in rows if "arch" in r}
    failures = 0
    for arch, shared in by_arch.items():
        if not arch.endswith("+shared"):
            continue
        base = by_arch.get(arch[: -len("+shared")])
        if base is None:
            continue
        label = arch[: -len("+shared")]
        if not shared.get("share_supported"):
            print(
                f"[perf-smoke] {label} sessions shared-vs-unshared: "
                f"sharing inert for this arch, gates skipped"
            )
            continue
        checks = [
            ("reprefill_tok", shared["reprefill_tok"] < base["reprefill_tok"],
             f"{shared['reprefill_tok']} < {base['reprefill_tok']}"),
            ("skipped_tok", shared["skipped_tok"] > 0,
             f"{shared['skipped_tok']} > 0"),
            ("followup_ttft_ms",
             shared["followup_ttft_ms"] < base["followup_ttft_ms"],
             f"{shared['followup_ttft_ms']:.1f} < {base['followup_ttft_ms']:.1f}"),
            ("pages_peak", shared["pages_peak"] <= shared["kv_pages"],
             f"{shared['pages_peak']} <= {shared['kv_pages']}"),
            ("tok_s", shared["tok_s"] >= base["tok_s"] * (1.0 - tolerance),
             f"{shared['tok_s']:.1f} >= {base['tok_s']:.1f} - {tolerance:.0%}"),
        ]
        if base.get("_tokens") is not None and shared.get("_tokens") is not None:
            checks.append(
                ("greedy_parity", shared["_tokens"] == base["_tokens"],
                 "bit-identical tokens per (session, turn)")
            )
        for metric, ok, detail in checks:
            print(
                f"[perf-smoke] {label} sessions {metric}: {detail} "
                f"{'OK' if ok else 'VIOLATION'}"
            )
            failures += 0 if ok else 1
    return failures


def run_overload_trace(
    archs=("llama3.2-1b",),
    *,
    rate: float = 2000.0,
    n_requests: int = 24,
    n_slots: int = 3,
    prompt_range=(6, 12),
    gen_range=(24, 32),
    deadline_ms: float = 300.0,
    tiers=(1.0, 0.5),
    tier_q: int = 2,
    seed: int = 0,
    alpha: float = 0.5,
    q: int = 2,
    decode_block: int = 4,
    page_size: int = 4,
    kv_pages: int = 0,
    temperature: float = 0.0,
    top_k: int = 0,
    warmup: bool = True,
    inject: str = "",
):
    """Replay one BURST trace twice — plain FIFO, then tiered admission —
    and gate overload behavior in the same run (:func:`check_overload_rows`).

    ``rate`` far above service rate piles ``n_requests`` onto ``n_slots``
    slots at once, so the FIFO row's tail requests wait out the whole
    backlog: its p95 TTFT is the makespan.  The tiered row arms the full
    overload stack on the SAME traffic: every request carries a deadline
    of ``min(deadline_ms, 0.45 * FIFO makespan)`` — same-run-relative so
    it binds on any runner speed (waiters not admitted in time shed with
    a structured :class:`RejectedOverload`), admission degrades new requests to deeper
    rank tiers under queue/page pressure (each degraded response carries
    the tier's spectral-bound certificate), and a sprinkling of
    priority-1 requests exercises page-reclaiming preemption.  Quality
    sheds before latency does — the row reports how much of each.

    ``inject="nan"`` adds a third row: the FIFO trace re-run with a
    :class:`FaultInjector` poisoning one request's logits to NaN
    mid-decode.  The gate demands exactly that request quarantined
    (status ``"error"``, tokens a clean prefix) and every OTHER request
    bit-identical to the uninjected FIFO row — a numerical blow-up in one
    slot must never leak into the rest of the batch.

    Needs ``alpha`` > 0: tiers are prefix slices of the compressed
    factors, so an uncompressed checkpoint has nothing to slice.
    """
    from repro.data.synthetic import modality_extras
    from repro.runtime.fault_tolerance import FaultInjector
    from repro.serving import Engine, Request, SamplingParams
    from repro.serving.engine import AdmissionPolicy, percentile

    assert alpha > 0, "overload trace needs a compressed checkpoint (--alpha)"
    rows = []
    for arch in archs:
        cfg = get_arch(arch, reduced=True)
        model = build_model(cfg)
        params = spectralize_params(
            model.init(jax.random.PRNGKey(seed)), jax.random.PRNGKey(9)
        )
        params, _, _ = compress_tree(
            params, CompressionPolicy(alpha=alpha, q=q, min_dim=16),
            jax.random.PRNGKey(1),
        )
        rng = np.random.default_rng(seed)
        arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_requests)).tolist()
        max_len = prompt_range[1] + gen_range[1]
        eff_pages = kv_pages or n_slots * (-(-max_len // page_size))
        trace = []
        for i in range(n_requests):
            trace.append(dict(
                prompt=rng.integers(
                    0, cfg.vocab, size=(int(rng.integers(*prompt_range)),)
                ).astype(np.int32),
                max_new=int(rng.integers(*gen_range)),
                # a few high-priority requests exercise preemption: when one
                # reaches the queue head it may reclaim a lower-priority slot
                priority=1 if i % 8 == 3 else 0,
            ))

        def build_reqs(*, deadline=None, priorities: bool):
            out = []
            for i, t in enumerate(trace):
                out.append(Request(
                    prompt=t["prompt"].copy(),
                    max_new_tokens=t["max_new"],
                    sampling=SamplingParams(
                        temperature=temperature, top_k=top_k, seed=seed + i
                    ),
                    extras=modality_extras(cfg, np.random.default_rng(seed + i)),
                    deadline_ms=deadline,
                    min_tier=len(tiers) - 1,
                    priority=t["priority"] if priorities else 0,
                ))
            return out

        def build_engine(*, tiered: bool, injector=None):
            return Engine(
                model, params, n_slots=n_slots, max_len=max_len,
                decode_block=decode_block, page_size=page_size,
                kv_pages=eff_pages,
                share_prefix=tiered,  # preempted K/V re-indexes as warm cache
                tiers=tiers if tiered else None, tier_q=tier_q,
                admission=AdmissionPolicy(
                    n_tiers=len(tiers),
                    degrade_queue_depth=max(2, n_slots),
                    degrade_free_frac=0.5,
                ) if tiered else None,
                preempt=tiered,
                injector=injector,
            )

        def warm(eng, *, tiered: bool):
            # compile outside the clock: every admission group size at each
            # trace prompt bucket, per tier (prefill programs + the fused
            # block), plus one continuation-length prompt so a preemption
            # resume mid-trace does not hit a cold bucket
            wrng = np.random.default_rng(seed + 1)
            wsp = SamplingParams(temperature=temperature, top_k=top_k, seed=seed)
            lens = sorted({t["prompt"].size for t in trace})
            lens.append(min(max_len - 2, prompt_range[1] + gen_range[0]))
            gs, g = [], 1
            while g < n_slots:
                gs.append(g)
                g *= 2
            gs.append(n_slots)
            for tier in range(len(tiers) if tiered else 1):
                for g in gs:
                    for n in lens:
                        eng.reset_prefix_cache()
                        eng.run([
                            Request(
                                prompt=wrng.integers(0, cfg.vocab, size=(int(n),)),
                                max_new_tokens=2, sampling=wsp,
                                extras=modality_extras(cfg, wrng),
                                tier=tier,
                            )
                            for _ in range(g)
                        ])
            eng.reset_prefix_cache()
            eng.reset_counters()

        def replay(eng, reqs, *, label, arm=None, deadline=None):
            if warmup:
                warm(eng, tiered=eng.tiers != (1.0,))
            if arm is not None:
                arm(eng)  # post-warmup: uid counter and step clock are live
            t0 = time.perf_counter()
            done = eng.run(reqs, arrivals=arrivals)
            dt = time.perf_counter() - t0
            assert len(done) == n_requests, (len(done), n_requests)
            ok = [r for r in done if r.status == "ok"]
            shed = [r for r in done if r.status == "shed"]
            errored = [r for r in done if r.status == "error"]
            ttfts = sorted(r.ttft for r in ok)
            lats = sorted(r.latency for r in ok)
            n_tok = sum(len(r.tokens) for r in done)
            cert_bounds = [
                c.prob_deviation_bound
                for c in eng.tier_certificates
                if c is not None
            ]
            row = dict(
                name=f"overload={arch}+{label}",
                arch=f"{arch}+{label}",
                seconds=dt,
                tok_s=n_tok / dt,
                p50_ms=percentile(lats, 0.5) * 1e3 if lats else 0.0,
                p95_ms=percentile(lats, 0.95) * 1e3 if lats else 0.0,
                ttft_ms=float(np.mean(ttfts)) * 1e3 if ttfts else 0.0,
                p95_ttft_ms=percentile(ttfts, 0.95) * 1e3 if ttfts else 0.0,
                completed=len(ok),
                shed=len(shed),
                errored=len(errored),
                degraded=eng.degraded_admissions,
                preempted=eng.preemptions,
                quarantined=eng.quarantined,
                cert_bound=max(cert_bounds) if cert_bounds else 0.0,
                n_requests=n_requests,
                decode_steps=eng.steps,
                host_syncs=eng.host_syncs,
                tok_per_sync=eng.tokens_per_sync,
                util=eng.batch_utilization,
                peak_active=eng.peak_active,
                kv_bytes_cap=eng.kv_bytes_capacity,
                kv_bytes_peak=eng.kv_bytes_peak,
                pages_peak=eng.peak_pages_in_use,
                prefill_chunks=eng.prefill_chunks,
                shared_hits=eng.shared_page_hits,
                cow_forks=eng.cow_forks,
                # same-run gate currency (underscore keys never reach
                # CSV/JSON): structured-rejection compliance + greedy tokens
                _shed_structured=all(
                    r.rejected is not None
                    and r.rejected.uid == r.uid
                    and r.rejected.reason == "deadline-expired"
                    and r.rejected.waited_ms >= deadline
                    for r in shed
                ),
                _status=[r.status for r in done],
                _tokens=(
                    [list(r.tokens) for r in reqs] if temperature == 0.0 else None
                ),
            )
            return row

        fifo_row = replay(build_engine(tiered=False),
                          build_reqs(priorities=False),
                          label="fifo")
        rows.append(fifo_row)
        # the deadline must BIND on this runner or the tiered row gates
        # nothing: an absolute wall-clock deadline a fast machine drains
        # the whole burst under never expires.  ``deadline_ms`` is a
        # ceiling — the effective deadline is capped at just under half
        # the measured FIFO makespan, i.e. the wait the backlog tail is
        # guaranteed to exceed under FIFO pacing, whatever this runner's
        # speed.
        eff_deadline = min(deadline_ms, fifo_row["seconds"] * 1e3 * 0.45)
        rows.append(replay(build_engine(tiered=True),
                           build_reqs(deadline=eff_deadline, priorities=True),
                           label="tiered", deadline=eff_deadline))
        if inject == "nan":
            # poison the FIRST trace request at its SECOND decode token:
            # admitted in the first step (so the first fused block, where
            # steps_done == 0, covers the poison step), with one clean token
            # already emitted (so the prefix gate has a prefix to check).
            # Armed POST-warmup: warmup consumes uids and the step clock
            # resets at the warmup boundary.
            injector = FaultInjector()

            def arm(eng):
                injector.nan_logits = (eng._next_uid, min(1, decode_block - 1))
                eng.injector = injector

            row = replay(build_engine(tiered=False),
                         build_reqs(priorities=False),
                         label="inject-nan", arm=arm)
            row["_fired"] = injector.fired.get("nan_logits", 0)
            rows.append(row)
    return rows


def check_overload_rows(rows) -> int:
    """Same-run FIFO-vs-tiered (and optional fault-injection) gates.

    Both rows replayed the IDENTICAL burst on the same machine, so the
    comparisons are deterministic where they can be and same-run-relative
    where timing is involved:

    - the FIFO row completes everything and sheds nothing (no policy);
    - the tiered row sheds at least one deadline-expired waiter, every
      shed request carries a structured rejection whose ``waited_ms``
      proves the deadline really expired, and p95 TTFT over its COMPLETED
      requests is strictly below the FIFO row's (the backlog tail the
      deadline cut off);
    - at least one admission was degraded to a deeper tier, and the
      deepest tier's certificate bound is finite and positive (quality
      shed is REPORTED, not silent);
    - the inject row (when present) quarantines exactly the poisoned
      request — status ``"error"``, tokens a clean PREFIX of the
      uninjected run's — and every other request is bit-identical.
    """
    by_arch = {r["arch"]: r for r in rows if "arch" in r}
    failures = 0
    for arch, tiered in by_arch.items():
        if not arch.endswith("+tiered"):
            continue
        label = arch[: -len("+tiered")]
        fifo = by_arch.get(f"{label}+fifo")
        if fifo is None:
            continue
        checks = [
            ("fifo_completes_all", fifo["completed"] == fifo["n_requests"],
             f"{fifo['completed']} == {fifo['n_requests']}"),
            ("fifo_sheds_nothing", fifo["shed"] == 0, f"{fifo['shed']} == 0"),
            ("tiered_sheds", tiered["shed"] > 0, f"{tiered['shed']} > 0"),
            ("tiered_completes_some", tiered["completed"] > 0,
             f"{tiered['completed']} > 0"),
            ("shed_structured", bool(tiered["_shed_structured"]),
             "every shed request carries a deadline-expired rejection"),
            ("p95_ttft_ms",
             tiered["p95_ttft_ms"] < fifo["p95_ttft_ms"],
             f"{tiered['p95_ttft_ms']:.1f} < {fifo['p95_ttft_ms']:.1f}"),
            ("degraded", tiered["degraded"] > 0, f"{tiered['degraded']} > 0"),
            ("cert_bound",
             0.0 < tiered["cert_bound"] < float("inf"),
             f"0 < {tiered['cert_bound']:.3g} < inf"),
        ]
        inj = by_arch.get(f"{label}+inject-nan")
        if inj is not None:
            n_err = sum(1 for s in inj["_status"] if s == "error")
            bad = [i for i, s in enumerate(inj["_status"]) if s == "error"]
            prefix_ok = others_ok = True
            if inj.get("_tokens") is not None and fifo.get("_tokens") is not None:
                for i, (got, want) in enumerate(zip(inj["_tokens"], fifo["_tokens"])):
                    if i in bad:
                        prefix_ok &= 0 < len(got) < len(want) and got == want[: len(got)]
                    else:
                        others_ok &= got == want
            checks += [
                ("inject_fired", inj["_fired"] == 1, f"{inj['_fired']} == 1"),
                ("quarantined_exactly_one",
                 inj["quarantined"] == 1 and n_err == 1,
                 f"quarantined={inj['quarantined']} errored={n_err}"),
                ("poisoned_prefix", prefix_ok,
                 "poisoned tokens are a clean prefix of the uninjected run"),
                ("others_bit_identical", others_ok,
                 "every other request matches the uninjected run"),
            ]
        for metric, ok, detail in checks:
            print(
                f"[perf-smoke] {label} overload {metric}: {detail} "
                f"{'OK' if ok else 'VIOLATION'}"
            )
            failures += 0 if ok else 1
    return failures


def run_failover_trace(
    archs=("llama3.2-1b",),
    *,
    rate: float = 200.0,
    n_requests: int = 12,
    n_slots: int = 2,
    n_replicas: int = 2,
    prompt_range=(3, 7),
    gen_range=(12, 16),
    sys_prompt_len: int = 8,
    page_size: int = 4,
    decode_block: int = 4,
    heartbeat_ms: float = 150.0,
    max_failovers: int = 3,
    kill_step: int = 6,
    seed: int = 0,
    temperature: float = 0.0,
    top_k: int = 0,
    warmup: bool = True,
    inject: str = "",
):
    """Replay one system-prompt burst three ways — a single engine (the
    bit-exactness reference), a healthy N-replica cluster, and (with
    ``inject="kill_replica"``) the same cluster with replica 0 killed
    mid-burst — and gate the failover contract in the same run
    (:func:`check_failover_rows`).

    The trace is SYSTEM-PROMPT traffic on a sharing engine:
    ``sys_prompt_len`` spans ≥ 2 full pages, so by kill time every
    replica's prefix index holds the shared prefix — a failover
    continuation re-routed to the survivor matches those pages read-only
    (``prefill_skipped > 0``) and the trace demonstrably exercises the
    PREFIX-MATCH resume path, not just cold re-prefill.

    Greedy determinism is gated per COMPUTE PATH.  Requests that never
    failed over must be bit-identical to the single-engine replay (same
    path, no excuse).  A failed-over request's credited prefix must be
    bit-identical up to the kill point, and its resumed tail is verified
    by REPLAYING the exact continuation on the reference engine in the
    same run — the resume must reproduce, bit for bit, what any healthy
    engine emits for that continuation.  (Prefill-written and
    decode-written KV differ in low-order bits — a property the engine's
    merged preemption path shares — so the resumed tail may legitimately
    diverge from the UNINTERRUPTED replay at an argmax near-tie; the
    replay check is the strongest bit-exactness the engine actually
    guarantees, and it is checked, not assumed.)

    All gates are same-run relative (two cluster rows share this
    machine's load); the only wall-clock allowance is the detection
    window — a killed replica's waiters cannot get their first token
    before the heartbeat deadline expires, so the kill row's p95 TTFT
    gate adds a ``heartbeat_ms``-proportional term.  Replica threads
    contend for the same CPU, so the cluster rows raise the straggler
    kill floor (``straggler_min_s=2.0``) — slow-device detection has its
    own unit tests; this trace must not false-kill under CI load.
    """
    from repro.analysis import sanitize
    from repro.data.synthetic import modality_extras
    from repro.runtime.fault_tolerance import FaultInjector
    from repro.serving import Cluster, Engine, Request, SamplingParams
    from repro.serving.engine import percentile
    from repro.serving.scheduler import FailoverBudget

    assert sys_prompt_len >= 2 * page_size, (
        "sys prompt must span >= 2 full pages so survivors prefix-match"
    )
    rows = []
    for arch in archs:
        cfg = get_arch(arch, reduced=True)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(seed))
        rng = np.random.default_rng(seed)
        arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_requests)).tolist()
        max_len = sys_prompt_len + prompt_range[1] + gen_range[1]
        sys_prompt = rng.integers(0, cfg.vocab, size=(sys_prompt_len,)).astype(
            np.int32
        )
        trace = []
        for i in range(n_requests):
            user = rng.integers(
                0, cfg.vocab, size=(int(rng.integers(*prompt_range)),)
            ).astype(np.int32)
            trace.append(dict(
                prompt=np.concatenate([sys_prompt, user]),
                max_new=int(rng.integers(*gen_range)),
            ))

        def build_reqs():
            return [
                Request(
                    prompt=t["prompt"].copy(),
                    max_new_tokens=t["max_new"],
                    sampling=SamplingParams(
                        temperature=temperature, top_k=top_k, seed=seed + i
                    ),
                    extras=modality_extras(cfg, np.random.default_rng(seed + i)),
                )
                for i, t in enumerate(trace)
            ]

        def make_engine(_rid=0):
            # chunk == page: every prompt exceeds it, so ALL prefill rides
            # the one compiled (1, C) chunk program — failover resumes
            # (arbitrary prompt+emitted lengths) never hit a cold bucket
            return Engine(
                model, params, n_slots=n_slots, max_len=max_len,
                decode_block=decode_block, page_size=page_size,
                prefill_chunk=page_size, share_prefix=True,
            )

        def warm(eng):
            wrng = np.random.default_rng(seed + 1)
            wsp = SamplingParams(temperature=temperature, top_k=top_k, seed=seed)
            for g in (1, n_slots):
                eng.run([
                    Request(
                        prompt=wrng.integers(
                            0, cfg.vocab, size=(sys_prompt_len + prompt_range[1],)
                        ),
                        max_new_tokens=2, sampling=wsp,
                        extras=modality_extras(cfg, wrng),
                    )
                    for _ in range(g)
                ])
            eng.reset_prefix_cache()
            eng.reset_counters()

        def summarize(label, done, reqs, engines, dt, clu=None, fired=0):
            assert len(done) == n_requests, (label, len(done), n_requests)
            ok = [r for r in done if r.status == "ok"]
            shed = [r for r in done if r.status == "shed"]
            errored = [r for r in done if r.status == "error"]
            ttfts = sorted(r.ttft for r in ok if r.ttft is not None)
            lats = sorted(r.latency for r in ok if r.latency is not None)
            n_tok = sum(len(r.tokens) for r in done)
            syncs = sum(e.host_syncs for e in engines)
            row = dict(
                name=f"failover={arch}+{label}",
                arch=f"{arch}+{label}",
                seconds=dt,
                tok_s=n_tok / dt,
                p50_ms=percentile(lats, 0.5) * 1e3 if lats else 0.0,
                p95_ms=percentile(lats, 0.95) * 1e3 if lats else 0.0,
                ttft_ms=float(np.mean(ttfts)) * 1e3 if ttfts else 0.0,
                p95_ttft_ms=percentile(ttfts, 0.95) * 1e3 if ttfts else 0.0,
                completed=len(ok),
                shed=len(shed),
                errored=len(errored),
                n_requests=n_requests,
                decode_steps=sum(e.steps for e in engines),
                host_syncs=syncs,
                tok_per_sync=(
                    sum(e.decoded_tokens for e in engines) / max(syncs, 1)
                ),
                util=float(np.mean([e.batch_utilization for e in engines])),
                peak_active=max(e.peak_active for e in engines),
                kv_bytes_cap=sum(e.kv_bytes_capacity for e in engines),
                kv_bytes_peak=sum(e.kv_bytes_peak for e in engines),
                pages_peak=max(e.peak_pages_in_use for e in engines),
                prefill_chunks=sum(e.prefill_chunks for e in engines),
                shared_hits=sum(e.shared_page_hits for e in engines),
                cow_forks=sum(e.cow_forks for e in engines),
                replicas=len(engines),
                heartbeat_ms=heartbeat_ms,
                failovers=clu.failovers if clu else 0,
                failovers_prefix_match=clu.failovers_prefix_match if clu else 0,
                replica_lost=clu.replica_deaths if clu else 0,
                heartbeat_misses=clu.heartbeat_misses if clu else 0,
                # same-run gate currency (underscore keys never reach
                # CSV/JSON)
                _status=[r.status for r in done],
                _tokens=(
                    [list(r.tokens) for r in reqs]
                    if temperature == 0.0 else None
                ),
                _rejects_structured=all(
                    r.rejected is not None and r.rejected.uid == r.uid
                    for r in shed
                ),
                _fired=fired,
                _failed_over=(
                    [r.uid in clu.resume_points for r in reqs]
                    if clu else [False] * len(reqs)
                ),
                _splits=(
                    {i: list(clu.resume_points[r.uid])
                     for i, r in enumerate(reqs) if r.uid in clu.resume_points}
                    if clu else {}
                ),
                _resume_bad=0,
            )
            return row

        # --- reference: one engine, no cluster, same trace -------------
        eng = make_engine()
        if warmup:
            warm(eng)
        reqs_single = build_reqs()
        t0 = time.perf_counter()
        done = eng.run(reqs_single, arrivals=arrivals)
        dt = time.perf_counter() - t0
        single_tokens = [list(r.tokens) for r in reqs_single]
        rows.append(summarize("single", done, reqs_single, [eng], dt))

        # --- the cluster rows: healthy, then with a replica killed -----
        def cluster_row(label, injector=None):
            clu = Cluster(
                make_engine, n_replicas,
                heartbeat_ms=heartbeat_ms,
                budget=FailoverBudget(max_failovers=max_failovers,
                                      base_ms=10.0),
                injector=injector,
                straggler_min_s=2.0,
            )
            if warmup:
                for rep in clu.replicas:
                    warm(rep.eng)
            reqs = build_reqs()
            t0 = time.perf_counter()
            done = clu.run(reqs, arrivals=arrivals, timeout_s=120.0)
            dt = time.perf_counter() - t0
            clu.close()
            if sanitize.enabled():
                # REPRO_SANITIZE=1: any guarded-attribute access that raced
                # during the trace was recorded, not raised; fail loud here.
                sanitize.check()
            fired = injector.fired.get("kill_replica", 0) if injector else 0
            row = summarize(
                label, done, reqs, [r.eng for r in clu.replicas], dt,
                clu=clu, fired=fired,
            )
            return row, reqs

        def verify_resumes(row, kreqs):
            """Replay each failed-over request's continuation(s) on the
            reference engine: the credited prefix must match the single
            replay bit-for-bit up to the first split, and every resumed
            tail must be exactly what the healthy engine emits for that
            continuation.  Returns the number of corrupt streams."""
            bad = 0
            eng.reset_prefix_cache()
            eng.reset_counters()
            for i, req in enumerate(kreqs):
                splits = row["_splits"].get(i)
                if not splits or req.status != "ok":
                    continue
                chain = list(req.tokens)
                if chain[: splits[0]] != single_tokens[i][: splits[0]]:
                    bad += 1
                    continue
                bounds = splits + [len(chain)]
                for j, k in enumerate(splits):
                    end = bounds[j + 1]
                    cont = Request(
                        prompt=np.concatenate(
                            [trace[i]["prompt"],
                             np.asarray(chain[:k], np.int32)]
                        ),
                        max_new_tokens=trace[i]["max_new"] - k,
                        sampling=SamplingParams(
                            temperature=temperature, top_k=top_k,
                            seed=seed + i,
                        ),
                        extras=modality_extras(
                            cfg, np.random.default_rng(seed + i)
                        ),
                    )
                    eng.run([cont])
                    if chain[k:end] != list(cont.tokens)[: end - k]:
                        bad += 1
                        break
            return bad

        hrow, _ = cluster_row("cluster")
        rows.append(hrow)
        if inject == "kill_replica":
            krow, kreqs = cluster_row(
                "cluster-kill",
                injector=FaultInjector(kill_replica=(0, kill_step)),
            )
            if temperature == 0.0:
                krow["_resume_bad"] = verify_resumes(krow, kreqs)
            rows.append(krow)
    return rows


def check_failover_rows(rows, *, tolerance: float = 0.5) -> int:
    """Same-run single-vs-cluster-vs-kill gates (the --trace failover
    contract).

    - the single row and the healthy cluster row complete everything,
      bit-identically (greedy: distribution across replicas must not
      change a single token);
    - the kill row loses exactly one replica to the injected fault and
      ZERO requests silently: every request completes or carries a
      structured rejection;
    - kill-row requests that never failed over are bit-identical to the
      single replay; failed-over requests carry a bit-identical credited
      prefix and a resumed tail bit-identical to the reference engine's
      replay of the same continuation (``_resume_bad == 0`` — see
      :func:`run_failover_trace` on per-compute-path determinism);
    - at least one failover happened and at least one resumed through a
      prefix match on the survivor (``prefill_skipped > 0``);
    - kill-row p95 TTFT over completed requests stays within
      ``tolerance`` of the healthy row, plus a detection allowance of
      4 x ``heartbeat_ms`` (a killed replica's waiters cannot be
      re-routed before the deadline expires — that window is the cost of
      detection, not a regression).
    """
    by_arch = {r["arch"]: r for r in rows if "arch" in r}
    failures = 0
    for arch, kill in by_arch.items():
        if not arch.endswith("+cluster-kill"):
            continue
        label = arch[: -len("+cluster-kill")]
        single = by_arch.get(f"{label}+single")
        healthy = by_arch.get(f"{label}+cluster")
        if single is None or healthy is None:
            continue
        checks = [
            ("single_completes_all",
             single["completed"] == single["n_requests"],
             f"{single['completed']} == {single['n_requests']}"),
            ("healthy_completes_all",
             healthy["completed"] == healthy["n_requests"]
             and healthy["replica_lost"] == 0,
             f"{healthy['completed']} == {healthy['n_requests']}, "
             f"deaths={healthy['replica_lost']}"),
            ("kill_fired", kill["_fired"] == 1, f"{kill['_fired']} == 1"),
            ("kill_replica_died", kill["replica_lost"] >= 1,
             f"{kill['replica_lost']} >= 1"),
            ("zero_silently_lost",
             len(kill["_status"]) == kill["n_requests"]
             and bool(kill["_rejects_structured"]),
             "every request completed or carries a structured rejection"),
            ("failover_observed", kill["failovers"] >= 1,
             f"{kill['failovers']} >= 1"),
            ("prefix_match_failover", kill["failovers_prefix_match"] >= 1,
             f"{kill['failovers_prefix_match']} >= 1"),
        ]
        if healthy.get("_tokens") is not None:
            checks.append(
                ("healthy_bit_identical",
                 healthy["_tokens"] == single["_tokens"],
                 "healthy cluster tokens match the single-engine replay")
            )
        if kill.get("_tokens") is not None and single.get("_tokens") is not None:
            exact = all(
                got == want
                for got, want, status, failed in zip(
                    kill["_tokens"], single["_tokens"], kill["_status"],
                    kill["_failed_over"],
                )
                if status == "ok" and not failed
            )
            checks.append(
                ("unfailed_bit_identical", exact,
                 "requests that never failed over match the single replay")
            )
            checks.append(
                ("failover_resume_exact", kill["_resume_bad"] == 0,
                 f"{kill['_resume_bad']} corrupt resumed stream(s): every "
                 "credited prefix and replayed continuation must match")
            )
        allowance = 4.0 * kill["heartbeat_ms"]
        ceil = healthy["p95_ttft_ms"] * (1.0 + tolerance) + allowance
        checks.append(
            ("p95_ttft_ms", kill["p95_ttft_ms"] <= ceil,
             f"{kill['p95_ttft_ms']:.1f} <= {healthy['p95_ttft_ms']:.1f} "
             f"+ {tolerance:.0%} + {allowance:.0f}ms detection")
        )
        for metric, ok, detail in checks:
            print(
                f"[perf-smoke] {label} failover {metric}: {detail} "
                f"{'OK' if ok else 'VIOLATION'}"
            )
            failures += 0 if ok else 1
    return failures


def write_json(rows, json_path, *, config=None):
    """Write trace rows as the BENCH_serving.json result document."""
    keys = (
        "tok_s", "p50_ms", "p95_ms", "ttft_ms", "ttft_p95_short_ms",
        "followup_ttft_ms", "reprefill_tok", "skipped_tok", "evictions",
        "cached_pages", "n_sessions", "kv_pages",
        "n_requests", "decode_steps", "host_syncs", "tok_per_sync", "util",
        "peak_active", "kv_bytes_cap", "kv_bytes_peak", "pages_peak",
        "prefill_chunks", "shared_hits", "cow_forks", "share_supported",
        "p95_ttft_ms", "completed", "shed", "errored", "degraded",
        "preempted", "quarantined", "cert_bound",
        "replicas", "heartbeat_ms", "failovers", "failovers_prefix_match",
        "replica_lost", "heartbeat_misses",
    )
    # failover rows also carry "shed", so sniff their own key first
    if any("failovers" in r for r in rows):
        kind = "failover_trace"
    elif any("reprefill_tok" in r for r in rows):
        kind = "sessions_trace"
    elif any("shed" in r for r in rows):
        kind = "overload_trace"
    else:
        kind = "poisson_trace"
    doc = {
        "kind": kind,
        "config": config or {},
        "rows": {
            r["arch"]: {k: r[k] for k in keys if k in r}
            for r in rows
            if "arch" in r
        },
    }
    with open(json_path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return doc


def check_baseline(rows, baseline_path, *, tolerance: float) -> int:
    """Compare trace rows to a checked-in baseline; return #regressions.

    tok/s regresses if current < baseline * (1 - tolerance); decode-batch
    utilization likewise; TTFT p95 over short requests (the long-prompt
    mixed trace: present when both sides report it) regresses UPWARD —
    current > baseline * (1 + tolerance) — since chunked prefill exists
    precisely to bound it.  Throughput on shared CI runners is noisy, so
    the tolerance is deliberately generous — the gate exists to catch the
    "decode got order-of-magnitude slower / the batch went idle / a long
    prefill stalls everyone again" class of regression, not 5% drift.
    Archs missing from the baseline are skipped with a note (so adding an
    arch to the trace never breaks CI).
    """
    with open(baseline_path) as f:
        base = json.load(f)["rows"]
    failures = 0
    for r in rows:
        arch = r.get("arch")
        if arch is None:
            continue
        if arch not in base:
            print(f"[perf-smoke] {arch}: no baseline entry, skipping")
            continue
        for metric in ("tok_s", "util"):
            floor = base[arch][metric] * (1.0 - tolerance)
            ok = r[metric] >= floor
            print(
                f"[perf-smoke] {arch} {metric}: current={r[metric]:.3f} "
                f"baseline={base[arch][metric]:.3f} floor={floor:.3f} "
                f"{'OK' if ok else 'REGRESSION'}"
            )
            failures += 0 if ok else 1
        if "ttft_p95_short_ms" in r and "ttft_p95_short_ms" in base[arch]:
            # informational only: absolute TTFT tracks runner load too
            # tightly to gate — the binding TTFT gate is the SAME-RUN
            # paged-vs-flat comparison in check_paged_rows
            print(
                f"[perf-smoke] {arch} ttft_p95_short_ms: "
                f"current={r['ttft_p95_short_ms']:.1f} "
                f"baseline={base[arch]['ttft_p95_short_ms']:.1f} (info)"
            )
    return failures


def check_paged_rows(rows, *, tolerance: float = 0.3) -> int:
    """Same-run flat-vs-paged gates (the --compare-paged contract).

    Both rows ran back-to-back on the SAME machine under the same load, so
    these comparisons are robust where absolute wall-clock floors are not:
    at equal KV bytes the paged engine must (a) admit strictly more
    concurrent requests (peak_active — a deterministic count, gated with
    NO slack), (b) not lose throughput, and (c) hold TTFT p95 for short
    requests at or below the flat engine's while a long prompt is
    prefilling (that bound is the entire point of chunked prefill).  The
    two timing-based checks still see half-trace noise (a noisy neighbor
    can land on one half only), so they get ``tolerance`` slack — tighter
    than the cross-machine baseline floors, but not zero.  Returns
    #violations.
    """
    by_arch = {r["arch"]: r for r in rows if "arch" in r}
    failures = 0
    for arch, flat in by_arch.items():
        paged = by_arch.get(f"{arch}+paged")
        if paged is None or arch.endswith("+paged"):
            continue
        checks = [
            ("peak_active", paged["peak_active"] > flat["peak_active"],
             f"{paged['peak_active']} > {flat['peak_active']}"),
            ("tok_s",
             paged["tok_s"] >= flat["tok_s"] * (1.0 - tolerance),
             f"{paged['tok_s']:.1f} >= {flat['tok_s']:.1f} - {tolerance:.0%}"),
        ]
        if "ttft_p95_short_ms" in paged and "ttft_p95_short_ms" in flat:
            checks.append(
                ("ttft_p95_short_ms",
                 paged["ttft_p95_short_ms"]
                 <= flat["ttft_p95_short_ms"] * (1.0 + tolerance),
                 f"{paged['ttft_p95_short_ms']:.1f} <= "
                 f"{flat['ttft_p95_short_ms']:.1f} + {tolerance:.0%}")
            )
        for metric, ok, detail in checks:
            print(
                f"[perf-smoke] {arch} paged-vs-flat {metric}: {detail} "
                f"{'OK' if ok else 'VIOLATION'}"
            )
            failures += 0 if ok else 1
    return failures


def check_shared_rows(rows, *, tolerance: float = 0.3) -> int:
    """Same-run unshared-vs-shared gates (the --shared-prefix contract).

    Both rows replay the IDENTICAL system-prompt trace through the paged
    engine at equal KV bytes, pairing ``X`` with ``X+shared``.  Two gates
    are deterministic counts and get NO slack: the sharing engine must
    peak strictly FEWER pages (a prefix page backing many slots occupies
    one page of HBM) and strictly MORE admitted concurrency (page-gated
    admission banks exactly those savings); it must also actually have
    shared something (hit counter), hold throughput within ``tolerance``
    (a timing number — same machine, but half-trace noise is real), and —
    for greedy traces — emit bit-identical tokens per request (sharing
    relocates bytes, never changes what is attended).  Returns #violations.

    The strict peak gates presuppose the trace actually SATURATES the
    page pool (arrivals far faster than service, as the CI config's burst
    rate guarantees): a trickle that never queues on pages peaks both
    rows identically and gates nothing.
    """
    by_arch = {r["arch"]: r for r in rows if "arch" in r}
    failures = 0
    for arch, shared in by_arch.items():
        if not arch.endswith("+shared"):
            continue
        base = by_arch.get(arch[: -len("+shared")])
        if base is None:
            continue
        if not shared.get("share_supported"):
            # sharing is documented-inert for this family (no paged
            # leaves or no mid-prompt prefill): identical rows are the
            # CORRECT outcome, not a regression
            print(
                f"[perf-smoke] {arch[: -len('+shared')]} shared-vs-unshared: "
                f"sharing inert for this arch, gates skipped"
            )
            continue
        checks = [
            ("pages_peak", shared["pages_peak"] < base["pages_peak"],
             f"{shared['pages_peak']} < {base['pages_peak']}"),
            ("peak_active", shared["peak_active"] > base["peak_active"],
             f"{shared['peak_active']} > {base['peak_active']}"),
            ("shared_hits", shared["shared_hits"] > 0,
             f"{shared['shared_hits']} > 0"),
            ("tok_s", shared["tok_s"] >= base["tok_s"] * (1.0 - tolerance),
             f"{shared['tok_s']:.1f} >= {base['tok_s']:.1f} - {tolerance:.0%}"),
        ]
        if base.get("_tokens") is not None and shared.get("_tokens") is not None:
            checks.append(
                ("greedy_parity", shared["_tokens"] == base["_tokens"],
                 "bit-identical tokens per request")
            )
        for metric, ok, detail in checks:
            print(
                f"[perf-smoke] {arch[: -len('+shared')]} shared-vs-unshared "
                f"{metric}: {detail} {'OK' if ok else 'VIOLATION'}"
            )
            failures += 0 if ok else 1
    return failures


def emit_csv(rows, csv_path=None):
    lines = []
    for r in rows:
        if "p50_ms" in r:  # trace rows
            extra = ""
            if "ttft_p95_short_ms" in r:
                extra = f";ttft_p95_short_ms={r['ttft_p95_short_ms']:.0f}"
            if "reprefill_tok" in r:  # sessions-trace columns
                extra += (
                    f";followup_ttft_ms={r['followup_ttft_ms']:.0f}"
                    f";reprefill_tok={r['reprefill_tok']}"
                    f";skipped_tok={r['skipped_tok']}"
                    f";evictions={r['evictions']}"
                    f";cached_pages={r['cached_pages']}"
                )
            if "failovers" in r:  # failover-trace columns
                extra += (
                    f";p95_ttft_ms={r['p95_ttft_ms']:.0f}"
                    f";completed={r['completed']}"
                    f";shed={r['shed']}"
                    f";replicas={r['replicas']}"
                    f";failovers={r['failovers']}"
                    f";prefix_match={r['failovers_prefix_match']}"
                    f";replica_lost={r['replica_lost']}"
                    f";heartbeat_misses={r['heartbeat_misses']}"
                )
            elif "shed" in r:  # overload-trace columns
                extra += (
                    f";p95_ttft_ms={r['p95_ttft_ms']:.0f}"
                    f";completed={r['completed']}"
                    f";shed={r['shed']}"
                    f";degraded={r['degraded']}"
                    f";preempted={r['preempted']}"
                    f";quarantined={r['quarantined']}"
                    f";cert_bound={r['cert_bound']:.4g}"
                )
            lines.append(
                f"serving/{r['name']},{r['seconds']*1e6:.0f},"
                f"tok_s={r['tok_s']:.1f};p50_ms={r['p50_ms']:.0f};"
                f"p95_ms={r['p95_ms']:.0f};ttft_ms={r['ttft_ms']:.0f};"
                f"n_req={r['n_requests']};decode_steps={r['decode_steps']};"
                f"host_syncs={r['host_syncs']};"
                f"tok_per_sync={r['tok_per_sync']:.1f};util={r['util']:.3f};"
                f"peak_active={r['peak_active']};"
                f"kv_bytes_peak={r['kv_bytes_peak']};"
                f"kv_bytes_cap={r['kv_bytes_cap']};"
                f"pages_peak={r['pages_peak']};"
                f"prefill_chunks={r['prefill_chunks']};"
                f"shared_hits={r['shared_hits']};"
                f"cow_forks={r['cow_forks']}"
                f"{extra}"
            )
        else:
            extra = f";hits={r['hits']}" if "hits" in r else ""
            lines.append(
                f"serving/{r['name']},{r['seconds']*1e6:.0f},"
                f"tok_s={r['tok_s']:.1f};agree={r['agree']:.3f};ratio={r['ratio']:.3f}"
                f"{extra}"
            )
    out = "\n".join(lines)
    print(out)
    if csv_path:
        # trace rows carry the WHOLE replay's wall-clock, not per-call time
        header = "name,total_us,derived" if any("p50_ms" in r for r in rows) else "name,us_per_call,derived"
        with open(csv_path, "w") as f:
            f.write(header + "\n" + out + "\n")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--sweep-backends",
        action="store_true",
        help="run the compressed model once per kernel backend and report "
        "per-backend throughput + dispatcher hit counts",
    )
    ap.add_argument(
        "--trace",
        choices=["poisson", "sessions", "overload", "failover"],
        default=None,
        help="replay an arrival trace through the continuous-batching "
        "engine: 'poisson' = independent requests; 'sessions' = "
        "multi-turn conversations replayed TWICE (prefix sharing off, "
        "then on) with the same-run session-cache gate; 'overload' = "
        "one burst replayed TWICE (plain FIFO, then tiered admission "
        "with deadline shedding and preemption) with the same-run "
        "overload gate; 'failover' = one system-prompt burst replayed "
        "on a single engine, a healthy replica cluster, and (with "
        "--inject kill_replica) a cluster losing a replica mid-burst, "
        "with the same-run bit-exact failover gate",
    )
    ap.add_argument("--arch", default="llama3.2-1b",
                    help="comma-separated reduced arch ids (trace mode)")
    ap.add_argument("--rate", type=float, default=20.0, help="req/s (trace mode)")
    ap.add_argument("--n-requests", type=int, default=16)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--decode-block", type=int, default=8,
                    help="decode tokens per host round-trip (trace mode)")
    ap.add_argument("--prompt-range", default="4,16",
                    help="min,max prompt tokens (trace mode)")
    ap.add_argument("--gen-range", default="4,16",
                    help="min,max generated tokens (trace mode)")
    ap.add_argument("--page-size", type=int, default=0,
                    help="KV page size in tokens; 0 = flat slot pool")
    ap.add_argument("--kv-pages", type=int, default=0,
                    help="paged-pool size in pages; 0 = flat-equivalent")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked-prefill chunk size; 0 = monolithic")
    ap.add_argument("--long-frac", type=float, default=0.0,
                    help="fraction of requests drawing LONG prompts "
                    "(long-prompt mixed trace)")
    ap.add_argument("--long-prompt-range", default="48,64",
                    help="min,max long-prompt tokens when --long-frac > 0")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="replay a common-system-prompt trace TWICE on the "
                    "paged engine at equal KV bytes — prefix sharing off, "
                    "then on (+shared row) — and report the same-run "
                    "contract (strictly fewer peak pages, strictly more "
                    "admitted concurrency, no throughput loss, "
                    "bit-identical greedy tokens)")
    ap.add_argument("--sys-prompt-len", type=int, default=12,
                    help="common system-prompt tokens for --shared-prefix "
                    "(keep >= 2 pages so full-page matching engages)")
    ap.add_argument("--n-sessions", type=int, default=4,
                    help="conversations in the sessions trace")
    ap.add_argument("--turns-range", default="3,5",
                    help="min,max chat turns per session (inclusive)")
    ap.add_argument("--user-range", default="3,6",
                    help="min,max new user tokens per turn (inclusive)")
    ap.add_argument("--think-ms", type=float, default=10.0,
                    help="delay between a reply and its follow-up turn")
    ap.add_argument("--warm-cache-pages", type=int, default=0,
                    help="LRU budget on matchable refcount-0 pages "
                    "(sessions trace, shared row); 0 = unbounded")
    ap.add_argument("--compare-paged", action="store_true",
                    help="run each arch TWICE at equal KV bytes: the flat "
                    "slot pool, then a paged pool (+paged row) with twice "
                    "the slots backed by the same page budget — the "
                    "admitted-concurrency/throughput comparison the paged "
                    "pool exists for")
    ap.add_argument("--deadline-ms", type=float, default=300.0,
                    help="admission deadline for the overload trace's "
                    "tiered row (waiters shed past it)")
    ap.add_argument("--tiers", default="1.0,0.5",
                    help="comma-separated rank fractions for the overload "
                    "trace's tiered row (first must be 1.0)")
    ap.add_argument("--inject", choices=["nan", "kill_replica"], default=None,
                    help="overload trace ('nan'): add a fault-injection row "
                    "(one request's logits poisoned to NaN mid-decode) "
                    "gated on exact single-request quarantine; failover "
                    "trace ('kill_replica'): add a cluster row with "
                    "replica 0 killed mid-burst, gated on bit-exact "
                    "failover with zero silent losses")
    ap.add_argument("--replicas", type=int, default=2,
                    help="cluster size for the failover trace")
    ap.add_argument("--heartbeat-ms", type=float, default=150.0,
                    help="failover trace: replica heartbeat deadline floor")
    ap.add_argument("--max-failovers", type=int, default=3,
                    help="failover trace: per-request retry budget before "
                    "a structured replica_lost rejection")
    ap.add_argument("--kill-step", type=int, default=6,
                    help="failover trace: replica-0 local step at which "
                    "--inject kill_replica fires")
    ap.add_argument("--no-warmup", action="store_true",
                    help="include jit compile time in the trace row")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--alpha", type=float, default=0.0,
                    help="RSI compression alpha (0 = dense) for trace mode")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--csv", default=None, help="also write rows to this CSV file")
    ap.add_argument("--json", default=None,
                    help="write trace rows to this JSON file (BENCH_serving.json)")
    ap.add_argument("--check-baseline", default=None,
                    help="baseline BENCH_serving.json to compare against; "
                    "exits non-zero if tok/s or utilization regresses")
    ap.add_argument("--tolerance", type=float, default=0.5,
                    help="allowed relative drop vs the baseline (CI runners "
                    "are noisy; this gates collapses, not drift)")
    args = ap.parse_args()
    if args.trace == "poisson":
        common = dict(
            rate=args.rate,
            n_requests=args.n_requests,
            temperature=args.temperature,
            top_k=args.top_k,
            seed=args.seed,
            alpha=args.alpha,
            decode_block=args.decode_block,
            prompt_range=tuple(int(x) for x in args.prompt_range.split(",")),
            gen_range=tuple(int(x) for x in args.gen_range.split(",")),
            long_frac=args.long_frac,
            long_prompt_range=tuple(int(x) for x in args.long_prompt_range.split(",")),
            warmup=not args.no_warmup,
        )
        arch_list = tuple(a.strip() for a in args.arch.split(",") if a.strip())
        # effective paged geometry, recorded verbatim in the --json config
        # block so a checked-in baseline documents the run that produced it
        eff = dict(page_size=args.page_size, kv_pages=args.kv_pages,
                   prefill_chunk=args.prefill_chunk)
        if args.compare_paged and args.shared_prefix:
            raise SystemExit(
                "--compare-paged and --shared-prefix are separate "
                "comparisons; run them as two invocations"
            )
        if args.shared_prefix:
            # identical paged geometry for both rows (EQUAL KV bytes, same
            # slots, same trace): the only difference is share_prefix.  The
            # default pool is sized to BIND — half the worst-case footprint
            # — so page-gated admission, not slot count, is what the banked
            # prefix pages relax.
            page = args.page_size or 4
            top = max(common["prompt_range"][1],
                      common["long_prompt_range"][1] if args.long_frac > 0 else 0)
            max_len = args.sys_prompt_len + top + common["gen_range"][1]
            max_pages = -(-max_len // page)
            eff = dict(page_size=page,
                       kv_pages=args.kv_pages or args.n_slots * max_pages // 2,
                       prefill_chunk=args.prefill_chunk,
                       sys_prompt_len=args.sys_prompt_len, share_prefix=True)
            base_kw = dict(
                n_slots=args.n_slots, max_len=max_len, page_size=page,
                kv_pages=eff["kv_pages"], prefill_chunk=args.prefill_chunk,
                sys_prompt_len=args.sys_prompt_len, **common,
            )
            # "+sys" keeps these rows distinct from the --compare-paged rows
            # in a merged baseline file; the pairing rule is X vs X+shared
            rows = run_trace(arch_list, row_suffix="+sys", **base_kw)
            rows += run_trace(
                arch_list, share_prefix=True, row_suffix="+sys+shared", **base_kw
            )
        elif args.compare_paged:
            # equal KV bytes: the paged pool holds exactly the flat pool's
            # token capacity (n_slots * max_len worth of pages) but backs
            # TWICE the decode slots — admission is page-gated, so the
            # paged engine can admit more concurrent requests whenever
            # real footprints are below the flat worst case.
            page = args.page_size or 16
            chunk = args.prefill_chunk or 2 * page
            top = max(common["prompt_range"][1],
                      common["long_prompt_range"][1] if args.long_frac > 0 else 0)
            max_len = top + common["gen_range"][1]
            max_pages = -(-max_len // page)
            eff = dict(page_size=page,
                       kv_pages=args.kv_pages or args.n_slots * max_pages,
                       prefill_chunk=chunk, paged_n_slots=2 * args.n_slots)
            rows = run_trace(arch_list, n_slots=args.n_slots, max_len=max_len, **common)
            rows += run_trace(
                arch_list,
                n_slots=eff["paged_n_slots"],
                max_len=max_len,
                page_size=eff["page_size"],
                kv_pages=eff["kv_pages"],
                prefill_chunk=eff["prefill_chunk"],
                row_suffix="+paged",
                **common,
            )
        else:
            rows = run_trace(
                arch_list,
                n_slots=args.n_slots,
                page_size=args.page_size,
                kv_pages=args.kv_pages,
                prefill_chunk=args.prefill_chunk,
                **common,
            )
    elif args.trace == "sessions":
        # one invocation = TWO rows over the identical multi-turn trace —
        # prefix sharing off, then on (+shared) — gated against each other
        page = args.page_size or 4
        chunk = args.prefill_chunk or 2 * page
        eff = dict(page_size=page, prefill_chunk=chunk,
                   sys_prompt_len=args.sys_prompt_len,
                   n_sessions=args.n_sessions, turns_range=args.turns_range,
                   user_range=args.user_range,
                   warm_cache_pages=args.warm_cache_pages)
        arch_list = tuple(a.strip() for a in args.arch.split(",") if a.strip())
        sess_kw = dict(
            n_sessions=args.n_sessions,
            turns_range=tuple(int(x) for x in args.turns_range.split(",")),
            user_range=tuple(int(x) for x in args.user_range.split(",")),
            gen_range=tuple(int(x) for x in args.gen_range.split(",")),
            sys_prompt_len=args.sys_prompt_len,
            rate=args.rate,
            think_time=args.think_ms / 1e3,
            n_slots=args.n_slots,
            seed=args.seed,
            alpha=args.alpha,
            decode_block=args.decode_block,
            page_size=page,
            kv_pages=args.kv_pages,
            prefill_chunk=chunk,
            temperature=args.temperature,
            top_k=args.top_k,
            warmup=not args.no_warmup,
        )
        rows = run_sessions_trace(arch_list, row_suffix="+turns", **sess_kw)
        rows += run_sessions_trace(
            arch_list, share_prefix=True,
            warm_cache_pages=args.warm_cache_pages,
            row_suffix="+turns+shared", **sess_kw,
        )
    elif args.trace == "overload":
        # one invocation = two (or three, with --inject) rows over the
        # identical burst — plain FIFO, tiered admission, optionally a
        # fault-injected FIFO re-run — gated against each other
        tiers = tuple(float(f) for f in args.tiers.split(",") if f)
        page = args.page_size or 4
        eff = dict(page_size=page, kv_pages=args.kv_pages,
                   deadline_ms=args.deadline_ms, tiers=args.tiers,
                   inject=args.inject or "")
        arch_list = tuple(a.strip() for a in args.arch.split(",") if a.strip())
        rows = run_overload_trace(
            arch_list,
            rate=args.rate,
            n_requests=args.n_requests,
            n_slots=args.n_slots,
            prompt_range=tuple(int(x) for x in args.prompt_range.split(",")),
            gen_range=tuple(int(x) for x in args.gen_range.split(",")),
            deadline_ms=args.deadline_ms,
            tiers=tiers,
            seed=args.seed,
            alpha=args.alpha or 0.5,
            decode_block=args.decode_block,
            page_size=page,
            kv_pages=args.kv_pages,
            temperature=args.temperature,
            top_k=args.top_k,
            warmup=not args.no_warmup,
            inject=args.inject or "",
        )
    elif args.trace == "failover":
        # one invocation = single-engine reference + healthy cluster +
        # (with --inject kill_replica) a kill row — gated against each
        # other in the same run
        page = args.page_size or 4
        eff = dict(page_size=page, sys_prompt_len=args.sys_prompt_len,
                   replicas=args.replicas, heartbeat_ms=args.heartbeat_ms,
                   max_failovers=args.max_failovers, kill_step=args.kill_step,
                   inject=args.inject or "")
        arch_list = tuple(a.strip() for a in args.arch.split(",") if a.strip())
        rows = run_failover_trace(
            arch_list,
            rate=args.rate,
            n_requests=args.n_requests,
            n_slots=args.n_slots,
            n_replicas=args.replicas,
            prompt_range=tuple(int(x) for x in args.prompt_range.split(",")),
            gen_range=tuple(int(x) for x in args.gen_range.split(",")),
            sys_prompt_len=args.sys_prompt_len,
            page_size=page,
            decode_block=args.decode_block,
            heartbeat_ms=args.heartbeat_ms,
            max_failovers=args.max_failovers,
            kill_step=args.kill_step,
            seed=args.seed,
            temperature=args.temperature,
            top_k=args.top_k,
            warmup=not args.no_warmup,
            inject=args.inject or "",
        )
    elif args.sweep_backends:
        rows = run_backend_sweep()
    else:
        rows = run()
    emit_csv(rows, csv_path=args.csv)
    if args.json:
        if args.trace is None:
            raise SystemExit("--json applies to --trace rows")
        write_json(
            rows,
            args.json,
            config=dict(
                rate=args.rate, n_requests=args.n_requests, n_slots=args.n_slots,
                decode_block=args.decode_block, seed=args.seed, alpha=args.alpha,
                prompt_range=args.prompt_range, gen_range=args.gen_range,
                long_frac=args.long_frac,
                long_prompt_range=args.long_prompt_range,
                compare_paged=args.compare_paged,
                **eff,
            ),
        )
    if args.check_baseline:
        if args.trace != "poisson":
            raise SystemExit("--check-baseline applies to --trace poisson rows")
        n_bad = check_baseline(rows, args.check_baseline, tolerance=args.tolerance)
        if args.compare_paged:
            # half the baseline tolerance: same-machine relative gates are
            # tighter than cross-machine absolute floors, but not noise-free
            n_bad += check_paged_rows(rows, tolerance=args.tolerance / 2)
        if n_bad:
            sys.exit(f"[perf-smoke] {n_bad} metric(s) regressed beyond tolerance")
    if args.trace == "poisson" and args.shared_prefix:
        # the shared-vs-unshared contract is gated UNCONDITIONALLY: both
        # rows ran back-to-back on this machine, so the comparison is
        # meaningful even where absolute cross-machine floors are not
        n_bad = check_shared_rows(rows, tolerance=args.tolerance / 2)
        if n_bad:
            sys.exit(f"[perf-smoke] {n_bad} shared-prefix gate(s) violated")
    if args.trace == "sessions":
        # likewise same-run: sharing off vs on over the identical
        # multi-turn conversations
        n_bad = check_sessions_rows(rows, tolerance=args.tolerance / 2)
        if n_bad:
            sys.exit(f"[perf-smoke] {n_bad} sessions gate(s) violated")
    if args.trace == "overload":
        # same-run: FIFO vs tiered admission over the identical burst,
        # plus exact-quarantine gates when --inject armed a fault
        n_bad = check_overload_rows(rows)
        if n_bad:
            sys.exit(f"[perf-smoke] {n_bad} overload gate(s) violated")
    if args.trace == "failover":
        # same-run: single engine vs healthy cluster vs kill row over the
        # identical burst — the bit-exact failover contract
        n_bad = check_failover_rows(rows, tolerance=args.tolerance)
        if n_bad:
            sys.exit(f"[perf-smoke] {n_bad} failover gate(s) violated")
