"""Serving-throughput benchmark: dense vs RSI-compressed decode (measured).

CPU wall-clock, reduced llama config — the RELATIVE throughput and agreement
numbers support EXPERIMENTS.md §Perf C2 (weight compression as a serving
lever).  Emits name,us_per_call,derived CSV rows.

``--sweep-backends`` additionally runs the compressed model once per kernel
backend (auto / xla / pallas / reference) through the unified dispatch
runtime and emits one CSV row per backend, annotated with the dispatcher's
hit counters — i.e. which execution path (fused / fused_batched / two_gemm /
dense) every linear in the compiled program actually took.

    PYTHONPATH=src python benchmarks/serving.py [--sweep-backends]
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.core import CompressionPolicy, compress_tree, spectralize_params
from repro.data.synthetic import SyntheticLM
from repro.models.model import build_model
from repro.runtime import dispatch
from repro.runtime.dispatch import BACKENDS, DispatchConfig, use_dispatch


def _setup(batch: int, prompt: int):
    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = spectralize_params(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(9))
    data = SyntheticLM(cfg, batch=batch, seq=prompt, kind="serve")
    bt = {k: jnp.asarray(v) for k, v in data.at_step(0).items()}
    return cfg, model, params, bt


def _bench(model, p, bt, prompt: int, gen: int):
    max_len = prompt + gen

    # Fresh closures per bench run: pjit's global jaxpr cache is keyed on the
    # function object, and the dispatch policy is ambient trace-time state —
    # reusing `model.decode_step` across backends would silently reuse the
    # FIRST backend's traced program (same idiom as serve_step.make_*_step).
    def prefill_fn(p, b):
        return model.prefill(p, b, max_len)

    def decode_fn(p, c, t, pos):
        return model.decode_step(p, c, t, pos)

    logits, cache = jax.jit(prefill_fn)(p, bt)
    step = jax.jit(decode_fn)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    # warm
    l2, c2 = step(p, cache, tok, jnp.int32(prompt))
    jax.block_until_ready(l2)
    t0 = time.perf_counter()
    toks = [tok]
    c = cache
    for i in range(gen):
        logits, c = step(p, c, toks[-1], jnp.int32(prompt + i))
        toks.append(jnp.argmax(logits, -1)[:, None].astype(jnp.int32))
    jax.block_until_ready(toks[-1])
    dt = time.perf_counter() - t0
    return np.concatenate([np.asarray(t) for t in toks[1:]], axis=1), dt


def run(alphas=(0.4, 0.2), q: int = 4, batch: int = 8, prompt: int = 16, gen: int = 16):
    cfg, model, params, bt = _setup(batch, prompt)

    ref, t_dense = _bench(model, params, bt, prompt, gen)
    rows = [dict(name="dense", alpha=0.0, seconds=t_dense, tok_s=batch * gen / t_dense, agree=1.0, ratio=1.0)]
    for alpha in alphas:
        cp, _, rep = compress_tree(
            params, CompressionPolicy(alpha=alpha, q=q, min_dim=32), jax.random.PRNGKey(1)
        )
        out, dt = _bench(model, cp, bt, prompt, gen)
        rows.append(
            dict(
                name=f"alpha={alpha}",
                alpha=alpha,
                seconds=dt,
                tok_s=batch * gen / dt,
                agree=float((out == ref).mean()),
                ratio=rep.ratio,
            )
        )
    return rows


def _hits_summary() -> str:
    """'path=count' pairs for the lowrank op, plus dense-linear sites."""
    agg = dispatch.counters_by_path()
    parts = [
        f"{path}={n}" for (op, path), n in sorted(agg.items()) if op == "lowrank_matmul"
    ]
    dense_n = sum(n for (op, _), n in agg.items() if op == "dense")
    if dense_n:
        parts.append(f"dense_linear={dense_n}")
    return "|".join(parts) if parts else "none"


def run_backend_sweep(
    alpha: float = 0.4, q: int = 4, batch: int = 4, prompt: int = 16, gen: int = 8
):
    """One row per dispatch backend for the SAME compressed checkpoint.

    Each backend gets a fresh trace (fresh jit closures), so the dispatcher's
    trace-time counters describe exactly the paths in that backend's program.
    """
    cfg, model, params, bt = _setup(batch, prompt)
    cp, _, rep = compress_tree(
        params, CompressionPolicy(alpha=alpha, q=q, min_dim=32), jax.random.PRNGKey(1)
    )
    rows = []
    ref = None
    for backend in BACKENDS:
        dispatch.reset_counters()
        with use_dispatch(DispatchConfig(backend=backend)):
            out, dt = _bench(model, cp, bt, prompt, gen)
        if ref is None:
            ref = out
        rows.append(
            dict(
                name=f"backend={backend}",
                alpha=alpha,
                seconds=dt,
                tok_s=batch * gen / dt,
                agree=float((out == ref).mean()),
                ratio=rep.ratio,
                hits=_hits_summary(),
            )
        )
    return rows


def emit_csv(rows):
    for r in rows:
        extra = f";hits={r['hits']}" if "hits" in r else ""
        print(
            f"serving/{r['name']},{r['seconds']*1e6:.0f},"
            f"tok_s={r['tok_s']:.1f};agree={r['agree']:.3f};ratio={r['ratio']:.3f}"
            f"{extra}"
        )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--sweep-backends",
        action="store_true",
        help="run the compressed model once per kernel backend and report "
        "per-backend throughput + dispatcher hit counts",
    )
    args = ap.parse_args()
    emit_csv(run_backend_sweep() if args.sweep_backends else run())
