"""Host-side data pipeline: device placement + background prefetch.

On a real multi-host pod each process feeds only its addressable shard of the
("pod","data")-sharded batch; ``shard_batch`` builds the global-shape arrays
with the right NamedSharding (single-controller semantics in this container,
jax.make_array_from_process_local_data on real fleets).
"""

from __future__ import annotations

import queue
import threading

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["shard_batch", "Prefetcher", "batch_sharding"]


def batch_sharding(rules, ndim_map: dict):
    """NamedShardings for a batch dict: batch dim over ("pod","data")."""
    out = {}
    for name, ndim in ndim_map.items():
        spec = ("batch",) + (None,) * (ndim - 1)
        out[name] = spec
    return out


def shard_batch(batch: dict, rules) -> dict:
    out = {}
    for name, arr in batch.items():
        spec = rules.spec(("batch",) + (None,) * (arr.ndim - 1), arr.shape)
        out[name] = jax.device_put(arr, NamedSharding(rules.mesh, spec))
    return out


class Prefetcher:
    """Background-thread prefetch of host batches onto devices."""

    def __init__(self, it, rules=None, depth: int = 2):
        self.it, self.rules = it, rules
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _worker(self):
        try:
            for b in self.it:
                if self._stop.is_set():
                    return
                if self.rules is not None:
                    b = shard_batch(b, self.rules)
                else:
                    b = jax.tree_util.tree_map(jax.numpy.asarray, b)
                self.q.put(b)
        except Exception as e:  # surface worker errors to the consumer
            self.q.put(e)
        self.q.put(StopIteration())

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if isinstance(item, StopIteration):
            raise item
        if isinstance(item, Exception):
            raise item
        return item

    def close(self):
        self._stop.set()
