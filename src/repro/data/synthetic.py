"""Deterministic synthetic data: token streams + modality-frontend stubs.

Determinism contract: batch contents are a pure function of (seed, step,
shard), so an elastic restart at step k on a different host/mesh layout
reproduces the exact same global batch — this is what makes
checkpoint-restart bitwise-reproducible in tests.
"""

from __future__ import annotations

import numpy as np

__all__ = ["SyntheticLM", "markov_tokens", "modality_extras", "classification_dataset"]


def modality_extras(cfg, rng) -> dict:
    """Per-REQUEST (unbatched) modality-frontend stubs for serving: the
    extra model inputs one request of this arch family needs, drawn from
    ``rng``.  Shared by the serving benchmark and the engine parity tests so
    both build identical request payloads."""
    e = {}
    if cfg.family == "vlm":
        e["image_embed"] = rng.standard_normal(
            (cfg.n_image_tokens, cfg.d_model)
        ).astype(np.float32)
    if cfg.family == "audio":
        e["frames"] = rng.standard_normal(
            (cfg.n_audio_frames, cfg.d_model)
        ).astype(np.float32)
    return e


def markov_tokens(seed: int, step: int, batch: int, seq: int, vocab: int) -> np.ndarray:
    """Cheap structured (non-uniform) token stream: a hashed Markov-ish chain
    so the model has something learnable; pure function of (seed, step)."""
    rng = np.random.default_rng(np.uint64(seed) * np.uint64(1_000_003) + np.uint64(step))
    base = rng.integers(0, vocab, size=(batch, 1), dtype=np.int64)
    steps = rng.integers(1, 7, size=(batch, seq), dtype=np.int64)
    toks = (base + np.cumsum(steps, axis=1)) % vocab
    return toks.astype(np.int32)


class SyntheticLM:
    """Iterator of LM batches matching ``batch_spec_template``."""

    def __init__(self, cfg, batch: int, seq: int, *, kind: str = "train", seed: int = 0):
        self.cfg, self.batch, self.seq, self.kind, self.seed = cfg, batch, seq, kind, seed
        self.step = 0

    def at_step(self, step: int) -> dict:
        cfg = self.cfg
        toks = markov_tokens(self.seed, step, self.batch, self.seq + 1, cfg.vocab)
        out = {"tokens": toks[:, :-1]}
        if self.kind == "train":
            out["targets"] = toks[:, 1:]
        rng = np.random.default_rng(np.uint64(self.seed) * np.uint64(7919) + np.uint64(step))
        if cfg.family == "vlm":
            out["image_embed"] = rng.standard_normal(
                (self.batch, cfg.n_image_tokens, cfg.d_model), dtype=np.float32
            )
        if cfg.family == "audio":
            out["frames"] = rng.standard_normal(
                (self.batch, cfg.n_audio_frames, cfg.d_model), dtype=np.float32
            )
        return out

    def __iter__(self):
        return self

    def __next__(self):
        b = self.at_step(self.step)
        self.step += 1
        return b


def classification_dataset(seed: int, n: int, dim: int, n_classes: int, *, margin: float = 1.5):
    """Synthetic 10-class dataset for the Table-4.1 reproduction: Gaussian
    clusters with controlled separation (margin) in `dim` dims.  Returns
    (X (n,dim) fp32, y (n,) int32, class_means)."""
    rng = np.random.default_rng(seed)
    means = rng.standard_normal((n_classes, dim)).astype(np.float32) * margin
    y = rng.integers(0, n_classes, size=(n,))
    X = means[y] + rng.standard_normal((n, dim)).astype(np.float32)
    return X.astype(np.float32), y.astype(np.int32), means
