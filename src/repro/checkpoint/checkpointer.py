"""Mesh-agnostic checkpointing: msgpack manifest + zstd-compressed npy leaves.

Design goals (fault tolerance at fleet scale):
  * ATOMIC    — writes land in ``step_<n>.tmp`` and are renamed only after the
    manifest (with per-leaf checksums) is fsync'd; a crash mid-save never
    corrupts the latest valid checkpoint.
  * ELASTIC   — leaves are saved in logical (unsharded) layout with their
    PartitionSpec recorded as metadata; ``restore`` re-shards onto whatever
    mesh the restarted job has (256 chips, 512 chips, 1 CPU — all valid).
  * ASYNC     — ``save_async`` snapshots to host memory then writes on a
    background thread, so the train loop blocks only for device->host copies.
  * SELF-DESCRIBING — tree structure, dtypes, shapes, step and a framework
    version tag all live in the manifest; restore validates checksums.

On real multi-host fleets each process would write only its addressable
shards (process-local npy files keyed by shard index); the single-controller
container writes full leaves.  The manifest format already carries the spec
so the multi-host writer is a drop-in.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:  # optional dep: fall back to stdlib zlib where zstandard is absent
    import zstandard
except ImportError:  # pragma: no cover
    zstandard = None

__all__ = ["Checkpointer", "latest_step", "save", "restore"]

_FORMAT_VERSION = 2


class _Codec:
    """zstd when available, zlib otherwise; recorded in the manifest so a
    checkpoint restores correctly regardless of which env wrote it."""

    @staticmethod
    def default() -> str:
        return "zstd" if zstandard is not None else "zlib"

    @staticmethod
    def compress(raw: bytes, codec: str) -> bytes:
        if codec == "zstd":
            if zstandard is None:
                raise ImportError("checkpoint written with zstd but zstandard not installed")
            return zstandard.ZstdCompressor(level=3).compress(raw)
        import zlib

        return zlib.compress(raw, 3)

    @staticmethod
    def decompress(blob: bytes, codec: str) -> bytes:
        if codec == "zstd":
            if zstandard is None:
                raise ImportError("checkpoint written with zstd but zstandard not installed")
            return zstandard.ZstdDecompressor().decompress(blob)
        import zlib

        return zlib.decompress(blob)


def _leaf_files(flat):
    return [f"leaf_{i:05d}.npy.zst" for i in range(len(flat))]


def _path_str(path):
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def save(state: Any, directory: str, step: int, *, extra: Optional[dict] = None):
    """Blocking atomic save of a pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(state)
    host = [np.asarray(jax.device_get(leaf)) for _, leaf in flat]
    _write(host, [_path_str(p) for p, _ in flat], directory, step, extra or {})


def _write(host_leaves, paths, directory, step, extra):
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"step_{step}.tmp")
    final = os.path.join(directory, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    codec = _Codec.default()
    manifest = {
        # zstd manifests stay at version 2 (readable by pre-codec readers);
        # zlib leaves are NOT, so the version bump makes the incompatibility
        # explicit instead of an opaque zstd frame error downstream.
        "version": _FORMAT_VERSION if codec == "zstd" else _FORMAT_VERSION + 1,
        "step": step,
        "extra": extra,
        "codec": codec,
        "leaves": [],
    }
    for i, (arr, path) in enumerate(zip(host_leaves, paths)):
        fname = f"leaf_{i:05d}.npy.zst"
        raw = arr.tobytes()
        digest = hashlib.sha256(raw).hexdigest()[:16]
        with open(os.path.join(tmp, fname), "wb") as f:
            f.write(_Codec.compress(raw, codec))
        manifest["leaves"].append(
            {
                "path": path,
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "sha": digest,
            }
        )
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # prune interrupted saves
    for d in os.listdir(directory):
        if d.endswith(".tmp"):
            shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, d, "manifest.msgpack")):
                steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(
    template: Any,
    directory: str,
    *,
    step: Optional[int] = None,
    shardings: Any = None,
    validate: bool = True,
):
    """Restore into the structure of ``template`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional parallel pytree of
    NamedShardings — this is the ELASTIC path: the mesh may differ from the
    one that saved."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint found in {directory}")
    d = os.path.join(directory, f"step_{step}")
    with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    version = manifest.get("version", 1)
    if version not in (1, 2, 3):
        raise ValueError(
            f"checkpoint format version {version} not supported by this reader "
            f"(known: 1-3)"
        )
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    if len(flat) != len(manifest["leaves"]):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, template has {len(flat)}"
        )
    by_path = {m["path"]: m for m in manifest["leaves"]}
    codec = manifest.get("codec", "zstd")  # pre-codec checkpoints were zstd
    sh_flat = (
        treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(flat)
    )
    out = []
    for (path, leaf), sh in zip(flat, sh_flat):
        meta = by_path[_path_str(path)]
        with open(os.path.join(d, meta["file"]), "rb") as f:
            raw = _Codec.decompress(f.read(), codec)
        if validate and hashlib.sha256(raw).hexdigest()[:16] != meta["sha"]:
            raise IOError(f"checksum mismatch for {meta['path']}")
        arr = np.frombuffer(raw, dtype=np.dtype(meta["dtype"])).reshape(meta["shape"])
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest


@dataclasses.dataclass
class Checkpointer:
    """Async checkpoint manager with retention."""

    directory: str
    keep: int = 3

    def __post_init__(self):
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save_async(self, state: Any, step: int, *, extra: Optional[dict] = None):
        self.wait()  # one outstanding save at a time
        flat, _ = jax.tree_util.tree_flatten_with_path(state)
        host = [np.asarray(jax.device_get(leaf)) for _, leaf in flat]
        paths = [_path_str(p) for p, _ in flat]

        def work():
            try:
                _write(host, paths, self.directory, step, extra or {})
                self._prune()
            except BaseException as e:  # propagated on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _prune(self):
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"), ignore_errors=True)

    def restore_latest(self, template, shardings=None):
        return restore(template, self.directory, shardings=shardings)
