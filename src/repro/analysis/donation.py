"""donation-safety: reads of donated bindings after a donating call.

``jax.jit(..., donate_argnums=...)`` lets XLA alias the donated buffers in
place — the caller's binding is INVALID the moment the call runs.  The
engine leans on this everywhere (the KV pool is donated through the fused
block, the chunk-prefill program, and the COW fork), so the contract is:
**every donated argument binding must be rebound from the call's result
(or never touched again).**

The pass resolves three donor shapes seen in this repo:

* direct:      ``f = jax.jit(fn, donate_argnums=(0,))``
* attribute:   ``self._chunk_jit = jax.jit(..., donate_argnums=(1,))``
* factory:     a function that *returns* a locally-built donating jit
               (``Engine._fused_fn``); assigning its result
               (``fused = self._fused_fn(greedy)``) makes the target a
               donor with the same indices.

At each donor call site, for every donated positional argument that is a
plain name or attribute (fresh temporaries like ``jnp.asarray(x)`` cannot
be re-read and are skipped):

* if the call statement itself rebinds the binding from the result
  (``x, self.cache = f(params, self.cache, ...)``), the site is safe;
* otherwise any later *read* of the binding in the same function — before
  a rebinding statement — is flagged, and a donating call inside a loop
  with no rebind at the call is flagged too (the next iteration reads the
  donated value).

Scope: per-function, straight-line statement order (the same
approximation the engine's code actually relies on).  Aliases and
cross-method reads are out of scope — documented, not detected.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from repro.analysis.core import Diagnostic, SourceFile

PASS_ID = "donation-safety"

__all__ = ["PASS_ID", "check"]


def _is_jit_func(node: ast.expr) -> bool:
    if isinstance(node, ast.Name):
        return node.id == "jit"
    if isinstance(node, ast.Attribute):
        return node.attr == "jit"
    return False


def _donate_indices(call: ast.Call) -> Optional[Tuple[int, ...]]:
    if not isinstance(call, ast.Call) or not _is_jit_func(call.func):
        return None
    for kw in call.keywords:
        if kw.arg in ("donate_argnums", "donate_argnames"):
            v = kw.value
            if isinstance(v, (ast.Tuple, ast.List)):
                idxs = tuple(
                    e.value for e in v.elts
                    if isinstance(e, ast.Constant) and isinstance(e.value, int)
                )
                return idxs or None
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                return (v.value,)
            return None
    return None


def _binding_key(node: ast.expr) -> Optional[str]:
    """Stable key for a rebindable binding: a bare name or a dotted
    attribute chain of names (``self.cache``).  Anything else (calls,
    subscripts, constants) is a fresh temporary — not trackable, and not
    re-readable, so not a donation hazard."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _binding_key(node.value)
        return None if base is None else f"{base}.{node.attr}"
    return None


def _assigned_keys(stmt: ast.stmt) -> List[str]:
    """Binding keys stored by an assignment-like statement."""
    targets: List[ast.expr] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
        targets = [stmt.target]
    elif isinstance(stmt, (ast.For, ast.AsyncFor)):
        targets = [stmt.target]
    elif isinstance(stmt, (ast.With, ast.AsyncWith)):
        targets = [i.optional_vars for i in stmt.items if i.optional_vars]
    out: List[str] = []
    for t in targets:
        for node in ast.walk(t):
            key = _binding_key(node)
            if key is not None and isinstance(
                getattr(node, "ctx", None), ast.Store
            ):
                out.append(key)
    return out


def _reads_in(node: ast.AST, key: str) -> List[int]:
    """Line numbers where ``key`` is read (Load ctx) inside ``node``."""
    out = []
    for n in ast.walk(node):
        if isinstance(n, (ast.Name, ast.Attribute)) and isinstance(
            n.ctx, ast.Load
        ):
            if _binding_key(n) == key:
                out.append(n.lineno)
    return out


class _DonorTable:
    """Donor names/attrs and their donated positional indices."""

    def __init__(self):
        self.by_name: Dict[str, Tuple[int, ...]] = {}
        self.by_attr: Dict[str, Tuple[int, ...]] = {}
        self.factories: Dict[str, Tuple[int, ...]] = {}

    def lookup(self, func: ast.expr) -> Optional[Tuple[int, ...]]:
        if isinstance(func, ast.Name):
            return self.by_name.get(func.id)
        if isinstance(func, ast.Attribute):
            return self.by_attr.get(func.attr)
        return None

    def factory_of(self, func: ast.expr) -> Optional[Tuple[int, ...]]:
        if isinstance(func, ast.Name):
            return self.factories.get(func.id)
        if isinstance(func, ast.Attribute):
            return self.factories.get(func.attr)
        return None


def _collect_donors(tree: ast.Module) -> _DonorTable:
    table = _DonorTable()
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            idxs = _donate_indices(node.value)
            if idxs is None:
                continue
            for t in node.targets:
                if isinstance(t, ast.Name):
                    table.by_name[t.id] = idxs
                elif isinstance(t, ast.Attribute):
                    table.by_attr[t.attr] = idxs
    # factories: a function whose return value is a locally-assigned donor
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            local: Dict[str, Tuple[int, ...]] = {}
            for sub in ast.walk(node):
                if isinstance(sub, ast.Assign) and isinstance(
                    sub.value, ast.Call
                ):
                    idxs = _donate_indices(sub.value)
                    if idxs is not None:
                        for t in sub.targets:
                            if isinstance(t, ast.Name):
                                local[t.id] = idxs
            for sub in ast.walk(node):
                if (
                    isinstance(sub, ast.Return)
                    and isinstance(sub.value, ast.Name)
                    and sub.value.id in local
                ):
                    table.factories[node.name] = local[sub.value.id]
    return table


def _find_donor_call(
    stmt: ast.stmt, table: _DonorTable, local: Dict[str, Tuple[int, ...]]
) -> Optional[Tuple[ast.Call, Tuple[int, ...]]]:
    for node in ast.walk(stmt):
        if isinstance(node, ast.Call):
            idxs = table.lookup(node.func)
            if idxs is None and isinstance(node.func, ast.Name):
                idxs = local.get(node.func.id)
            if idxs is not None:
                return node, idxs
    return None


def check(src: SourceFile) -> List[Diagnostic]:
    table = _collect_donors(src.tree)
    diags: List[Diagnostic] = []
    for fn in ast.walk(src.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        # donors bound locally in this function (incl. factory results)
        local: Dict[str, Tuple[int, ...]] = {}
        for sub in ast.walk(fn):
            if isinstance(sub, ast.Assign) and isinstance(sub.value, ast.Call):
                idxs = _donate_indices(sub.value)
                if idxs is None:
                    idxs = table.factory_of(sub.value.func)
                if idxs is not None:
                    for t in sub.targets:
                        if isinstance(t, ast.Name):
                            local[t.id] = idxs
        diags.extend(_check_function(src, fn, table, local))
    return diags


def _enclosing_loops(fn: ast.AST, stmt: ast.stmt) -> bool:
    """Is ``stmt`` (by line range) inside a loop of ``fn``?"""
    for node in ast.walk(fn):
        if isinstance(node, (ast.For, ast.While, ast.AsyncFor)):
            if node is stmt:
                continue
            if (
                node.lineno <= stmt.lineno
                and (node.end_lineno or node.lineno) >= (stmt.end_lineno or stmt.lineno)
            ):
                return True
    return False


def _check_function(
    src: SourceFile,
    fn: ast.AST,
    table: _DonorTable,
    local: Dict[str, Tuple[int, ...]],
) -> List[Diagnostic]:
    diags: List[Diagnostic] = []
    stmts = [n for n in ast.walk(fn) if isinstance(n, ast.stmt)]
    stmts.sort(key=lambda s: (s.lineno, -(s.end_lineno or s.lineno)))
    for stmt in stmts:
        if not isinstance(
            stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign, ast.Expr, ast.Return)
        ):
            continue
        found = _find_donor_call(stmt, table, local)
        if found is None:
            continue
        call, idxs = found
        rebound = set(_assigned_keys(stmt))
        for i in idxs:
            if i >= len(call.args):
                continue
            key = _binding_key(call.args[i])
            if key is None:
                continue  # fresh temporary (call/subscript/constant)
            if key in rebound:
                continue  # rebinding at the call statement: the contract
            call_end = stmt.end_lineno or stmt.lineno
            # 1) later reads before any rebinding (line-ordered scan)
            rebind_line = None
            for later in stmts:
                if later.lineno <= call_end or later is stmt:
                    continue
                if key in _assigned_keys(later) and not _reads_in(
                    later.value if isinstance(later, ast.Assign) else later, key
                ):
                    rebind_line = later.lineno
                    break
            for later in stmts:
                if later.lineno <= call_end:
                    continue
                if rebind_line is not None and later.lineno >= rebind_line:
                    break
                reads = [ln for ln in _reads_in(later, key) if ln > call_end]
                if reads:
                    diags.append(
                        Diagnostic(
                            PASS_ID,
                            src.path,
                            reads[0],
                            f"`{key}` read after being donated at line "
                            f"{call.lineno} (donate_argnums index {i}); "
                            f"rebind it from the call result",
                        )
                    )
                    break
            # 2) donation inside a loop with no rebind at the call: the
            #    next iteration re-reads the donated binding
            if _enclosing_loops(fn, stmt):
                diags.append(
                    Diagnostic(
                        PASS_ID,
                        src.path,
                        call.lineno,
                        f"`{key}` donated (index {i}) inside a loop without "
                        f"rebinding at the call — the next iteration reads "
                        f"a donated buffer",
                    )
                )
    # dedupe (a read can be reached from several stmt walks)
    seen = set()
    out = []
    for d in diags:
        k = (d.line, d.message)
        if k not in seen:
            seen.add(k)
            out.append(d)
    return out
