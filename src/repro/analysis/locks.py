"""lock-discipline: guarded-attribute access must hold the owning lock.

Shared state is annotated at its definition site::

    self.inbox = []          # guarded by: inbox_lock
    _COUNTS = Counter()      # guarded by: _COUNTS_LOCK

The pass then checks every access (read or write) of an annotated
attribute anywhere in the module.  An access is OK when it is:

* lexically inside ``with <lockname>:`` (matched by the lock's *leaf*
  name: ``with self._lock:``, ``with rep.inbox_lock:``, ``with
  _COUNTS_LOCK:`` all match their respective annotations — same-named
  locks on different objects are treated as may-alias, which is exactly
  the convention this repo follows);
* inside ``__init__`` (single-threaded construction) or a
  ``*_locked``-suffixed helper (the documented caller-holds-it
  convention);
* inside a function *dominated* by the lock: every intra-module call
  site (bare ``name(...)`` or ``self.name(...)``) is itself under the
  lock — lexically, via the caller's own domination, or from an exempt
  function.  This is a fixpoint over the intra-module call graph, so
  ``Cluster._fail_over`` (only ever called with ``self._lock`` held)
  passes without renaming;
* at module level (import-time initialization).

Everything else is flagged with the attribute, the missing lock, and the
enclosing function.  The annotation parser (:func:`parse_guards`) is
shared with :mod:`repro.analysis.sanitize`, which arms the same
annotations as runtime descriptors under ``REPRO_SANITIZE=1``.
"""

from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.core import Diagnostic, SourceFile

PASS_ID = "lock-discipline"

__all__ = ["PASS_ID", "check", "parse_guards", "GUARD_RE"]

GUARD_RE = re.compile(r"#\s*guarded by:\s*([A-Za-z_][A-Za-z0-9_]*)")
_ATTR_DEF_RE = re.compile(r"^\s*self\.([A-Za-z_][A-Za-z0-9_]*)\s*[:=\[]")
_FIELD_DEF_RE = re.compile(r"^\s+([A-Za-z_][A-Za-z0-9_]*)\s*[:=]")
_GLOBAL_DEF_RE = re.compile(r"^([A-Za-z_][A-Za-z0-9_]*)\s*[:=]")


def parse_guards(lines: Sequence[str]) -> Tuple[Dict[str, str], Dict[str, str]]:
    """Extract ``# guarded by:`` annotations from source lines.

    Returns ``(attr_guards, global_guards)``: attribute-name -> lock-name
    for ``self.X = ...`` definition lines and indented class-body /
    dataclass field lines (``fired: Dict[...] = field(...)``), and
    global-name -> lock-name for column-0 ``X = ...`` lines.  Shared with
    the runtime sanitizer, which calls this on
    ``inspect.getsource(cls)`` lines.
    """
    attr_guards: Dict[str, str] = {}
    global_guards: Dict[str, str] = {}
    for line in lines:
        m = GUARD_RE.search(line)
        if not m:
            continue
        lock = m.group(1)
        am = _ATTR_DEF_RE.match(line)
        if am:
            attr_guards[am.group(1)] = lock
            continue
        gm = _GLOBAL_DEF_RE.match(line)
        if gm:
            global_guards[gm.group(1)] = lock
            continue
        fm = _FIELD_DEF_RE.match(line)
        if fm:
            attr_guards[fm.group(1)] = lock
    return attr_guards, global_guards


def _leaf(node: ast.expr) -> Optional[str]:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


def _with_lock_ranges(fn: ast.AST) -> List[Tuple[str, int, int]]:
    """(lockname, first_line, last_line) for every ``with`` in ``fn``
    whose context expression's leaf name looks like a lock."""
    out: List[Tuple[str, int, int]] = []
    for node in ast.walk(fn):
        if isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                name = _leaf(item.context_expr)
                if name is not None:
                    out.append(
                        (name, node.lineno, node.end_lineno or node.lineno)
                    )
    return out


def _is_exempt(fn: ast.AST) -> bool:
    name = getattr(fn, "name", "")
    return name == "__init__" or name.endswith("_locked")


class _FnInfo:
    def __init__(self, fn: ast.AST):
        self.fn = fn
        self.name = fn.name
        self.ranges = _with_lock_ranges(fn)
        self.exempt = _is_exempt(fn)
        # locks held for my entire body as established by my callers
        # (fixpoint; optimistic start, shrinks monotonically)
        self.entry_held: Optional[Set[str]] = None

    def lexical_locks(self, line: int) -> Set[str]:
        return {
            name for name, lo, hi in self.ranges if lo <= line <= hi
        }


def _functions(tree: ast.Module) -> List[_FnInfo]:
    return [
        _FnInfo(n)
        for n in ast.walk(tree)
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
    ]


def _innermost_fn(fns: List[_FnInfo], line: int) -> Optional[_FnInfo]:
    best: Optional[_FnInfo] = None
    best_span = None
    for info in fns:
        lo = info.fn.lineno
        hi = info.fn.end_lineno or lo
        if lo <= line <= hi:
            span = hi - lo
            if best_span is None or span < best_span:
                best, best_span = info, span
    return best


def _call_sites(
    tree: ast.Module, fns: List[_FnInfo]
) -> Dict[str, List[Tuple[Optional[_FnInfo], int]]]:
    """fn-name -> [(caller_info_or_None_for_module_level, call_line)]."""
    names = {f.name for f in fns}
    sites: Dict[str, List[Tuple[Optional[_FnInfo], int]]] = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        callee: Optional[str] = None
        if isinstance(f, ast.Name) and f.id in names:
            callee = f.id
        elif (
            isinstance(f, ast.Attribute)
            and isinstance(f.value, ast.Name)
            and f.value.id == "self"
            and f.attr in names
        ):
            callee = f.attr
        if callee is None:
            continue
        caller = _innermost_fn(fns, node.lineno)
        if caller is not None and caller.name == callee:
            continue  # recursion: a self-call can't establish the lock
        sites.setdefault(callee, []).append((caller, node.lineno))
    return sites


def _solve_domination(
    fns: List[_FnInfo],
    sites: Dict[str, List[Tuple[Optional[_FnInfo], int]]],
    all_locks: Set[str],
) -> None:
    """Fixpoint: entry_held[F] = ∩ over call sites of locks provably held
    at the site.  Functions with no intra-module call sites are entry
    points (threads, tests, CLI) — nothing is held on entry."""
    by_name: Dict[str, List[_FnInfo]] = {}
    for f in fns:
        by_name.setdefault(f.name, []).append(f)
    for f in fns:
        f.entry_held = set(all_locks) if sites.get(f.name) else set()
    changed = True
    while changed:
        changed = False
        for f in fns:
            site_list = sites.get(f.name)
            if not site_list:
                continue
            held = set(all_locks)
            for caller, line in site_list:
                if caller is None:
                    here: Set[str] = set()  # module-level call
                elif caller.exempt:
                    here = set(all_locks)
                else:
                    here = caller.lexical_locks(line) | (
                        caller.entry_held or set()
                    )
                held &= here
                if not held:
                    break
            if held != f.entry_held:
                f.entry_held = held
                changed = True


def check(src: SourceFile) -> List[Diagnostic]:
    attr_guards, global_guards = parse_guards(src.lines)
    if not attr_guards and not global_guards:
        return []
    fns = _functions(src.tree)
    sites = _call_sites(src.tree, fns)
    all_locks = set(attr_guards.values()) | set(global_guards.values())
    _solve_domination(fns, sites, all_locks)

    diags: List[Diagnostic] = []

    def flag(line: int, what: str, lock: str, where: str) -> None:
        diags.append(
            Diagnostic(
                PASS_ID,
                src.path,
                line,
                f"`{what}` accessed without holding `{lock}` "
                f"(in `{where}`) — wrap in `with {lock}:` or move into a "
                f"`_locked` helper",
            )
        )

    for node in ast.walk(src.tree):
        name: Optional[str] = None
        lock: Optional[str] = None
        if isinstance(node, ast.Attribute) and node.attr in attr_guards:
            name, lock = node.attr, attr_guards[node.attr]
            # the lock object itself (`with x.inbox_lock:`) is not data
            if name == lock:
                continue
        elif isinstance(node, ast.Name) and node.id in global_guards:
            name, lock = node.id, global_guards[node.id]
        else:
            continue
        fn = _innermost_fn(fns, node.lineno)
        if fn is None:
            continue  # module level: import-time init
        if fn.exempt:
            continue
        if lock in fn.lexical_locks(node.lineno):
            continue
        if lock in (fn.entry_held or set()):
            continue
        what = f"self.{name}" if isinstance(node, ast.Attribute) else name
        flag(node.lineno, what, lock, fn.name)

    # dedupe per (line, message): AugAssign targets appear once anyway,
    # but `x.attr` inside a single line can be walked via several parents
    seen = set()
    out = []
    for d in diags:
        k = (d.line, d.message)
        if k not in seen:
            seen.add(k)
            out.append(d)
    return out
