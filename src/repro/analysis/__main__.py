"""CLI entry point: ``python -m repro.analysis [--strict] [paths...]``.

Exit codes: 0 clean, 1 findings over baseline in ``--strict``, 2 internal
error (unparsable file or a crashed pass — never silently "clean").
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Optional, Sequence

from repro.analysis.core import (
    EXIT_CLEAN,
    EXIT_FINDINGS,
    EXIT_INTERNAL,
    counts_by_pass,
    diff_against_baseline,
    load_baseline,
    run_analysis,
)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Repo-invariant static analysis (donation-safety, "
        "jit-purity, lock-discipline, pallas-contract).",
    )
    ap.add_argument("paths", nargs="*", default=None,
                    help="files or directories to analyze (default: src)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any finding over the baseline")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write a machine-readable report (use '-' for stdout)")
    ap.add_argument("--baseline", metavar="PATH", default=None,
                    help="accepted per-pass finding counts to diff against")
    ap.add_argument("--pass", dest="passes", action="append", default=None,
                    metavar="PASS_ID", help="run only the named pass(es)")
    args = ap.parse_args(argv)

    paths = args.paths or ["src"]
    try:
        diags, errors, n_files = run_analysis(paths, pass_ids=args.passes)
    except Exception as e:  # driver bug: still honor the exit contract
        print(f"internal error: {type(e).__name__}: {e}", file=sys.stderr)
        return EXIT_INTERNAL

    baseline = {}
    if args.baseline:
        try:
            baseline = load_baseline(args.baseline)
        except (OSError, ValueError) as e:
            print(f"internal error: bad baseline {args.baseline}: {e}",
                  file=sys.stderr)
            return EXIT_INTERNAL

    for d in diags:
        print(d.format())
    for err in errors:
        print(f"INTERNAL: {err}", file=sys.stderr)

    counts = counts_by_pass(diags)
    over = diff_against_baseline(diags, baseline)
    summary = ", ".join(f"{k}={v}" for k, v in sorted(counts.items())) or "clean"
    print(f"{n_files} files, {len(diags)} finding(s): {summary}")
    if baseline and over:
        print("over baseline: "
              + ", ".join(f"{k}+{v}" for k, v in sorted(over.items())))

    if args.json:
        report = {
            "files": n_files,
            "counts": counts,
            "over_baseline": over,
            "internal_errors": errors,
            "diagnostics": [d.to_json() for d in diags],
        }
        text = json.dumps(report, indent=2, sort_keys=True)
        if args.json == "-":
            print(text)
        else:
            with open(args.json, "w", encoding="utf-8") as f:
                f.write(text + "\n")

    if errors:
        return EXIT_INTERNAL
    if args.strict:
        failing = over if baseline else counts
        if failing:
            return EXIT_FINDINGS
    return EXIT_CLEAN


if __name__ == "__main__":
    sys.exit(main())
