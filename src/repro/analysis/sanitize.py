"""Runtime lock sanitizer: the dynamic cross-check of lock-discipline.

Enabled with ``REPRO_SANITIZE=1``.  :func:`install` parses the same
``# guarded by: <lockname>`` annotations the static pass reads — straight
from ``inspect.getsource(cls)`` via the shared parser in
:mod:`repro.analysis.locks` — and replaces each guarded attribute with a
data descriptor.  Every get/set of a guarded attribute on an *armed*
instance asserts the owning lock is held by the current thread.

Design points that make this usable under the real cluster tests:

* **Record, don't raise.**  A raise inside a replica thread would be
  swallowed by the failover machinery (the replica is simply marked dead
  and the test still passes).  Violations are appended to a module-level
  list; ``check()`` raises with the full set, and the test suite calls
  it from an autouse fixture after every test.
* **Arming is explicit and per-instance.**  Construction is
  single-threaded and intentionally lock-free (``__init__`` is exempt in
  the static pass too); the cluster arms replicas when their threads
  start and disarms on ``close()``, so post-join teardown reads are
  clean by construction.
* **Lock identity by name, ownership by thread.**  The named lock
  attribute is looked up on the same instance and auto-wrapped in
  :class:`OwnedLock` (owner = thread ident, cleared *before* the inner
  release so a racing acquirer can never be misattributed).  A plain
  unwrapped lock degrades to ``locked()`` — weaker, but never a false
  positive for the holding thread.

Scope: instance attributes of the serving cluster classes.  Module-level
guarded globals (the dispatch counters) are covered statically only.
"""

from __future__ import annotations

import inspect
import os
import threading
import traceback
from typing import List, Optional, Type

from repro.analysis.locks import parse_guards

__all__ = [
    "OwnedLock",
    "enabled",
    "install",
    "uninstall",
    "maybe_install",
    "arm",
    "disarm",
    "violations",
    "reset",
    "check",
]

_VIOLATIONS: List[str] = []
_VIOLATIONS_LOCK = threading.Lock()


def enabled() -> bool:
    return os.environ.get("REPRO_SANITIZE", "") == "1"


class OwnedLock:
    """A lock wrapper that knows which thread holds it.

    Supports the subset of the ``threading.Lock`` API this repo uses
    (``with``, ``acquire``/``release``, ``locked``) plus
    :meth:`held_by_me`.
    """

    __slots__ = ("_inner", "_owner")

    def __init__(self, inner=None):
        self._inner = inner if inner is not None else threading.Lock()
        self._owner: Optional[int] = None

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        got = self._inner.acquire(blocking, timeout)
        if got:
            self._owner = threading.get_ident()
        return got

    def release(self) -> None:
        # clear BEFORE releasing: after release another thread may acquire
        # and set itself as owner; a late clear would erase that
        self._owner = None
        self._inner.release()

    def __enter__(self) -> "OwnedLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def locked(self) -> bool:
        return self._inner.locked()

    def held_by_me(self) -> bool:
        return self._owner == threading.get_ident()


def _caller() -> str:
    """file:line of the innermost frame outside this module."""
    here = __file__
    for frame in reversed(traceback.extract_stack()):
        if frame.filename != here:
            return f"{frame.filename}:{frame.lineno}"
    return "<unknown>"


def _record(msg: str) -> None:
    with _VIOLATIONS_LOCK:
        _VIOLATIONS.append(msg)


def violations() -> List[str]:
    with _VIOLATIONS_LOCK:
        return list(_VIOLATIONS)


def reset() -> None:
    with _VIOLATIONS_LOCK:
        _VIOLATIONS.clear()


def check() -> None:
    """Raise if any guarded access happened without its lock."""
    found = violations()
    if found:
        reset()
        detail = "\n  ".join(found[:20])
        more = f"\n  ... and {len(found) - 20} more" if len(found) > 20 else ""
        raise AssertionError(
            f"sanitizer recorded {len(found)} unguarded accesses:\n"
            f"  {detail}{more}"
        )


def _lock_held(inst, lockname: str) -> Optional[bool]:
    lock = inst.__dict__.get(lockname)
    if lock is None:
        lock = getattr(inst, lockname, None)
    if lock is None:
        return None  # lock not constructed yet (mid-__init__)
    if isinstance(lock, OwnedLock):
        return lock.held_by_me()
    locked = getattr(lock, "locked", None)
    if callable(locked):
        return bool(locked())  # plain lock: can't attribute ownership
    return None


class _GuardedAttr:
    """Data descriptor asserting the owning lock at get/set time."""

    def __init__(self, name: str, lockname: str):
        self.name = name
        self.lockname = lockname
        self.slot = f"_guarded__{name}"

    def _verify(self, inst, op: str) -> None:
        if not inst.__dict__.get("_sanitize_armed"):
            return
        held = _lock_held(inst, self.lockname)
        if held is False:
            _record(
                f"{type(inst).__name__}.{self.name} {op} without "
                f"`{self.lockname}` held "
                f"[thread {threading.current_thread().name}] at {_caller()}"
            )

    def __get__(self, inst, owner=None):
        if inst is None:
            return self
        try:
            value = inst.__dict__[self.slot]
        except KeyError:
            raise AttributeError(self.name) from None
        self._verify(inst, "read")
        return value

    def __set__(self, inst, value) -> None:
        self._verify(inst, "write")
        inst.__dict__[self.slot] = value

    def __delete__(self, inst) -> None:
        self._verify(inst, "delete")
        inst.__dict__.pop(self.slot, None)


class _LockAttr:
    """Descriptor that wraps assigned locks in :class:`OwnedLock`."""

    def __init__(self, name: str):
        self.name = name
        self.slot = f"_lockattr__{name}"

    def __get__(self, inst, owner=None):
        if inst is None:
            return self
        try:
            return inst.__dict__[self.slot]
        except KeyError:
            raise AttributeError(self.name) from None

    def __set__(self, inst, value) -> None:
        if value is not None and not isinstance(value, OwnedLock):
            value = OwnedLock(value)
        inst.__dict__[self.slot] = value


def arm(inst) -> None:
    """Start asserting on this instance's guarded attributes."""
    inst.__dict__["_sanitize_armed"] = True


def disarm(inst) -> None:
    inst.__dict__["_sanitize_armed"] = False


def install(cls: Type) -> int:
    """Wrap ``cls``'s annotated attributes in sanitizing descriptors.

    Returns the number of attributes wrapped.  Idempotent.
    """
    if cls.__dict__.get("_sanitize_installed"):
        return 0
    try:
        source = inspect.getsource(cls)
    except (OSError, TypeError):
        return 0
    attr_guards, _ = parse_guards(source.splitlines())
    if not attr_guards:
        return 0
    saved = {}
    for attr, lockname in attr_guards.items():
        saved[attr] = cls.__dict__.get(attr)
        setattr(cls, attr, _GuardedAttr(attr, lockname))
    for lockname in sorted(set(attr_guards.values())):
        if lockname not in attr_guards:  # a lock is never its own data
            saved.setdefault(lockname, cls.__dict__.get(lockname))
            setattr(cls, lockname, _LockAttr(lockname))
    cls._sanitize_installed = True
    cls._sanitize_saved = saved
    return len(attr_guards)


def uninstall(cls: Type) -> None:
    if not cls.__dict__.get("_sanitize_installed"):
        return
    saved = cls.__dict__.get("_sanitize_saved", {})
    for attr, prev in saved.items():
        if prev is None:
            try:
                delattr(cls, attr)
            except AttributeError:
                pass
        else:
            setattr(cls, attr, prev)
    cls._sanitize_installed = False
    cls._sanitize_saved = {}


def maybe_install(*classes: Type) -> None:
    """Install on each class iff ``REPRO_SANITIZE=1``.  Called at the
    bottom of ``serving/cluster.py`` so plain runs pay zero overhead."""
    if not enabled():
        return
    for cls in classes:
        install(cls)
