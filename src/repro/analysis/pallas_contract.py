"""pallas-contract: static VMEM estimate + grid/index_map/kernel arity.

For every ``pl.pallas_call(...)`` the pass checks two contracts:

**Arity.**  ``len(grid)`` index axes must match every BlockSpec
``index_map``'s parameter count, and the kernel's positional parameter
count must equal ``n_in_specs + n_out_specs + n_scratch`` (each ``+1``
per scalar-prefetch operand when the call uses
``pltpu.PrefetchScalarGridSpec(num_scalar_prefetch=...)``).  A
``functools.partial(kernel, kw=...)`` wrapper is unwrapped and its
keyword-bound names excluded from the positional count.

**VMEM.**  Per-program bytes = Σ over in/out BlockSpecs of
``prod(block_shape) * itemsize`` (``None`` dims squeeze to 1; itemsize
defaults to 4 — fp32-conservative) plus scratch ``pltpu.VMEM(shape,
dtype)`` allocations at their declared dtype.  The total must fit
``DEFAULT_VMEM_LIMIT`` — the same 14 MiB window the runtime
``fused_vmem_bytes`` budget models.

Block dims are integers only after resolution, done per enclosing
function with a shrink-only abstract interpretation:

* literal ints and module-level integer constants;
* keyword defaults (``def f(x, bq=128)`` — 128 bounds ``bq``);
* ``b = min(x, y)`` — the min of the *resolvable* operands is a valid
  upper bound even when the others are dynamic shapes;
* ``while X % b: b //= 2`` — shrink-only, keeps any existing bound;
* a module-level ``VMEM_ANALYSIS_BOUNDS = {"name": bound}`` dict for
  dims that are genuinely dynamic (head dims, page sizes): the kernel
  author's declared worst case, checked here so growing a model config
  past it forces a conscious edit.

A dim that still cannot be bounded is itself a finding — unless the
enclosing function performs its own runtime budget check (calls
``_check_fits`` / ``fits_fused``), which is the dynamic version of this
gate and takes precedence.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from repro.analysis.core import Diagnostic, SourceFile

PASS_ID = "pallas-contract"

__all__ = ["PASS_ID", "check", "DEFAULT_VMEM_LIMIT"]

# mirrors kernels/lowrank_matmul.DEFAULT_VMEM_LIMIT (the analysis package
# is stdlib-only and must not import kernel modules)
DEFAULT_VMEM_LIMIT = 14 * 2**20

_RUNTIME_CHECKS = {"_check_fits", "fits_fused"}

_ITEMSIZE = {
    "float64": 8, "int64": 8, "uint64": 8,
    "float32": 4, "int32": 4, "uint32": 4,
    "bfloat16": 2, "float16": 2, "int16": 2, "uint16": 2,
    "int8": 1, "uint8": 1, "bool_": 1, "bool": 1,
    "float8_e4m3fn": 1, "float8_e5m2": 1,
}
_DEFAULT_ITEMSIZE = 4


def _dotted_leaf(node: ast.expr) -> Optional[str]:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


class _Env:
    """Upper bounds for integer-valued names in one function scope."""

    def __init__(self, bounds: Dict[str, int]):
        self.bounds = dict(bounds)

    def resolve(self, node: ast.expr) -> Optional[int]:
        if isinstance(node, ast.Constant):
            if node.value is None:
                return 1  # squeezed BlockSpec dim
            if isinstance(node.value, int) and not isinstance(node.value, bool):
                return node.value
            return None
        if isinstance(node, ast.Name):
            return self.bounds.get(node.id)
        if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
            v = self.resolve(node.operand)
            return None if v is None else -v
        if isinstance(node, ast.BinOp):
            l, r = self.resolve(node.left), self.resolve(node.right)
            if l is None or r is None:
                return None
            try:
                if isinstance(node.op, ast.Add):
                    return l + r
                if isinstance(node.op, ast.Sub):
                    return l - r
                if isinstance(node.op, ast.Mult):
                    return l * r
                if isinstance(node.op, ast.FloorDiv):
                    return l // r if r else None
                if isinstance(node.op, ast.Mod):
                    return l % r if r else None
                if isinstance(node.op, ast.Pow):
                    return l ** r if 0 <= r < 64 else None
            except (OverflowError, ZeroDivisionError):
                return None
            return None
        if isinstance(node, ast.Call):
            name = _dotted_leaf(node.func)
            vals = [self.resolve(a) for a in node.args]
            if name == "min":
                known = [v for v in vals if v is not None]
                # min of the resolvable operands is a sound upper bound
                return min(known) if known else None
            if name == "max":
                if vals and all(v is not None for v in vals):
                    return max(vals)  # type: ignore[arg-type]
                return None
        return None


def _module_bounds(tree: ast.Module) -> Dict[str, int]:
    """Integer module constants + the VMEM_ANALYSIS_BOUNDS declaration."""
    env = _Env({})
    out: Dict[str, int] = {}
    for node in tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            t = node.targets[0]
            if not isinstance(t, ast.Name):
                continue
            if t.id == "VMEM_ANALYSIS_BOUNDS" and isinstance(node.value, ast.Dict):
                for k, v in zip(node.value.keys, node.value.values):
                    if (
                        isinstance(k, ast.Constant)
                        and isinstance(k.value, str)
                    ):
                        bound = env.resolve(v)
                        if bound is not None:
                            out[k.value] = bound
                continue
            env.bounds.update(out)
            val = env.resolve(node.value)
            if val is not None:
                out[t.id] = val
    return out


def _function_env(fn: ast.AST, module_bounds: Dict[str, int],
                  upto_line: int) -> _Env:
    env = _Env(module_bounds)
    args = fn.args
    defaults = args.defaults
    if defaults:
        for param, default in zip(args.args[-len(defaults):], defaults):
            v = env.resolve(default)
            if v is not None:
                env.bounds.setdefault(param.arg, v)
    for param, default in zip(args.kwonlyargs, args.kw_defaults):
        if default is not None:
            v = env.resolve(default)
            if v is not None:
                env.bounds.setdefault(param.arg, v)
    # straight-line abstract interpretation of assignments before the call
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and node.lineno < upto_line:
            if len(node.targets) != 1:
                continue
            t = node.targets[0]
            if isinstance(t, ast.Name):
                v = env.resolve(node.value)
                if v is not None:
                    env.bounds[t.id] = v
            elif isinstance(t, (ast.Tuple, ast.List)) and isinstance(
                node.value, (ast.Tuple, ast.List)
            ) and len(t.elts) == len(node.value.elts):
                # `bm_, bn_ = min(bm, M), min(bn, N)` — zip-resolve
                for sub_t, sub_v in zip(t.elts, node.value.elts):
                    if isinstance(sub_t, ast.Name):
                        v = env.resolve(sub_v)
                        if v is not None:
                            env.bounds[sub_t.id] = v
        # `while X % b: b //= 2` only shrinks b — existing bound stays valid
    return env


def _kw(call: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _resolve_name_assign(fn: ast.AST, name: str,
                         upto_line: int) -> Optional[ast.expr]:
    """Most recent `name = <expr>` in ``fn`` before ``upto_line``."""
    best: Optional[ast.expr] = None
    for node in ast.walk(fn):
        if (
            isinstance(node, ast.Assign)
            and node.lineno <= upto_line
            and len(node.targets) == 1
            and isinstance(node.targets[0], ast.Name)
            and node.targets[0].id == name
        ):
            best = node.value
    return best


def _spec_list(node: Optional[ast.expr]) -> Optional[List[ast.expr]]:
    if node is None:
        return None
    if isinstance(node, (ast.List, ast.Tuple)):
        return list(node.elts)
    return [node]  # single BlockSpec out_specs


def _block_dims(spec: ast.expr) -> Optional[List[ast.expr]]:
    """BlockSpec((d0, d1, ...), index_map) -> the dim expressions."""
    if not isinstance(spec, ast.Call):
        return None
    if _dotted_leaf(spec.func) != "BlockSpec":
        return None
    if not spec.args:
        return None
    shape = spec.args[0]
    if isinstance(shape, (ast.Tuple, ast.List)):
        return list(shape.elts)
    return None


def _index_map(spec: ast.expr) -> Optional[ast.Lambda]:
    if isinstance(spec, ast.Call) and len(spec.args) >= 2:
        im = spec.args[1]
        if isinstance(im, ast.Lambda):
            return im
    return None


def _lambda_arity(lam: ast.Lambda) -> int:
    a = lam.args
    return len(a.args) + len(a.posonlyargs)


def _kernel_positional_count(
    kernel_expr: ast.expr, tree: ast.Module
) -> Optional[Tuple[str, int]]:
    """(kernel_name, positional_param_count) with partial kwargs removed."""
    bound_kw: List[str] = []
    expr = kernel_expr
    if isinstance(expr, ast.Call):
        leaf = _dotted_leaf(expr.func)
        if leaf == "partial" and expr.args:
            bound_kw = [kw.arg for kw in expr.keywords if kw.arg]
            expr = expr.args[0]
        else:
            return None
    name = _dotted_leaf(expr)
    if name is None:
        return None
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) and node.name == name:
            a = node.args
            positional = [p.arg for p in a.posonlyargs + a.args]
            positional = [p for p in positional if p not in bound_kw]
            n_kw_defaults = 0
            # trailing positional params with defaults not bound by the
            # partial are still consumed positionally by pallas; but
            # params that are keyword-ONLY never are
            return name, len(positional) - n_kw_defaults
    return None


def _grid_len(grid_expr: Optional[ast.expr], fn: ast.AST,
              line: int) -> Optional[int]:
    if grid_expr is None:
        return None
    if isinstance(grid_expr, ast.Name):
        grid_expr = _resolve_name_assign(fn, grid_expr.id, line)
        if grid_expr is None:
            return None
    if isinstance(grid_expr, (ast.Tuple, ast.List)):
        return len(grid_expr.elts)
    return None


def _scratch_bytes(node: ast.expr, env: _Env) -> Optional[int]:
    """pltpu.VMEM((shape...), jnp.float32) -> bytes (None = unresolved)."""
    if not isinstance(node, ast.Call):
        return None
    if _dotted_leaf(node.func) not in ("VMEM", "SMEM"):
        return None
    if not node.args:
        return None
    shape = node.args[0]
    if not isinstance(shape, (ast.Tuple, ast.List)):
        return None
    total = 1
    for dim in shape.elts:
        v = env.resolve(dim)
        if v is None:
            return None
        total *= max(v, 1)
    itemsize = _DEFAULT_ITEMSIZE
    if len(node.args) >= 2:
        dt = _dotted_leaf(node.args[1])
        if dt in _ITEMSIZE:
            itemsize = _ITEMSIZE[dt]
    return total * itemsize


def _enclosing_fn(tree: ast.Module, line: int) -> Optional[ast.AST]:
    best = None
    best_span = None
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            lo, hi = node.lineno, node.end_lineno or node.lineno
            if lo <= line <= hi:
                span = hi - lo
                if best_span is None or span < best_span:
                    best, best_span = node, span
    return best


def _has_runtime_check(fn: ast.AST) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            leaf = _dotted_leaf(node.func)
            if leaf in _RUNTIME_CHECKS:
                return True
    return False


def check(src: SourceFile) -> List[Diagnostic]:
    module_bounds = _module_bounds(src.tree)
    diags: List[Diagnostic] = []

    for node in ast.walk(src.tree):
        if not isinstance(node, ast.Call):
            continue
        if _dotted_leaf(node.func) != "pallas_call":
            continue
        call = node
        fn = _enclosing_fn(src.tree, call.lineno)
        if fn is None:
            continue

        # ---- collect specs: direct kwargs or a PrefetchScalarGridSpec
        grid_expr = _kw(call, "grid")
        in_specs = _kw(call, "in_specs")
        out_specs = _kw(call, "out_specs")
        scratch = _kw(call, "scratch_shapes")
        n_prefetch = 0
        gs = _kw(call, "grid_spec")
        if gs is not None:
            if isinstance(gs, ast.Name):
                gs = _resolve_name_assign(fn, gs.id, call.lineno)
            if isinstance(gs, ast.Call):
                grid_expr = _kw(gs, "grid") or grid_expr
                in_specs = _kw(gs, "in_specs") or in_specs
                out_specs = _kw(gs, "out_specs") or out_specs
                scratch = _kw(gs, "scratch_shapes") or scratch
                if _dotted_leaf(gs.func) == "PrefetchScalarGridSpec":
                    np_expr = _kw(gs, "num_scalar_prefetch")
                    if isinstance(np_expr, ast.Constant) and isinstance(
                        np_expr.value, int
                    ):
                        n_prefetch = np_expr.value
                    else:
                        n_prefetch = 1
            else:
                continue  # unresolvable grid_spec: nothing to check

        in_list = _spec_list(in_specs) or []
        out_list = _spec_list(out_specs) or []
        scratch_list = _spec_list(scratch) or []

        # ---- arity: grid vs index_map
        n_grid = _grid_len(grid_expr, fn, call.lineno)
        if n_grid is not None:
            want = n_grid + n_prefetch
            for spec in in_list + out_list:
                im = _index_map(spec)
                if im is None:
                    continue
                got = _lambda_arity(im)
                if got != want:
                    diags.append(
                        Diagnostic(
                            PASS_ID, src.path, im.lineno,
                            f"index_map takes {got} args but grid has "
                            f"{n_grid} axes"
                            + (f" + {n_prefetch} scalar-prefetch operand(s)"
                               if n_prefetch else ""),
                        )
                    )

        # ---- arity: kernel signature vs operand count
        if call.args:
            resolved = _kernel_positional_count(call.args[0], src.tree)
            if resolved is not None and (in_list or out_list):
                kname, n_params = resolved
                want = n_prefetch + len(in_list) + len(out_list) + len(scratch_list)
                if n_params != want:
                    diags.append(
                        Diagnostic(
                            PASS_ID, src.path, call.lineno,
                            f"kernel `{kname}` takes {n_params} positional "
                            f"refs but pallas_call passes {want} "
                            f"({n_prefetch} prefetch + {len(in_list)} in + "
                            f"{len(out_list)} out + {len(scratch_list)} "
                            f"scratch)",
                        )
                    )

        # ---- VMEM budget
        if not in_list and not out_list:
            continue
        env = _function_env(fn, module_bounds, call.lineno)
        total = 0
        unresolved: List[str] = []
        for spec in in_list + out_list:
            dims = _block_dims(spec)
            if dims is None:
                continue  # non-BlockSpec entry (e.g. pl.ANY)
            block = 1
            for dim in dims:
                v = env.resolve(dim)
                if v is None:
                    try:
                        unresolved.append(ast.unparse(dim))
                    except Exception:
                        unresolved.append("<dim>")
                else:
                    block *= max(v, 1)
            total += block * _DEFAULT_ITEMSIZE
        for s in scratch_list:
            b = _scratch_bytes(s, env)
            if b is not None:
                total += b

        if unresolved:
            if not _has_runtime_check(fn):
                uniq = sorted(set(unresolved))
                diags.append(
                    Diagnostic(
                        PASS_ID, src.path, call.lineno,
                        f"cannot bound block dim(s) {', '.join(uniq)} for "
                        f"the VMEM estimate — add them to "
                        f"VMEM_ANALYSIS_BOUNDS or gate the call on a "
                        f"runtime budget check",
                    )
                )
            continue
        if total > DEFAULT_VMEM_LIMIT and not _has_runtime_check(fn):
            diags.append(
                Diagnostic(
                    PASS_ID, src.path, call.lineno,
                    f"static VMEM estimate {total} B exceeds the "
                    f"{DEFAULT_VMEM_LIMIT} B budget "
                    f"({total / 2**20:.1f} MiB > "
                    f"{DEFAULT_VMEM_LIMIT // 2**20} MiB) — shrink block "
                    f"shapes or add a runtime budget check",
                )
            )
    return diags
