"""Driver core for the static-analysis suite.

Owns the pieces every pass shares: parsed source files with suppression
comments, the :class:`Diagnostic` record, file collection, the pass
registry, baseline diffing, and the exit-code contract
(0 clean / 1 findings / 2 internal error).
"""

from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "Diagnostic",
    "SourceFile",
    "collect_files",
    "run_analysis",
    "load_baseline",
    "diff_against_baseline",
    "EXIT_CLEAN",
    "EXIT_FINDINGS",
    "EXIT_INTERNAL",
]

EXIT_CLEAN = 0
EXIT_FINDINGS = 1
EXIT_INTERNAL = 2

_SUPPRESS_RE = re.compile(r"#\s*repro-lint:\s*ignore\[([a-z\-*,\s]+)\]")


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding: ``path:line: [pass-id] message``."""

    pass_id: str
    path: str
    line: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: [{self.pass_id}] {self.message}"

    def to_json(self) -> dict:
        return {
            "pass": self.pass_id,
            "path": self.path,
            "line": self.line,
            "message": self.message,
        }


class SourceFile:
    """A parsed module plus its per-line suppression table.

    ``# repro-lint: ignore[pass-id]`` (comma-separated ids, or ``*``) on a
    line suppresses findings anchored to that line.
    """

    def __init__(self, path: str, text: str):
        self.path = path
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=path)
        self.suppressions: Dict[int, set] = {}
        for lineno, line in enumerate(self.lines, 1):
            m = _SUPPRESS_RE.search(line)
            if m:
                ids = {s.strip() for s in m.group(1).split(",") if s.strip()}
                self.suppressions[lineno] = ids

    @classmethod
    def read(cls, path: str) -> "SourceFile":
        with open(path, "r", encoding="utf-8") as f:
            return cls(path, f.read())

    def suppressed(self, pass_id: str, line: int) -> bool:
        ids = self.suppressions.get(line)
        return ids is not None and (pass_id in ids or "*" in ids)

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""


def collect_files(paths: Sequence[str]) -> List[str]:
    """Expand files/directories into a sorted list of ``.py`` files."""
    out: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, dirs, names in os.walk(p):
                dirs[:] = sorted(
                    d for d in dirs
                    if not d.startswith(".") and d != "__pycache__"
                )
                for name in sorted(names):
                    if name.endswith(".py"):
                        out.append(os.path.join(root, name))
        elif p.endswith(".py"):
            out.append(p)
    return out


def _registry():
    # imported lazily so a syntax error in one pass module surfaces as an
    # internal error (exit 2), not an import-time crash of the package
    from repro.analysis import donation, locks, pallas_contract, purity

    return [donation, purity, locks, pallas_contract]


def run_analysis(
    paths: Sequence[str],
    pass_ids: Optional[Iterable[str]] = None,
) -> Tuple[List[Diagnostic], List[str], int]:
    """Run every pass over every file.

    Returns ``(diagnostics, internal_errors, n_files)``.  A file that
    fails to parse or a pass that raises is an INTERNAL error — reported
    and mapped to exit code 2, never silently swallowed as "clean".
    """
    modules = _registry()
    if pass_ids is not None:
        wanted = set(pass_ids)
        modules = [m for m in modules if m.PASS_ID in wanted]
    files = collect_files(paths)
    diags: List[Diagnostic] = []
    errors: List[str] = []
    for path in files:
        try:
            src = SourceFile.read(path)
        except (OSError, SyntaxError, ValueError) as e:
            errors.append(f"{path}: parse failed: {e}")
            continue
        for mod in modules:
            try:
                found = mod.check(src)
            except Exception as e:  # a buggy pass must not masquerade as clean
                errors.append(
                    f"{path}: pass {mod.PASS_ID} crashed: {type(e).__name__}: {e}"
                )
                continue
            diags.extend(
                d for d in found if not src.suppressed(d.pass_id, d.line)
            )
    diags.sort(key=lambda d: (d.path, d.line, d.pass_id))
    return diags, errors, len(files)


def counts_by_pass(diags: Sequence[Diagnostic]) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for d in diags:
        out[d.pass_id] = out.get(d.pass_id, 0) + 1
    return out


def load_baseline(path: str) -> Dict[str, int]:
    with open(path, "r", encoding="utf-8") as f:
        data = json.load(f)
    counts = data.get("counts", data)
    return {str(k): int(v) for k, v in counts.items()}


def diff_against_baseline(
    diags: Sequence[Diagnostic], baseline: Dict[str, int]
) -> Dict[str, int]:
    """Per-pass finding count MINUS the accepted baseline count (floored
    at 0).  Any positive entry is a regression the strict gate fails on."""
    current = counts_by_pass(diags)
    out: Dict[str, int] = {}
    for pass_id, n in current.items():
        extra = n - baseline.get(pass_id, 0)
        if extra > 0:
            out[pass_id] = extra
    return out
