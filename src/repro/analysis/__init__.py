"""Repo-invariant static analysis for the RSI serving stack.

Four AST-driven passes turn the repo's hardest-won debugging lessons into
machine-checked contracts:

* ``donation-safety`` — a binding passed at a ``donate_argnums`` position
  of a jitted wrapper is INVALID after the call; any read without
  rebinding from the result is flagged (the engine donates the KV pool
  through five programs — a stale read is silent corruption).
* ``jit-purity`` — host side effects inside functions reachable from
  ``jax.jit`` / ``lax.scan`` / ``pallas_call`` bodies (``print``,
  ``time.*``, ``.item()``, ``np.asarray`` on tracers, mutation of
  captured module state, ``threading``) run at TRACE time, not per step —
  at best a perf lie, at worst nondeterminism.
* ``lock-discipline`` — attributes annotated ``# guarded by: <lockname>``
  must only be touched under ``with <lockname>:``, from a helper whose
  every intra-module call site holds the lock, or from a
  ``_locked``-suffixed helper (the documented caller-holds-it convention).
* ``pallas-contract`` — every ``pallas_call``'s per-program VMEM estimate
  (BlockSpec block shapes x dtype + scratch) must fit the
  ``fused_vmem_bytes`` budget model's limit, and grid / index_map /
  kernel-signature arities must agree.

Run as ``python -m repro.analysis [--strict] [--json PATH]
[--baseline analysis/baseline.json] [paths...]``.  Suppress a single
finding with ``# repro-lint: ignore[pass-id]`` on the flagged line (plus a
one-line justification).  The companion runtime sanitizer
(:mod:`repro.analysis.sanitize`, armed via ``REPRO_SANITIZE=1``) wraps
``guarded by:``-annotated attributes in debug descriptors asserting the
owning lock is held at access time — the dynamic cross-check of the
lock-discipline pass under the real cluster/failover tests.

The package is deliberately stdlib-only (``ast`` + ``re``): it must run in
CI before any heavyweight import and must be importable from
``serving/cluster.py`` (sanitizer hook) without cycles.
"""

from repro.analysis.core import Diagnostic, SourceFile, run_analysis

__all__ = ["Diagnostic", "SourceFile", "run_analysis"]
