"""jit-purity: host side effects inside traced (jit/scan/pallas) code.

Anything reachable from a ``jax.jit`` / ``jax.lax.scan`` /
``pl.pallas_call`` body runs at TRACE time, once — not per step.  A
``print`` there silently stops printing after the first call; ``time.*``
measures tracing, not compute; ``.item()`` / ``float()`` / ``np.asarray``
on a tracer either crashes or forces a device sync; mutating captured
module state from traced code is nondeterminism; ``threading`` inside a
trace is never what anyone meant.

Roots are found syntactically, all within one module:

* ``jax.jit(fn, ...)`` / ``jit(fn)`` — first positional arg by name, or
  an inline ``lambda``;
* ``functools.partial(jax.jit, ...)`` used as a decorator;
* ``@jax.jit`` / ``@jit`` decorators;
* ``jax.lax.scan(body, ...)`` / ``lax.scan(body, ...)``;
* ``pl.pallas_call(kernel, ...)`` — including ``functools.partial(kernel,
  ...)`` as the first argument.

From those roots the pass closes over the intra-module call graph (bare
``name(...)`` calls and ``self.method(...)`` calls) and checks every
reachable function body for:

* calls to ``print`` / ``input`` / ``breakpoint`` / ``open``;
* calls through the ``time`` or ``threading`` modules;
* ``.item()`` / ``.tolist()`` / ``.block_until_ready()`` method calls;
* ``float(x)`` / ``int(x)`` / ``np.asarray(x)`` / ``np.array(x)`` where
  ``x`` is a *parameter* of the enclosing reachable function (i.e. very
  likely a tracer — literals and locals derived from shapes are fine);
* assignment to attributes (``obj.x = ...`` — mutation of captured
  Python state);
* subscript stores or mutator-method calls (``.append``/``.extend``/
  ``.add``/``.update``/``.pop``) on MODULE-LEVEL globals only.  Pallas
  kernels assign through refs (``o_ref[...] = ...``, ``acc_ref[...] +=``)
  and ``@pl.when`` nested functions store to enclosing-scope refs — both
  are the intended idiom, so closure/parameter names are never flagged
  for subscript stores.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from repro.analysis.core import Diagnostic, SourceFile

PASS_ID = "jit-purity"

__all__ = ["PASS_ID", "check"]

_BANNED_BUILTIN_CALLS = {"print", "input", "breakpoint", "open"}
_BANNED_MODULES = {"time", "threading"}
_SYNC_METHODS = {"item", "tolist", "block_until_ready"}
_MUTATOR_METHODS = {"append", "extend", "add", "update", "pop", "insert",
                    "remove", "clear", "setdefault"}
_CAST_CALLS = {"float", "int"}


def _dotted(node: ast.expr) -> Optional[str]:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return None if base is None else f"{base}.{node.attr}"
    return None


def _is_trace_entry(func: ast.expr) -> bool:
    """Is this call expression a tracing entry point (jit/scan/pallas)?"""
    d = _dotted(func)
    if d is None:
        return False
    leaf = d.rsplit(".", 1)[-1]
    return leaf in ("jit", "scan", "pallas_call")


def _first_arg_func_names(call: ast.Call) -> List[ast.AST]:
    """Resolve the traced-callable argument(s) of a tracing call: names
    (for graph closure) and inline lambdas/defs (checked directly)."""
    if not call.args:
        return []
    arg = call.args[0]
    # functools.partial(kernel, ...) -> unwrap to the kernel
    if isinstance(arg, ast.Call):
        d = _dotted(arg.func)
        if d is not None and d.rsplit(".", 1)[-1] == "partial" and arg.args:
            arg = arg.args[0]
    if isinstance(arg, (ast.Name, ast.Attribute, ast.Lambda)):
        return [arg]
    return []


class _Module:
    """Per-module function table + intra-module call graph."""

    def __init__(self, tree: ast.Module):
        # name -> list of defs (methods across classes may share a name;
        # a syntactic pass treats that as may-alias and checks them all)
        self.defs: Dict[str, List[ast.AST]] = {}
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.defs.setdefault(node.name, []).append(node)

    def callees(self, fn: ast.AST) -> Set[str]:
        """Names of intra-module functions called from ``fn``'s own body
        (nested defs are separate nodes, but walking them is harmless —
        if the outer is traced, its nested defs are too)."""
        out: Set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                f = node.func
                if isinstance(f, ast.Name) and f.id in self.defs:
                    out.add(f.id)
                elif (
                    isinstance(f, ast.Attribute)
                    and isinstance(f.value, ast.Name)
                    and f.value.id == "self"
                    and f.attr in self.defs
                ):
                    out.add(f.attr)
        return out


def _collect_roots(tree: ast.Module, mod: _Module) -> List[ast.AST]:
    roots: List[ast.AST] = []
    names: Set[str] = set()

    def add(node: ast.AST) -> None:
        if isinstance(node, (ast.Name, ast.Attribute)):
            d = _dotted(node)
            if d is not None:
                names.add(d.rsplit(".", 1)[-1])
        elif isinstance(node, ast.Lambda):
            roots.append(node)

    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _is_trace_entry(node.func):
            for target in _first_arg_func_names(node):
                add(target)
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                d: Optional[str] = None
                if isinstance(dec, (ast.Name, ast.Attribute)):
                    d = _dotted(dec)
                elif isinstance(dec, ast.Call):
                    # @functools.partial(jax.jit, static_argnames=...)
                    inner = _dotted(dec.func)
                    if inner is not None and inner.rsplit(".", 1)[-1] == "partial":
                        if dec.args:
                            d = _dotted(dec.args[0])
                    else:
                        d = inner
                if d is not None and d.rsplit(".", 1)[-1] in ("jit", "pallas_call"):
                    names.add(node.name)

    # closure over the intra-module call graph
    seen: Set[str] = set()
    work = sorted(names)
    while work:
        name = work.pop()
        if name in seen or name not in mod.defs:
            continue
        seen.add(name)
        for fn in mod.defs[name]:
            roots.append(fn)
            for callee in mod.callees(fn):
                if callee not in seen:
                    work.append(callee)
    return roots


def _param_names(fn: ast.AST) -> Set[str]:
    if isinstance(fn, ast.Lambda):
        a = fn.args
    elif isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
        a = fn.args
    else:
        return set()
    names = {p.arg for p in a.args + a.posonlyargs + a.kwonlyargs}
    if a.vararg:
        names.add(a.vararg.arg)
    if a.kwarg:
        names.add(a.kwarg.arg)
    names.discard("self")
    return names


def _module_globals(tree: ast.Module) -> Set[str]:
    out: Set[str] = set()
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    out.add(t.id)
        elif isinstance(node, ast.AnnAssign) and isinstance(node.target, ast.Name):
            out.add(node.target.id)
    return out


def _check_body(
    src: SourceFile, fn: ast.AST, globals_: Set[str]
) -> List[Diagnostic]:
    diags: List[Diagnostic] = []
    params = _param_names(fn)

    def flag(node: ast.AST, msg: str) -> None:
        diags.append(Diagnostic(PASS_ID, src.path, node.lineno, msg))

    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            f = node.func
            d = _dotted(f)
            if isinstance(f, ast.Name):
                if f.id in _BANNED_BUILTIN_CALLS:
                    flag(node, f"`{f.id}()` inside traced code runs at "
                               f"trace time, not per step")
                elif f.id in _CAST_CALLS and node.args:
                    a0 = node.args[0]
                    if isinstance(a0, ast.Name) and a0.id in params:
                        flag(node, f"`{f.id}({a0.id})` on a traced argument "
                                   f"forces a host sync / trace-time crash")
            elif isinstance(f, ast.Attribute):
                root = d.split(".", 1)[0] if d else None
                if root in _BANNED_MODULES:
                    flag(node, f"`{d}()` inside traced code measures/acts at "
                               f"trace time — move it outside the jit")
                elif f.attr in _SYNC_METHODS:
                    flag(node, f"`.{f.attr}()` inside traced code forces a "
                               f"host sync (or fails on a tracer)")
                elif d in ("np.asarray", "np.array", "numpy.asarray",
                           "numpy.array") and node.args:
                    a0 = node.args[0]
                    if isinstance(a0, ast.Name) and a0.id in params:
                        flag(node, f"`{d}({a0.id})` materializes a traced "
                                   f"argument on the host")
                elif f.attr in _MUTATOR_METHODS:
                    base = f.value
                    if isinstance(base, ast.Name) and base.id in globals_:
                        flag(node, f"mutation of module-level `{base.id}` "
                                   f"(.{f.attr}) from traced code")
        elif isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) else [node.target]
            for t in targets:
                if isinstance(t, ast.Attribute):
                    flag(t, f"assignment to `{_dotted(t) or t.attr}` mutates "
                            f"captured Python state from traced code")
                elif isinstance(t, ast.Subscript):
                    base = t.value
                    if isinstance(base, ast.Name) and base.id in globals_:
                        flag(t, f"subscript store into module-level "
                                f"`{base.id}` from traced code")
    return diags


def check(src: SourceFile) -> List[Diagnostic]:
    mod = _Module(src.tree)
    roots = _collect_roots(src.tree, mod)
    globals_ = _module_globals(src.tree)
    diags: List[Diagnostic] = []
    seen_fns = set()
    for fn in roots:
        if id(fn) in seen_fns:
            continue
        seen_fns.add(id(fn))
        diags.extend(_check_body(src, fn, globals_))
    # dedupe: nested defs can be reached both as roots and via walk
    seen = set()
    out = []
    for d in sorted(diags, key=lambda d: (d.line, d.message)):
        k = (d.line, d.message)
        if k not in seen:
            seen.add(k)
            out.append(d)
    return out
