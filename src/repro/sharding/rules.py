"""Logical-axis sharding rules (MaxText-style, path-regex keyed).

Params are annotated *by path pattern*, not in the model code: a single rule
table covers all ten architectures because the model zoo uses consistent
names (wq/wk/wv/wo, w_gate/w_up/w_down, experts/..., w_x/w_B/...).

Logical axis names:
  fsdp      -> "data"   (ZeRO-3-style parameter sharding)
  tp        -> "model"  (tensor parallel: heads / d_ff / d_inner)
  ep        -> "model"  (expert parallel: MoE expert axis)
  tp_vocab  -> "model"  (vocab-sharded embedding / lm head)
  batch     -> ("pod", "data")
  layer     -> None     (lax.scan stacking axis, never sharded)

Every assignment is guarded by divisibility: if a dim is not divisible by the
product of mesh-axis sizes, the assignment silently drops to replicated (this
is what makes e.g. mamba2-130m's 24-head dims work on a 16-way model axis).

``maybe_constrain`` gives model code optional activation-sharding hints that
are no-ops outside an active rule context — so the same model code runs
single-device (tests) and on the production mesh (dry-run/train).
"""

from __future__ import annotations

import contextlib
import dataclasses
import re
import threading
from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "MeshRules",
    "active_rules",
    "use_rules",
    "maybe_constrain",
    "param_specs",
    "PARAM_RULES",
]

_state = threading.local()


@dataclasses.dataclass
class MeshRules:
    mesh: Mesh
    logical: dict = dataclasses.field(
        default_factory=lambda: {
            "fsdp": ("data",),
            "tp": ("model",),
            "ep": ("model",),
            "tp_vocab": ("model",),
            "batch": ("pod", "data"),
            "seq": (),  # flip to ("model",) for sequence parallelism
            "layer": (),
        }
    )

    def axis_size(self, names: Sequence[str]) -> int:
        n = 1
        for a in names:
            if a in self.mesh.shape:
                n *= self.mesh.shape[a]
        return n

    def resolve(self, logical_name: Optional[str], dim: int) -> Optional[tuple]:
        """Mesh axes for one dim, or None if unmapped/non-divisible."""
        if logical_name is None:
            return None
        axes = tuple(a for a in self.logical.get(logical_name, ()) if a in self.mesh.shape)
        if not axes:
            return None
        if dim % self.axis_size(axes) != 0:
            return None
        return axes if len(axes) > 1 else axes[0]

    def spec(self, logical_axes: Sequence[Optional[str]], shape: Sequence[int]) -> P:
        assert len(logical_axes) == len(shape), (logical_axes, shape)
        return P(*(self.resolve(n, d) for n, d in zip(logical_axes, shape)))

    def sharding(self, logical_axes, shape) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))


def active_rules() -> Optional[MeshRules]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[MeshRules]):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield rules
    finally:
        _state.rules = prev


def maybe_constrain(x: jax.Array, logical_axes: Sequence[Optional[str]]):
    """with_sharding_constraint if a rule context is active, else identity."""
    rules = active_rules()
    if rules is None:
        return x
    spec = rules.spec(logical_axes, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


# --------------------------------------------------------------------------- #
# Parameter rules: (path regex, logical axes for the *trailing* dims).
# Leading unmatched dims (the lax.scan "layer"/group axes) default to None.
# First match wins.
# --------------------------------------------------------------------------- #
PARAM_RULES: list = [
    # embeddings / heads
    (r"(?:^|/)embed(?:/tok)?$", ("tp_vocab", "fsdp")),
    (r"(?:^|/)pos_embed$", (None, None)),
    (r"(?:^|/)lm_head$", ("fsdp", "tp_vocab")),
    # MLA
    (r"/wq_a$", ("fsdp", None)),
    (r"/wq_b$", (None, "tp")),
    (r"/wkv_a$", ("fsdp", None)),
    (r"/wkv_b$", (None, "tp")),
    # attention (dense + cross)
    (r"/w[qkv]$", ("fsdp", "tp")),
    (r"/wo$", ("tp", "fsdp")),
    (r"/b[qkv]$", ("tp",)),
    # MoE
    (r"/experts/w_(gate|up)$", ("ep", "fsdp", None)),
    (r"/experts/w_down$", ("ep", None, "fsdp")),
    (r"/router/gate_w$", ("fsdp", None)),
    # dense / shared-expert FFN
    (r"/w_(gate|up)$", ("fsdp", "tp")),
    (r"/w_down$", ("tp", "fsdp")),
    # SSM (mamba2)
    (r"/w_(z|x)$", ("fsdp", "tp")),
    (r"/w_(B|C)$", ("fsdp", None)),
    (r"/w_dt$", ("fsdp", None)),
    (r"/out_proj$", ("tp", "fsdp")),
    (r"/conv_w$", (None, "tp")),
    (r"/(A_log|dt_bias|D_param)$", ("tp",)),
    (r"/ssm_norm/scale$", ("tp",)),
    # low-rank factors (post-compression trees): A keeps the input-dim rule,
    # B keeps the output-dim rule, k axis unsharded.  These two generic rules
    # rely on compress_tree's spec_transform instead when specs are threaded;
    # they are the fallback for freshly-initialized low-rank params.
    (r"/a$", ("fsdp", None)),
    (r"/b$", (None, "tp")),
    # norms, biases, scalars
    (r".*", None),  # replicated
]


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def spec_for(path_str: str, shape: Sequence[int], rules: MeshRules) -> P:
    for pat, logical in PARAM_RULES:
        if re.search(pat, path_str):
            if logical is None:
                return P()
            n_lead = len(shape) - len(logical)
            if n_lead < 0:  # rule longer than shape (e.g. 1-D bias w/ 2-D rule)
                logical = logical[-len(shape):]
                n_lead = 0
            full = (None,) * n_lead + tuple(logical)
            return rules.spec(full, shape)
    return P()


def param_specs(params: Any, rules: MeshRules) -> Any:
    """PartitionSpec pytree parallel to a params pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [spec_for(_path_str(p), leaf.shape, rules) for p, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(params: Any, rules: MeshRules) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(rules.mesh, s), param_specs(params, rules)
    )
