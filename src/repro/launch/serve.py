"""Serving launcher: continuous-batching engine (default) or the legacy
static batched prefill+decode path, optionally from an RSI-compressed
checkpoint.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
        --batch 4 --prompt-len 16 --gen 32 [--engine continuous|static] \
        [--n-slots 4] [--decode-block 8] [--temperature 0.7 --top-k 40] \
        [--page-size 64 [--kv-pages N] [--prefill-chunk 256] [--share-prefix]] \
        [--compress-alpha 0.3 --q 4] [--kernels auto|xla|pallas|reference]

``--engine continuous`` (default) routes requests through
``repro.serving.Engine``: a slotted KV-cache pool with FIFO admission,
padded micro-batch prefill, a device-resident FUSED decode loop
(``--decode-block`` tokens per host round-trip, sampling and stop
detection on device, KV pool donated through the step), and per-request
sampling params.  ``--engine static`` keeps the original fixed-shape
``greedy_generate`` path.

``--page-size`` switches the continuous engine to the PAGED KV pool:
fixed-size pages shared by all slots through per-slot block tables,
admission gated on each request's actual page need (``--kv-pages`` sizes
the pool; default matches flat capacity), and — with ``--prefill-chunk`` —
long prompts prefilled chunk-by-chunk interleaved with decode blocks so a
long prefill no longer stalls running requests.  ``--share-prefix`` adds
refcounted copy-on-write prompt-prefix sharing on top: repeated leading
full pages (system-prompt traffic) are mapped read-only instead of
re-allocated and re-prefilled.

``--tiers 1.0,0.5`` arms elastic-rank serving on a compressed checkpoint:
nested prefix slices of the SAME factors serve as cheaper fallback tiers,
and ``--degrade-queue-depth`` / ``--degrade-free-frac`` let admission move
new requests to a deeper tier under pressure instead of queueing them
(each degraded response carries the tier's spectral-bound certificate).
``--deadline-ms`` sheds waiters not admitted in time with a structured
rejection; ``--preempt`` lets queue-head requests preempt lower-priority
actives (their pages re-index as warm cache for bit-exact resume).

``--replicas N`` serves through a :class:`repro.serving.Cluster`: N
thread-backed engine replicas behind one shared admission queue with
least-loaded routing, per-replica heartbeats (``--heartbeat-ms`` floor,
deadline adapted from observed step times), and bit-exact failover — a
dead replica's in-flight requests resume on survivors with at most
``--max-failovers`` retries before a structured ``replica_lost``
rejection.  ``--event-log PATH`` appends one JSON line per serving event
(shed / degrade / preempt / quarantine / straggler / failover / replica
life-cycle), so post-mortems read a log instead of scraping stdout.

SIGINT/SIGTERM drain gracefully: the queue is shed with ``"shutdown"``
rejections, active slots decode to completion, and the summary still
prints — a second signal kills the process as usual.

Kernel backend selection goes through repro.runtime.dispatch: ``--kernels``
overrides the arch config's ``kernels`` field, and the dispatcher's hit
counters are printed after generation so you can see which path every linear
actually took.
"""

from __future__ import annotations

import argparse
import signal
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--engine", choices=["continuous", "static"], default="continuous")
    ap.add_argument("--batch", type=int, default=4, help="number of requests")
    ap.add_argument("--n-slots", type=int, default=0,
                    help="cache slots in the pool (default: --batch)")
    ap.add_argument("--decode-block", type=int, default=8,
                    help="decode tokens per host round-trip (continuous engine)")
    ap.add_argument("--page-size", type=int, default=0,
                    help="KV-cache page size in tokens; 0 = flat slot pool "
                    "(continuous engine)")
    ap.add_argument("--kv-pages", type=int, default=0,
                    help="pages in the paged pool; 0 = flat-equivalent "
                    "capacity (n_slots * ceil(max_len / page_size))")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="prefill prompts longer than this in page-backed "
                    "chunks interleaved with decode; 0 = monolithic "
                    "(requires --page-size)")
    ap.add_argument("--share-prefix", action="store_true",
                    help="refcounted copy-on-write prompt-prefix sharing: "
                    "requests repeating an earlier prompt's leading full "
                    "pages map them read-only and prefill only the "
                    "unshared tail (requires --page-size; inert for "
                    "families without mid-prompt prefill)")
    ap.add_argument("--warm-cache-pages", type=int, default=0,
                    help="cap on refcount-0 pages kept matchable in the "
                    "prefix index (LRU eviction); 0 = unbounded "
                    "(requires --page-size)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 = softmax sampling (continuous engine)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="0 = full vocab (continuous engine)")
    ap.add_argument("--compress-alpha", type=float, default=0.0)
    ap.add_argument("--q", type=int, default=4)
    ap.add_argument("--tiers", default="",
                    help="comma-separated rank fractions, first must be 1.0 "
                    "(e.g. '1.0,0.5,0.25'): nested elastic-rank tiers served "
                    "from prefix slices of the compressed factors "
                    "(continuous engine; requires --compress-alpha)")
    ap.add_argument("--tier-q", type=int, default=2,
                    help="power iterations for the per-tier certificate probe")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="shed requests not ADMITTED within this many ms "
                    "(structured rejection; 0 = no deadline)")
    ap.add_argument("--degrade-queue-depth", type=int, default=0,
                    help="queue depth at which admission degrades new "
                    "requests to a deeper tier; 0 = disabled")
    ap.add_argument("--degrade-free-frac", type=float, default=0.0,
                    help="free-page fraction below which admission degrades "
                    "new requests to a deeper tier; 0 = disabled")
    ap.add_argument("--preempt", action="store_true",
                    help="queue-head requests may preempt lower-priority "
                    "actives; preempted K/V re-indexes as warm cache for "
                    "bit-exact resume (requires --share-prefix)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replicas behind one shared admission queue "
                    "(thread-backed; heartbeat failure detection + bit-exact "
                    "failover; continuous engine only)")
    ap.add_argument("--heartbeat-ms", type=float, default=1000.0,
                    help="replica heartbeat deadline floor; the effective "
                    "per-replica deadline adapts up from observed step times")
    ap.add_argument("--max-failovers", type=int, default=2,
                    help="failovers per request before it is rejected with "
                    "reason='replica_lost'")
    ap.add_argument("--event-log", default="",
                    help="append one JSON line per serving event (shed, "
                    "degrade, preempt, quarantine, straggler, failover, "
                    "replica life-cycle) to this path")
    ap.add_argument("--close-sessions", action="store_true",
                    help="after the run, drop each prompt's cached prefix "
                    "branch (the session-close hook) and report freed pages "
                    "(requires --share-prefix)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--kernels",
        choices=["auto", "xla", "pallas", "reference"],
        default=None,
        help="kernel backend (default: the arch config's `kernels` field)",
    )
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.registry import get_arch
    from repro.core import CompressionPolicy, compress_tree
    from repro.data.synthetic import SyntheticLM
    from repro.models.model import build_model
    from repro.runtime import dispatch
    from repro.runtime.dispatch import DispatchConfig, use_dispatch

    cfg = get_arch(args.arch, reduced=args.reduced)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    n0 = sum(x.size for x in jax.tree_util.tree_leaves(params))

    if args.compress_alpha > 0:
        policy = CompressionPolicy(alpha=args.compress_alpha, q=args.q, min_dim=16)
        params, _, rep = compress_tree(params, policy, jax.random.PRNGKey(1))
        print("[compress]", rep.summary())

    data = SyntheticLM(cfg, batch=args.batch, seq=args.prompt_len, kind="serve", seed=args.seed)
    batch = {k: jnp.asarray(v) for k, v in data.at_step(0).items()}
    max_len = args.prompt_len + args.gen

    dcfg = (
        DispatchConfig(backend=args.kernels)
        if args.kernels is not None
        else DispatchConfig.from_arch(cfg)
    )
    dispatch.reset_counters()

    if args.engine == "static":
        from repro.train.serve_step import greedy_generate

        t0 = time.time()
        with use_dispatch(dcfg):
            out = greedy_generate(model, params, batch, steps=args.gen, max_len=max_len)
        out = np.asarray(out)
        dt = time.time() - t0
        print(f"[static] generated {out.shape} tokens in {dt:.2f}s "
              f"({args.batch * args.gen / dt:.1f} tok/s, params {n0/1e6:.1f}M, "
              f"kernels={dcfg.backend})")
        print("first sequences:", out[: min(2, args.batch), :12].tolist())
    else:
        from repro.serving import Cluster, Engine, EventLog, Request, SamplingParams
        from repro.serving.engine import AdmissionPolicy, percentile
        from repro.serving.scheduler import FailoverBudget

        tiers = tuple(float(f) for f in args.tiers.split(",") if f) or None
        admission = None
        if args.deadline_ms > 0 or args.degrade_queue_depth > 0 or args.degrade_free_frac > 0:
            admission = AdmissionPolicy(
                n_tiers=len(tiers) if tiers else 1,
                degrade_queue_depth=args.degrade_queue_depth or None,
                degrade_free_frac=args.degrade_free_frac or None,
            )
        n_slots = args.n_slots or args.batch
        event_log = EventLog(args.event_log) if args.event_log else None

        def make_engine(rid=0):
            return Engine(model, params, n_slots=n_slots, max_len=max_len,
                          dispatch=dcfg,
                          decode_block=args.decode_block,
                          page_size=args.page_size or None,
                          kv_pages=args.kv_pages or None,
                          prefill_chunk=args.prefill_chunk or None,
                          share_prefix=args.share_prefix,
                          warm_cache_pages=args.warm_cache_pages or None,
                          tiers=tiers, tier_q=args.tier_q,
                          admission=admission, preempt=args.preempt)

        cluster = None
        if args.replicas > 1:
            cluster = Cluster(
                make_engine, args.replicas,
                heartbeat_ms=args.heartbeat_ms,
                budget=FailoverBudget(max_failovers=args.max_failovers,
                                      base_ms=10.0),
                event_log=event_log,
            )
            eng = cluster.replicas[0].eng  # summary counters below aggregate
        else:
            eng = make_engine()
            if event_log is not None:
                sink = event_log.sink()
                eng.on_event = sink
                eng.scheduler.on_event = sink
        np_batch = {k: np.asarray(v) for k, v in batch.items()}
        reqs = []
        for b in range(args.batch):
            extras = {k: v[b] for k, v in np_batch.items() if k != "tokens"}
            # per-request seed: otherwise every request shares one PRNG
            # stream and sampled continuations are correlated across the batch
            sp = SamplingParams(
                temperature=args.temperature, top_k=args.top_k, seed=args.seed + b
            )
            reqs.append(Request(
                prompt=np_batch["tokens"][b], max_new_tokens=args.gen,
                sampling=sp, extras=extras,
                deadline_ms=args.deadline_ms or None,
                min_tier=(len(tiers) - 1) if tiers else 0,
            ))

        # graceful drain: first SIGINT/SIGTERM sheds the queue and lets
        # active slots decode to completion; default handling is restored
        # afterwards so a SECOND signal kills the process as usual
        draining = {"on": False}

        def _drain(signum, frame):
            draining["on"] = True
            print(f"\n[drain] caught {signal.Signals(signum).name}: "
                  "shedding the queue, finishing active slots")
            for s, h in prev_handlers.items():
                signal.signal(s, h)

        prev_handlers = {}
        for s in (signal.SIGINT, signal.SIGTERM):
            try:
                prev_handlers[s] = signal.signal(s, _drain)
            except ValueError:  # not the main thread (tests)
                break

        t0 = time.time()
        try:
            if cluster is not None:
                done = cluster.run(reqs, stop=lambda: draining["on"])
                cluster.close()
            else:
                done = eng.run(reqs, stop=lambda: draining["on"])
        finally:
            for s, h in prev_handlers.items():
                if signal.getsignal(s) == _drain:
                    signal.signal(s, h)
        dt = time.time() - t0
        engines = [r.eng for r in cluster.replicas] if cluster is not None else [eng]
        if cluster is not None:
            print(f"[cluster] replicas={args.replicas} "
                  f"failovers={cluster.failovers} "
                  f"prefix_match={cluster.failovers_prefix_match} "
                  f"replica_deaths={cluster.replica_deaths} "
                  f"heartbeat_misses={cluster.heartbeat_misses} "
                  f"rejoins={cluster.rejoins} "
                  f"replica_lost_rejections={cluster.exhausted}")
        ok = [r for r in done if r.status == "ok"]
        shed = [r for r in done if r.status == "shed"]
        errored = [r for r in done if r.status == "error"]
        n_tok = sum(len(r.tokens) for r in done)
        print(f"[continuous] {len(done)} requests, {n_tok} tokens in {dt:.2f}s "
              f"({n_tok / dt:.1f} tok/s, slots={n_slots}, params {n0/1e6:.1f}M, "
              f"kernels={dcfg.backend})")
        if shed or errored or admission is not None or tiers:
            by_tier = [0] * (len(tiers) if tiers else 1)
            for r in ok:
                by_tier[r.tier] += 1
            certs = " ".join(
                f"t{i}<= {c.prob_deviation_bound:.3g}"
                for i, c in enumerate(eng.tier_certificates)
                if c is not None
            )
            print(f"[overload] ok={len(ok)} shed={len(shed)} "
                  f"errored={len(errored)} "
                  f"degraded={eng.degraded_admissions} "
                  f"preemptions={eng.preemptions} "
                  f"quarantined={eng.quarantined} "
                  f"tier_counts={by_tier}" + (f" cert_bounds[{certs}]" if certs else ""))
            for r in shed:
                print(f"[shed] uid={r.rejected.uid} reason={r.rejected.reason} "
                      f"waited={r.rejected.waited_ms:.0f}ms "
                      f"queue_depth={r.rejected.queue_depth}")
        # a replay that completed ZERO requests has no percentiles —
        # report n/a instead of crashing on percentile([], ...)
        lats = sorted(r.latency for r in ok)
        lat_s = (
            f"p50={percentile(lats, 0.5)*1e3:.0f}ms "
            f"p95={percentile(lats, 0.95)*1e3:.0f}ms"
            if lats
            else "p50=n/a p95=n/a (0 completed)"
        )
        steps_t = sum(e.steps for e in engines)
        syncs_t = sum(e.host_syncs for e in engines)
        dec_t = sum(e.decoded_tokens for e in engines)
        print(f"latency {lat_s} "
              f"decode_steps={steps_t} host_syncs={syncs_t} "
              f"tok_per_sync={dec_t / max(syncs_t, 1):.1f} "
              f"util={sum(e.batch_utilization for e in engines) / len(engines):.3f}")
        for i, e in enumerate(engines):
            tag = f"[paged r{i}]" if cluster is not None else "[paged]"
            if not e.paged:
                continue
            print(f"{tag} page_size={e.page_size} pool={e.kv_pages} pages "
                  f"peak_pages={e.peak_pages_in_use} "
                  f"peak_active={e.peak_active} "
                  f"prefill_chunks={e.prefill_chunks} "
                  f"kv_bytes_cap={e.kv_bytes_capacity}")
            if args.share_prefix:
                print(f"[shared{' r%d' % i if cluster is not None else ''}] "
                      f"shared_pages={e.shared_page_hits} "
                      f"cow_forks={e.cow_forks} "
                      f"matched_admissions={e.shared_admissions} "
                      f"prefill_tok_skipped={e.skipped_prefill_tokens} "
                      f"cached_pages={e.prefix_cached_pages} "
                      f"evictions={e.prefix_evictions}")
        if args.close_sessions and args.share_prefix and cluster is None:
            freed = sum(eng.drop_session(r.prompt) for r in done)
            print(f"[sessions] closed {len(done)}, freed {freed} cached "
                  f"pages (cached now {eng.prefix_cached_pages})")
        if event_log is not None:
            event_log.close()
            print(f"[events] JSON lines appended to {args.event_log}")
        ok_done = ok if ok else done
        if ok_done and ok_done[0].tokens:
            out = np.asarray([ok_done[0].tokens], np.int32)
            print("first sequence:", ok_done[0].tokens[:12])
        else:
            out = np.zeros((0, 0), np.int32)

    print("[dispatch] per-site kernel paths:")
    print(dispatch.format_counters())
    return out


if __name__ == "__main__":
    main()
