import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the REAL step function (train_step for train
shapes, prefill/serve steps for inference shapes) with production shardings,
lowers it against ShapeDtypeStruct stand-ins (zero allocation), compiles it
for the 16x16 single-pod AND 2x16x16 multi-pod host-device meshes, and
records memory_analysis / cost_analysis / parsed-collective roofline terms
into benchmarks/dryrun_results/.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --skip-existing
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCell
from repro.configs.registry import all_cells, cell_is_runnable, get_arch, get_shape, ARCH_IDS
from repro.launch.mesh import HW, make_production_mesh, make_rules
from repro.models.model import analytic_param_count, batch_spec_template, build_model
from repro.roofline.analysis import parse_collectives, roofline_terms
from repro.roofline.hlo_stats import analyze_hlo
from repro.runtime.dispatch import DispatchConfig, use_dispatch
from repro.sharding.rules import param_specs
from repro.train import optimizer as opt_mod
from repro.train.serve_step import cache_specs, make_decode_step, make_prefill_step
from repro.train.train_step import (
    TrainState,
    make_train_step,
    state_specs,
)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "benchmarks", "dryrun_results")


def input_specs(cfg: ArchConfig, cell: ShapeCell) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a cell (the
    pattern required by the dry-run: weak-type-correct, shardable, no device
    allocation)."""
    tmpl = batch_spec_template(cfg, cell.global_batch, cell.seq_len, kind=cell.kind)
    return {k: jax.ShapeDtypeStruct(shape, dtype) for k, (shape, dtype) in tmpl.items()}


def _make_optimizer(cfg):
    sched = opt_mod.cosine_schedule(3e-4, 2000, 100_000)
    if cfg.optimizer == "adafactor":
        return opt_mod.adafactor(sched)
    if cfg.optimizer == "sgdm":
        return opt_mod.sgdm(sched)
    return opt_mod.adamw(sched)


def _shardings(tree_specs, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def _batch_shardings(batch_struct, rules):
    return {
        k: NamedSharding(
            rules.mesh, rules.spec(("batch",) + (None,) * (v.ndim - 1), v.shape)
        )
        for k, v in batch_struct.items()
    }


def build_lowered(arch_id: str, shape_name: str, mesh, *, reduced: bool = False):
    """Returns (lowered, meta) for one cell.  Kernel-backend selection for
    every linear happens at trace time under the arch's dispatch policy."""
    cfg = get_arch(arch_id, reduced=reduced)
    with use_dispatch(DispatchConfig.from_arch(cfg)):
        return _build_lowered(cfg, arch_id, shape_name, mesh)


def _build_lowered(cfg, arch_id: str, shape_name: str, mesh):
    cell = get_shape(shape_name)
    rules = make_rules(mesh, sequence_parallel=cell.kind != "decode")
    model = build_model(cfg)
    params_struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_specs = param_specs(params_struct, rules)
    p_sh = _shardings(p_specs, mesh)
    batch_struct = input_specs(cfg, cell)
    b_sh = _batch_shardings(batch_struct, rules)

    if cell.kind == "train":
        opt = _make_optimizer(cfg)
        opt_struct = jax.eval_shape(opt.init, params_struct)
        state_struct = TrainState(
            params=params_struct,
            opt_state=opt_struct,
            step=jax.ShapeDtypeStruct((), jnp.int32),
        )
        st_specs = state_specs(state_struct, rules)
        st_sh = TrainState(
            params=_shardings(st_specs.params, mesh),
            opt_state=_shardings(st_specs.opt_state, mesh),
            step=NamedSharding(mesh, P()),
        )
        step_fn = make_train_step(model, opt, rules=rules, accum_steps=cfg.accum_steps)
        metric_sh = {k: NamedSharding(mesh, P()) for k in ("loss", "aux_loss", "grad_norm")}
        jitted = jax.jit(
            step_fn,
            in_shardings=(st_sh, b_sh),
            out_shardings=(st_sh, metric_sh),
            donate_argnums=(0,),
        )
        lowered = jitted.lower(state_struct, batch_struct)
    elif cell.kind == "prefill":
        fn = make_prefill_step(model, rules=rules, max_len=cell.seq_len)
        out_struct = jax.eval_shape(fn, params_struct, batch_struct)
        logits_sh = NamedSharding(
            mesh, rules.spec(("batch", "tp_vocab"), out_struct[0].shape)
        )
        c_specs = cache_specs(out_struct[1], rules)
        c_sh = _shardings(c_specs, mesh)
        jitted = jax.jit(fn, in_shardings=(p_sh, b_sh), out_shardings=(logits_sh, c_sh))
        lowered = jitted.lower(params_struct, batch_struct)
    elif cell.kind == "decode":
        cache_struct = jax.eval_shape(
            lambda: model.init_cache(cell.global_batch, cell.seq_len)
        )
        c_specs = cache_specs(cache_struct, rules)
        c_sh = _shardings(c_specs, mesh)
        fn = make_decode_step(model, rules=rules)
        pos_struct = jax.ShapeDtypeStruct((), jnp.int32)
        logits_struct = jax.eval_shape(
            fn, params_struct, cache_struct, batch_struct["tokens"], pos_struct
        )[0]
        logits_sh = NamedSharding(mesh, rules.spec(("batch", "tp_vocab"), logits_struct.shape))
        jitted = jax.jit(
            fn,
            in_shardings=(p_sh, c_sh, b_sh["tokens"], NamedSharding(mesh, P())),
            out_shardings=(logits_sh, c_sh),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(params_struct, cache_struct, batch_struct["tokens"], pos_struct)
    else:
        raise ValueError(cell.kind)

    n_params = analytic_param_count(cfg)
    n_active = analytic_param_count(cfg, active_only=True)
    meta = {
        "arch": arch_id,
        "shape": shape_name,
        "kind": cell.kind,
        "tokens": cell.tokens,
        "n_params": n_params,
        "n_params_active": n_active,
        "model_flops_global": _model_flops(cfg, cell, n_params, n_active),
    }
    return lowered, meta


def _model_flops(cfg, cell, n_params, n_active):
    """6*N*D (train: fwd+bwd), 2*N*D (inference fwd only), N = active params."""
    mult = 6 if cell.kind == "train" else 2
    return mult * n_active * cell.tokens


def run_cell(arch_id, shape_name, *, multi_pod: bool, reduced=False, save=True):
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    tag = "multi_pod_2x16x16" if multi_pod else "single_pod_16x16"
    t0 = time.time()
    lowered, meta = build_lowered(arch_id, shape_name, mesh, reduced=reduced)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # jax<0.5 returns [dict] per program
        cost = cost[0] if cost else {}
    # cost_analysis counts while bodies ONCE (no trip counts) — useless for
    # scanned models.  analyze_hlo walks the module with trip-count
    # multiplication; we record both (raw for reference).
    xla_flops_raw = float(cost.get("flops", 0.0))
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
        }
        # donated inputs alias outputs: count them once
        mem_info["total_bytes"] = (
            mem_info["argument_bytes"]
            + mem_info["output_bytes"]
            + mem_info["temp_bytes"]
            - mem_info["alias_bytes"]
        )
    except Exception as e:  # pragma: no cover
        mem_info = {"error": repr(e)}

    hlo = compiled.as_text()
    stats = analyze_hlo(hlo, world=chips)
    flops = stats.flops
    hbm_bytes = stats.hbm_bytes
    rf = roofline_terms(
        flops=flops,
        hbm_bytes=hbm_bytes,
        coll_bytes=stats.total_coll_bytes,
        chips=chips,
        model_flops_global=meta["model_flops_global"],
        ici_bw=HW.ICI_LINK_BW * HW.ICI_LINKS_USED,
    )
    result = {
        **meta,
        "mesh": tag,
        "chips": chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_per_chip": flops,
        "xla_cost_analysis_flops_raw": xla_flops_raw,
        "hbm_bytes_per_chip": hbm_bytes,
        "collective_bytes_per_chip": stats.total_coll_bytes,
        "collectives_by_kind": stats.coll_bytes,
        "collective_op_count": stats.coll_ops,
        "memory": mem_info,
        "t_compute": rf.t_compute,
        "t_memory": rf.t_memory,
        "t_collective": rf.t_collective,
        "bottleneck": rf.bottleneck,
        "useful_flops_ratio": rf.useful_flops_ratio,
        "roofline_fraction": rf.roofline_fraction,
        "hlo_bytes": len(hlo),
    }
    if save:
        outdir = os.path.join(os.path.abspath(RESULTS_DIR), tag)
        os.makedirs(outdir, exist_ok=True)
        with open(os.path.join(outdir, f"{arch_id}__{shape_name}.json"), "w") as f:
            json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--reduced", action="store_true", help="debug: tiny configs")
    args = ap.parse_args()

    cells = all_cells() if args.all else None
    if cells is None:
        if not args.arch:
            ap.error("--arch/--shape or --all required")
        shapes = [args.shape] if args.shape else [
            s for s in ("train_4k", "prefill_32k", "decode_32k", "long_500k")
            if cell_is_runnable(args.arch, s)[0]
        ]
        cells = [(args.arch, s) for s in shapes]

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    failures = []
    for arch_id, shape_name in cells:
        ok, why = cell_is_runnable(arch_id, shape_name)
        if not ok:
            print(f"SKIP  {arch_id:24s} {shape_name:12s} ({why})")
            continue
        for mp in meshes:
            tag = "multi_pod_2x16x16" if mp else "single_pod_16x16"
            out = os.path.join(os.path.abspath(RESULTS_DIR), tag, f"{arch_id}__{shape_name}.json")
            if args.skip_existing and os.path.exists(out):
                print(f"HAVE  {arch_id:24s} {shape_name:12s} {tag}")
                continue
            try:
                r = run_cell(arch_id, shape_name, multi_pod=mp, reduced=args.reduced)
                print(
                    f"OK    {arch_id:24s} {shape_name:12s} {tag:18s} "
                    f"compile={r['compile_s']:7.1f}s  bottleneck={r['bottleneck']:10s} "
                    f"t=({r['t_compute']:.3f},{r['t_memory']:.3f},{r['t_collective']:.3f})s "
                    f"mem={r['memory'].get('total_bytes', 0)/2**30:.2f}GiB/chip"
                )
            except Exception as e:
                failures.append((arch_id, shape_name, tag, repr(e)))
                print(f"FAIL  {arch_id:24s} {shape_name:12s} {tag}: {e}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES")
        for f in failures:
            print("  ", *f[:3])
        raise SystemExit(1)
    print("\nall requested cells compiled.")


if __name__ == "__main__":
    main()
