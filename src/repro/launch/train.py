"""Training launcher: checkpoint-restart loop with straggler watchdog.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
        --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt

On a real fleet this process runs per-host under the cluster scheduler with
jax.distributed.initialize(); in this container it runs single-process (the
mesh is trivially 1 device unless --fake-devices is given for experiments).
The restart contract: rerunning the same command resumes from the latest
valid checkpoint with identical results (deterministic data).
"""

from __future__ import annotations

import argparse
import os
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--fake-devices", type=int, default=0)
    ap.add_argument("--compress-alpha", type=float, default=0.0,
                    help="if >0: RSI-compress params before training (low-rank fine-tune)")
    ap.add_argument("--compress-q", type=int, default=4)
    args = ap.parse_args(argv)

    if args.fake_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.fake_devices}"
        )

    import jax
    import jax.numpy as jnp

    from repro.configs.registry import get_arch
    from repro.checkpoint import checkpointer as ckpt
    from repro.core import CompressionPolicy, compress_tree
    from repro.data.synthetic import SyntheticLM
    from repro.models.model import build_model
    from repro.runtime.dispatch import DispatchConfig, use_dispatch
    from repro.runtime.fault_tolerance import TrainLoopRunner
    from repro.train import optimizer as opt_mod
    from repro.train.train_step import TrainState, init_train_state, make_train_step

    cfg = get_arch(args.arch, reduced=args.reduced)
    model = build_model(cfg)
    opt = {
        "adamw": lambda s: opt_mod.adamw(s, weight_decay=0.01),
        "adafactor": opt_mod.adafactor,
        "sgdm": opt_mod.sgdm,
    }[cfg.optimizer](opt_mod.cosine_schedule(args.lr, max(args.steps // 20, 1), args.steps))

    state = init_train_state(model, opt, jax.random.PRNGKey(args.seed))
    if args.compress_alpha > 0:
        policy = CompressionPolicy(alpha=args.compress_alpha, q=args.compress_q, min_dim=32)
        new_params, _, rep = compress_tree(state.params, policy, jax.random.PRNGKey(1))
        print("[compress]", rep.summary())
        state = TrainState(params=new_params, opt_state=opt.init(new_params), step=state.step)

    start_step = 0
    checkpointer = None
    if args.ckpt_dir:
        checkpointer = ckpt.Checkpointer(args.ckpt_dir, keep=3)
        last = ckpt.latest_step(args.ckpt_dir)
        if last is not None:
            state, _ = ckpt.restore(state, args.ckpt_dir)
            start_step = last
            print(f"[resume] restored step {last} from {args.ckpt_dir}")

    data = SyntheticLM(cfg, batch=args.batch, seq=args.seq, seed=args.seed)
    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0,))

    t_start = time.time()

    def on_metrics(step, m):
        if step % args.log_every == 0:
            dt = time.time() - t_start
            print(
                f"step {step:5d}  loss {float(m['loss']):.4f}  "
                f"aux {float(m['aux_loss']):.4f}  gnorm {float(m['grad_norm']):.3f}  "
                f"({dt:.1f}s)"
            )
            sys.stdout.flush()

    runner = TrainLoopRunner(
        step_fn,
        data.at_step,
        checkpointer,
        save_every=args.save_every,
    )
    # the arch's kernel policy must be ambient while the step traces (first
    # call inside runner.run), same as serve/dryrun
    with use_dispatch(DispatchConfig.from_arch(cfg)):
        state, metrics = runner.run(
            state,
            args.steps,
            shard_fn=lambda b: jax.tree_util.tree_map(jnp.asarray, b),
            start_step=start_step,
            on_metrics=on_metrics,
        )
    if checkpointer:
        checkpointer.wait()
    if runner.watchdog.straggler_steps:
        print(f"[watchdog] straggler steps: {runner.watchdog.straggler_steps}")
    print(f"done: final loss {float(metrics['loss']):.4f}")
    return state, metrics


if __name__ == "__main__":
    main()
