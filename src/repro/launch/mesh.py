"""Production meshes.  Defined as FUNCTIONS so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before first init)."""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_rules", "HW"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips ("data","model").  Multi-pod: 2 pods =
    512 chips ("pod","data","model"); the pod axis is DP by default (or
    pipeline stages via repro.train.pipeline_parallel)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    from repro.runtime.compat import make_mesh

    return make_mesh(shape, axes)


def make_rules(mesh, *, sequence_parallel: bool = True):
    from repro.sharding.rules import MeshRules

    rules = MeshRules(mesh)
    if sequence_parallel:
        rules.logical["seq"] = ("model",)
    return rules


class HW:
    """TPU v5e roofline constants (per chip)."""

    PEAK_FLOPS_BF16 = 197e12  # FLOP/s
    HBM_BW = 819e9  # B/s
    ICI_LINK_BW = 50e9  # B/s per link (assignment constant)
    # ring collectives stream both directions of a torus axis concurrently
    ICI_LINKS_USED = 2
    HBM_PER_CHIP = 16 * 2**30
