"""Compression CLI: apply RSI (Alg 3.1) to a model / checkpoint.

    PYTHONPATH=src python -m repro.launch.compress --arch llama3.2-1b --reduced \
        --alpha 0.3 --q 4 [--in-ckpt DIR] [--out-ckpt DIR] [--rank-rule energy]

Loads params (fresh init or checkpoint), compresses every policy-selected
linear with RSI, reports per-layer ranks + compression ratio + (optionally)
spectral-error estimates, and writes a factored checkpoint that train/serve
load transparently.
"""

from __future__ import annotations

import argparse


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--alpha", type=float, default=0.4)
    ap.add_argument("--q", type=int, default=4)
    ap.add_argument("--rank-rule", choices=["alpha", "energy"], default="alpha")
    ap.add_argument("--energy", type=float, default=0.95)
    ap.add_argument("--min-dim", type=int, default=257)
    ap.add_argument("--in-ckpt", default="")
    ap.add_argument("--out-ckpt", default="")
    ap.add_argument("--errors", action="store_true", help="estimate spectral errors (slow)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from repro.checkpoint import checkpointer as ckpt
    from repro.configs.registry import get_arch
    from repro.core import CompressionPolicy, compress_tree, spectral_norm
    from repro.core.lowrank import is_lowrank, materialize
    from repro.models.model import build_model

    cfg = get_arch(args.arch, reduced=args.reduced)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    if args.in_ckpt:
        params, _ = ckpt.restore(params, args.in_ckpt)
        print(f"[load] {args.in_ckpt}")

    policy = CompressionPolicy(
        alpha=args.alpha,
        q=args.q,
        rank_rule=args.rank_rule,
        energy=args.energy,
        min_dim=args.min_dim,
    )
    new_params, _, rep = compress_tree(params, policy, jax.random.PRNGKey(1))
    print(rep.summary())
    for layer in rep.layers:
        if layer.compressed:
            print(
                f"  {layer.path:48s} {str(layer.shape):>22s} rank={layer.rank:4d} "
                f"params {layer.params_before/1e6:8.2f}M -> {layer.params_after/1e6:8.2f}M"
            )

    if args.errors:
        flat_old = dict(_walk(params))
        for path, leaf in _walk(new_params):
            if is_lowrank(leaf):
                W = flat_old[path]
                if W.ndim > 2:
                    W = W.reshape((-1,) + W.shape[-2:])[0]
                    approx = materialize(
                        jax.tree_util.tree_map(lambda a: a.reshape((-1,) + a.shape[-2:])[0], leaf)
                    )
                else:
                    approx = materialize(leaf)
                err = float(spectral_norm(W - approx, jax.random.PRNGKey(2)))
                print(f"  spectral err {path}: {err:.4f}")

    if args.out_ckpt:
        ckpt.save(new_params, args.out_ckpt, 0, extra={"policy": vars(args)})
        print(f"[saved] {args.out_ckpt}/step_0")
    return new_params, rep


def _walk(tree, prefix=""):
    from repro.core.lowrank import is_lowrank

    if is_lowrank(tree) or not isinstance(tree, dict):
        yield prefix, tree
        return
    for k, v in tree.items():
        yield from _walk(v, f"{prefix}/{k}" if prefix else k)


if __name__ == "__main__":
    main()
