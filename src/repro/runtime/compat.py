"""JAX version compatibility shims for the distributed layer.

The codebase targets the modern public APIs (``jax.shard_map`` with
``check_vma``, ``jax.make_mesh(..., axis_types=...)``); older installs (e.g.
jax 0.4.x) expose ``jax.experimental.shard_map`` with ``check_rep`` and a
``make_mesh`` without axis types.  Everything that builds meshes or
shard_maps goes through these two wrappers so one import site owns the
difference.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map", "make_mesh", "axis_size"]


def axis_size(axis_name: str):
    """``jax.lax.axis_size`` where available; the ``psum(1, axis)`` idiom
    (constant-folded to a static int under named axes) on older jax."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` where available, else the experimental API.

    The replication-check kwarg is probed by signature: mid-band versions
    expose ``jax.shard_map`` but still call it ``check_rep``."""
    if hasattr(jax, "shard_map"):
        import inspect

        params = inspect.signature(jax.shard_map).parameters
        kw = "check_vma" if "check_vma" in params else "check_rep"
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **{kw: check_vma}
        )
    from jax.experimental.shard_map import shard_map as _shard_map  # jax<0.6

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
    )


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis types when the install supports them."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                axis_shapes, axis_names, axis_types=(axis_type.Auto,) * len(axis_names)
            )
        except TypeError:  # make_mesh predates axis_types
            pass
    return jax.make_mesh(axis_shapes, axis_names)
