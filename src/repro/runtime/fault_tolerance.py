"""Fleet-scale fault-tolerance machinery (restart, stragglers, elasticity).

What runs for real in this container: the watchdog statistics, the retry
wrapper, deterministic-restart bookkeeping, and the elastic re-shard path
(exercised by tests against simulated failures).  What is fleet-only and
stubbed behind the same interfaces: process heartbeats and the coordinator
RPC (on a real TPU fleet these hook into the cluster scheduler; here the
heartbeat source is a local clock and failure injection is explicit).
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = [
    "StepWatchdog",
    "RetryableStep",
    "ElasticReshard",
    "TrainLoopRunner",
    "FaultInjector",
    "ReplicaKilled",
    "backoff_s",
]


def backoff_s(
    attempt: int,
    *,
    base_s: float,
    cap_s: float,
    salt: int = 0,
) -> float:
    """Capped exponential backoff with DETERMINISTIC jitter.

    ``base_s * 2**attempt`` capped at ``cap_s``, scaled by a jitter factor
    in [0.5, 1.0] derived from a hash of ``(salt, attempt)`` — so retries
    de-synchronize across requests/replicas (different salts) while every
    run of the same (salt, attempt) pair sleeps the identical duration
    (reproducible traces; no global RNG state touched).
    """
    if base_s <= 0:
        return 0.0
    raw = min(base_s * (2.0 ** max(attempt, 0)), cap_s)
    h = hashlib.blake2b(f"{salt}:{attempt}".encode(), digest_size=8).digest()
    frac = 0.5 + (int.from_bytes(h, "big") / 2.0**64) * 0.5
    return raw * frac


@dataclasses.dataclass
class StepWatchdog:
    """Step-time statistics + straggler detection.

    A step slower than ``straggler_factor`` x the rolling median is flagged;
    on a fleet the flag feeds the re-scheduling path (drain + re-mesh), here
    it is surfaced in metrics and tested directly.
    """

    straggler_factor: float = 3.0
    window: int = 50

    def __post_init__(self):
        self.durations: list = []
        self.straggler_steps: list = []

    def observe(self, step: int, seconds: float) -> bool:
        self.durations.append(seconds)
        hist = self.durations[-self.window :]
        med = float(np.median(hist[:-1])) if len(hist) > 1 else seconds
        is_straggler = len(hist) > 5 and seconds > self.straggler_factor * med
        if is_straggler:
            self.straggler_steps.append(step)
        return is_straggler

    @property
    def median(self) -> float:
        return float(np.median(self.durations[-self.window :])) if self.durations else 0.0


class RetryableStep:
    """Wrap a step function with bounded retries and capped-exponential
    backoff.

    On real fleets the caught class is jaxlib XlaRuntimeError (preempted
    replica / link flap); tests inject arbitrary exceptions.  After
    ``max_retries`` consecutive failures the error propagates to the restart
    loop, which falls back to the last checkpoint.

    Backoff is OFF by default (``base_delay_s=0``): the train restart loop
    retries hot, matching the historical behaviour.  The serving cluster
    arms it (``base_delay_s > 0``) so failover retries de-synchronize:
    attempt ``k`` sleeps ``backoff_s(k, base_s, cap_s, salt=jitter_salt)``
    — capped exponential with deterministic jitter.  ``sleep`` is
    injectable so tests record delays instead of waiting them out.
    """

    def __init__(
        self,
        fn: Callable,
        *,
        max_retries: int = 2,
        retryable=(Exception,),
        base_delay_s: float = 0.0,
        max_delay_s: float = 1.0,
        jitter_salt: int = 0,
        sleep: Callable[[float], None] = time.sleep,
    ):
        self.fn, self.max_retries, self.retryable = fn, max_retries, retryable
        self.base_delay_s = base_delay_s
        self.max_delay_s = max_delay_s
        self.jitter_salt = jitter_salt
        self._sleep = sleep
        self.total_retries = 0   # failures observed (counts the final one too)
        self.total_attempts = 0  # calls into fn
        self.backoffs = 0        # sleeps actually taken
        self.total_backoff_s = 0.0

    def __call__(self, *args, **kw):
        for attempt in range(self.max_retries + 1):
            self.total_attempts += 1
            try:
                return self.fn(*args, **kw)
            except self.retryable:
                self.total_retries += 1
                if attempt == self.max_retries:
                    raise
                delay = backoff_s(
                    attempt,
                    base_s=self.base_delay_s,
                    cap_s=self.max_delay_s,
                    salt=self.jitter_salt,
                )
                if delay > 0:
                    self.backoffs += 1
                    self.total_backoff_s += delay
                    self._sleep(delay)
        raise AssertionError("unreachable")


class ReplicaKilled(RuntimeError):
    """Raised inside a replica's step loop by ``FaultInjector.kill_replica``
    — simulates a process/device loss.  The cluster treats any exception
    escaping a replica step as fatal to that replica; this type exists so
    tests can tell injected kills from genuine bugs."""


@dataclasses.dataclass
class FaultInjector:
    """Deterministic fault injection for the serving engine (tests and
    ``benchmarks/serving.py --inject``).

    Engine-level fault classes, each armed independently:

    ``nan_logits = (uid, device_step)`` — poison request ``uid``'s logits
    to NaN at global decode step ``device_step`` (the engine's cumulative
    ``steps`` counter).  The poison rides two runtime scalars through the
    jitted fused block — same compiled program armed or not — so the
    engine's per-slot non-finite QUARANTINE path is exercised exactly as
    a real numerical blow-up would: the poisoned slot freezes and errors,
    the rest of the batch keeps decoding.

    ``deny_pages = (start, stop)`` — every page reservation issued during
    engine steps ``[start, stop)`` fails, simulating pool exhaustion:
    admissions queue, deadlines expire, preemption triggers.

    ``slow_steps = (start, stop)`` with ``slow_ms`` — sleep before each
    engine step in the window, simulating a straggling device so
    wall-clock deadlines expire under load.

    Replica-level fault classes (serving cluster; step indices here are
    the REPLICA's local step counter, checked via ``on_replica_step``):

    ``kill_replica = (replica, local_step)`` — raise :class:`ReplicaKilled`
    from replica ``replica``'s step loop at exactly ``local_step``,
    simulating a dead process; the cluster must fail its in-flight
    requests over to survivors.

    ``hang_replica = (replica, local_step)`` with ``hang_s`` — block the
    replica's step loop for ``hang_s`` seconds once, simulating a wedged
    device: no exception, the heartbeat just stops, and the monitor must
    catch it via the deadline.

    ``slow_replica = (replica, start, stop)`` with ``slow_ms`` — sleep
    ``slow_ms`` before each step in ``[start, stop)`` on that replica
    only, simulating a straggler that the watchdog flags.

    ``fired`` counts what actually triggered, so a test that armed a
    fault can assert the fault genuinely happened.
    """

    nan_logits: Optional[Tuple[int, int]] = None
    deny_pages: Optional[Tuple[int, int]] = None
    slow_steps: Optional[Tuple[int, int]] = None
    slow_ms: float = 0.0
    kill_replica: Optional[Tuple[int, int]] = None
    hang_replica: Optional[Tuple[int, int]] = None
    hang_s: float = 0.5
    slow_replica: Optional[Tuple[int, int, int]] = None
    fired: Dict[str, int] = dataclasses.field(default_factory=dict)  # guarded by: _fired_lock
    _fired_lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False
    )

    def _hit(self, kind: str) -> None:
        # one injector is shared across all replica threads: the bare
        # read-modify-write this replaces was a lost-update race under
        # concurrent kill/slow faults (caught by the lock-discipline pass)
        with self._fired_lock:
            self.fired[kind] = self.fired.get(kind, 0) + 1

    def deny_reserve(self, step_idx: int) -> bool:
        """True when page reservations must fail at engine step ``step_idx``."""
        if self.deny_pages is None:
            return False
        a, b = self.deny_pages
        if a <= step_idx < b:
            self._hit("deny_pages")
            return True
        return False

    def on_step(self, step_idx: int) -> None:
        """Engine-step hook: applies the slow-step fault when armed."""
        if self.slow_steps is None or self.slow_ms <= 0:
            return
        a, b = self.slow_steps
        if a <= step_idx < b:
            self._hit("slow_step")
            time.sleep(self.slow_ms / 1e3)

    def on_replica_step(self, replica: int, step_idx: int) -> None:
        """Replica-step hook (cluster path): applies replica-level faults.

        Called by the replica thread BEFORE it steps its engine, with the
        replica id and that replica's local step counter.  Raising here is
        equivalent to the engine step itself raising.
        """
        if self.kill_replica is not None:
            rid, at = self.kill_replica
            if replica == rid and step_idx == at:
                self._hit("kill_replica")
                raise ReplicaKilled(f"injected kill: replica {rid} at step {at}")
        if self.hang_replica is not None:
            rid, at = self.hang_replica
            if replica == rid and step_idx == at:
                self._hit("hang_replica")
                time.sleep(self.hang_s)
        if self.slow_replica is not None:
            rid, a, b = self.slow_replica
            if replica == rid and a <= step_idx < b and self.slow_ms > 0:
                self._hit("slow_replica")
                time.sleep(self.slow_ms / 1e3)

    def poison_for(self, uid_of_slot: Callable[[int], Optional[int]],
                   n_slots: int, steps_done: int, block: int) -> Tuple[int, int]:
        """Resolve the NaN fault to (slot, step-within-block) for the next
        fused block, or (-1, -1) when it does not land in this block."""
        if self.nan_logits is None:
            return -1, -1
        uid, at_step = self.nan_logits
        rel = at_step - steps_done
        if not (0 <= rel < block):
            return -1, -1
        for s in range(n_slots):
            if uid_of_slot(s) == uid:
                self._hit("nan_logits")
                return s, rel
        return -1, -1


@dataclasses.dataclass
class ElasticReshard:
    """Re-lay a host-restored state onto a (possibly different) mesh."""

    def apply(self, state_np: Any, shardings: Any) -> Any:
        return jax.tree_util.tree_map(
            lambda arr, sh: jax.device_put(np.asarray(arr), sh), state_np, shardings
        )


@dataclasses.dataclass
class TrainLoopRunner:
    """Checkpoint-restart training loop (the launch/train.py core).

    Failure contract: any exception from the step escapes RetryableStep ->
    the runner restores the latest checkpoint and resumes; the data pipeline
    is deterministic in step so the retrained batches are identical.
    """

    step_fn: Callable  # (state, batch) -> (state, metrics)
    data_at_step: Callable  # step -> host batch
    checkpointer: Any
    save_every: int = 50
    watchdog: StepWatchdog = dataclasses.field(default_factory=StepWatchdog)

    def run(
        self,
        state,
        n_steps: int,
        *,
        shard_fn: Callable = lambda b: b,
        start_step: int = 0,
        on_metrics: Optional[Callable] = None,
        fail_at: Optional[Callable] = None,  # test hook: step -> bool
    ):
        step = start_step
        metrics = None
        while step < n_steps:
            t0 = time.monotonic()
            if fail_at is not None and fail_at(step):
                raise RuntimeError(f"injected failure at step {step}")
            batch = shard_fn(self.data_at_step(step))
            state, metrics = self.step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            self.watchdog.observe(step, time.monotonic() - t0)
            step += 1
            if on_metrics:
                on_metrics(step, metrics)
            if self.checkpointer is not None and step % self.save_every == 0:
                self.checkpointer.save_async(state, step)
        if self.checkpointer is not None:
            self.checkpointer.save_async(state, step)
            self.checkpointer.wait()
        return state, metrics
