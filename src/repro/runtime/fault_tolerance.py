"""Fleet-scale fault-tolerance machinery (restart, stragglers, elasticity).

What runs for real in this container: the watchdog statistics, the retry
wrapper, deterministic-restart bookkeeping, and the elastic re-shard path
(exercised by tests against simulated failures).  What is fleet-only and
stubbed behind the same interfaces: process heartbeats and the coordinator
RPC (on a real TPU fleet these hook into the cluster scheduler; here the
heartbeat source is a local clock and failure injection is explicit).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

__all__ = ["StepWatchdog", "RetryableStep", "ElasticReshard", "TrainLoopRunner"]


@dataclasses.dataclass
class StepWatchdog:
    """Step-time statistics + straggler detection.

    A step slower than ``straggler_factor`` x the rolling median is flagged;
    on a fleet the flag feeds the re-scheduling path (drain + re-mesh), here
    it is surfaced in metrics and tested directly.
    """

    straggler_factor: float = 3.0
    window: int = 50

    def __post_init__(self):
        self.durations: list = []
        self.straggler_steps: list = []

    def observe(self, step: int, seconds: float) -> bool:
        self.durations.append(seconds)
        hist = self.durations[-self.window :]
        med = float(np.median(hist[:-1])) if len(hist) > 1 else seconds
        is_straggler = len(hist) > 5 and seconds > self.straggler_factor * med
        if is_straggler:
            self.straggler_steps.append(step)
        return is_straggler

    @property
    def median(self) -> float:
        return float(np.median(self.durations[-self.window :])) if self.durations else 0.0


class RetryableStep:
    """Wrap a step function with bounded retries.

    On real fleets the caught class is jaxlib XlaRuntimeError (preempted
    replica / link flap); tests inject arbitrary exceptions.  After
    ``max_retries`` consecutive failures the error propagates to the restart
    loop, which falls back to the last checkpoint.
    """

    def __init__(self, fn: Callable, *, max_retries: int = 2, retryable=(Exception,)):
        self.fn, self.max_retries, self.retryable = fn, max_retries, retryable
        self.total_retries = 0

    def __call__(self, *args, **kw):
        for attempt in range(self.max_retries + 1):
            try:
                return self.fn(*args, **kw)
            except self.retryable:
                self.total_retries += 1
                if attempt == self.max_retries:
                    raise
        raise AssertionError("unreachable")


@dataclasses.dataclass
class ElasticReshard:
    """Re-lay a host-restored state onto a (possibly different) mesh."""

    def apply(self, state_np: Any, shardings: Any) -> Any:
        return jax.tree_util.tree_map(
            lambda arr, sh: jax.device_put(np.asarray(arr), sh), state_np, shardings
        )


@dataclasses.dataclass
class TrainLoopRunner:
    """Checkpoint-restart training loop (the launch/train.py core).

    Failure contract: any exception from the step escapes RetryableStep ->
    the runner restores the latest checkpoint and resumes; the data pipeline
    is deterministic in step so the retrained batches are identical.
    """

    step_fn: Callable  # (state, batch) -> (state, metrics)
    data_at_step: Callable  # step -> host batch
    checkpointer: Any
    save_every: int = 50
    watchdog: StepWatchdog = dataclasses.field(default_factory=StepWatchdog)

    def run(
        self,
        state,
        n_steps: int,
        *,
        shard_fn: Callable = lambda b: b,
        start_step: int = 0,
        on_metrics: Optional[Callable] = None,
        fail_at: Optional[Callable] = None,  # test hook: step -> bool
    ):
        step = start_step
        metrics = None
        while step < n_steps:
            t0 = time.monotonic()
            if fail_at is not None and fail_at(step):
                raise RuntimeError(f"injected failure at step {step}")
            batch = shard_fn(self.data_at_step(step))
            state, metrics = self.step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            self.watchdog.observe(step, time.monotonic() - t0)
            step += 1
            if on_metrics:
                on_metrics(step, metrics)
            if self.checkpointer is not None and step % self.save_every == 0:
                self.checkpointer.save_async(state, step)
        if self.checkpointer is not None:
            self.checkpointer.save_async(state, step)
            self.checkpointer.wait()
        return state, metrics
