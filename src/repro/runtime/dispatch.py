"""Unified kernel-dispatch runtime: shape/platform-aware execution layer.

Replaces the ad-hoc ``use_pallas`` flag that used to be hand-threaded through
every model signature.  All backend selection — Pallas vs XLA vs reference,
fused vs two-GEMM vs dense-rematerialized, interpret-mode detection, VMEM
residency budgeting — lives here, in ONE policy layer, and the model zoo
calls shape-only entry points (``lowrank_apply``, ``dense_apply``, ...).

Usage mirrors ``sharding.rules.use_rules``:

    from repro.runtime.dispatch import DispatchConfig, use_dispatch

    with use_dispatch(DispatchConfig.from_arch(cfg)):
        logits, cache = model.prefill(params, batch, max_len)

Outside any context a default ``DispatchConfig()`` (backend="auto") applies,
so model code keeps working standalone (tests, notebooks) with the same
platform-appropriate choices.

Selection is made at TRACE time (shapes and platform are static), so each
decision is recorded once per traced call site in the hit counters —
``counters()`` / ``format_counters()`` let benchmarks report exactly which
path every linear in a compiled program took.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import threading
from collections import Counter
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.decode_attention import (
    decode_attention_pallas,
    paged_decode_attention_pallas,
)
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.lowrank_matmul import (
    DEFAULT_VMEM_LIMIT,
    fused_vmem_bytes,
    lowrank_matmul_batched_pallas,
    lowrank_matmul_pallas,
)
from repro.kernels.sketch_matmul import sketch_matmul_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas

__all__ = [
    "BACKENDS",
    "OPS",
    "PATH_DENSE",
    "PATH_TWO_GEMM",
    "PATH_FUSED",
    "PATH_FUSED_BATCHED",
    "DispatchConfig",
    "active_dispatch",
    "use_dispatch",
    "choose_lowrank_path",
    "choose_decode_path",
    "choose_paged_decode_path",
    "lowrank_apply",
    "dense_apply",
    "sketch_matmul",
    "ssd_scan",
    "flash_attention",
    "decode_attention",
    "paged_decode_attention",
    "counters",
    "counters_by_path",
    "reset_counters",
    "format_counters",
]

BACKENDS = ("auto", "xla", "pallas", "reference")
OPS = (
    "dense",
    "lowrank_matmul",
    "sketch_matmul",
    "ssd_scan",
    "flash_attention",
    "decode_attention",
    "paged_decode_attention",
)

# auto table: below this cache depth the flash-decode kernel's grid overhead
# exceeds what the dense einsum costs, so short caches stay on XLA
DECODE_MIN_SEQ = 128

# low-rank execution paths (what the auto table chooses between)
PATH_DENSE = "dense"  # materialize A @ B once, single GEMM (rank >= break-even)
PATH_TWO_GEMM = "two_gemm"  # (x @ A) @ B in XLA; (M, r) intermediate via HBM
PATH_FUSED = "fused"  # Pallas kernel, intermediate resident in VMEM
PATH_FUSED_BATCHED = "fused_batched"  # stacked (L, ...) fused kernel


@dataclasses.dataclass(frozen=True)
class DispatchConfig:
    """One immutable policy object injected once (MaxText-style) instead of a
    bool threaded through ~25 call sites.

    backend   : "auto" (shape/platform table) | "xla" | "pallas" | "reference"
    overrides : per-op backend pins, e.g. (("flash_attention", "xla"),)
    vmem_limit_bytes : dtype-aware residency budget for the fused path
                       (replaces the old static MAX_RANK/MAX_N constants)
    dense_min_tokens : flattened token count above which an over-break-even
                       rank is rematerialized to a dense GEMM
    fused_min_rank : ranks BELOW this never take the fused Pallas path —
                     elastic-rank tiers (core.lowrank.slice_rank) can slice
                     factors down to rank 1-2, where the kernel's rank-tile
                     grid is almost entirely padding and two thin XLA GEMMs
                     win; each tier's program re-traces, so the same config
                     routes each tier to its own best path
    interpret : force Pallas interpret mode; None = infer (non-TPU backends
                cannot lower Pallas-TPU natively)
    """

    backend: str = "auto"
    overrides: Tuple[Tuple[str, str], ...] = ()
    vmem_limit_bytes: int = DEFAULT_VMEM_LIMIT
    dense_min_tokens: int = 2048
    fused_min_rank: int = 2
    interpret: Optional[bool] = None

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"backend {self.backend!r} not in {BACKENDS}")
        for op, be in self.overrides:
            if op not in OPS:
                raise ValueError(f"override op {op!r} not in {OPS}")
            if be not in BACKENDS:
                raise ValueError(f"override backend {be!r} not in {BACKENDS}")

    @classmethod
    def from_arch(cls, cfg, **kw) -> "DispatchConfig":
        """Build from an ArchConfig's ``kernels`` field (``use_pallas`` is
        folded into ``kernels`` by ArchConfig itself, as a deprecated alias)."""
        return cls(backend=getattr(cfg, "kernels", "auto"), **kw)

    def backend_for(self, op: str) -> str:
        for o, be in self.overrides:
            if o == op:
                return be
        return self.backend

    def replace(self, **kw) -> "DispatchConfig":
        return dataclasses.replace(self, **kw)


_state = threading.local()
_DEFAULT = DispatchConfig()


def active_dispatch() -> DispatchConfig:
    return getattr(_state, "dispatch", None) or _DEFAULT


@contextlib.contextmanager
def use_dispatch(config: Optional[DispatchConfig] = None, **kw):
    """Install a DispatchConfig for the dynamic extent (mirrors use_rules).

    Keyword form: ``use_dispatch(backend="pallas")``.  Must be active while
    the model function is TRACED (jit tracing happens on first call)."""
    if config is None:
        config = DispatchConfig(**kw)
    elif kw:
        config = config.replace(**kw)
    prev = getattr(_state, "dispatch", None)
    _state.dispatch = config
    try:
        yield config
    finally:
        _state.dispatch = prev


# --------------------------------------------------------------------------- #
# hit counters (trace-time): (op, path, shape-signature) -> count
# --------------------------------------------------------------------------- #
_COUNTS: Counter = Counter()  # guarded by: _COUNTS_LOCK
_COUNTS_LOCK = threading.Lock()


def _record(op: str, path: str, sig: tuple):
    with _COUNTS_LOCK:
        _COUNTS[(op, path, sig)] += 1


def counters() -> dict:
    """{(op, path, shape_sig): hits} — one entry per distinct traced site."""
    with _COUNTS_LOCK:
        return dict(_COUNTS)


def counters_by_path() -> dict:
    """{(op, path): hits} aggregated over shapes."""
    agg: Counter = Counter()
    for (op, path, _sig), n in counters().items():
        agg[(op, path)] += n
    return dict(agg)


def reset_counters():
    with _COUNTS_LOCK:
        _COUNTS.clear()


def format_counters() -> str:
    rows = sorted(counters().items())
    if not rows:
        return "(no dispatched ops recorded)"
    return "\n".join(
        f"{op:16s} {path:14s} {str(sig):32s} x{n}" for (op, path, sig), n in rows
    )


# --------------------------------------------------------------------------- #
# auto selection table
# --------------------------------------------------------------------------- #
def _platform(platform: Optional[str]) -> str:
    return platform if platform is not None else jax.default_backend()


def _interpret(config: DispatchConfig, platform: str) -> bool:
    if config.interpret is not None:
        return config.interpret
    return platform != "tpu"


def _break_even_rank(d_in: int, d_out: int) -> int:
    return (d_in * d_out - 1) // (d_in + d_out)


def _lowrank_dims(x_shape, a_shape, b_shape):
    """(n_stack_dims, L, M, K, r, N) for a possibly-stacked factored apply."""
    nl = len(a_shape) - 2
    if len(b_shape) != len(a_shape):
        raise ValueError(f"A/B rank mismatch: A {a_shape}, B {b_shape}")
    if nl and (a_shape[:nl] != b_shape[:nl] or tuple(x_shape[:nl]) != a_shape[:nl]):
        raise ValueError(
            f"stacked lowrank apply: leading dims disagree "
            f"(x {x_shape}, A {a_shape}, B {b_shape})"
        )
    if x_shape[-1] != a_shape[-2]:
        raise ValueError(
            f"lowrank apply: x contraction dim {x_shape[-1]} != A rows "
            f"{a_shape[-2]} (x {x_shape}, A {a_shape})"
        )
    L = math.prod(a_shape[:nl]) if nl else 1
    M = math.prod(x_shape[nl:-1]) if len(x_shape) - nl > 1 else 1
    return nl, L, M, a_shape[-2], a_shape[-1], b_shape[-1]


def choose_lowrank_path(
    x_shape,
    a_shape,
    b_shape,
    dtype,
    *,
    config: Optional[DispatchConfig] = None,
    platform: Optional[str] = None,
) -> str:
    """The auto selection table: dense / two-GEMM / fused per call site.

    Inputs are static (shapes, dtype, platform), so this is a pure trace-time
    decision.  ``platform`` is injectable for tests.
    """
    config = config or active_dispatch()
    platform = _platform(platform)
    nl, _L, M, K, r, N = _lowrank_dims(x_shape, a_shape, b_shape)
    be = config.backend_for("lowrank_matmul")
    fused = PATH_FUSED_BATCHED if nl else PATH_FUSED
    # rank floor: a prefix-sliced tier (core.lowrank.slice_rank) can carry
    # rank 1-2 factors, where the fused kernel's rank tile is ~all padding
    fits = (
        fused_vmem_bytes(r, N, dtype) <= config.vmem_limit_bytes
        and r >= config.fused_min_rank
    )

    if be == "reference":
        return PATH_TWO_GEMM
    if be == "pallas":
        # forced Pallas still may not oversubscribe VMEM (or undershoot rank)
        return fused if fits else PATH_TWO_GEMM
    if be == "auto" and platform == "tpu" and fits:
        return fused
    # XLA (or auto off-TPU / non-resident): if the rank exceeds break-even the
    # factored form is MORE flops than dense — rematerialize W once when the
    # token batch amortizes the (K, r) @ (r, N) remat.
    if r >= _break_even_rank(K, N) and M >= config.dense_min_tokens:
        return PATH_DENSE
    return PATH_TWO_GEMM


# --------------------------------------------------------------------------- #
# execution entry points
# --------------------------------------------------------------------------- #
def dense_apply(x: jax.Array, w: jax.Array) -> jax.Array:
    """y = x @ W (dense kernel) with fp32 MXU accumulation."""
    _record("dense", "xla", (x.shape[-1], w.shape[-1]))
    return jnp.matmul(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def lowrank_apply(x: jax.Array, A: jax.Array, B: jax.Array) -> jax.Array:
    """y = (x @ A) @ B via whichever path the dispatch table selects.

    2-D factors: x (..., K), A (K, r), B (r, N) — leading x dims flattened.
    Stacked factors: A (L..., K, r), B (L..., r, N) with x (L..., M..., K) —
    the scan/expert-stacked case.  Every path canonicalizes the stacked case
    to (L, M, K) @ (L, K, r) @ (L, r, N) first, so fused and fallback paths
    agree for any leading-dim layout (bare jnp.matmul broadcasting would
    crash or silently misalign inner batch dims against the stack).
    """
    config = active_dispatch()
    platform = _platform(None)
    path = choose_lowrank_path(
        x.shape, A.shape, B.shape, x.dtype, config=config, platform=platform
    )
    nl, L, M, K, r, N = _lowrank_dims(x.shape, A.shape, B.shape)
    _record("lowrank_matmul", path, (L, M, K, r, N))
    out_shape = x.shape[:-1] + (N,)
    if nl:
        xc, Ac, Bc = x.reshape(L, M, K), A.reshape(L, K, r), B.reshape(L, r, N)
    else:
        xc, Ac, Bc = x, A, B  # 2-D factors broadcast over any x leading dims

    if path == PATH_DENSE:
        w = jnp.matmul(
            Ac.astype(jnp.float32), Bc.astype(jnp.float32)
        ).astype(x.dtype)
        y = jnp.matmul(xc, w, preferred_element_type=jnp.float32).astype(x.dtype)
        return y.reshape(out_shape)
    if path == PATH_FUSED:
        y = lowrank_matmul_pallas(
            xc.reshape(-1, K), Ac, Bc,
            interpret=_interpret(config, platform),
            vmem_limit=config.vmem_limit_bytes,
        )
        return y.reshape(out_shape)
    if path == PATH_FUSED_BATCHED:
        y = lowrank_matmul_batched_pallas(
            xc, Ac, Bc,
            interpret=_interpret(config, platform),
            vmem_limit=config.vmem_limit_bytes,
        )
        return y.reshape(out_shape)
    # two-GEMM fallback IS the reference implementation for this op
    return _ref.lowrank_matmul_ref(xc, Ac, Bc).reshape(out_shape)


def _use_pallas(op: str, config: DispatchConfig, platform: str) -> bool:
    be = config.backend_for(op)
    if be == "pallas":
        return True
    if be in ("xla", "reference"):
        return False
    return platform == "tpu"  # auto: interpret-mode Pallas is a debug tool


def sketch_matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """(M, K) @ (K, N) — RSI sketch GEMM."""
    config = active_dispatch()
    platform = _platform(None)
    if _use_pallas("sketch_matmul", config, platform):
        _record("sketch_matmul", "pallas", (a.shape, b.shape))
        return sketch_matmul_pallas(a, b, interpret=_interpret(config, platform))
    _record("sketch_matmul", "xla", (a.shape, b.shape))
    return _ref.sketch_matmul_ref(a, b)


def ssd_scan(x, dt, B_in, C_in, A, *, chunk: int = 128):
    """Mamba2 SSD chunked scan.  Returns (y, final_state)."""
    config = active_dispatch()
    platform = _platform(None)
    if _use_pallas("ssd_scan", config, platform):
        _record("ssd_scan", "pallas", (x.shape, chunk))
        return ssd_scan_pallas(
            x, dt, B_in, C_in, A, chunk=chunk, interpret=_interpret(config, platform)
        )
    _record("ssd_scan", "xla", (x.shape, chunk))
    xbar = (x.astype(jnp.float32) * dt[..., None].astype(jnp.float32)).astype(x.dtype)
    return _ref.ssd_scan_ref(xbar, dt, B_in, C_in, A)


def flash_attention(q, k, v, *, causal: bool = True):
    """Forward-only flash attention (prefill hot path)."""
    config = active_dispatch()
    platform = _platform(None)
    if _use_pallas("flash_attention", config, platform):
        _record("flash_attention", "pallas", (q.shape, causal))
        return flash_attention_pallas(
            q, k, v, causal=causal, interpret=_interpret(config, platform)
        )
    _record("flash_attention", "xla", (q.shape, causal))
    return _ref.flash_attention_ref(q, k, v, causal=causal)


def choose_decode_path(
    q_shape,
    kv_shape,
    *,
    config: Optional[DispatchConfig] = None,
    platform: Optional[str] = None,
) -> str:
    """Auto table for one-token decode attention: "pallas" or "xla".

    Like ``choose_lowrank_path`` this is a pure trace-time decision over
    static shapes and platform: the flash-decode kernel wins on TPU once the
    cache is deep enough to amortize its grid (DECODE_MIN_SEQ); short caches
    and non-TPU platforms take the dense einsum reference.  A pinned
    "pallas" backend always takes the kernel (interpret mode off-TPU);
    "xla"/"reference" always take the einsum.
    """
    config = config or active_dispatch()
    platform = _platform(platform)
    be = config.backend_for("decode_attention")
    if be == "pallas":
        return "pallas"
    if be in ("xla", "reference"):
        return "xla"
    if platform == "tpu" and kv_shape[1] >= DECODE_MIN_SEQ:
        return "pallas"
    return "xla"


def decode_attention(q, k_cache, v_cache, valid):
    """One-token GQA attention over a cache (the serving decode hot path).

    q: (B, 1, H, hd); k_cache: (B, S, KV, hd); v_cache: (B, S, KV, vd);
    valid: (B, S) bool strict per-slot mask.  Fully-masked rows produce
    zeros (see kernels/ref.decode_attention_ref).
    """
    config = active_dispatch()
    platform = _platform(None)
    path = choose_decode_path(q.shape, k_cache.shape, config=config, platform=platform)
    B, _, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    _record("decode_attention", path, (B, S, KV, H // KV, hd))
    if path == "pallas":
        return decode_attention_pallas(
            q, k_cache, v_cache, valid, interpret=_interpret(config, platform)
        )
    return _ref.decode_attention_ref(q, k_cache, v_cache, valid)


def choose_paged_decode_path(
    q_shape,
    pool_shape,
    n_tbl: int,
    *,
    config: Optional[DispatchConfig] = None,
    platform: Optional[str] = None,
) -> str:
    """Auto table for BLOCK-TABLE decode attention: "pallas" or "xla".

    Same shape logic as :func:`choose_decode_path` with the cache depth
    measured LOGICALLY (``n_tbl`` block-table entries x page tokens): on TPU
    a deep-enough virtual sequence amortizes the paged kernel's grid, while
    short tables and non-TPU platforms take the gather-einsum reference
    (kernels/ref.paged_decode_attention_ref).  Pins behave as everywhere
    else: "pallas" forces the kernel (interpret off-TPU), "xla"/"reference"
    force the gather.
    """
    config = config or active_dispatch()
    platform = _platform(platform)
    be = config.backend_for("paged_decode_attention")
    if be == "pallas":
        return "pallas"
    if be in ("xla", "reference"):
        return "xla"
    if platform == "tpu" and n_tbl * pool_shape[1] >= DECODE_MIN_SEQ:
        return "pallas"
    return "xla"


def paged_decode_attention(q, k_pool, v_pool, block_table, n_valid):
    """One-token GQA attention through a paged KV pool (continuous batching).

    q: (B, 1, H, hd); pools: (P, page, KV, hd/vd) physical pages shared by
    every slot; block_table: (B, n_tbl) int32 page ids; n_valid: (B,) int32
    valid logical positions.  The Pallas kernel streams pages through the
    block table with scalar-prefetch index maps (no per-slot gather is ever
    materialized); the XLA path gathers and defers to the flat einsum
    oracle.  Fully-masked rows produce zeros on both paths.
    """
    config = active_dispatch()
    platform = _platform(None)
    n_tbl = block_table.shape[1]
    path = choose_paged_decode_path(
        q.shape, k_pool.shape, n_tbl, config=config, platform=platform
    )
    B, _, H, hd = q.shape
    P, page, KV = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    _record("paged_decode_attention", path, (B, P, page, n_tbl, KV, H // KV, hd))
    if path == "pallas":
        return paged_decode_attention_pallas(
            q, k_pool, v_pool, block_table, n_valid,
            interpret=_interpret(config, platform),
        )
    return _ref.paged_decode_attention_ref(q, k_pool, v_pool, block_table, n_valid)
