"""Trip-count-aware HLO module analysis.

``compiled.cost_analysis()`` counts each while-loop BODY once — for
scan-over-layers models that undercounts FLOPs/bytes by the layer count (an
80-layer qwen2 step would report ~1/80th of its compute).  This module
parses the optimized HLO text into its computation graph and walks it
recursively, multiplying while bodies by their trip counts (recovered from
the loop-condition constant) and counting:

  * flops        — dot/convolution FLOPs from operand shapes + contracting
                   dims (2*prod(result)*prod(contraction)); elementwise
                   transcendentals counted at 1 flop/elem (negligible next
                   to the dots, included for completeness);
  * hbm_bytes    — Σ per top-level instruction (operand+result bytes).
                   The module is post-fusion, so fusion internals are NOT
                   counted — each fusion contributes its boundary traffic,
                   which is the standard "bytes accessed" HBM model;
  * collectives  — ring-transfer bytes per op kind (same formulas as
                   analysis.parse_collectives) with while-multiplication.

All numbers are per-device (the SPMD module is per-device).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict

__all__ = ["HloStats", "analyze_hlo"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "u4": 1, "s4": 1,
}

_COLL_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# rtype is lazy-`.*?` (NOT [^=]) because tuple types embed `/*index=N*/`
# comments containing '='; the first `word(` after the '=' is always the op.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$"
)
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")
_CALL_ATTR_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_ATTR_RE = re.compile(r"condition=%?([\w.\-]+)")
_GROUPS_ITOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_elems_bytes(type_str: str):
    n_total, b_total = 0, 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        n_total += n
        b_total += n * _DTYPE_BYTES[dt]
    return n_total, b_total


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLL_KINDS}
    )
    coll_ops: int = 0

    def add(self, other: "HloStats", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for k in _COLL_KINDS:
            self.coll_bytes[k] += other.coll_bytes[k] * mult
        self.coll_ops += int(other.coll_ops * mult)

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())


class _Instr:
    __slots__ = ("name", "rtype", "op", "rest")

    def __init__(self, name, rtype, op, rest):
        self.name, self.rtype, self.op, self.rest = name, rtype, op, rest


def _parse_computations(hlo: str):
    comps: Dict[str, list] = {}
    cur = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            comps[cur].append(_Instr(m.group(1), m.group(2), m.group(3), m.group(4)))
    return comps


def _dot_flops(instr: _Instr, shapes: Dict[str, str]) -> float:
    """2 * prod(result dims) * prod(lhs contracting dim sizes)."""
    _, rb = _shape_elems_bytes(instr.rtype)
    r_elems, _ = _shape_elems_bytes(instr.rtype)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rest)
    ops = [o.strip().lstrip("%") for o in instr.rest.split(")")[0].split(",")[:2]]
    lhs_type = shapes.get(ops[0], "")
    dims_m = _SHAPE_RE.search(lhs_type)
    if not (m and dims_m):
        return 2.0 * r_elems  # fallback: unknown contraction
    lhs_dims = [int(d) for d in dims_m.group(2).split(",") if d]
    contract = 1
    for idx in m.group(1).split(","):
        if idx != "" and int(idx) < len(lhs_dims):
            contract *= lhs_dims[int(idx)]
    return 2.0 * r_elems * contract


def _group_size(rest: str, world: int) -> int:
    m = _GROUPS_ITOTA_RE.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(rest)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return world


def _trip_count(cond_instrs, comps=None) -> int:
    """Trip count from the counted-loop pattern.  The bound is the SCALAR
    s32 constant in the condition computation (`compare(counter, N)`,
    possibly wrapped in a fusion); LE adds one.  Non-scalar constants
    (shape/table data) are ignored — taking any constant over-counts."""
    best = 0
    le = False
    for ins in cond_instrs:
        if ins.op == "constant" and re.match(r"^[su]\d+\[\]", ins.rtype.strip()):
            m = re.match(r"\s*(\d+)\s*\)?", ins.rest)
            if m:
                best = max(best, int(m.group(1)))
        if "direction=LE" in ins.rest:
            le = True
        # compare may live inside a wrapped fusion
        if comps is not None and ins.op == "fusion":
            called = _CALL_ATTR_RE.search(ins.rest)
            if called:
                for ins2 in comps.get(called.group(1), []):
                    if "direction=LE" in ins2.rest:
                        le = True
    if best == 0:
        return 1
    return best + (1 if le else 0)


def _slice_effective_bytes(fused_instrs):
    """{param_index: effective bytes} for fusion params consumed only by
    dynamic-slice (touches slice-sized data, not the whole buffer)."""
    params = {}
    for ins in fused_instrs:
        if ins.op == "parameter":
            m = re.match(r"\s*(\d+)", ins.rest)
            if m:
                params[ins.name] = int(m.group(1))
    consumers: Dict[str, list] = {p: [] for p in params}
    for ins in fused_instrs:
        if ins.op == "parameter":
            continue
        for o in re.findall(r"%([\w.\-]+)", ins.rest.split("metadata")[0]):
            if o in consumers:
                consumers[o].append(ins)
    out = {}
    for pname, uses in consumers.items():
        if uses and all(u.op == "dynamic-slice" for u in uses):
            out[params[pname]] = sum(_shape_elems_bytes(u.rtype)[1] for u in uses)
    return out


# elementwise transcendental ops counted at 1 flop/element
_EW_OPS = {
    "add", "subtract", "multiply", "divide", "exponential", "log", "tanh",
    "rsqrt", "sqrt", "power", "maximum", "minimum", "compare", "select",
}


def analyze_hlo(hlo: str, *, world: int) -> HloStats:
    comps = _parse_computations(hlo)
    cache: Dict[str, HloStats] = {}

    # find entry: computation named like ENTRY (first in file order that is
    # referenced by no other, fallback "main")
    referenced = set()
    for instrs in comps.values():
        for ins in instrs:
            for m in _CALL_ATTR_RE.finditer(ins.rest):
                referenced.add(m.group(1))
            for m in _COND_ATTR_RE.finditer(ins.rest):
                referenced.add(m.group(1))
    entry = None
    for name in comps:
        if ("main" in name and name not in referenced) or entry is None and name not in referenced:
            entry = name
            if "main" in name:
                break
    if entry is None:
        entry = next(iter(comps))

    def cost(comp_name: str, *, count_bytes: bool) -> HloStats:
        key = (comp_name, count_bytes)
        if key in cache:
            return cache[key]
        st = HloStats()
        shapes = {ins.name: ins.rtype for ins in comps.get(comp_name, [])}
        # parameters also have shapes in rest — add from 'parameter' ops
        for ins in comps.get(comp_name, []):
            op = ins.op
            kind = op[:-6] if op.endswith("-start") else op
            if kind in _COLL_KINDS and not op.endswith("-done"):
                n = _group_size(ins.rest, world)
                _, b = _shape_elems_bytes(ins.rtype)
                if n > 1:
                    if kind == "all-reduce":
                        moved = 2.0 * (n - 1) / n * b
                    elif kind == "all-gather":
                        moved = (n - 1) / n * b
                    elif kind == "reduce-scatter":
                        moved = (n - 1.0) * b
                    elif kind == "all-to-all":
                        moved = (n - 1) / n * b
                    else:
                        moved = float(b)
                    st.coll_bytes[kind] += moved
                    st.coll_ops += 1
            if op in ("dot", "convolution"):
                st.flops += _dot_flops(ins, shapes)
            elif op in _EW_OPS:
                n, _ = _shape_elems_bytes(ins.rtype)
                st.flops += n
            # ---- bytes: boundary traffic of top-level ops ----
            if count_bytes and op not in ("parameter", "constant", "tuple", "get-tuple-element", "bitcast"):
                _, rb = _shape_elems_bytes(ins.rtype)
                opnd_names = re.findall(r"%([\w.\-]+)", ins.rest.split("metadata")[0])
                op_bytes = []
                for opnd in opnd_names:
                    if opnd in shapes:
                        op_bytes.append((shapes[opnd], _shape_elems_bytes(shapes[opnd])[1]))
                if op == "dynamic-slice":
                    st.hbm_bytes += 2.0 * rb  # read slice + write result
                elif op in ("fusion", "dynamic-update-slice") and any(
                    t.split("{")[0] == ins.rtype.split("{")[0] for t, _ in op_bytes
                ):
                    # in-place update pattern (DUS / accumulate fusions): the
                    # buffer-sized operand aliases the result; real traffic is
                    # the non-aliased operands read + the touched slice write.
                    other = sum(
                        b for t, b in op_bytes if t.split("{")[0] != ins.rtype.split("{")[0]
                    )
                    st.hbm_bytes += 2.0 * other
                elif op == "fusion":
                    # slice-consuming fusions: a param consumed ONLY by
                    # dynamic-slice inside the fused computation touches the
                    # slice, not the whole (possibly multi-GB, loop-carried)
                    # operand buffer.  Operand position i binds parameter(i).
                    eff = {
                        i: (_shape_elems_bytes(shapes[nm])[1] if nm in shapes else 0)
                        for i, nm in enumerate(opnd_names)
                    }
                    called = _CALL_ATTR_RE.search(ins.rest)
                    if called and called.group(1) in comps:
                        for idx, b in _slice_effective_bytes(comps[called.group(1)]).items():
                            if idx in eff:
                                eff[idx] = min(eff[idx], b)
                    st.hbm_bytes += rb + sum(eff.values())
                else:
                    st.hbm_bytes += rb + sum(b for _, b in op_bytes)
            # ---- recurse ----
            if op == "while":
                body = _CALL_ATTR_RE.search(ins.rest)
                cond = _COND_ATTR_RE.search(ins.rest)
                trip = _trip_count(comps.get(cond.group(1), []), comps) if cond else 1
                if body:
                    st.add(cost(body.group(1), count_bytes=count_bytes), mult=trip)
            elif op == "fusion":
                called = _CALL_ATTR_RE.search(ins.rest)
                if called:
                    # fusions: count INTERNAL flops, but bytes only at the
                    # boundary (already added above)
                    st.add(cost(called.group(1), count_bytes=False))
            elif op in ("call", "conditional", "custom-call", "async-start"):
                for m in _CALL_ATTR_RE.finditer(ins.rest):
                    st.add(cost(m.group(1), count_bytes=count_bytes))
            elif op in ("reduce", "sort", "scatter", "select-and-scatter", "map"):
                pass  # applied computations are tiny per-element lambdas
        cache[key] = st
        return st

    return cost(entry, count_bytes=True)
