"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell, in seconds:

    t_compute = HLO_FLOPs   / (PEAK_FLOPS_BF16)        [per-chip]
    t_memory  = HLO_bytes   / (HBM_BW)                 [per-chip]
    t_coll    = coll_bytes  / (ICI_LINK_BW * LINKS)    [per-chip]

``compiled.cost_analysis()`` supplies per-chip FLOPs and bytes (the SPMD
module is per-device).  Collective bytes are NOT in cost_analysis: we parse
the optimized HLO text and apply ring-transfer formulas per op kind with the
participant count from replica_groups.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

__all__ = ["CollectiveStats", "parse_collectives", "roofline_terms", "Roofline"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# "bf16[8,4096,512]{...}" or "(f32[...], f32[...])" result types
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_ITOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, world: int) -> int:
    m = _GROUPS_ITOTA_RE.search(line)
    if m:
        n_groups, group_size = int(m.group(1)), int(m.group(2))
        return group_size
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return world


@dataclasses.dataclass
class CollectiveStats:
    """Per-chip collective traffic (ring-transfer bytes) by op kind."""

    by_kind: dict
    op_count: int

    @property
    def total_bytes(self) -> float:
        return sum(self.by_kind.values())


def parse_collectives(hlo_text: str, *, world: int) -> CollectiveStats:
    by_kind = {k: 0.0 for k in _COLL_KINDS}
    count = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        # match "<result_type> <op>(" instruction forms, incl. "-start" async
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", s)
        if not m:
            continue
        result_type, op = m.group(1), m.group(2)
        kind = op[:-6] if op.endswith("-start") else op
        if kind not in _COLL_KINDS:
            continue
        if op.endswith("-done"):
            continue
        n = _group_size(s, world)
        if n <= 1:
            continue
        b = _shape_bytes(result_type)
        if kind == "all-reduce":
            moved = 2.0 * (n - 1) / n * b
        elif kind == "all-gather":
            moved = (n - 1) / n * b  # b is the gathered (result) size
        elif kind == "reduce-scatter":
            moved = (n - 1) * b  # b is the scattered (result) size
        elif kind == "all-to-all":
            moved = (n - 1) / n * b
        else:  # collective-permute
            moved = float(b)
        by_kind[kind] += moved
        count += 1
    return CollectiveStats(by_kind=by_kind, op_count=count)


@dataclasses.dataclass
class Roofline:
    flops: float  # per-chip HLO FLOPs
    hbm_bytes: float  # per-chip bytes accessed
    coll_bytes: float  # per-chip collective bytes moved
    t_compute: float
    t_memory: float
    t_collective: float
    model_flops_global: float  # 6*N*D analytic
    chips: int

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_total(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (global HLO FLOPs) — remat/redundancy waste."""
        hlo_global = self.flops * self.chips
        return self.model_flops_global / hlo_global if hlo_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-FLOPs utilization at the bounding term == achievable MFU."""
        if self.t_total <= 0:
            return 0.0
        return (self.model_flops_global / self.chips) / (
            self.t_total * 197e12
        )


def roofline_terms(
    *,
    flops: float,
    hbm_bytes: float,
    coll_bytes: float,
    chips: int,
    model_flops_global: float,
    peak_flops: float = 197e12,
    hbm_bw: float = 819e9,
    ici_bw: float = 50e9 * 2,
) -> Roofline:
    return Roofline(
        flops=flops,
        hbm_bytes=hbm_bytes,
        coll_bytes=coll_bytes,
        t_compute=flops / peak_flops,
        t_memory=hbm_bytes / hbm_bw,
        t_collective=coll_bytes / ici_bw,
        model_flops_global=model_flops_global,
        chips=chips,
    )
