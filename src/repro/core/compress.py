"""Layer-wise RSI compression pipeline over model parameter pytrees.

This is the end-to-end feature of the paper (Sec. 4.2) as a framework
component: walk a params pytree, select compressible linear kernels by policy,
run (batched) RSI on each, and emit (i) a new pytree where selected dense
leaves are replaced by factored ``{"a","b"}`` subtrees, (ii) a matching
transformed sharding-spec tree, and (iii) a :class:`CompressionReport`.

Rank policies:
  * ``alpha`` — the paper's rule  k = ceil(alpha * min(C, D)).
  * ``energy`` — beyond-paper adaptive rule: smallest k whose sketched
    spectrum retains ``energy`` fraction of the squared Frobenius mass
    (addresses the paper's "future work: adaptive layer-wise ranks").

Stacked parameters from lax.scan layers — shape (L, d_in, d_out) or
(L, E, d_in, d_out) for per-expert kernels — are compressed with vmapped RSI
(one independent sketch per layer/expert), so a whole 80-layer stack is one
XLA call.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import lowrank
from repro.core.rsi import rsi_factors, rsi

__all__ = ["CompressionPolicy", "LayerReport", "CompressionReport", "compress_tree"]


@dataclasses.dataclass(frozen=True)
class CompressionPolicy:
    """What to compress and how hard.

    Attributes:
      alpha: paper's compression factor in (0, 1); rank k = ceil(alpha*min dim).
      q: RSI iteration count (q=1 == RSVD baseline).
      rank_rule: 'alpha' | 'energy'.
      energy: squared-singular-value mass to retain under the 'energy' rule.
      min_dim: skip matrices whose min(C, D) is below this (routers, tiny
        projections — compressing them saves nothing and risks quality).
      include: regex on the '/'-joined param path; only matches compress.
      exclude: regex; matches are never compressed (e.g. embeddings by default
        — their "rows are tokens" structure is not a spectral-decay regime).
      break_even_only: skip layers where the alpha-rule rank would *grow* the
        parameter count (paper Table 4.1 alpha=0.8 rows have ratio > 1.0; this
        flag reproduces or avoids that regime).
      oversample: RSI oversampling p.
      max_rank: optional hard cap on k (VMEM sizing for the fused serve kernel).
    """

    alpha: float = 0.4
    q: int = 4
    rank_rule: str = "alpha"
    energy: float = 0.95
    min_dim: int = 257
    include: str = r".*"
    exclude: str = r"(?:^|/)(embed|embedding|router|gate_w|conv|dt_|A_log|D_param|norm)"
    break_even_only: bool = True
    oversample: int = 0
    max_rank: int | None = None

    def rank_for(self, c: int, d: int) -> int:
        k = int(-(-self.alpha * min(c, d) // 1))  # ceil
        if self.max_rank is not None:
            k = min(k, self.max_rank)
        return max(k, 1)


@dataclasses.dataclass
class LayerReport:
    path: str
    shape: tuple
    rank: int
    params_before: int
    params_after: int
    compressed: bool
    reason: str = ""


@dataclasses.dataclass
class CompressionReport:
    policy: CompressionPolicy
    layers: list
    params_before: int = 0
    params_after: int = 0

    @property
    def ratio(self) -> float:
        """Paper's compression ratio: compressed params / original params."""
        return self.params_after / max(self.params_before, 1)

    def summary(self) -> str:
        n = sum(1 for l in self.layers if l.compressed)
        return (
            f"compressed {n}/{len(self.layers)} tensors, "
            f"ratio={self.ratio:.3f} (alpha={self.policy.alpha}, q={self.policy.q})"
        )


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((name, path, leaf))
    return out, treedef


def _energy_rank(W2d: jax.Array, policy: CompressionPolicy, key) -> int:
    """Adaptive rank: sketch the spectrum once at the break-even rank, then
    choose the smallest k capturing `energy` of squared mass (concrete Python
    int — rank must be static for the factored shapes)."""
    c, d = W2d.shape
    probe = min(lowrank.break_even_rank(c, d), min(c, d))
    res = rsi(W2d, probe, max(policy.q, 2), key, oversample=policy.oversample)
    s2 = jnp.cumsum(res.S.astype(jnp.float32) ** 2)
    total = s2[-1]
    k = int(jnp.searchsorted(s2, policy.energy * total)) + 1
    return max(1, min(k, probe))


def compress_tree(
    params: Any,
    policy: CompressionPolicy,
    key: jax.Array,
    *,
    specs: Any = None,
    spec_transform: Callable[[Any], Any] | None = None,
) -> tuple[Any, Any, CompressionReport]:
    """Compress every policy-selected kernel in ``params``.

    Args:
      params: model parameter pytree.  Kernels may be 2-D (in,out), 3-D
        (layers,in,out) or 4-D (layers,experts,in,out); the trailing two dims
        are the matrix, leading dims are vmapped.
      policy: CompressionPolicy.
      specs: optional parallel pytree of PartitionSpecs; transformed in lock
        step (dense spec -> {"a": spec_a, "b": spec_b}).
      spec_transform: fn(dense_spec) -> (spec_a, spec_b); defaults to keeping
        the input-dim spec on A and output-dim spec on B with the k axis
        unsharded.

    Returns:
      (new_params, new_specs, report)
    """
    inc, exc = re.compile(policy.include), re.compile(policy.exclude)
    leaves, _ = _flatten_with_paths(params)
    report = CompressionReport(policy=policy, layers=[])

    # Mutate via nested dict copies (params trees here are nested dicts).
    def deep_set(tree, path, value):
        node = tree
        for p in path[:-1]:
            node = node[p.key]
        node[path[-1].key] = value

    new_params = jax.tree_util.tree_map(lambda x: x, params)
    new_specs = jax.tree_util.tree_map(lambda x: x, specs) if specs is not None else None

    keys = jax.random.split(key, max(len(leaves), 1))
    for (name, path, leaf), k_i in zip(leaves, keys):
        if not hasattr(leaf, "ndim"):
            continue
        report.params_before += leaf.size
        report.params_after += leaf.size  # adjusted below on compression
        if leaf.ndim < 2:
            continue
        c, d = leaf.shape[-2], leaf.shape[-1]
        entry = LayerReport(
            path=name,
            shape=tuple(leaf.shape),
            rank=0,
            params_before=leaf.size,
            params_after=leaf.size,
            compressed=False,
        )
        report.layers.append(entry)
        if not inc.search(name) or exc.search(name):
            entry.reason = "policy-excluded"
            continue
        if min(c, d) < policy.min_dim:
            entry.reason = f"min-dim {min(c, d)} < {policy.min_dim}"
            continue

        if policy.rank_rule == "energy":
            w2d = leaf.reshape(-1, c, d)[0]
            rank = _energy_rank(w2d, policy, k_i)
        else:
            rank = policy.rank_for(c, d)
        if policy.break_even_only and rank >= lowrank.break_even_rank(c, d):
            entry.reason = f"rank {rank} >= break-even {lowrank.break_even_rank(c, d)}"
            continue

        fact = lambda W, kk: rsi_factors(
            W, rank, policy.q, kk, oversample=policy.oversample
        )
        lead = leaf.shape[:-2]
        if lead:
            w_flat = leaf.reshape((-1,) + leaf.shape[-2:])
            kk = jax.random.split(k_i, w_flat.shape[0])
            A, B = jax.vmap(fact)(w_flat, kk)
            A = A.reshape(lead + A.shape[1:])
            B = B.reshape(lead + B.shape[1:])
        else:
            A, B = fact(leaf, k_i)

        node = lowrank.lowrank_params(A, B)
        deep_set(new_params, path, node)
        entry.rank = rank
        entry.params_after = A.size + B.size
        entry.compressed = True
        report.params_after += entry.params_after - entry.params_before

        if new_specs is not None:
            import jax.sharding as jsh

            def default_tf(sp):
                if sp is None:
                    sp = jsh.PartitionSpec()
                parts = tuple(sp)
                lead_n = leaf.ndim - 2
                lead_sp = parts[:lead_n] if len(parts) >= lead_n else (None,) * lead_n
                in_sp = parts[lead_n] if len(parts) > lead_n else None
                out_sp = parts[lead_n + 1] if len(parts) > lead_n + 1 else None
                return (
                    jsh.PartitionSpec(*lead_sp, in_sp, None),
                    jsh.PartitionSpec(*lead_sp, None, out_sp),
                )

            tf = spec_transform or default_tf
            node_spec = None
            try:
                node_spec_src = new_specs
                for p in path[:-1]:
                    node_spec_src = node_spec_src[p.key]
                sp_a, sp_b = tf(node_spec_src[path[-1].key])
                node_spec_src[path[-1].key] = {"a": sp_a, "b": sp_b}
            except (KeyError, TypeError):
                pass  # spec tree not parallel at this path; leave untouched

    return new_params, new_specs, report
