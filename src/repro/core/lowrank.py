"""Factored (low-rank) linear parameters: W (in,out) ~= A (in,k) @ B (k,out).

Convention note: the paper writes z = W h with W in R^{C x D} (out x in).  The
framework stores every linear kernel in the JAX-native orientation (d_in,
d_out) with y = x @ W; RSI is orientation-agnostic so the factors here are the
transposes of the paper's (A_paper, B_paper) — parameter counts and spectral
errors are identical.

A compressed linear is represented *structurally* in the params pytree: the
dense leaf ``W`` is replaced by the subtree ``{"a": A, "b": B}``.  Every
linear-apply site in the model zoo goes through :func:`apply_linear`, so a
compressed checkpoint is a drop-in replacement for a dense one in both the
training and serving paths.
"""

from __future__ import annotations

import math
from typing import Any, Mapping

import jax
import jax.numpy as jnp

__all__ = [
    "is_lowrank",
    "lowrank_params",
    "apply_linear",
    "param_count",
    "break_even_rank",
    "materialize",
    "slice_rank",
    "min_rank",
]


def is_lowrank(p: Any) -> bool:
    return isinstance(p, Mapping) and "a" in p and "b" in p


def lowrank_params(A: jax.Array, B: jax.Array) -> dict:
    return {"a": A, "b": B}


def apply_linear(p: Any, x: jax.Array) -> jax.Array:
    """y = x @ W for dense W, or (x @ A) @ B for the factored form.

    Backend selection (fused Pallas VMEM kernel vs two XLA GEMMs vs dense
    rematerialization, batched fused for stacked factors) is owned entirely
    by :mod:`repro.runtime.dispatch` — install a policy with ``use_dispatch``;
    without one the "auto" shape/platform table applies.
    """
    from repro.runtime import dispatch

    if is_lowrank(p):
        return dispatch.lowrank_apply(x, p["a"], p["b"])
    return dispatch.dense_apply(x, p)


def param_count(p: Any) -> int:
    if is_lowrank(p):
        return p["a"].size + p["b"].size
    return p.size


def break_even_rank(d_in: int, d_out: int) -> int:
    """Largest k for which (d_in + d_out) * k < d_in * d_out."""
    return (d_in * d_out - 1) // (d_in + d_out)


def materialize(p: Any) -> jax.Array:
    """Densify a (possibly factored) kernel — for analysis/tests only."""
    if is_lowrank(p):
        a32 = p["a"].astype(jnp.float32)
        b32 = p["b"].astype(jnp.float32)
        return (a32 @ b32).astype(p["a"].dtype)
    return p


def _sliced_rank(r: int, fraction: float) -> int:
    return max(1, min(r, int(math.ceil(fraction * r))))


def slice_rank(params: Any, fraction: float):
    """Prefix-slice every factored leaf to ``ceil(fraction * rank)`` columns.

    RSI orders singular directions by decreasing singular value, so the
    factors are *nested*: the best rank-``r'`` approximation available from a
    rank-``r`` factor pair is exactly the prefix slice ``A[..., :, :r']``,
    ``B[..., :r', :]``.  One stored checkpoint therefore serves every cheaper
    tier with zero extra memory — the slices are views taken at trace time.

    Stacked factors (leading scan-layer / MoE-expert dims) slice on the same
    trailing rank axis.  Dense leaves and non-factored subtrees pass through
    untouched, so the result is a drop-in params pytree for the same model.
    """
    if not 0.0 < fraction <= 1.0:
        raise ValueError(f"rank fraction must be in (0, 1], got {fraction}")
    if fraction == 1.0:
        return params

    def walk(node: Any) -> Any:
        if is_lowrank(node):
            r = node["a"].shape[-1]
            k = _sliced_rank(r, fraction)
            out = dict(node)
            out["a"] = node["a"][..., :, :k]
            out["b"] = node["b"][..., :k, :]
            return out
        if isinstance(node, Mapping):
            return {key: walk(val) for key, val in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node

    return walk(params)


def min_rank(params: Any) -> int:
    """Smallest factored rank in the pytree (0 when nothing is factored)."""
    ranks: list = []

    def walk(node: Any) -> None:
        if is_lowrank(node):
            ranks.append(int(node["a"].shape[-1]))
            return
        if isinstance(node, Mapping):
            for v in node.values():
                walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)

    walk(params)
    return min(ranks) if ranks else 0
