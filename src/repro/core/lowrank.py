"""Factored (low-rank) linear parameters: W (in,out) ~= A (in,k) @ B (k,out).

Convention note: the paper writes z = W h with W in R^{C x D} (out x in).  The
framework stores every linear kernel in the JAX-native orientation (d_in,
d_out) with y = x @ W; RSI is orientation-agnostic so the factors here are the
transposes of the paper's (A_paper, B_paper) — parameter counts and spectral
errors are identical.

A compressed linear is represented *structurally* in the params pytree: the
dense leaf ``W`` is replaced by the subtree ``{"a": A, "b": B}``.  Every
linear-apply site in the model zoo goes through :func:`apply_linear`, so a
compressed checkpoint is a drop-in replacement for a dense one in both the
training and serving paths.
"""

from __future__ import annotations

from typing import Any, Mapping

import jax
import jax.numpy as jnp

__all__ = [
    "is_lowrank",
    "lowrank_params",
    "apply_linear",
    "param_count",
    "break_even_rank",
    "materialize",
]


def is_lowrank(p: Any) -> bool:
    return isinstance(p, Mapping) and "a" in p and "b" in p


def lowrank_params(A: jax.Array, B: jax.Array) -> dict:
    return {"a": A, "b": B}


def apply_linear(p: Any, x: jax.Array) -> jax.Array:
    """y = x @ W for dense W, or (x @ A) @ B for the factored form.

    Backend selection (fused Pallas VMEM kernel vs two XLA GEMMs vs dense
    rematerialization, batched fused for stacked factors) is owned entirely
    by :mod:`repro.runtime.dispatch` — install a policy with ``use_dispatch``;
    without one the "auto" shape/platform table applies.
    """
    from repro.runtime import dispatch

    if is_lowrank(p):
        return dispatch.lowrank_apply(x, p["a"], p["b"])
    return dispatch.dense_apply(x, p)


def param_count(p: Any) -> int:
    if is_lowrank(p):
        return p["a"].size + p["b"].size
    return p.size


def break_even_rank(d_in: int, d_out: int) -> int:
    """Largest k for which (d_in + d_out) * k < d_in * d_out."""
    return (d_in * d_out - 1) // (d_in + d_out)


def materialize(p: Any) -> jax.Array:
    """Densify a (possibly factored) kernel — for analysis/tests only."""
    if is_lowrank(p):
        a32 = p["a"].astype(jnp.float32)
        b32 = p["b"].astype(jnp.float32)
        return (a32 @ b32).astype(p["a"].dtype)
    return p
