"""Paper core: randomized subspace iteration compression (Alg 3.1 + Thm 3.2)."""

from repro.core.rsi import (  # noqa: F401
    RSIResult,
    rsi,
    rsvd,
    rsi_factors,
    cholesky_qr,
    cholesky_qr2,
    rsi_flops,
    matmul_count,
)
from repro.core.spectral import (  # noqa: F401
    spectral_norm,
    normalized_error,
    normalized_error_factored,
    synth_spectrum_matrix,
    vgg_like_spectrum,
    effective_rank,
    spectralize_params,
)
from repro.core.bounds import (  # noqa: F401
    softmax_jacobian,
    softmax_perturbation_bound,
    CompressionCertificate,
    certify_head,
)
from repro.core.lowrank import (  # noqa: F401
    is_lowrank,
    lowrank_params,
    apply_linear,
    break_even_rank,
    materialize,
)
from repro.core.compress import (  # noqa: F401
    CompressionPolicy,
    CompressionReport,
    compress_tree,
)
