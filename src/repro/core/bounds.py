"""Theorem 3.2 machinery: softmax-perturbation certificates for compression.

The paper's theory: for logits z = W h(x) + b and z~ = W~ h(x) + b,

    || softmax(z~) - softmax(z) ||_inf  <=  (1/2) * R * ||W - W~||_2,

with R >= sup_x ||h(x)||_2.  This module provides the Jacobian (Lemma 3.1),
the bound itself, and a *certificate* object used by the compression pipeline
to report per-layer reliability guarantees (the framework-level feature built
on the theorem: given calibration features, certify the maximum probability
deviation of the compressed classifier head).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = [
    "softmax_jacobian",
    "softmax_perturbation_bound",
    "CompressionCertificate",
    "certify_head",
    "certify_tier",
]


def softmax_jacobian(u: jax.Array) -> jax.Array:
    """Lemma 3.1: J_sigma(u) = diag(sigma(u)) - sigma(u) sigma(u)^T."""
    s = jax.nn.softmax(u)
    return jnp.diag(s) - jnp.outer(s, s)


def softmax_perturbation_bound(spectral_err: jax.Array, R: jax.Array) -> jax.Array:
    """Theorem 3.2 RHS: (1/2) R ||W - W~||_2."""
    return 0.5 * R * spectral_err


@dataclasses.dataclass(frozen=True)
class CompressionCertificate:
    """Reliability certificate for one compressed classifier head.

    Attributes:
      spectral_error: estimated ||W - W~||_2.
      feature_radius: R, max ||h(x)||_2 over the calibration set (plus slack).
      prob_deviation_bound: (1/2) R ||W - W~||_2 — Thm 3.2 guarantee on every
        class probability for every input with ||h|| <= R.
      rank: rank of the approximation.
      q: RSI iteration count used.
    """

    spectral_error: float
    feature_radius: float
    prob_deviation_bound: float
    rank: int
    q: int

    def guarantees_top1_stability(self, margin: float) -> bool:
        """If the calibration top-1 softmax margin exceeds 2x the bound, the
        argmax prediction provably cannot flip for those inputs."""
        return margin > 2.0 * self.prob_deviation_bound


def certify_head(
    W: jax.Array,
    W_approx: jax.Array,
    calib_features: jax.Array,
    key: jax.Array,
    *,
    rank: int,
    q: int,
    radius_slack: float = 1.0,
) -> CompressionCertificate:
    """Build a Thm-3.2 certificate from a calibration feature batch (N, D)."""
    from repro.core.spectral import spectral_norm

    err = float(spectral_norm(W - W_approx, key))
    R = float(jnp.max(jnp.linalg.norm(calib_features.astype(jnp.float32), axis=-1)))
    R *= radius_slack
    return CompressionCertificate(
        spectral_error=err,
        feature_radius=R,
        prob_deviation_bound=float(softmax_perturbation_bound(err, R)),
        rank=rank,
        q=q,
    )


def certify_tier(
    a: jax.Array,
    b: jax.Array,
    tier_rank: int,
    key: jax.Array,
    *,
    q: int,
    feature_radius: float | None = None,
) -> CompressionCertificate:
    """Thm-3.2 certificate for a *nested tier* of one factor pair.

    The tier-``r'`` head is the prefix slice of the stored rank-``r`` factors,
    so the extra deviation a degraded tier introduces over the serving tier is
    exactly the spectral norm of the dropped tail ``A[:, r':] @ B[r':, :]``.
    Because RSI orders directions by decreasing singular value this is just
    the largest dropped singular value — cheap to read off the factor norms
    without rematerializing W.

    ``feature_radius`` defaults to the column-norm bound of the sliced-off
    subspace's worst input (1.0), i.e. callers serving normalized features
    can pass their measured R instead.
    """
    from repro.core.spectral import spectral_norm

    a32 = a.astype(jnp.float32)
    b32 = b.astype(jnp.float32)
    if tier_rank >= a.shape[-1]:
        err = 0.0
    else:
        tail = a32[..., :, tier_rank:] @ b32[..., tier_rank:, :]
        if tail.ndim > 2:  # stacked factors: certify the worst stacked slice
            flat = tail.reshape((-1,) + tail.shape[-2:])
            errs = [float(spectral_norm(flat[i], key)) for i in range(flat.shape[0])]
            err = max(errs)
        else:
            err = float(spectral_norm(tail, key))
    R = 1.0 if feature_radius is None else float(feature_radius)
    return CompressionCertificate(
        spectral_error=err,
        feature_radius=R,
        prob_deviation_bound=float(softmax_perturbation_bound(err, R)),
        rank=int(tier_rank),
        q=q,
    )
