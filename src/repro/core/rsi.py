"""Randomized Subspace Iteration (RSI) — the paper's Algorithm 3.1.

Implements the paper's core contribution: randomized low-rank approximation with
``q`` power iterations to amplify spectral separation (s_i -> s_i^{2q-1}),
fixing the failure of plain randomized SVD (RSVD == RSI with q=1) on the slowly
decaying singular spectra typical of pretrained weight matrices.

All routines are pure JAX, jittable, and dtype-polymorphic.  Orthonormalization
is CholeskyQR2 by default (two rounds of Cholesky QR) — on TPU this is three
MXU-friendly GEMMs plus a k x k Cholesky, numerically comparable to Householder
QR for the well-conditioned sketches subspace iteration produces, and it is the
form that distributes over a mesh with only k x k collectives
(see core/distributed_rsi.py).  ``qr_method='householder'`` recovers the
paper-literal jnp.linalg.qr.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "RSIResult",
    "rsi",
    "rsvd",
    "rsi_factors",
    "cholesky_qr",
    "cholesky_qr2",
    "matmul_count",
    "rsi_flops",
]


class RSIResult(NamedTuple):
    """Approximate truncated SVD ``W ~= U @ diag(S) @ Vt`` of rank ``k``."""

    U: jax.Array  # (C, k)
    S: jax.Array  # (k,)
    Vt: jax.Array  # (k, D)


def cholesky_qr(X: jax.Array, *, eps: float = 0.0) -> jax.Array:
    """One round of Cholesky QR: Q = X @ R^-1 with R = chol(X^T X).

    Accumulates the Gram matrix in fp32 regardless of input dtype (TPU:
    bf16 inputs would otherwise destroy orthogonality).
    """
    x32 = X.astype(jnp.float32)
    g = x32.T @ x32
    if eps:
        g = g + eps * jnp.trace(g) / g.shape[0] * jnp.eye(g.shape[0], dtype=g.dtype)
    r = jnp.linalg.cholesky(g.T).T  # upper-triangular R with G = R^T R
    q = jax.scipy.linalg.solve_triangular(r.T, x32.T, lower=True).T
    return q.astype(X.dtype)


def cholesky_qr2(X: jax.Array) -> jax.Array:
    """CholeskyQR2: two rounds restore orthogonality to ~machine precision."""
    return cholesky_qr(cholesky_qr(X, eps=1e-12))


def _orthonormalize(X: jax.Array, method: str) -> jax.Array:
    if method == "cholesky_qr2":
        return cholesky_qr2(X)
    if method == "householder":
        q, _ = jnp.linalg.qr(X.astype(jnp.float32))
        return q.astype(X.dtype)
    raise ValueError(f"unknown qr_method {method!r}")


@functools.partial(
    jax.jit, static_argnames=("k", "q", "oversample", "qr_method", "stabilize_every")
)
def rsi(
    W: jax.Array,
    k: int,
    q: int,
    key: jax.Array,
    *,
    oversample: int = 0,
    qr_method: str = "cholesky_qr2",
    stabilize_every: int = 1,
) -> RSIResult:
    """Algorithm 3.1 of the paper: randomized subspace iteration.

    Args:
      W: (C, D) weight matrix.  Works for either orientation.
      k: target rank.
      q: number of power iterations; ``q=1`` is exactly RSVD.
      key: PRNG key for the Gaussian test matrix Omega (D, k+oversample).
      oversample: extra sketch columns p (approximation uses first k singular
        triplets only).  The paper uses p=0; p in [5, 10] is the standard
        Halko-Martinsson-Tropp robustness tweak and is exposed as an option.
      qr_method: 'cholesky_qr2' (TPU-native default) or 'householder'
        (paper-literal).
      stabilize_every: re-orthonormalize Y every this many iterations
        (1 = every iteration, matching Alg 3.1's per-iteration QR).

    Returns:
      RSIResult(U (C,k), S (k,), Vt (k,D)) with W ~= U @ diag(S) @ Vt.
    """
    if q < 1:
        raise ValueError("q must be >= 1 (q=1 is RSVD)")
    C, D = W.shape
    ell = min(k + oversample, min(C, D))
    # Sketch in the compute dtype of W; accumulation inside GEMMs is fp32 on TPU
    # via preferred_element_type below.
    omega = jax.random.normal(key, (D, ell), dtype=jnp.float32).astype(W.dtype)

    def mm(a, b):
        return jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(W.dtype)

    # --- Alg 3.1 lines 1-6: power iterations -------------------------------
    Y = omega  # (D, ell)
    X = None
    for t in range(q):
        X = mm(W, Y)  # (C, ell)
        if (t % max(stabilize_every, 1)) == 0 or t == q - 1:
            X = _orthonormalize(X, qr_method)
        Y = mm(W.T, X)  # (D, ell)

    # --- Alg 3.1 lines 7-8: SVD of the small matrix Y^T (ell x D) ----------
    # Computed via the Gram trick so that only ell x ell objects need a dense
    # factorization: G = Y^T Y = (U_hat S^2 U_hat^T);  V = Y U_hat S^-1.
    y32 = Y.astype(jnp.float32)
    G = y32.T @ y32  # (ell, ell)
    evals, u_hat = jnp.linalg.eigh(G)  # ascending
    evals = jnp.maximum(evals, 0.0)
    order = jnp.argsort(-evals)
    evals = evals[order]
    u_hat = u_hat[:, order]
    S = jnp.sqrt(evals)
    # Guard rank-deficient tails.
    s_safe = jnp.where(S > 0, S, 1.0)
    V = y32 @ (u_hat / s_safe[None, :])  # (D, ell), columns ~ right sing. vecs
    U = mm(X.astype(jnp.float32), u_hat)  # (C, ell)

    return RSIResult(
        U=U[:, :k].astype(W.dtype),
        S=S[:k].astype(W.dtype),
        Vt=V[:, :k].T.astype(W.dtype),
    )


def rsvd(W: jax.Array, k: int, key: jax.Array, **kw) -> RSIResult:
    """Randomized SVD (Halko et al.) == RSI with q = 1."""
    return rsi(W, k, 1, key, **kw)


def rsi_factors(
    W: jax.Array, k: int, q: int, key: jax.Array, **kw
) -> tuple[jax.Array, jax.Array]:
    """Paper Sec. 3 factored form: W ~= A @ B, A = U S^1/2 (C,k), B = S^1/2 V^T (k,D)."""
    res = rsi(W, k, q, key, **kw)
    root_s = jnp.sqrt(jnp.maximum(res.S.astype(jnp.float32), 0.0)).astype(W.dtype)
    A = res.U * root_s[None, :]
    B = root_s[:, None] * res.Vt
    return A, B


def matmul_count(q: int) -> int:
    """m of Eq. (3.14): number of multiplications with W or W^T."""
    return 2 * q


def rsi_flops(C: int, D: int, k: int, q: int, *, oversample: int = 0) -> int:
    """Dominant FLOP count of Alg 3.1 (used by the roofline/benchmark layer).

    Per iteration: W@Y (2CDl) + CholeskyQR2 on (C,l) (~ 2*(2Cl^2)) + W^T@X (2CDl);
    epilogue: Gram (2Dl^2) + eigh (~26 l^3, lumped) + V (2Dl^2) + U (2Cl^2).
    """
    ell = k + oversample
    per_iter = 2 * C * D * ell * 2 + 4 * C * ell * ell
    epilogue = 4 * D * ell * ell + 2 * C * ell * ell + 26 * ell**3
    return q * per_iter + epilogue
