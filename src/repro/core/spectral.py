"""Spectral utilities: norm estimation, normalized error, synthetic spectra.

The paper's quality metric is the *normalized spectral error*
``||W - W_k~||_2 / s_{k+1}`` (== 1 for the optimal truncated SVD).  Computing
exact spectral norms of residuals is O(DC^2); for large layers we provide a
randomized power-method estimator whose error is itself controllable.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = [
    "spectral_norm",
    "normalized_error",
    "normalized_error_factored",
    "synth_spectrum_matrix",
    "vgg_like_spectrum",
    "effective_rank",
]


@functools.partial(jax.jit, static_argnames=("iters",))
def spectral_norm(M: jax.Array, key: jax.Array, *, iters: int = 32) -> jax.Array:
    """Randomized power-method estimate of ||M||_2 (fp32 accumulation).

    With ``iters`` power steps the estimate is a lower bound converging
    geometrically in (s2/s1)^iters; 32 iterations is conservative for the
    residual matrices encountered here.
    """
    m32 = M.astype(jnp.float32)
    C, D = M.shape
    v = jax.random.normal(key, (D,), dtype=jnp.float32)
    v = v / jnp.linalg.norm(v)

    def body(_, v):
        u = m32 @ v
        u = u / (jnp.linalg.norm(u) + 1e-30)
        w = m32.T @ u
        return w / (jnp.linalg.norm(w) + 1e-30)

    v = jax.lax.fori_loop(0, iters, body, v)
    return jnp.linalg.norm(m32 @ v)


def normalized_error(
    W: jax.Array,
    U: jax.Array,
    S: jax.Array,
    Vt: jax.Array,
    s_next: jax.Array | float,
    key: jax.Array,
    *,
    iters: int = 32,
) -> jax.Array:
    """Paper metric: ||W - U S Vt||_2 / s_{k+1}."""
    approx = (U * S[None, :]) @ Vt
    return spectral_norm(W - approx.astype(W.dtype), key, iters=iters) / s_next


def normalized_error_factored(
    W: jax.Array, A: jax.Array, B: jax.Array, s_next, key: jax.Array, *, iters: int = 32
) -> jax.Array:
    """Same metric for the factored form W ~= A @ B."""
    return spectral_norm(W - (A @ B).astype(W.dtype), key, iters=iters) / s_next


def synth_spectrum_matrix(
    key: jax.Array,
    C: int,
    D: int,
    singular_values: jax.Array,
    dtype=jnp.float32,
) -> jax.Array:
    """Random matrix with a prescribed singular spectrum (Haar factors).

    Used to reproduce the paper's Figure 1.1 / 4.x regimes without the
    original pretrained checkpoints: we synthesize W = U diag(s) V^T with the
    target decay profile and Haar-random singular vectors.
    """
    r = min(C, D)
    s = jnp.asarray(singular_values, dtype=jnp.float32)
    assert s.shape == (r,), (s.shape, r)
    ku, kv = jax.random.split(key)
    # Haar via QR of Gaussian.
    gu = jax.random.normal(ku, (C, r), dtype=jnp.float32)
    gv = jax.random.normal(kv, (D, r), dtype=jnp.float32)
    qu, _ = jnp.linalg.qr(gu)
    qv, _ = jnp.linalg.qr(gv)
    return ((qu * s[None, :]) @ qv.T).astype(dtype)


def vgg_like_spectrum(r: int, *, s1: float = 30.0, knee: float = 0.02, tail_decay: float = 0.35):
    """Spectrum shaped like Fig 1.1(a): fast initial drop then a slow tail.

    s_i = s1 * [ knee + (1-knee) * i^{-1.2} ] * (r-i)/r^{tail_decay-ish}.
    The exact constants were fit by eye to the published figure: s_1 ~ 30,
    ~2 decades drop over the first ~100 indices, then slow algebraic decay.
    """
    i = jnp.arange(1, r + 1, dtype=jnp.float32)
    fast = i ** (-1.2)
    slow = knee * (i / r) ** (-tail_decay)
    return s1 * (fast + slow) / (1.0 + knee)


def spectralize_params(params, key, *, min_dim: int = 32, spectrum=vgg_like_spectrum):
    """Replace every large 2-D kernel in a params pytree with a matrix of the
    same shape/Frobenius norm but a PRETRAINED-LIKE slow-decay spectrum.

    Freshly initialized Gaussian weights have near-flat spectra — the worst
    case for low-rank compression and NOT the regime the paper addresses.
    Tests/examples that validate compression quality on whole models use this
    to simulate pretrained weights (DESIGN.md §7)."""
    flat, treedef = jax.tree_util.tree_flatten(params)
    keys = jax.random.split(key, max(len(flat), 1))

    def one(leaf, k):
        if not hasattr(leaf, "ndim") or leaf.ndim < 2:
            return leaf
        c, d = leaf.shape[-2], leaf.shape[-1]
        if min(c, d) < min_dim:
            return leaf

        def make(kk, ref):
            W = synth_spectrum_matrix(kk, c, d, spectrum(min(c, d)))
            scale = jnp.linalg.norm(ref.astype(jnp.float32)) / (
                jnp.linalg.norm(W) + 1e-9
            )
            return (W * scale).astype(leaf.dtype)

        lead = leaf.shape[:-2]
        if lead:
            n = int(np_prod(lead))
            ks = jax.random.split(k, n)
            flat_leaf = leaf.reshape((n, c, d))
            out = jax.vmap(make)(ks, flat_leaf)
            return out.reshape(leaf.shape)
        return make(k, leaf)

    return jax.tree_util.tree_unflatten(
        treedef, [one(l, k) for l, k in zip(flat, keys)]
    )


def np_prod(t):
    out = 1
    for x in t:
        out *= int(x)
    return out


def effective_rank(s: jax.Array) -> jax.Array:
    """Entropy-based effective rank of a spectrum (for rank-policy heuristics)."""
    p = s / jnp.sum(s)
    p = jnp.where(p > 0, p, 1.0)
    return jnp.exp(-jnp.sum(jnp.where(s > 0, p * jnp.log(p), 0.0)))
