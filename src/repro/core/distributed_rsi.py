"""Mesh-distributed RSI via shard_map.

Compresses a weight matrix that is *already sharded* across a (data, model)
mesh — the situation on a real pod, where e.g. qwen2-72b's 8192 x 29568 FFN
kernels live FSDP x TP sharded and must never be gathered to one host.

Layout (per shard_map block):
    W    : P(row_axis, col_axis)   block (C/dp, D/tp)
    Omega: P(col_axis, None)       block (D/tp, l)
    X    : P(row_axis, None)       block (C/dp, l)
    Y    : P(col_axis, None)       block (D/tp, l)

Communication per power iteration (the TPU-native part — see DESIGN.md §4):
    * psum over col_axis of the partial X      — (C/dp)·l words
    * psum over row_axis of the partial Y      — (D/tp)·l words
    * two psums of l x l Gram matrices         — CholeskyQR2
No tall matrix is ever gathered; the only replicated objects are l x l.

The epilogue SVD uses the Gram trick (G = Y^T Y psum -> eigh, l x l), so the
result factors come back *already sharded*: U as P(row_axis, None), Vt as
P(None, col_axis) — exactly the specs a TP-sharded LowRankLinear wants.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.rsi import RSIResult

__all__ = ["distributed_rsi", "distributed_rsi_factors"]


def _psum(x, axis_name):
    if axis_name is None:
        return x
    return jax.lax.psum(x, axis_name)


def _dist_cholesky_qr(X, row_axis, *, eps=0.0):
    """CholeskyQR with the Gram matrix psum-reduced over the row shards."""
    x32 = X.astype(jnp.float32)
    g = _psum(x32.T @ x32, row_axis)
    if eps:
        g = g + eps * jnp.trace(g) / g.shape[0] * jnp.eye(g.shape[0], dtype=g.dtype)
    r = jnp.linalg.cholesky(g.T).T
    q = jax.scipy.linalg.solve_triangular(r.T, x32.T, lower=True).T
    return q.astype(X.dtype)


def _dist_cholesky_qr2(X, row_axis):
    return _dist_cholesky_qr(_dist_cholesky_qr(X, row_axis, eps=1e-12), row_axis)


def _rsi_block(W, omega, *, k, q, row_axis, col_axis):
    """shard_map body.  W block (c, d); omega block (d, l)."""

    def mm(a, b):
        return jnp.matmul(a, b, preferred_element_type=jnp.float32)

    Y = omega.astype(jnp.float32)
    W32 = W.astype(jnp.float32)
    X = None
    for _ in range(q):
        X = _psum(mm(W32, Y), col_axis)  # (c, l) summed over D shards
        X = _dist_cholesky_qr2(X, row_axis)
        Y = _psum(mm(W32.T, X), row_axis)  # (d, l) summed over C shards

    G = _psum(Y.T @ Y, col_axis)  # (l, l) replicated
    evals, u_hat = jnp.linalg.eigh(G)
    evals = jnp.maximum(evals, 0.0)
    order = jnp.argsort(-evals)
    evals, u_hat = evals[order], u_hat[:, order]
    S = jnp.sqrt(evals)
    s_safe = jnp.where(S > 0, S, 1.0)
    V = Y @ (u_hat / s_safe[None, :])  # (d, l) sharded on D
    U = X @ u_hat  # (c, l) sharded on C
    return U[:, :k].astype(W.dtype), S[:k].astype(W.dtype), V[:, :k].T.astype(W.dtype)


def distributed_rsi(
    W: jax.Array,
    k: int,
    q: int,
    key: jax.Array,
    mesh: jax.sharding.Mesh,
    *,
    row_axis: str | Sequence[str] | None = "data",
    col_axis: str | Sequence[str] | None = "model",
    oversample: int = 0,
) -> RSIResult:
    """Distributed Algorithm 3.1 for a (C, D) matrix sharded P(row_axis, col_axis)."""
    C, D = W.shape
    ell = min(k + oversample, min(C, D))
    omega = jax.random.normal(key, (D, ell), dtype=jnp.float32).astype(W.dtype)

    body = functools.partial(
        _rsi_block, k=k, q=q, row_axis=row_axis, col_axis=col_axis
    )
    from repro.runtime.compat import shard_map

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(row_axis, col_axis), P(col_axis, None)),
        out_specs=(P(row_axis, None), P(), P(None, col_axis)),
    )
    U, S, Vt = fn(W, omega)
    return RSIResult(U=U, S=S, Vt=Vt)


def distributed_rsi_factors(W, k, q, key, mesh, **kw):
    """Sharded factored form A (C,k) P(row,None), B (k,D) P(None,col)."""
    res = distributed_rsi(W, k, q, key, mesh, **kw)
    root_s = jnp.sqrt(jnp.maximum(res.S.astype(jnp.float32), 0.0)).astype(W.dtype)
    return res.U * root_s[None, :], root_s[:, None] * res.Vt
