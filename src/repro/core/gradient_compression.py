"""RSI-PowerSGD: low-rank compression of the data-parallel gradient all-reduce.

Beyond-paper application of the same algorithmic core: instead of all-reducing
full gradient matrices G (C x D), each data-parallel replica sketches its
gradient into rank-r factors with ONE warm-started subspace iteration (the
paper's Alg 3.1 with q=1 but Omega carried over from the previous step — the
"warm subspace" makes one iteration behave like many across steps), and only
the factors are all-reduced:

    comm per matrix: O((C + D) * r)   vs   O(C * D)

Error feedback (Karimireddy et al.) keeps the compressed optimizer unbiased in
the long run: the residual G - P Q^T is added back into the next step's
gradient before sketching.

Works inside a shard_map'd train step (axis_name given) or, for tests and
single-host use, with ``axis_name=None`` (psum becomes identity).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["PowerSGDState", "init_powersgd", "compress_allreduce", "comm_bytes"]


@dataclasses.dataclass(frozen=True)
class PowerSGDConfig:
    rank: int = 4
    min_size: int = 65536  # tensors smaller than this are all-reduced densely
    ef: bool = True  # error feedback


def _is_matrix(x) -> bool:
    return x.ndim >= 2 and x.shape[-1] > 1 and x.shape[-2] > 1


class PowerSGDState:
    """Pytree: per-leaf warm Q factors + error-feedback residuals."""

    def __init__(self, qs, errors):
        self.qs = qs
        self.errors = errors


jax.tree_util.register_pytree_node(
    PowerSGDState,
    lambda s: ((s.qs, s.errors), None),
    lambda _, c: PowerSGDState(*c),
)


def init_powersgd(grads: Any, key: jax.Array, cfg: PowerSGDConfig = PowerSGDConfig()):
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    keys = jax.random.split(key, max(len(leaves), 1))

    def init_leaf(g, k):
        if not _is_matrix(g) or g.size < cfg.min_size:
            return None
        d = g.shape[-1]
        r = min(cfg.rank, min(g.shape[-2], d))
        lead = g.shape[:-2]
        q = jax.random.normal(k, lead + (d, r), dtype=jnp.float32)
        return q

    qs = jax.tree_util.tree_unflatten(
        treedef, [init_leaf(g, k) for g, k in zip(leaves, keys)]
    )
    errors = jax.tree_util.tree_map(
        lambda g: jnp.zeros_like(g) if _is_matrix(g) and g.size >= cfg.min_size else None,
        grads,
    )
    return PowerSGDState(qs, errors)


def _orth(p):
    """Local CholeskyQR — P is replicated post-allreduce so no comm needed."""
    p32 = p.astype(jnp.float32)
    g = jnp.einsum("...ir,...is->...rs", p32, p32)
    eye = jnp.eye(g.shape[-1], dtype=g.dtype)
    g = g + 1e-12 * eye * jnp.trace(g, axis1=-2, axis2=-1)[..., None, None]
    chol = jnp.linalg.cholesky(g)
    return jnp.einsum(
        "...ir,...rs->...is",
        p32,
        jnp.linalg.inv(chol).swapaxes(-1, -2),
    )


def compress_allreduce(
    grads: Any,
    state: PowerSGDState,
    axis_name: str | None,
    cfg: PowerSGDConfig = PowerSGDConfig(),
):
    """All-reduce `grads` across `axis_name`, compressing large matrices.

    Returns (mean_grads, new_state).  Factors are mean-reduced (psum / n).
    """

    def pmean(x):
        return jax.lax.pmean(x, axis_name) if axis_name is not None else x

    def one(g, q, e):
        if q is None:
            return pmean(g), None, None
        g32 = g.astype(jnp.float32)
        if cfg.ef and e is not None:
            g32 = g32 + e
        # One warm-started power iteration: P = G Q; orth; Q' = G^T P.
        p = pmean(jnp.einsum("...cd,...dr->...cr", g32, q))
        p = _orth(p)
        q_new = pmean(jnp.einsum("...cd,...cr->...dr", g32, p))
        approx = jnp.einsum("...cr,...dr->...cd", p, q_new)
        err = (g32 - approx) if cfg.ef else None
        return approx.astype(g.dtype), q_new, err

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_q = treedef.flatten_up_to(state.qs)
    flat_e = treedef.flatten_up_to(state.errors)
    outs = [one(g, q, e) for g, q, e in zip(flat_g, flat_q, flat_e)]
    new_g = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_q = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    new_e = jax.tree_util.tree_unflatten(treedef, [o[2] for o in outs])
    return new_g, PowerSGDState(new_q, new_e)


def comm_bytes(grads: Any, cfg: PowerSGDConfig = PowerSGDConfig()) -> tuple[int, int]:
    """(dense_bytes, compressed_bytes) per all-reduce — for EXPERIMENTS.md."""
    dense = comp = 0
    for g in jax.tree_util.tree_leaves(grads):
        b = g.size * g.dtype.itemsize
        dense += b
        if _is_matrix(g) and g.size >= cfg.min_size:
            c, d = g.shape[-2], g.shape[-1]
            lead = int(g.size // (c * d))
            r = min(cfg.rank, min(c, d))
            comp += lead * (c + d) * r * 4
        else:
            comp += b
    return dense, comp
