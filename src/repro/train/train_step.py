"""Train-step builders: loss, grad, optimizer apply — fully pjit-shardable.

The returned step function has signature
    train_step(state: TrainState, batch) -> (TrainState, metrics)
and is pure (jit/lower-able with ShapeDtypeStructs — this is what the
multi-pod dry-run compiles).  Features:

  * cross-entropy over the PADDED vocab with the padding columns masked,
    optional z-loss;
  * MoE auxiliary load-balance loss folded in;
  * global-norm gradient clipping;
  * gradient accumulation (lax.scan over microbatches);
  * activation sharding rules (FSDP/TP/SP) threaded via use_rules so every
    maybe_constrain in the model zoo becomes a real with_sharding_constraint.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.sharding.rules import MeshRules, use_rules, param_specs
from repro.train import optimizer as opt_mod

__all__ = ["TrainState", "make_train_step", "softmax_xent", "init_train_state", "state_specs"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jax.Array


def softmax_xent(logits, targets, *, real_vocab: int, z_loss: float = 1e-4):
    """logits fp32 (..., Vp); targets int (...).  Padded vocab masked."""
    Vp = logits.shape[-1]
    if real_vocab < Vp:
        neg = jnp.full((Vp - real_vocab,), -1e30, logits.dtype)
        logits = logits.at[..., real_vocab:].set(neg)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    loss = jnp.mean(lse - gold)
    if z_loss:
        loss = loss + z_loss * jnp.mean(jnp.square(lse))
    return loss


def chunked_softmax_xent(
    feats,
    targets,
    head_apply,
    *,
    real_vocab: int,
    z_loss: float = 1e-4,
    n_chunks: int = 16,
):
    """Head-matmul + cross-entropy fused over sequence chunks.

    The full-batch fp32 logits (tokens x padded-vocab) are the largest
    single tensor of a training step (e.g. qwen2-72b train_4k: 2.5 GiB/chip
    saved for backward).  Chunking the head over the sequence and
    jax.checkpoint-ing each chunk keeps only (B, S/n, Vp) alive and
    recomputes chunk logits in the backward pass — peak memory drops ~n x
    for one extra head matmul per chunk.
    """
    B, S, d = feats.shape
    while S % n_chunks:
        n_chunks //= 2
    if n_chunks <= 1:
        return softmax_xent(
            head_apply(feats), targets, real_vocab=real_vocab, z_loss=z_loss
        )
    xf = feats.reshape(B, n_chunks, S // n_chunks, d).swapaxes(0, 1)
    tf = targets.reshape(B, n_chunks, S // n_chunks).swapaxes(0, 1)

    def body(carry, inp):
        x_c, t_c = inp
        logits = head_apply(x_c)  # (B, S/n, Vp) fp32
        Vp = logits.shape[-1]
        if real_vocab < Vp:
            neg = jnp.full((Vp - real_vocab,), -1e30, logits.dtype)
            logits = logits.at[..., real_vocab:].set(neg)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t_c[..., None], axis=-1)[..., 0]
        sl, sz = carry
        return (sl + jnp.sum(lse - gold), sz + jnp.sum(jnp.square(lse))), None

    body = jax.checkpoint(body, prevent_cse=False)
    (sl, sz), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), (xf, tf))
    n_tok = B * S
    loss = sl / n_tok
    if z_loss:
        loss = loss + z_loss * sz / n_tok
    return loss


def init_train_state(model, optimizer, key) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt_state=optimizer.init(params), step=jnp.zeros((), jnp.int32))


def state_specs(state: TrainState, rules: MeshRules) -> TrainState:
    """PartitionSpec tree for a TrainState: optimizer moments inherit their
    parameter's spec.  AdamW m/v and SGD momentum mirror the params tree;
    adafactor's factored stats get the param spec minus the reduced axis."""
    from jax.sharding import PartitionSpec as P

    p_specs = param_specs(state.params, rules)

    def walk(o, s):
        if isinstance(o, dict) and set(o) == {"vr", "vc"} and not isinstance(s, dict):
            parts = tuple(s) if s is not None else ()
            vr = P(*parts[:-1]) if parts else P()
            vc = P(*(parts[:-2] + parts[-1:])) if len(parts) >= 2 else P()
            return {"vr": vr, "vc": vc}
        if isinstance(o, dict) and set(o) == {"v"} and not isinstance(s, dict):
            return {"v": s if s is not None else P()}
        if isinstance(o, dict):
            if isinstance(s, dict) and set(o) <= set(s):
                return {k: walk(v, s[k]) for k, v in o.items()}
            # e.g. adamw's top level {"m": <params tree>, "v": <params tree>}
            return {k: walk(v, s) for k, v in o.items()}
        return s if s is not None else P()

    return TrainState(params=p_specs, opt_state=walk(state.opt_state, p_specs), step=P())


def make_train_step(
    model,
    optimizer: opt_mod.Optimizer,
    *,
    rules: Optional[MeshRules] = None,
    accum_steps: int = 1,
    max_grad_norm: float = 1.0,
    aux_weight: float = 0.01,
    z_loss: float = 1e-4,
):
    cfg = model.cfg

    def loss_fn(params, batch):
        if model.forward_features is not None:
            feats, aux = model.forward_features(params, batch)
            loss = chunked_softmax_xent(
                feats,
                batch["targets"],
                lambda x: model.head_apply(params, x),
                real_vocab=cfg.vocab,
                z_loss=z_loss,
            )
        else:
            logits, aux = model.forward(params, batch)
            loss = softmax_xent(
                logits, batch["targets"], real_vocab=cfg.vocab, z_loss=z_loss
            )
        total = loss + aux_weight * jnp.asarray(aux, jnp.float32)
        return total, (loss, jnp.asarray(aux, jnp.float32))

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if accum_steps == 1:
            (total, (loss, aux)), grads = grad_fn(params, batch)
            return grads, loss, aux
        # microbatch scan: batch dim must divide accum_steps
        def resh(x):
            return x.reshape((accum_steps, x.shape[0] // accum_steps) + x.shape[1:])

        micro = jax.tree_util.tree_map(resh, batch)
        zeros = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(carry, mb):
            acc, lsum, asum = carry
            (total, (loss, aux)), grads = grad_fn(params, mb)
            acc = jax.tree_util.tree_map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return (acc, lsum + loss, asum + aux), None

        (acc, lsum, asum), _ = jax.lax.scan(body, (zeros, 0.0, 0.0), micro)
        grads = jax.tree_util.tree_map(lambda a: a / accum_steps, acc)
        return grads, lsum / accum_steps, asum / accum_steps

    def train_step(state: TrainState, batch):
        with use_rules(rules):
            grads, loss, aux = compute_grads(state.params, batch)
            grads, gnorm = opt_mod.clip_by_global_norm(grads, max_grad_norm)
            updates, opt_state = optimizer.update(grads, state.opt_state, state.params, state.step)
            params = opt_mod.apply_updates(state.params, updates)
        new_state = TrainState(params=params, opt_state=opt_state, step=state.step + 1)
        metrics = {"loss": loss, "aux_loss": aux, "grad_norm": gnorm}
        return new_state, metrics

    return train_step
