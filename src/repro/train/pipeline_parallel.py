"""GPipe-style pipeline parallelism over the ``pod`` mesh axis.

The multi-pod mesh's "pod" axis defaults to pure data parallelism; this
module provides the alternative: stage-partitioned layers with microbatched
activation streaming via ``lax.ppermute`` inside shard_map.  Backward is
plain autodiff through the pipeline loop (ppermute is differentiable), i.e.
GPipe scheduling with full activation stash — the 1F1B schedule is left as a
scheduling optimization knob (see EXPERIMENTS.md §Perf notes).

Usage: layers stacked on axis 0 with n_layers % n_stages == 0; each stage
owns a contiguous slice (in_spec P("pod") on the layer axis).  Microbatches
stream through stages; outputs are collected on the last stage and broadcast
with a psum so every pod exits with the full result (what the loss needs).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["gpipe_apply", "pipeline_forward"]


def _stage_body(block_fn, stage_params, x):
    """Run this stage's slice of layers (scan over the local stack)."""

    def step(h, lp):
        return block_fn(lp, h), None

    y, _ = jax.lax.scan(step, x, stage_params)
    return y


def pipeline_forward(block_fn, params_stack, x_mb, *, axis: str = "pod"):
    """shard_map body: params_stack (L/S, ...) local slice; x_mb (M, b, ...)
    microbatches (replicated input).  Returns (M, b, ...) outputs."""
    from repro.runtime.compat import axis_size

    S = axis_size(axis)
    stage = jax.lax.axis_index(axis)
    M = x_mb.shape[0]
    T = M + S - 1  # total pipeline ticks
    perm = [(i, (i + 1) % S) for i in range(S)]

    buf = jnp.zeros_like(x_mb[0])  # activation arriving from the previous stage
    outs = jnp.zeros_like(x_mb)

    def tick(t, carry):
        buf, outs = carry
        mb_in = t - stage  # microbatch index entering this stage at tick t
        feed = jax.lax.dynamic_index_in_dim(x_mb, jnp.clip(t, 0, M - 1), keepdims=False)
        x_in = jnp.where(stage == 0, feed, buf)
        active = (mb_in >= 0) & (mb_in < M)
        y = _stage_body(block_fn, params_stack, x_in)
        y = jnp.where(active, y, jnp.zeros_like(y))
        # collect finished microbatch on the last stage
        out_idx = jnp.clip(mb_in, 0, M - 1)
        take = active & (stage == S - 1)
        cur = jax.lax.dynamic_index_in_dim(outs, out_idx, keepdims=False)
        outs = jax.lax.dynamic_update_index_in_dim(
            outs, jnp.where(take, y, cur), out_idx, axis=0
        )
        buf = jax.lax.ppermute(y, axis, perm)
        return buf, outs

    _, outs = jax.lax.fori_loop(0, T, tick, (buf, outs))
    # broadcast the last stage's collected outputs to all stages
    last = jnp.zeros((S,), outs.dtype).at[S - 1].set(1.0)
    outs = jax.lax.psum(outs * last[stage], axis)
    return outs


def gpipe_apply(block_fn, mesh, *, n_microbatches: int, axis: str = "pod"):
    """Returns fn(params_stack, x) running the stacked blocks as a pipeline.

    params_stack: (L, ...) with L % n_stages == 0, sharded P(axis) on dim 0.
    x: (B, ...) with B % n_microbatches == 0 (replicated across `axis`).
    """

    def fn(params_stack, x):
        B = x.shape[0]
        mb = x.reshape((n_microbatches, B // n_microbatches) + x.shape[1:])

        body = functools.partial(pipeline_forward, block_fn, axis=axis)
        param_spec = jax.tree_util.tree_map(lambda _: P(axis), params_stack)
        from repro.runtime.compat import shard_map

        out = shard_map(
            body,
            mesh=mesh,
            in_specs=(param_spec, P()),
            out_specs=P(),
            check_vma=False,
        )(params_stack, mb)
        return out.reshape((B,) + x.shape[1:])

    return fn
