"""Optimizers from scratch (no optax): AdamW, Adafactor, SGD-momentum.

Minimal optax-like contract: ``Optimizer(init, update)`` over pytrees.
Adafactor implements factored second moments for >=2-D leaves (row/col
statistics) — the memory-frugal choice for the 236B-parameter dry-run cells
(m+v fp32 for 236B is ~1.9 TB; factored stats are ~O((C+D)/CD) of that).

All moment math runs in fp32 regardless of param dtype (bf16-safe).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = [
    "Optimizer",
    "adamw",
    "adafactor",
    "sgdm",
    "clip_by_global_norm",
    "cosine_schedule",
    "linear_schedule",
    "constant_schedule",
    "global_norm",
    "apply_updates",
]


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple]  # (grads, state, params, step)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype),
        params,
        updates,
    )


# --------------------------------------------------------------------------- #
# schedules
# --------------------------------------------------------------------------- #
def constant_schedule(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_schedule(lr: float, warmup: int, total: int):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = s / max(warmup, 1)
        decay = jnp.maximum(0.0, (total - s) / max(total - warmup, 1))
        return lr * jnp.minimum(warm, decay)

    return fn


def cosine_schedule(lr: float, warmup: int, total: int, floor: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, s / max(warmup, 1))
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return lr * warm * cos

    return fn


def _wd_mask(params):
    """Decay matrices only (not norms/biases/scalars) — the standard mask."""
    return jax.tree_util.tree_map(lambda p: p.ndim >= 2, params)


# --------------------------------------------------------------------------- #
# AdamW
# --------------------------------------------------------------------------- #
def adamw(
    lr: Callable,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
        }

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        lr_t = lr(step)
        bc1 = 1 - b1**t
        bc2 = 1 - b2**t
        mask = _wd_mask(params)

        def upd(g, m, v, p, decay_ok):
            g32 = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * g32
            v2 = b2 * v + (1 - b2) * jnp.square(g32)
            mh = m2 / bc1
            vh = v2 / bc2
            u = -lr_t * (mh / (jnp.sqrt(vh) + eps))
            if weight_decay:
                u = u - lr_t * weight_decay * p.astype(jnp.float32) * decay_ok
            return u, m2, v2

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        flat_p = treedef.flatten_up_to(params)
        flat_mask = treedef.flatten_up_to(mask)
        outs = [upd(g, m, v, p, mk) for g, m, v, p, mk in zip(flat_g, flat_m, flat_v, flat_p, flat_mask)]
        updates = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
        new_state = {
            "m": jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs]),
            "v": jax.tree_util.tree_unflatten(treedef, [o[2] for o in outs]),
        }
        return updates, new_state

    return Optimizer(init, update)


# --------------------------------------------------------------------------- #
# Adafactor (factored second moments; Shazeer & Stern 2018)
# --------------------------------------------------------------------------- #
def adafactor(
    lr: Callable,
    *,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
) -> Optimizer:
    def init(params):
        def leaf(p):
            if p.ndim >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),  # row stats
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return jax.tree_util.tree_map(leaf, params, is_leaf=lambda x: hasattr(x, "ndim"))

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t**-decay
        lr_t = lr(step)

        def upd(g, s, p):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if g.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.mean(vr, axis=-1, keepdims=True)
                rhat = (vr / jnp.maximum(denom, eps))[..., None]
                u = g32 * jax.lax.rsqrt(rhat * vc[..., None, :] + eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g32 * jax.lax.rsqrt(v + eps)
                new_s = {"v": v}
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            u = -lr_t * u
            if weight_decay:
                u = u - lr_t * weight_decay * p.astype(jnp.float32) * (p.ndim >= 2)
            return u, new_s

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_s = treedef.flatten_up_to(state)
        flat_p = treedef.flatten_up_to(params)
        outs = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        updates = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
        new_state = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
        return updates, new_state

    return Optimizer(init, update)


# --------------------------------------------------------------------------- #
# SGD + momentum
# --------------------------------------------------------------------------- #
def sgdm(lr: Callable, *, momentum: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(grads, state, params, step):
        lr_t = lr(step)

        def upd(g, m):
            g32 = g.astype(jnp.float32)
            m2 = momentum * m + g32
            u = -(lr_t * (g32 + momentum * m2)) if nesterov else -(lr_t * m2)
            return u, m2

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state)
        outs = [upd(g, m) for g, m in zip(flat_g, flat_m)]
        return (
            jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs]),
            jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs]),
        )

    return Optimizer(init, update)
