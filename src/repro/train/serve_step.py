"""Serving steps: batched prefill + single-token decode, pjit-shardable.

``serve_step`` (decode) is what the decode_* dry-run cells lower: one new
token per sequence against a seq_len-deep cache.  Cache sharding follows the
same rules as activations: batch over ("pod","data") when divisible, heads /
latent dims over "model".
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding.rules import MeshRules, use_rules

__all__ = ["make_prefill_step", "make_decode_step", "cache_specs", "greedy_generate"]


def make_prefill_step(model, *, rules: Optional[MeshRules] = None, max_len: int):
    def prefill_step(params, batch):
        with use_rules(rules):
            return model.prefill(params, batch, max_len)

    return prefill_step


def make_decode_step(model, *, rules: Optional[MeshRules] = None):
    def decode_step(params, cache, tokens, pos):
        with use_rules(rules):
            return model.decode_step(params, cache, tokens, pos)

    return decode_step


def cache_specs(cache, rules: MeshRules):
    """PartitionSpecs for a decode cache.

    Layout conventions in the model zoo (leading dim = stacked layers):
      KV caches   (L, B, S, KV, hd)   -> (None, batch, None, tp, None)
      MLA latents (L, B, S, lora)     -> (None, batch, None, None)
      SSM state   (L, B, nh, hd, st)  -> (None, batch, tp, None, None)
      conv tails  (L, B, w-1, ch)     -> (None, batch, None, tp)
      cross K/V   (G, B, T, H, hd)    -> (None, batch, None, tp, None)
    Batch sharding is divisibility-guarded (long_500k has B=1 -> replicated).
    """
    from jax.sharding import PartitionSpec as P

    def leaf_spec(path, leaf):
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        shape = leaf.shape
        if name.endswith("state"):  # (L, B, nh, hd, st) stacked / (B,nh,hd,st)
            if leaf.ndim == 5:
                return rules.spec((None, "batch", "tp", None, None), shape)
            return rules.spec(("batch", "tp", None, None), shape)
        if name.endswith("conv_x") or name.endswith("conv_B") or name.endswith("conv_C"):
            return rules.spec((None, "batch", None, "tp"), shape)
        if leaf.ndim == 6:  # vlm self-KV (G, n_self, B, S, KV, hd)
            return rules.spec((None, None, "batch", "tp", None, None), shape)
        if leaf.ndim == 5:
            if "cross" in name:  # (G/L, B, T_img, H, hd): heads shard fine
                return rules.spec((None, "batch", None, "tp", None), shape)
            # KV cache (L, B, S, KV, hd): shard the SEQUENCE over "model" —
            # flash-decode layout: attention is local per S-shard, softmax
            # stats all-reduce is O(B*H).  Head sharding would force a full
            # cache all-gather whenever KV heads < mesh axis (GQA).
            return rules.spec((None, "batch", "tp", None, None), shape)
        if leaf.ndim == 4:  # MLA latent (L, B, S, lora): same S-sharding
            return rules.spec((None, "batch", "tp", None), shape)
        if leaf.ndim == 3:
            return rules.spec(("batch", None, None), shape)
        return P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    return jax.tree_util.tree_unflatten(
        treedef, [leaf_spec(p, l) for p, l in flat]
    )


def greedy_generate(model, params, batch, *, steps: int, max_len: int):
    """Reference batched greedy decoding loop (examples/serving).

    This fixed-shape loop is the PARITY ORACLE for the continuous-batching
    engine: ``repro.serving.Engine`` must emit, per greedy request, exactly
    these tokens for that prompt alone (tests/test_engine_parity.py), so
    changes here are semantic changes to the serving contract.
    """
    logits, cache = model.prefill(params, batch, max_len)
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    start = batch["tokens"].shape[1]
    out = [tok]
    step_fn = jax.jit(model.decode_step)
    for i in range(steps - 1):
        logits, cache = step_fn(params, cache, tok, jnp.int32(start + i))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
