"""Mamba2 block via SSD (state-space duality), pure JAX.

Chunked SSD algorithm (Dao & Gu 2024): the sequence is split into chunks of
length Q; within a chunk the recurrence is computed as a masked quadratic
attention-like product on the MXU; across chunks a (heads, head_dim, state)
state is carried through a lax.scan.  This is the TPU-native adaptation of
the CUDA selective-scan kernel: the only sequential loop is over chunks, and
everything inside a chunk is dense matmuls (see kernels/ssd_scan.py for the
Pallas version of the inner chunk computation).

Projections are stored SEPARATELY (w_z, w_x, w_B, w_C, w_dt) rather than as
one fused in_proj: mathematically identical (the conv is depthwise so it
splits too), but it keeps tensor-parallel sharding clean (no sharded-concat
slicing) and lets RSI compress each projection independently.

Decode is the O(1)-per-token recurrence with a (width-1) depthwise-conv ring
buffer and the (nh, hd, state) SSM state as the cache.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import modules as nn
from repro.sharding.rules import maybe_constrain

__all__ = [
    "mamba2_init",
    "mamba2_forward",
    "mamba2_init_cache",
    "mamba2_decode",
]


def mamba2_init(key, cfg, dtype):
    d, din, s, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    w = cfg.ssm_conv_width
    ks = nn.split_key_tree(
        key, ["w_z", "w_x", "w_B", "w_C", "w_dt", "conv_x", "conv_B", "conv_C", "out"]
    )
    p = {
        "w_z": nn.dense_init(ks["w_z"], d, din, dtype),
        "w_x": nn.dense_init(ks["w_x"], d, din, dtype),
        "w_B": nn.dense_init(ks["w_B"], d, s, dtype),
        "w_C": nn.dense_init(ks["w_C"], d, s, dtype),
        "w_dt": nn.dense_init(ks["w_dt"], d, nh, dtype),
        "dt_bias": jnp.zeros((nh,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(dtype),
        "D_param": jnp.ones((nh,), dtype),
        "conv_x": (jax.random.normal(ks["conv_x"], (w, din)) * w**-0.5).astype(dtype),
        "conv_B": (jax.random.normal(ks["conv_B"], (w, s)) * w**-0.5).astype(dtype),
        "conv_C": (jax.random.normal(ks["conv_C"], (w, s)) * w**-0.5).astype(dtype),
        "ssm_norm": nn.rmsnorm_init(din, dtype),
        "out_proj": nn.dense_init(ks["out"], din, d, dtype, scale=din**-0.5),
    }
    return p


def _causal_depthwise_conv(x, w, tail=None):
    """x: (B, L, ch); w: (width, ch); tail: (B, width-1, ch) left context."""
    width = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], width - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(width):  # width is 4 — unrolled adds, no conv primitive
        out = out + xp[:, i : i + x.shape[1]].astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(out).astype(x.dtype)


def _ssd_chunk_scan(xb, dt, B_in, C_in, A, chunk, state0=None):
    """Chunked SSD.  xb: (B, L, nh, hd) *already dt-scaled*; dt: (B, L, nh);
    B_in/C_in: (B, L, s); A: (nh,) negative reals.  Returns (y, final_state).
    """
    Bsz, L, nh, hd = xb.shape
    s = B_in.shape[-1]
    Q = min(chunk, L)
    while L % Q:
        Q //= 2
    Nc = L // Q

    xc = xb.reshape(Bsz, Nc, Q, nh, hd)
    dtc = dt.reshape(Bsz, Nc, Q, nh)
    Bc = B_in.reshape(Bsz, Nc, Q, s).astype(jnp.float32)
    Cc = C_in.reshape(Bsz, Nc, Q, s).astype(jnp.float32)

    da = dtc * A[None, None, None, :]  # (B,Nc,Q,nh), negative
    lcum = jnp.cumsum(da, axis=2)  # within-chunk cumulative log-decay

    if state0 is None:
        state0 = jnp.zeros((Bsz, nh, hd, s), jnp.float32)

    def body(state, inp):
        xq, dq, bq, cq, lq = inp  # (B,Q,nh,hd),(B,Q,nh),(B,Q,s),(B,Q,s),(B,Q,nh)
        xq32 = xq.astype(jnp.float32)
        # intra-chunk: M[t,u] = exp(l_t - l_u) (t>=u);  scores = (C_t.B_u) * M
        cb = jnp.einsum("bts,bus->btu", cq, bq)  # (B,Q,Q)
        seg = lq[:, :, None, :] - lq[:, None, :, :]  # (B,Q,Q,nh) = l_t - l_u
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        # mask BEFORE exp: the t<u half has seg>0 and would overflow, and a
        # post-exp where() leaks NaN into the backward pass.
        m = jnp.exp(jnp.where(tri[None, :, :, None], seg, -1e30))
        y_intra = jnp.einsum("btu,btuh,buhd->bthd", cb, m, xq32)
        # inter-chunk: contribution of the carried state
        y_inter = jnp.einsum("bts,bhds,bth->bthd", cq, state, jnp.exp(lq))
        # state update: decay whole chunk + inject chunk inputs
        l_last = lq[:, -1:, :]  # (B,1,nh)
        w_in = jnp.exp(l_last - lq)  # (B,Q,nh): decay from step u to chunk end
        state_new = state * jnp.exp(l_last)[:, 0, :, None, None] + jnp.einsum(
            "bus,buh,buhd->bhds", bq, w_in, xq32
        )
        return state_new, (y_intra + y_inter).astype(xb.dtype)

    inputs = (
        xc.swapaxes(0, 1),
        dtc.swapaxes(0, 1),
        Bc.swapaxes(0, 1),
        Cc.swapaxes(0, 1),
        lcum.swapaxes(0, 1),
    )
    state, ys = jax.lax.scan(body, state0, inputs)
    y = ys.swapaxes(0, 1).reshape(Bsz, L, nh, hd)
    return y, state


def mamba2_forward(p, u, cfg, *, return_cache=False):
    """u: (B, L, d_model) -> (B, L, d_model)."""
    B, L, _ = u.shape
    din, s, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim

    z = nn.dense(p["w_z"], u)
    x_raw = nn.dense(p["w_x"], u)
    B_raw = nn.dense(p["w_B"], u)
    C_raw = nn.dense(p["w_C"], u)
    dt = jax.nn.softplus(
        nn.dense(p["w_dt"], u).astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )  # (B,L,nh)

    x = _causal_depthwise_conv(x_raw, p["conv_x"])
    Bv = _causal_depthwise_conv(B_raw, p["conv_B"])
    Cv = _causal_depthwise_conv(C_raw, p["conv_C"])

    xh = x.reshape(B, L, nh, hd)
    xh = maybe_constrain(xh, ("batch", None, "tp", None))
    xbar = (xh.astype(jnp.float32) * dt[..., None]).astype(xh.dtype)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    y, state = _ssd_chunk_scan(xbar, dt, Bv, Cv, A, cfg.ssm_chunk)
    y = y + xh.astype(jnp.float32).astype(y.dtype) * p["D_param"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(B, L, din)
    y = nn.rmsnorm(p["ssm_norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), cfg.norm_eps)
    out = nn.dense(p["out_proj"], y)
    if not return_cache:
        return out
    w = cfg.ssm_conv_width
    cache = {
        "conv_x": jax.lax.dynamic_slice_in_dim(x_raw, L - (w - 1), w - 1, axis=1),
        "conv_B": jax.lax.dynamic_slice_in_dim(B_raw, L - (w - 1), w - 1, axis=1),
        "conv_C": jax.lax.dynamic_slice_in_dim(C_raw, L - (w - 1), w - 1, axis=1),
        "state": state,
    }
    return out, cache


def mamba2_init_cache(cfg, batch: int, dtype):
    din, s, nh, hd, w = (
        cfg.d_inner,
        cfg.ssm_state,
        cfg.n_ssm_heads,
        cfg.ssm_head_dim,
        cfg.ssm_conv_width,
    )
    return {
        "conv_x": jnp.zeros((batch, w - 1, din), dtype),
        "conv_B": jnp.zeros((batch, w - 1, s), dtype),
        "conv_C": jnp.zeros((batch, w - 1, s), dtype),
        "state": jnp.zeros((batch, nh, hd, s), jnp.float32),
    }


def mamba2_decode(p, u, cache, cfg):
    """Single-token recurrence.  u: (B, 1, d_model)."""
    B = u.shape[0]
    din, s, nh, hd, w = (
        cfg.d_inner,
        cfg.ssm_state,
        cfg.n_ssm_heads,
        cfg.ssm_head_dim,
        cfg.ssm_conv_width,
    )
    z = nn.dense(p["w_z"], u)
    x_raw = nn.dense(p["w_x"], u)
    B_raw = nn.dense(p["w_B"], u)
    C_raw = nn.dense(p["w_C"], u)
    dt = jax.nn.softplus(
        nn.dense(p["w_dt"], u).astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )[:, 0]  # (B, nh)

    x = _causal_depthwise_conv(x_raw, p["conv_x"], tail=cache["conv_x"])[:, 0]
    Bv = _causal_depthwise_conv(B_raw, p["conv_B"], tail=cache["conv_B"])[:, 0]
    Cv = _causal_depthwise_conv(C_raw, p["conv_C"], tail=cache["conv_C"])[:, 0]

    xh = x.reshape(B, nh, hd).astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A[None, :])  # (B, nh)
    state = cache["state"] * decay[:, :, None, None] + jnp.einsum(
        "bs,bhd,bh->bhds", Bv.astype(jnp.float32), xh, dt
    )
    y = jnp.einsum("bs,bhds->bhd", Cv.astype(jnp.float32), state)
    y = y + xh * p["D_param"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, 1, din).astype(u.dtype)
    y = nn.rmsnorm(p["ssm_norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), cfg.norm_eps)
    out = nn.dense(p["out_proj"], y)

    def roll(buf, new):
        return jnp.concatenate([buf[:, 1:], new], axis=1)

    new_cache = {
        "conv_x": roll(cache["conv_x"], x_raw),
        "conv_B": roll(cache["conv_B"], B_raw),
        "conv_C": roll(cache["conv_C"], C_raw),
        "state": state,
    }
    return out, new_cache
