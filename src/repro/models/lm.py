"""Unified decoder-only LM covering dense / moe / vlm / hybrid / ssm families.

Homogeneous layer stacks are lax.scan'd over stacked params (compile time and
HLO size are O(1) in depth — mandatory for the 80-layer qwen2-72b dry-run).
Heterogeneous interleavings are expressed as scans over "super-blocks":
  * vlm   — scan over groups of (cross_attn_every-1 self blocks + 1 cross block)
  * hybrid— python segments of scanned mamba blocks + one SHARED attn block
Remat ("block" policy) checkpoints each scanned block body.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import modules as nn
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.sharding.rules import maybe_constrain

__all__ = [
    "lm_init",
    "lm_forward",
    "lm_init_cache",
    "lm_init_cache_paged",
    "lm_prefill",
    "lm_prefill_chunk",
    "lm_decode_step",
]


# --------------------------------------------------------------------------- #
# per-layer blocks
# --------------------------------------------------------------------------- #
def _block_init(key, cfg, dtype, *, layer_kind: str):
    ks = nn.split_key_tree(key, ["attn", "mlp"])
    p = {}
    if layer_kind == "mamba":
        p["ssm_in_norm"] = nn.rmsnorm_init(cfg.d_model, dtype)
        p["mamba"] = ssm_mod.mamba2_init(ks["attn"], cfg, dtype)
        return p
    p["attn_norm"] = nn.rmsnorm_init(cfg.d_model, dtype)
    if layer_kind == "mla":
        p["attn"] = attn.mla_init(ks["attn"], cfg, dtype)
    elif layer_kind == "cross":
        p["cross"] = attn.cross_attn_init(ks["attn"], cfg, dtype)
    else:
        p["attn"] = attn.gqa_init(ks["attn"], cfg, dtype)
    p["mlp_norm"] = nn.rmsnorm_init(cfg.d_model, dtype)
    if layer_kind == "moe":
        p["moe"] = moe_mod.moe_init(ks["mlp"], cfg, dtype)
    else:
        f = cfg.dense_d_ff if (layer_kind == "dense_ffn" and cfg.dense_d_ff) else cfg.d_ff
        p["mlp"] = moe_mod.ffn_init(ks["mlp"], cfg.d_model, f, dtype)
    return p


def _self_block(p, x, cfg, *, positions, mla: bool, use_moe: bool):
    h = nn.rmsnorm(p["attn_norm"], x, cfg.norm_eps)
    if mla:
        a = attn.mla_forward(p["attn"], h, cfg, positions=positions)
    else:
        a = attn.gqa_forward(p["attn"], h, cfg, positions=positions)
    x = x + a
    h = nn.rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
    if use_moe:
        m, aux = moe_mod.moe_forward(p["moe"], h, cfg)
    else:
        m, aux = moe_mod.ffn_forward(p["mlp"], h), 0.0
    return x + m, aux


def _cross_block(p, x, img_kv, cfg):
    h = nn.rmsnorm(p["attn_norm"], x, cfg.norm_eps)
    x = x + attn.cross_attn(p["cross"], h, img_kv, cfg)
    h = nn.rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
    return x + moe_mod.ffn_forward(p["mlp"], h)


def _mamba_block(p, x, cfg):
    h = nn.rmsnorm(p["ssm_in_norm"], x, cfg.norm_eps)
    return x + ssm_mod.mamba2_forward(p["mamba"], h, cfg)


def _stacked_init(key, cfg, dtype, n, *, layer_kind):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _block_init(k, cfg, dtype, layer_kind=layer_kind))(keys)


def _scan_blocks(body, params_stack, x, *, remat: bool, group: int = 1, extra=()):
    """body(layer_params, x, extra) -> (x, aux).  ``extra`` threads captured
    traced arrays (e.g. VLM image embeddings) through the custom_vjp
    explicitly — custom_vjp functions must not close over tracers."""
    # Manual activation checkpointing: jax.checkpoint-inside-scan lets the
    # compiler choose what to stack for backward, and XLA's convert-hoisting
    # turns the bf16 residual stack into fp32 (3x memory on the dominant
    # training buffer).  This custom_vjp owns the schedule: the forward scan
    # emits exactly ONE bf16 residual per ``group`` layers (the block input),
    # and the backward scan re-runs each block under jax.vjp in reverse.

    def constrained(h):
        # Sequence-parallel residual: shard the carry's seq axis over
        # "model" — bounds checkpoint memory for the 32k/4k train cells.
        return maybe_constrain(h, ("batch", "seq", None))

    if not remat:
        def step(carry, lp):
            x, aux = carry
            x, a = body(lp, constrained(x), extra)
            return (x, aux + jnp.asarray(a, jnp.float32)), None

        (x, aux), _ = jax.lax.scan(step, (x, jnp.float32(0)), params_stack)
        return x, aux

    L = jax.tree_util.tree_leaves(params_stack)[0].shape[0]
    g = max(1, min(group, L))
    while L % g:
        g -= 1
    grouped = jax.tree_util.tree_map(
        lambda a: a.reshape((L // g, g) + a.shape[1:]), params_stack
    )

    def group_apply(gp, h, ex):
        def inner(carry, lp):
            h, aux = carry
            h, a = body(lp, constrained(h), ex)
            return (h, aux + jnp.asarray(a, jnp.float32)), None

        (h, aux), _ = jax.lax.scan(inner, (h, jnp.float32(0)), gp)
        return h, aux

    def fwd_scan(gstack, x0, ex):
        def step(carry, gp):
            x, aux = carry
            x_in = constrained(x)
            x2, a = group_apply(gp, x_in, ex)
            return (x2, aux + a), x_in  # residual: one bf16 carry per group

        (xL, aux), xs = jax.lax.scan(step, (x0, jnp.float32(0)), gstack)
        return xL, aux, xs

    @jax.custom_vjp
    def run(gstack, x0, ex):
        xL, aux, _ = fwd_scan(gstack, x0, ex)
        return xL, aux

    def run_fwd(gstack, x0, ex):
        xL, aux, xs = fwd_scan(gstack, x0, ex)
        return (xL, aux), (gstack, xs, ex)

    def run_bwd(res, ct):
        gstack, xs, ex = res
        d_xL, d_aux = ct
        d_aux = jnp.asarray(d_aux, jnp.float32)
        d_ex0 = jax.tree_util.tree_map(
            lambda a: jnp.zeros(a.shape, jnp.float32), ex
        )

        def bstep(carry, inp):
            dx, dex = carry
            gp, x_in = inp
            x_in = jax.lax.optimization_barrier(x_in)
            _, vjp_fn = jax.vjp(group_apply, gp, x_in, ex)
            dgp, dxin, dex_i = vjp_fn((dx, d_aux))
            dex = jax.tree_util.tree_map(
                lambda acc, g: acc + g.astype(jnp.float32), dex, dex_i
            )
            return (dxin, dex), dgp

        (dx0, dex), dgs = jax.lax.scan(bstep, (d_xL, d_ex0), (gstack, xs), reverse=True)
        dex = jax.tree_util.tree_map(lambda a, e: a.astype(e.dtype), dex, ex)
        return dgs, dx0, dex

    run.defvjp(run_fwd, run_bwd)
    xL, aux = run(grouped, x, extra)
    return xL, aux


# --------------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------------- #
def lm_init(key, cfg):
    dtype = jnp.dtype(cfg.dtype)
    ks = nn.split_key_tree(key, ["embed", "layers", "head", "shared", "dense0"])
    p = {
        "embed": nn.embed_init(ks["embed"], cfg.vocab_padded, cfg.d_model, dtype),
        "final_norm": nn.rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = nn.dense_init(ks["head"], cfg.d_model, cfg.vocab_padded, dtype)

    fam = cfg.family
    if fam in ("dense",):
        p["layers"] = _stacked_init(ks["layers"], cfg, dtype, cfg.n_layers, layer_kind="gqa")
    elif fam == "moe":
        kind = "mla" if cfg.kv_lora_rank else "gqa"
        n_moe = cfg.n_layers - cfg.first_dense_layers
        if cfg.first_dense_layers:
            p["dense_layers"] = _stacked_init(
                ks["dense0"], cfg, dtype, cfg.first_dense_layers, layer_kind="dense_ffn"
            )
            # replace the attn sub-init to match the moe stack's attention kind
            if kind == "mla":
                keys = jax.random.split(ks["dense0"], cfg.first_dense_layers)
                p["dense_layers"]["attn"] = jax.vmap(
                    lambda k: attn.mla_init(k, cfg, dtype)
                )(keys)
        p["layers"] = _stacked_init(ks["layers"], cfg, dtype, n_moe, layer_kind="moe")
        if kind == "mla":
            keys = jax.random.split(ks["shared"], n_moe)
            p["layers"]["attn"] = jax.vmap(lambda k: attn.mla_init(k, cfg, dtype))(keys)
    elif fam == "vlm":
        n_groups = cfg.n_layers // cfg.cross_attn_every
        n_self = cfg.cross_attn_every - 1
        kg = jax.random.split(ks["layers"], n_groups)

        def group_init(k):
            k1, k2 = jax.random.split(k)
            return {
                "self": _stacked_init(k1, cfg, dtype, n_self, layer_kind="gqa"),
                "cross": _block_init(k2, cfg, dtype, layer_kind="cross"),
            }

        p["layers"] = jax.vmap(group_init)(kg)
    elif fam == "hybrid":
        p["layers"] = _stacked_init(ks["layers"], cfg, dtype, cfg.n_layers, layer_kind="mamba")
        p["shared_attn"] = _block_init(ks["shared"], cfg, dtype, layer_kind="gqa")
    elif fam == "ssm":
        p["layers"] = _stacked_init(ks["layers"], cfg, dtype, cfg.n_layers, layer_kind="mamba")
    else:
        raise ValueError(f"lm_init: unsupported family {fam}")
    return p


# --------------------------------------------------------------------------- #
# forward (train / prefill trunk)
# --------------------------------------------------------------------------- #
def _trunk(p, x, cfg, batch, positions):
    """Embedded activations -> final hidden states.  Returns (x, aux)."""
    remat = cfg.remat == "block"
    fam = cfg.family
    aux = 0.0
    if fam in ("dense",):
        body = lambda lp, h, _: _self_block(lp, h, cfg, positions=positions, mla=False, use_moe=False)
        x, aux = _scan_blocks(body, p["layers"], x, remat=remat, group=cfg.remat_group)
    elif fam == "moe":
        mla = bool(cfg.kv_lora_rank)
        if "dense_layers" in p:
            body0 = lambda lp, h, _: _self_block(
                lp, h, cfg, positions=positions, mla=mla, use_moe=False
            )
            x, a0 = _scan_blocks(body0, p["dense_layers"], x, remat=remat)
            aux += a0
        body = lambda lp, h, _: _self_block(lp, h, cfg, positions=positions, mla=mla, use_moe=True)
        x, a1 = _scan_blocks(body, p["layers"], x, remat=remat, group=cfg.remat_group)
        aux += a1
    elif fam == "vlm":
        img = batch["image_embed"].astype(x.dtype)

        def group_body(gp, h, img_ex):
            body = lambda lp, hh, _: _self_block(
                lp, hh, cfg, positions=positions, mla=False, use_moe=False
            )
            # inner stack un-remated: the outer super-block checkpoint covers it
            h, a = _scan_blocks(body, gp["self"], h, remat=False)
            kv = attn.cross_attn_kv(gp["cross"]["cross"], img_ex, cfg)
            h = _cross_block(gp["cross"], h, kv, cfg)
            return h, a

        x, aux = _scan_blocks(group_body, p["layers"], x, remat=remat, extra=img)
    elif fam in ("hybrid", "ssm"):
        body = lambda lp, h, _: (_mamba_block(lp, h, cfg), 0.0)
        if fam == "ssm":
            x, aux = _scan_blocks(body, p["layers"], x, remat=remat, group=cfg.remat_group)
        else:
            # zamba2: segments of mamba blocks + tied shared attention block
            segs = _hybrid_segments(cfg)
            off = 0
            for seg_len, with_attn in segs:
                seg_params = jax.tree_util.tree_map(
                    lambda a: jax.lax.slice_in_dim(a, off, off + seg_len, axis=0),
                    p["layers"],
                )
                x, _ = _scan_blocks(body, seg_params, x, remat=remat, group=cfg.remat_group)
                off += seg_len
                if with_attn:
                    x, _ = _self_block(
                        p["shared_attn"], x, cfg, positions=positions, mla=False, use_moe=False
                    )
    else:
        raise ValueError(fam)
    return x, aux


def _hybrid_segments(cfg):
    """[(n_mamba_layers, apply_shared_attn_after)] covering n_layers.  The
    tied attention block fires after every full ``attn_every`` mamba segment
    (zamba2-38L/6 -> 6 applications; the trailing partial segment gets none)."""
    segs, done = [], 0
    while done < cfg.n_layers:
        n = min(cfg.attn_every, cfg.n_layers - done)
        done += n
        segs.append((n, n == cfg.attn_every))
    return segs


def _logits(p, x, cfg):
    head = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    if isinstance(head, dict):  # compressed lm_head
        logits = nn.dense(head, x)
    else:
        logits = jnp.matmul(x, head, preferred_element_type=jnp.float32)
    spec = ("batch",) + (None,) * (x.ndim - 2) + ("tp_vocab",)
    logits = maybe_constrain(logits, spec)
    return logits.astype(jnp.float32)


def lm_forward(p, batch, cfg):
    """batch['tokens']: (B, S) -> (logits fp32 (B,S,Vp), aux_loss)."""
    x, aux = lm_forward_features(p, batch, cfg)
    return _logits(p, x, cfg), aux


def lm_forward_features(p, batch, cfg):
    """Trunk only: final-norm hidden states (B, S, d).  The chunked-loss
    training path applies the LM head per token chunk (never materializing
    the full fp32 logits — see train_step.chunked_softmax_xent)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = nn.embed_lookup(p["embed"], tokens)
    x = maybe_constrain(x, ("batch", None, None))
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x, aux = _trunk(p, x, cfg, batch, positions)
    x = nn.rmsnorm(p["final_norm"], x, cfg.norm_eps)
    return x, aux


def lm_head_apply(p, x, cfg):
    return _logits(p, x, cfg)


# --------------------------------------------------------------------------- #
# serving: cache init / prefill / decode
# --------------------------------------------------------------------------- #
def _layer_cache_init(cfg, batch, max_len, dtype, *, layer_kind):
    if layer_kind == "mamba":
        return ssm_mod.mamba2_init_cache(cfg, batch, dtype)
    if layer_kind == "mla":
        return attn.mla_init_cache(cfg, batch, max_len, dtype)
    return attn.gqa_init_cache(cfg, batch, max_len, dtype)


def lm_init_cache(cfg, batch_size: int, max_len: int):
    dtype = jnp.dtype(cfg.dtype)
    fam = cfg.family

    def stack(n, kind):
        one = _layer_cache_init(cfg, batch_size, max_len, dtype, layer_kind=kind)
        return jax.tree_util.tree_map(lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy(), one)

    if fam == "dense":
        return {"layers": stack(cfg.n_layers, "gqa")}
    if fam == "moe":
        kind = "mla" if cfg.kv_lora_rank else "gqa"
        c = {"layers": stack(cfg.n_layers - cfg.first_dense_layers, kind)}
        if cfg.first_dense_layers:
            c["dense_layers"] = stack(cfg.first_dense_layers, kind)
        return c
    if fam == "vlm":
        n_groups = cfg.n_layers // cfg.cross_attn_every
        return {
            "layers": stack_groups_vlm(cfg, batch_size, max_len, dtype, n_groups),
        }
    if fam == "hybrid":
        n_apps = len([s for s in _hybrid_segments(cfg) if s[1]])
        return {
            "layers": stack(cfg.n_layers, "mamba"),
            "shared_attn": stack(n_apps, "gqa"),
        }
    if fam == "ssm":
        return {"layers": stack(cfg.n_layers, "mamba")}
    raise ValueError(fam)


def _mask_like(tree, paged: bool):
    return jax.tree_util.tree_map(lambda _: paged, tree)


def _layer_cache_init_paged(cfg, batch, max_len, dtype, page_size, n_phys, *, layer_kind):
    """(one_layer_cache, paged?) — paged leaves swap (B, S) for (P, page)."""
    if layer_kind == "mamba":
        return ssm_mod.mamba2_init_cache(cfg, batch, dtype), False
    if layer_kind == "mla":
        return attn.mla_init_cache_paged(cfg, page_size, n_phys, dtype)
    c, paged = attn.gqa_init_cache_paged(cfg, page_size, n_phys, dtype)
    if not paged:  # sliding-window ring stays slot-resident
        return attn.gqa_init_cache(cfg, batch, max_len, dtype), False
    return c, paged


def lm_init_cache_paged(cfg, batch_size: int, max_len: int, *, page_size: int, n_pages: int):
    """Paged decode cache: physical page pools + per-slot block table.

    Per-token cache leaves trade their (B, S) slot reservation for
    (n_pages + 1, page_size) physical pools shared by every slot (the +1 is
    the trailing trash page — attention.trash_page); per-slot state that is
    O(1) or window-bounded (mamba conv/state, SWA rings, VLM cross-KV)
    keeps the slot layout.  The (batch, max_pages) ``block_table`` rides in
    the cache pytree — initialized to the trash id, rewritten per slot by
    the engine at admission — and is shared by every layer.

    Returns ``(cache, paged_mask)`` where ``paged_mask`` mirrors the cache
    structure (sans block_table) with one bool per leaf, so the engine
    knows which scatter (page vs slot) each prefill leaf takes.
    """
    dtype = jnp.dtype(cfg.dtype)
    fam = cfg.family
    n_phys = n_pages + 1
    max_pages = -(-max_len // page_size)

    def stack(n, kind):
        one, paged = _layer_cache_init_paged(
            cfg, batch_size, max_len, dtype, page_size, n_phys, layer_kind=kind
        )
        stacked = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy(), one
        )
        return stacked, _mask_like(stacked, paged)

    if fam == "dense":
        c, m = stack(cfg.n_layers, "gqa")
        cache, mask = {"layers": c}, {"layers": m}
    elif fam == "moe":
        kind = "mla" if cfg.kv_lora_rank else "gqa"
        c, m = stack(cfg.n_layers - cfg.first_dense_layers, kind)
        cache, mask = {"layers": c}, {"layers": m}
        if cfg.first_dense_layers:
            c0, m0 = stack(cfg.first_dense_layers, kind)
            cache["dense_layers"], mask["dense_layers"] = c0, m0
    elif fam == "vlm":
        n_groups = cfg.n_layers // cfg.cross_attn_every
        n_self = cfg.cross_attn_every - 1
        one_self, paged = _layer_cache_init_paged(
            cfg, batch_size, max_len, dtype, page_size, n_phys, layer_kind="gqa"
        )
        self_stack = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (n_groups, n_self) + a.shape).copy(), one_self
        )
        H, hd, T = cfg.n_heads, cfg.head_dim, cfg.n_image_tokens
        cross_kv = {
            "k": jnp.zeros((n_groups, batch_size, T, H, hd), dtype),
            "v": jnp.zeros((n_groups, batch_size, T, H, hd), dtype),
        }
        cache = {"layers": {"self": self_stack, "cross_kv": cross_kv}}
        mask = {"layers": {
            "self": _mask_like(self_stack, paged),
            "cross_kv": _mask_like(cross_kv, False),
        }}
    elif fam == "hybrid":
        n_apps = len([s for s in _hybrid_segments(cfg) if s[1]])
        c, m = stack(cfg.n_layers, "mamba")
        ca, ma = stack(n_apps, "gqa")
        cache = {"layers": c, "shared_attn": ca}
        mask = {"layers": m, "shared_attn": ma}
    elif fam == "ssm":
        c, m = stack(cfg.n_layers, "mamba")
        cache, mask = {"layers": c}, {"layers": m}
    else:
        raise ValueError(fam)
    cache["block_table"] = jnp.full((batch_size, max_pages), n_pages, jnp.int32)
    return cache, mask


def stack_groups_vlm(cfg, batch_size, max_len, dtype, n_groups):
    n_self = cfg.cross_attn_every - 1
    one_self = attn.gqa_init_cache(cfg, batch_size, max_len, dtype)
    self_stack = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (n_groups, n_self) + a.shape).copy(), one_self
    )
    H, hd, T = cfg.n_heads, cfg.head_dim, cfg.n_image_tokens
    cross_kv = {
        "k": jnp.zeros((n_groups, batch_size, T, H, hd), dtype),
        "v": jnp.zeros((n_groups, batch_size, T, H, hd), dtype),
    }
    return {"self": self_stack, "cross_kv": cross_kv}


def _hybrid_shared_positions(cfg):
    return [i for i, s in enumerate(_hybrid_segments(cfg)) if s[1]]


def lm_prefill(p, batch, cfg, max_len: int, *, last_index=None):
    """Run the prompt through the model, building the decode cache.

    Returns (last_token_logits (B, Vp), cache).  Implemented as forward with
    per-layer cache capture; scan layers capture stacked caches.

    ``last_index``: optional (B,) int32 — per-sequence index of the LAST
    valid prompt token.  Right-padded ragged micro-batches (continuous
    batching) pass this so each sequence's next-token logits come from its
    own final token rather than the padded tail; causal masking guarantees
    those logits are unaffected by the padding to the right.  Default
    (None) keeps the classic fixed-shape behaviour (last column).
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = nn.embed_lookup(p["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    fam = cfg.family
    remat = cfg.remat == "block"
    dtype = jnp.dtype(cfg.dtype)

    def pad_kv(kv):
        """Right-pad prefill K/V (B,S,KV,hd) to max_len slots."""
        k, v = kv
        Sc = k.shape[1]
        tgt = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
        if Sc < tgt:
            pad = [(0, 0), (0, tgt - Sc), (0, 0), (0, 0)]
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        return {"k": k, "v": v}

    cache = {}
    aux_positions = positions

    def gqa_body(lp, h):
        hh = nn.rmsnorm(lp["attn_norm"], h, cfg.norm_eps)
        a, kv = attn.gqa_forward(lp["attn"], hh, cfg, positions=aux_positions, return_cache=True)
        h = h + a
        hh = nn.rmsnorm(lp["mlp_norm"], h, cfg.norm_eps)
        h = h + moe_mod.ffn_forward(lp["mlp"], hh)
        return h, pad_kv(kv)

    def mla_body(lp, h, *, use_moe):
        hh = nn.rmsnorm(lp["attn_norm"], h, cfg.norm_eps)
        a, (c_kv, k_rope) = attn.mla_forward(
            lp["attn"], hh, cfg, positions=aux_positions, return_cache=True
        )
        h = h + a
        hh = nn.rmsnorm(lp["mlp_norm"], h, cfg.norm_eps)
        if use_moe:
            m, _ = moe_mod.moe_forward(lp["moe"], hh, cfg)
        else:
            m = moe_mod.ffn_forward(lp["mlp"], hh)
        h = h + m
        pad = [(0, 0), (0, max_len - S), (0, 0)]
        return h, {"c_kv": jnp.pad(c_kv, pad), "k_rope": jnp.pad(k_rope, pad)}

    def moe_gqa_body(lp, h):
        hh = nn.rmsnorm(lp["attn_norm"], h, cfg.norm_eps)
        a, kv = attn.gqa_forward(lp["attn"], hh, cfg, positions=aux_positions, return_cache=True)
        h = h + a
        hh = nn.rmsnorm(lp["mlp_norm"], h, cfg.norm_eps)
        m, _ = moe_mod.moe_forward(lp["moe"], hh, cfg)
        return h + m, pad_kv(kv)

    def mamba_body(lp, h):
        hh = nn.rmsnorm(lp["ssm_in_norm"], h, cfg.norm_eps)
        o, c = ssm_mod.mamba2_forward(lp["mamba"], hh, cfg, return_cache=True)
        return h + o, c

    def scan_with_cache(body, stack, h):
        fn = jax.checkpoint(body, prevent_cse=True) if remat else body

        def step(carry, lp):
            carry = maybe_constrain(carry, ("batch", "seq", None))
            h2, c = fn(lp, carry)
            return h2, c

        return jax.lax.scan(step, h, stack)

    if fam == "dense":
        x, kvs = scan_with_cache(gqa_body, p["layers"], x)
        cache = {"layers": kvs}
    elif fam == "moe":
        mla = bool(cfg.kv_lora_rank)
        body = (lambda lp, h: mla_body(lp, h, use_moe=True)) if mla else moe_gqa_body
        cache = {}
        if "dense_layers" in p:
            dbody = (
                (lambda lp, h: mla_body(lp, h, use_moe=False))
                if mla
                else gqa_body
            )
            x, c0 = scan_with_cache(dbody, p["dense_layers"], x)
            cache["dense_layers"] = c0
        x, kvs = scan_with_cache(body, p["layers"], x)
        cache["layers"] = kvs
    elif fam == "vlm":
        img = batch["image_embed"].astype(x.dtype)

        def group_body(gp, h):
            h, selfc = scan_with_cache(gqa_body, gp["self"], h)
            kv = attn.cross_attn_kv(gp["cross"]["cross"], img, cfg)
            h = _cross_block(gp["cross"], h, kv, cfg)
            return h, {"self": selfc, "cross_kv": {"k": kv[0], "v": kv[1]}}

        x, gc = scan_with_cache(group_body, p["layers"], x)
        cache = {"layers": gc}
    elif fam in ("hybrid", "ssm"):
        if fam == "ssm":
            x, cs = scan_with_cache(mamba_body, p["layers"], x)
            cache = {"layers": cs}
        else:
            segs = _hybrid_segments(cfg)
            off, seg_caches, shared_caches = 0, [], []
            for seg_len, with_attn in segs:
                seg_params = jax.tree_util.tree_map(
                    lambda a: jax.lax.slice_in_dim(a, off, off + seg_len, axis=0),
                    p["layers"],
                )
                x, c = scan_with_cache(mamba_body, seg_params, x)
                seg_caches.append(c)
                off += seg_len
                if with_attn:
                    hh = nn.rmsnorm(p["shared_attn"]["attn_norm"], x, cfg.norm_eps)
                    a, kv = attn.gqa_forward(
                        p["shared_attn"]["attn"],
                        hh,
                        cfg,
                        positions=aux_positions,
                        return_cache=True,
                    )
                    x = x + a
                    hh = nn.rmsnorm(p["shared_attn"]["mlp_norm"], x, cfg.norm_eps)
                    x = x + moe_mod.ffn_forward(p["shared_attn"]["mlp"], hh)
                    # zamba2 detail: the shared block's weights are tied but
                    # its KV cache differs per application point.
                    shared_caches.append(pad_kv(kv))
            cache = {
                "layers": jax.tree_util.tree_map(
                    lambda *cs: jnp.concatenate(cs, axis=0), *seg_caches
                ),
                "shared_attn": jax.tree_util.tree_map(
                    lambda *cs: jnp.stack(cs, axis=0), *shared_caches
                ),
            }
    else:
        raise ValueError(fam)

    x = nn.rmsnorm(p["final_norm"], x, cfg.norm_eps)
    if last_index is None:
        last = x[:, -1:, :]
    else:
        idx = jnp.asarray(last_index, jnp.int32)
        last = x[jnp.arange(B), idx][:, None, :]
    logits = _logits(p, last, cfg)[:, 0]
    return logits, cache


def lm_prefill_chunk(p, cache, tokens, cfg, *, bt_row, start, n_real):
    """One page-aligned chunk of a long prompt's prefill (paged cache only).

    tokens: (1, C) int32 — the chunk at absolute positions ``start + [0, C)``
    of one slot's prompt, right-padded when fewer than C real tokens remain
    (``n_real`` of them are real; padded rows write to the trash page).
    ``bt_row``: the slot's (n_tbl,) page ids, passed EXPLICITLY rather than
    read from ``cache["block_table"]`` — the engine keeps the device table's
    row pointed at trash until the last chunk lands, so the fused decode
    block's frozen-slot re-feeds (which write through the table at position
    0) cannot corrupt a half-prefilled slot's pages.

    Each attention layer writes the chunk's K/V into the slot's pages, then
    attends over the gathered logical cache with an absolute-position
    causal mask — chunk-by-chunk prefill computes the same function as the
    monolithic prefill (bit-identical to its single-flash-block path; see
    attention._chunk_masked_attention).  Supported for the attention
    families whose prefill has no cross-chunk recurrent state (dense + moe,
    no sliding window) — build_model gates ``prefill_chunk`` accordingly;
    other families prefill monolithically.

    This is also the serving stack's MID-PROMPT prefill entry point:
    ``start`` need not be 0 and positions before it need not have been
    written by this request at all — shared-prefix admission points
    ``bt_row``'s leading entries at read-only pages another request
    prefilled and starts the chunk loop at the first unshared position.
    The only write targets are pages at or after ``start // page_size``
    (the engine COW-forks that boundary page when it is shared), so the
    mid-prompt contract needs no flag: it is a property of write-then-
    attend over an explicit block-table row.

    Returns ``(last_logits (1, Vp), cache)`` where ``last_logits`` is taken
    at the chunk's last REAL token — only the final chunk's logits are
    meaningful to the caller.
    """
    fam = cfg.family
    if fam not in ("dense", "moe") or cfg.sliding_window is not None:
        raise ValueError(f"chunked prefill unsupported for family {fam!r}")
    B, C = tokens.shape
    bt_row = jnp.asarray(bt_row, jnp.int32).reshape(-1)  # (n_tbl,)
    x = nn.embed_lookup(p["embed"], tokens)
    mla = bool(cfg.kv_lora_rank)

    def chunk_body(lp, h, c):
        hh = nn.rmsnorm(lp["attn_norm"], h, cfg.norm_eps)
        if mla:
            a, c2 = attn.mla_prefill_chunk(lp["attn"], hh, c, cfg, bt_row, start, n_real)
        else:
            a, c2 = attn.gqa_prefill_chunk(lp["attn"], hh, c, cfg, bt_row, start, n_real)
        h = h + a
        hh = nn.rmsnorm(lp["mlp_norm"], h, cfg.norm_eps)
        if "moe" in lp:
            m, _ = moe_mod.moe_forward(lp["moe"], hh, cfg)
        else:
            m = moe_mod.ffn_forward(lp["mlp"], hh)
        return h + m, c2

    def scan_chunk(stack, caches, h):
        # layer scan with the page pools as CARRY (same in-place aliasing
        # rationale as lm_decode_step.scan_steps)
        n = jax.tree_util.tree_leaves(stack)[0].shape[0]

        def body(carry, inp):
            h, cs = carry
            lp, i = inp
            c = jax.tree_util.tree_map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, keepdims=False), cs
            )
            h2, c2 = chunk_body(lp, h, c)
            cs2 = jax.tree_util.tree_map(
                lambda a, u: jax.lax.dynamic_update_index_in_dim(a, u, i, axis=0),
                cs,
                c2,
            )
            return (h2, cs2), None

        (h, caches), _ = jax.lax.scan(body, (h, caches), (stack, jnp.arange(n)))
        return h, caches

    new_cache = dict(cache)
    if "dense_layers" in p:
        x, c0 = scan_chunk(p["dense_layers"], cache["dense_layers"], x)
        new_cache["dense_layers"] = c0
    x, c = scan_chunk(p["layers"], cache["layers"], x)
    new_cache["layers"] = c
    x = nn.rmsnorm(p["final_norm"], x, cfg.norm_eps)
    last = x[jnp.arange(B), jnp.clip(n_real - 1, 0, C - 1)][:, None, :]
    logits = _logits(p, last, cfg)[:, 0]
    return logits, new_cache


def lm_decode_step(p, cache, tokens, pos, cfg):
    """tokens: (B, 1) int32; pos: scalar or (B,) per-slot positions
    (continuous batching).  Returns (logits (B,Vp), cache).

    A cache built by :func:`lm_init_cache_paged` carries a ``block_table``
    leaf; its presence routes per-token attention caches through the paged
    decode twins (block-table writes + the "paged_decode_attention" dispatch
    op) while slot-resident leaves (mamba state, SWA rings, cross-KV) keep
    the flat step — same logits either way.
    """
    B = tokens.shape[0]
    x = nn.embed_lookup(p["embed"], tokens)
    fam = cfg.family
    bt = cache.get("block_table")
    paged_attn = bt is not None and cfg.sliding_window is None

    def gqa_step(lp, h, c):
        hh = nn.rmsnorm(lp["attn_norm"], h, cfg.norm_eps)
        if paged_attn:
            a, c2 = attn.gqa_decode_paged(lp["attn"], hh, c, pos, cfg, bt)
        else:
            a, c2 = attn.gqa_decode(lp["attn"], hh, c, pos, cfg)
        h = h + a
        hh = nn.rmsnorm(lp["mlp_norm"], h, cfg.norm_eps)
        return h + moe_mod.ffn_forward(lp["mlp"], hh), c2

    def moe_step(lp, h, c, *, mla):
        hh = nn.rmsnorm(lp["attn_norm"], h, cfg.norm_eps)
        if mla:
            if paged_attn:
                a, c2 = attn.mla_decode_paged(lp["attn"], hh, c, pos, cfg, bt)
            else:
                a, c2 = attn.mla_decode(lp["attn"], hh, c, pos, cfg)
        elif paged_attn:
            a, c2 = attn.gqa_decode_paged(lp["attn"], hh, c, pos, cfg, bt)
        else:
            a, c2 = attn.gqa_decode(lp["attn"], hh, c, pos, cfg)
        h = h + a
        hh = nn.rmsnorm(lp["mlp_norm"], h, cfg.norm_eps)
        if "moe" in lp:
            m, _ = moe_mod.moe_forward(lp["moe"], hh, cfg)
        else:
            m = moe_mod.ffn_forward(lp["mlp"], hh)
        return h + m, c2

    def mamba_step(lp, h, c):
        hh = nn.rmsnorm(lp["ssm_in_norm"], h, cfg.norm_eps)
        o, c2 = ssm_mod.mamba2_decode(lp["mamba"], hh, c, cfg)
        return h + o, c2

    def scan_steps(step, stack, caches, h):
        """Scan layers with the cache stack as CARRY, updated in place via
        dynamic_update_index.

        Perf log (EXPERIMENTS.md §Perf): both this and the xs/ys formulation
        were measured.  Byte traffic is equivalent (the residual full-stack
        copies in the CPU-lowered HLO come from dot-layout/convert
        rewrites, not the scan form), but the carry form peaks ~40% lower
        HBM (11.8 vs 19.3 GiB/chip on qwen2-72b decode_32k) because the
        while-loop carry aliases in place while xs/ys double-buffers."""
        n = jax.tree_util.tree_leaves(stack)[0].shape[0]

        def body(carry, inp):
            h, cs = carry
            lp, i = inp
            c = jax.tree_util.tree_map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, keepdims=False), cs
            )
            h2, c2 = step(lp, h, c)
            cs2 = jax.tree_util.tree_map(
                lambda a, u: jax.lax.dynamic_update_index_in_dim(a, u, i, axis=0),
                cs,
                c2,
            )
            return (h2, cs2), None

        (h, caches), _ = jax.lax.scan(body, (h, caches), (stack, jnp.arange(n)))
        return h, caches

    new_cache = dict(cache)
    if fam == "dense":
        x, c = scan_steps(gqa_step, p["layers"], cache["layers"], x)
        new_cache["layers"] = c
    elif fam == "moe":
        mla = bool(cfg.kv_lora_rank)
        if "dense_layers" in p:
            x, c0 = scan_steps(
                lambda lp, h, c: moe_step(lp, h, c, mla=mla),
                p["dense_layers"],
                cache["dense_layers"],
                x,
            )
            new_cache["dense_layers"] = c0
        x, c = scan_steps(
            lambda lp, h, c: moe_step(lp, h, c, mla=mla), p["layers"], cache["layers"], x
        )
        new_cache["layers"] = c
    elif fam == "vlm":
        def group_step(gp, h, gc):
            h, sc = scan_steps(gqa_step, gp["self"], gc["self"], h)
            kv = (gc["cross_kv"]["k"], gc["cross_kv"]["v"])
            h = _cross_block(gp["cross"], h, kv, cfg)
            return h, {"self": sc, "cross_kv": gc["cross_kv"]}

        x, gc = scan_steps(group_step, p["layers"], cache["layers"], x)
        new_cache["layers"] = gc
    elif fam in ("hybrid", "ssm"):
        if fam == "ssm":
            x, c = scan_steps(mamba_step, p["layers"], cache["layers"], x)
            new_cache["layers"] = c
        else:
            segs = _hybrid_segments(cfg)
            off, shared_i = 0, 0
            seg_caches = []
            shared_cache = cache["shared_attn"]
            new_shared = []
            for seg_len, with_attn in segs:
                sl = lambda a: jax.lax.slice_in_dim(a, off, off + seg_len, axis=0)
                seg_params = jax.tree_util.tree_map(sl, p["layers"])
                seg_cache = jax.tree_util.tree_map(sl, cache["layers"])
                x, c = scan_steps(mamba_step, seg_params, seg_cache, x)
                seg_caches.append(c)
                off += seg_len
                if with_attn:
                    sc = jax.tree_util.tree_map(lambda a: a[shared_i], shared_cache)
                    hh = nn.rmsnorm(p["shared_attn"]["attn_norm"], x, cfg.norm_eps)
                    if paged_attn:
                        a, sc2 = attn.gqa_decode_paged(
                            p["shared_attn"]["attn"], hh, sc, pos, cfg, bt
                        )
                    else:
                        a, sc2 = attn.gqa_decode(p["shared_attn"]["attn"], hh, sc, pos, cfg)
                    x = x + a
                    hh = nn.rmsnorm(p["shared_attn"]["mlp_norm"], x, cfg.norm_eps)
                    x = x + moe_mod.ffn_forward(p["shared_attn"]["mlp"], hh)
                    new_shared.append(sc2)
                    shared_i += 1
            new_cache["layers"] = jax.tree_util.tree_map(
                lambda *cs: jnp.concatenate(cs, axis=0), *seg_caches
            )
            new_cache["shared_attn"] = jax.tree_util.tree_map(
                lambda *cs: jnp.stack(cs, axis=0), *new_shared
            )
    else:
        raise ValueError(fam)

    x = nn.rmsnorm(p["final_norm"], x, cfg.norm_eps)
    logits = _logits(p, x, cfg)[:, 0]
    return logits, new_cache
