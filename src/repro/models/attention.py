"""Attention variants: GQA (+SWA, +bias), MLA (deepseek), cross-attention.

Memory discipline: training/prefill attention is *chunked* (flash-style
online softmax written in XLA: outer lax.scan over query chunks, inner
lax.scan over KV chunks, fp32 running max/denominator).  Nothing of size
S x S is ever materialized, which is what lets the 32k-prefill cells fit.
An optional Pallas flash kernel (kernels/flash_attention.py) replaces the
inner loop on real TPUs; the XLA path is the dry-run/compile target.

Decode: single-token attention over a preallocated KV cache.
Sliding-window archs use a RING-BUFFER cache of size ``window`` (keys stored
with rope pre-applied), so a 500k-context SWA decode holds only O(window)
state.  MLA decode uses the absorbed-weight latent trick: scores and context
are computed directly in the kv_lora latent space, so the cache is
(kv_lora + rope_dim) per token instead of 2*H*head_dim.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import modules as nn
from repro.sharding.rules import maybe_constrain

NEG_INF = -1e30


def position_vector(pos, batch: int):
    """Normalize a decode position to a per-sequence (B,) int32 vector.

    Decode entry points accept either a scalar ``pos`` (the classic
    fixed-shape path: every sequence sits at the same position) or a (B,)
    vector (continuous batching: every cache slot advances independently).
    Both forms route through the SAME vectorized code below, so the static
    and continuous serving paths stay bit-identical.
    """
    pos = jnp.asarray(pos, jnp.int32)
    return jnp.broadcast_to(pos, (batch,)) if pos.ndim == 0 else pos.reshape(batch)


# --------------------------------------------------------------------------- #
# Core chunked attention (training / prefill)
# --------------------------------------------------------------------------- #
def _pick_chunk(s: int, target: int) -> int:
    c = min(target, s)
    while s % c:
        c //= 2
    return max(c, 1)


def _mask_for(iq, jkv, cq, ckv, *, causal, window, q_offset):
    q_pos = q_offset + iq * cq + jnp.arange(cq)
    k_pos = jkv * ckv + jnp.arange(ckv)
    mask = jnp.ones((cq, ckv), dtype=bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    return mask


def _flash_fwd_pass(q, k, v, causal, window, q_chunk, kv_chunk, q_offset):
    """Online-softmax forward.  Returns (out (B,Sq,H,vd), lse (B,KV,G,Sq))."""
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    vd = v.shape[-1]
    G = H // KV
    scale = hd**-0.5
    cq = _pick_chunk(Sq, q_chunk)
    ckv = _pick_chunk(Skv, kv_chunk)
    nq, nkv = Sq // cq, Skv // ckv

    qg = q.reshape(B, nq, cq, KV, G, hd)
    kg = k.reshape(B, nkv, ckv, KV, hd)
    vg = v.reshape(B, nkv, ckv, KV, vd)

    def q_body(_, qi):
        q_blk, iq = qi  # (B, cq, KV, G, hd)
        qs = (q_blk.astype(jnp.float32) * scale).astype(q.dtype)

        def kv_body(carry, kvj):
            m, l, acc = carry
            k_blk, v_blk, jkv = kvj
            s = jnp.einsum(
                "bqkgh,bckh->bkgqc", qs, k_blk, preferred_element_type=jnp.float32
            )  # (B, KV, G, cq, ckv) fp32
            mask = _mask_for(iq, jkv, cq, ckv, causal=causal, window=window, q_offset=q_offset)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqc,bckv->bkgqv",
                p.astype(v.dtype),
                v_blk,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, cq), jnp.float32)
        a0 = jnp.zeros((B, KV, G, cq, vd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_body,
            (m0, l0, a0),
            (kg.swapaxes(0, 1), vg.swapaxes(0, 1), jnp.arange(nkv)),
        )
        l_safe = jnp.maximum(l, 1e-30)
        out = acc / l_safe[..., None]  # (B, KV, G, cq, vd)
        lse = m + jnp.log(l_safe)  # (B, KV, G, cq)
        return None, (out.transpose(0, 3, 1, 2, 4), lse)

    _, (outs, lses) = jax.lax.scan(q_body, None, (qg.swapaxes(0, 1), jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, vd)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(B, KV, G, Sq)
    return out.astype(q.dtype), lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, causal, window, q_chunk, kv_chunk, q_offset):
    out, _ = _flash_fwd_pass(q, k, v, causal, window, q_chunk, kv_chunk, q_offset)
    return out


def _flash_fwd(q, k, v, causal, window, q_chunk, kv_chunk, q_offset):
    out, lse = _flash_fwd_pass(q, k, v, causal, window, q_chunk, kv_chunk, q_offset)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, q_chunk, kv_chunk, q_offset, res, dout):
    """Flash-attention backward: probabilities are RECOMPUTED per (q, kv)
    chunk pair from the saved lse — only O(S·H) residuals are kept, never
    the O(S^2) score/probability stacks that plain autodiff-through-scan
    would save.  This is what makes 32k-token training cells fit in HBM."""
    q, k, v, out, lse = res
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    vd = v.shape[-1]
    G = H // KV
    scale = hd**-0.5
    cq = _pick_chunk(Sq, q_chunk)
    ckv = _pick_chunk(Skv, kv_chunk)
    nq, nkv = Sq // cq, Skv // ckv

    qg = q.reshape(B, nq, cq, KV, G, hd)
    kg = k.reshape(B, nkv, ckv, KV, hd)
    vg = v.reshape(B, nkv, ckv, KV, vd)
    dog = dout.reshape(B, nq, cq, KV, G, vd)
    og = out.reshape(B, nq, cq, KV, G, vd)
    lseg = lse.reshape(B, KV, G, nq, cq)
    # delta = rowsum(dout * out) (B, nq, KV, G, cq)
    delta = jnp.sum(dog.astype(jnp.float32) * og.astype(jnp.float32), axis=-1)
    delta = delta.transpose(0, 1, 3, 4, 2)  # (B, nq, KV, G, cq)

    def q_body(carry, qi):
        dk_full, dv_full = carry  # fp32 (B, Skv, KV, hd/vd)
        q_blk, do_blk, lse_blk, delta_blk, iq = qi
        qs = (q_blk.astype(jnp.float32) * scale).astype(q.dtype)

        def kv_body(inner, kvj):
            dq_acc, dk_f, dv_f = inner
            jkv = kvj
            k_blk = jax.lax.dynamic_slice_in_dim(kg, jkv, 1, axis=1)[:, 0]
            v_blk = jax.lax.dynamic_slice_in_dim(vg, jkv, 1, axis=1)[:, 0]
            s = jnp.einsum(
                "bqkgh,bckh->bkgqc", qs, k_blk, preferred_element_type=jnp.float32
            )
            mask = _mask_for(iq, jkv, cq, ckv, causal=causal, window=window, q_offset=q_offset)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            p = jnp.exp(s - lse_blk[..., None])  # (B,KV,G,cq,ckv)
            pb = p.astype(v.dtype)
            # dv += p^T @ dout
            dv_blk = jnp.einsum(
                "bkgqc,bqkgv->bckv", pb, do_blk, preferred_element_type=jnp.float32
            )
            # dp = dout @ v^T ; ds = p * (dp - delta)
            dp = jnp.einsum(
                "bqkgv,bckv->bkgqc", do_blk, v_blk, preferred_element_type=jnp.float32
            )
            ds = p * (dp - delta_blk[..., None])  # fp32
            dsb = ds.astype(q.dtype)
            dq_acc = dq_acc + jnp.einsum(
                "bkgqc,bckh->bqkgh", dsb, k_blk, preferred_element_type=jnp.float32
            )
            dk_blk = jnp.einsum(
                "bkgqc,bqkgh->bckh", dsb, qs, preferred_element_type=jnp.float32
            )
            dk_f = jax.lax.dynamic_update_slice_in_dim(
                dk_f, jax.lax.dynamic_slice_in_dim(dk_f, jkv * ckv, ckv, axis=1) + dk_blk,
                jkv * ckv, axis=1,
            )
            dv_f = jax.lax.dynamic_update_slice_in_dim(
                dv_f, jax.lax.dynamic_slice_in_dim(dv_f, jkv * ckv, ckv, axis=1) + dv_blk,
                jkv * ckv, axis=1,
            )
            return (dq_acc, dk_f, dv_f), None

        dq0 = jnp.zeros((B, cq, KV, G, hd), jnp.float32)
        (dq_blk, dk_full, dv_full), _ = jax.lax.scan(
            kv_body, (dq0, dk_full, dv_full), jnp.arange(nkv)
        )
        return (dk_full, dv_full), dq_blk * scale

    dk0 = jnp.zeros((B, Skv, KV, hd), jnp.float32)
    dv0 = jnp.zeros((B, Skv, KV, vd), jnp.float32)
    (dk, dv), dqs = jax.lax.scan(
        q_body,
        (dk0, dv0),
        (
            qg.swapaxes(0, 1),
            dog.swapaxes(0, 1),
            lseg.transpose(3, 0, 1, 2, 4),
            delta.swapaxes(0, 1),
            jnp.arange(nq),
        ),
    )
    dq = dqs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, hd).astype(q.dtype)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_fwd, _flash_bwd)


def chunked_attention(
    q,
    k,
    v,
    *,
    causal: bool,
    window: int | None = None,
    q_chunk: int = 2048,
    kv_chunk: int = 1024,
    q_offset: int = 0,
):
    """Online-softmax (flash) attention with memory-safe custom VJP.

    q: (B, Sq, H, hd); k: (B, Skv, KV, hd); v: (B, Skv, KV, vd).
    GQA via head grouping (H = KV * G).  Returns (B, Sq, H, vd).

    Sharding boundary: activations arrive SEQUENCE-sharded (SP) from the
    residual stream, but the flash loops slice KV chunks along the sequence —
    dynamic-slicing a sharded dim forces a per-block-step all-gather (the
    deepseek MLA cell paid a 3776x-repeated fp32 K/V gather for this).
    Re-constrain q/k/v to HEAD sharding here: one resharding per layer
    instead of one gather per flash block step.
    """
    from repro.sharding.rules import active_rules

    rules = active_rules()
    # Only force the resharding when the head dim actually maps onto the
    # model axis — for head counts not divisible by the axis (minitron 24H,
    # whisper 12H) a dropped-to-None constraint would force REPLICATION,
    # regressing those cells ~4x (measured; see EXPERIMENTS.md §Perf B-3).
    if rules is not None and rules.resolve("tp", q.shape[2]) is not None:
        q = maybe_constrain(q, ("batch", None, "tp", None))
        if rules.resolve("tp", k.shape[2]) is not None:
            k = maybe_constrain(k, ("batch", None, "tp", None))
            v = maybe_constrain(v, ("batch", None, "tp", None))
        out = _flash(q, k, v, causal, window, q_chunk, kv_chunk, q_offset)
        return maybe_constrain(out, ("batch", None, "tp", None))
    return _flash(q, k, v, causal, window, q_chunk, kv_chunk, q_offset)


def paged_decode_attention(q, k_pool, v_pool, block_table, n_valid):
    """One-token attention through a paged KV pool (block-table indirection).

    q: (B, 1, H, hd); pools: (P, page, KV, *) fixed-size physical pages
    shared by every slot (the LAST physical page is the pool's trash page —
    see ``trash_page``); block_table: (B, n_tbl) int32; ``n_valid``: scalar
    or (B,) count of valid logical positions.  Masking is strict per slot
    exactly as in :func:`decode_attention`; execution goes through the
    dispatch runtime ("paged_decode_attention"): the block-table Pallas
    kernel on TPU for deep-enough virtual sequences, the gather-einsum
    reference elsewhere.
    """
    from repro.runtime import dispatch

    nv = position_vector(n_valid, q.shape[0])
    return dispatch.paged_decode_attention(q, k_pool, v_pool, block_table, nv)


def trash_page(pool) -> int:
    """Physical id of a pool's write-off page (ALWAYS the last one).

    Paged-cache convention: a pool carries ``n_pages`` allocatable pages
    plus one trailing trash page.  Inactive/frozen slots and padded prefill
    rows write there; block-table entries beyond a slot's allocation point
    there.  Its contents are garbage by design and are never attended —
    every read path masks by ``n_valid`` first.
    """
    return pool.shape[0] - 1


def copy_page(pool, src, dst, *, axis: int = 0):
    """Duplicate physical page ``src`` into ``dst`` along the pool's page
    ``axis`` — the copy-on-write fork of shared-prefix serving.

    Shared block-table entries are read-only by contract: when a slot's
    prefill must re-enter the last matched prefix page (the whole prompt
    was covered, but its final token still has to run to produce the
    sampling logits), the engine forks that page with this and points the
    slot's table at the private copy, so the writer never mutates storage
    other slots are reading.  ``src``/``dst`` may be traced scalars (page
    ids are runtime data — one compiled program covers every fork).
    """
    moved = jnp.moveaxis(pool, axis, 0)
    return jnp.moveaxis(moved.at[dst].set(moved[src]), 0, axis)


def _paged_write(pool, block_table, pos_v, rows, *, live=None):
    """Scatter token rows into their pages: logical position ``pos`` lives at
    ``pool[table[pos // page], pos % page]``.

    Two shapes: ``block_table`` (B, n_tbl) with one position per slot (the
    decode step — row b writes through table row b), or a SINGLE table row
    (n_tbl,) with many positions (a prefill chunk writing one slot's pages).
    ``live`` (optional bool mask over positions) routes dead rows to the
    trash page — collisions there are harmless because trash is never read
    validly.
    """
    page = pool.shape[1]
    idx = jnp.clip(pos_v // page, 0, block_table.shape[-1] - 1)
    if block_table.ndim == 2:
        ids = block_table[jnp.arange(pos_v.shape[0]), idx]
    else:
        ids = block_table[idx]
    if live is not None:
        ids = jnp.where(live, ids, trash_page(pool))
    return pool.at[ids, pos_v % page].set(rows)


def decode_attention(q, k_cache, v_cache, n_valid, *, rotate_mask=None):
    """One-token attention over a cache.  q: (B, 1, H, hd); caches
    (B, S, KV, *).  ``n_valid``: number of valid cache slots — a scalar
    (uniform batch) or a (B,) vector (continuous batching: each slot has
    its own length).  Masking is STRICTLY per sequence: slot b never
    attends past ``n_valid[b]``, so ragged-length sequences can coexist in
    one cache tensor without cross-contamination from stale entries.
    ``rotate_mask`` optionally marks valid slots for ring-buffer caches.
    A fully-masked row (an empty/inactive slot in the continuous-batching
    pool: all-False ``rotate_mask`` or ``n_valid == 0``) produces ZEROS —
    never NaN and never a uniform average over stale cache garbage.

    Execution goes through the unified dispatch runtime like the low-rank
    matmuls: the Pallas flash-decode kernel (kernels/decode_attention.py —
    split-KV online softmax, GQA tiling, zero cache copies) on TPU for deep
    caches, the dense einsum oracle (kernels/ref.decode_attention_ref)
    elsewhere.  Both paths keep the cache in its storage dtype with fp32
    MXU accumulation — an astype here would materialize a fp32 copy of the
    whole multi-GB cache.  The cache's sequence dim is sharded over "model"
    (see serve_step.cache_specs); on the XLA path the softmax over the
    sharded axis lowers to two tiny stat all-reduces under the SPMD
    partitioner."""
    from repro.runtime import dispatch

    B = q.shape[0]
    S = k_cache.shape[1]
    if rotate_mask is None:
        nv = position_vector(n_valid, B)
        valid = jnp.arange(S)[None, :] < nv[:, None]
    else:
        valid = rotate_mask
    return dispatch.decode_attention(q, k_cache, v_cache, valid)


# --------------------------------------------------------------------------- #
# GQA block (llama / qwen / minitron / danube / zamba-shared / whisper-self)
# --------------------------------------------------------------------------- #
def gqa_init(key, cfg, dtype, *, d_model=None):
    d = d_model or cfg.d_model
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = nn.split_key_tree(key, ["wq", "wk", "wv", "wo"])
    p = {
        "wq": nn.dense_init(ks["wq"], d, H * hd, dtype),
        "wk": nn.dense_init(ks["wk"], d, KV * hd, dtype),
        "wv": nn.dense_init(ks["wv"], d, KV * hd, dtype),
        "wo": nn.dense_init(ks["wo"], H * hd, d, dtype, scale=(H * hd) ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    return p


def _qkv(p, x, cfg, positions, *, rope: bool):
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = nn.dense(p["wq"], x)
    k = nn.dense(p["wk"], x)
    v = nn.dense(p["wv"], x)
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if rope:
        q = nn.apply_rope(q, positions, cfg.rope_theta)
        k = nn.apply_rope(k, positions, cfg.rope_theta)
    q = maybe_constrain(q, ("batch", None, "tp", None))
    k = maybe_constrain(k, ("batch", None, "tp", None))
    return q, k, v


def gqa_forward(p, x, cfg, *, positions=None, causal=True, rope=True, return_cache=False):
    """Full-sequence GQA attention (train / prefill)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _qkv(p, x, cfg, positions, rope=rope)
    out = chunked_attention(q, k, v, causal=causal, window=cfg.sliding_window)
    out = nn.dense(p["wo"], out.reshape(B, S, -1))
    if not return_cache:
        return out
    # Prefill cache; SWA keeps only the last `window` positions (ring layout:
    # slot i holds absolute position (S - W) + i ... rotated so that decode's
    # pos % W indexing lines up).
    W = cfg.sliding_window
    if W is not None and S > W:
        k_tail, v_tail = k[:, -W:], v[:, -W:]
        # Place absolute position p at slot p % W.
        slots = (jnp.arange(S - W, S)) % W
        order = jnp.argsort(slots)
        k_tail, v_tail = k_tail[:, order], v_tail[:, order]
        return out, (k_tail, v_tail)
    return out, (k, v)


def gqa_init_cache(cfg, batch: int, max_len: int, dtype):
    W = cfg.sliding_window
    S = min(max_len, W) if W is not None else max_len
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, S, KV, hd), dtype),
        "v": jnp.zeros((batch, S, KV, hd), dtype),
    }


def gqa_decode(p, x, cache, pos, cfg):
    """x: (B, 1, d); pos: absolute position of the new token — scalar int32
    or a (B,) vector for per-slot positions (continuous batching)."""
    B = x.shape[0]
    pos_v = position_vector(pos, B)
    positions = pos_v[:, None]
    q, k, v = _qkv(p, x, cfg, positions, rope=True)
    S = cache["k"].shape[1]
    slot = pos_v % S  # ring for SWA; identity when S == max_len
    b_idx = jnp.arange(B)
    k_cache = cache["k"].at[b_idx, slot].set(k[:, 0])
    v_cache = cache["v"].at[b_idx, slot].set(v[:, 0])
    if cfg.sliding_window is not None and S == cfg.sliding_window:
        n_valid = jnp.minimum(pos_v + 1, S)  # (B,)
        rotate_mask = jnp.arange(S)[None, :] < n_valid[:, None]
        out = decode_attention(q, k_cache, v_cache, n_valid, rotate_mask=rotate_mask)
    else:
        out = decode_attention(q, k_cache, v_cache, pos_v + 1)
    out = nn.dense(p["wo"], out.reshape(B, 1, -1))
    return out, {"k": k_cache, "v": v_cache}


# --------------------------------------------------------------------------- #
# Paged GQA (block-table KV pool; continuous-batching serving)
# --------------------------------------------------------------------------- #
def gqa_init_cache_paged(cfg, page_size: int, n_pages_phys: int, dtype):
    """Physical page pools replacing the per-slot (B, S) reservation.

    Returns ``(cache, paged)``.  Sliding-window archs keep their O(window)
    ring — paging a window-bounded cache banks nothing — so they return
    ``paged=False`` and the caller falls back to :func:`gqa_init_cache`
    (the paged-mask tree tells the engine which scatter to use per leaf).
    """
    if cfg.sliding_window is not None:
        return None, False
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((n_pages_phys, page_size, KV, hd), dtype),
        "v": jnp.zeros((n_pages_phys, page_size, KV, hd), dtype),
    }, True


def gqa_decode_paged(p, x, cache, pos, cfg, block_table):
    """Paged-cache twin of :func:`gqa_decode`: the new token's K/V is
    scattered into the slot's current page and attention walks the block
    table.  Computes EXACTLY what :func:`gqa_decode` computes on the flat
    layout (bit-identical when the logical depth matches), with no
    per-slot worst-case reservation."""
    B = x.shape[0]
    pos_v = position_vector(pos, B)
    q, k, v = _qkv(p, x, cfg, pos_v[:, None], rope=True)
    k_pool = _paged_write(cache["k"], block_table, pos_v, k[:, 0])
    v_pool = _paged_write(cache["v"], block_table, pos_v, v[:, 0])
    out = paged_decode_attention(q, k_pool, v_pool, block_table, pos_v + 1)
    out = nn.dense(p["wo"], out.reshape(B, 1, -1))
    return out, {"k": k_pool, "v": v_pool}


def _chunk_masked_attention(q, k, v, q_pos):
    """Causal attention of a prefill CHUNK against a gathered cache view.

    q: (B, C, H, hd) chunk queries at absolute positions ``q_pos`` (B, C);
    k/v: (B, S, KV, *) the slot's gathered logical cache (chunk K/V already
    written) — query i attends exactly the logical positions j <= q_pos[i].

    Numerics deliberately MIRROR ``_flash_fwd_pass``'s single-KV-block path
    (same einsum contractions, probabilities cast to the cache dtype BEFORE
    the V matmul, the denominator divided out AFTER): masked columns are
    exact zeros and trailing-zero reductions are exact, so on prompts whose
    monolithic prefill runs one flash KV block (S <= kv_chunk) chunked
    prefill is BIT-identical to it — a divide-before-matmul variant was
    measurably off by an ulp, enough to flip near-tie argmaxes.
    """
    B, C, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, C, KV, G, hd)
    qs = (qg.astype(jnp.float32) * hd**-0.5).astype(q.dtype)
    s = jnp.einsum(
        "bqkgh,bckh->bkgqc", qs, k, preferred_element_type=jnp.float32
    )  # (B, KV, G, C, S) fp32
    mask = jnp.arange(S)[None, None, :] <= q_pos[:, :, None]  # (B, C, S)
    mask = mask[:, None, None]  # (B, 1, 1, C, S) broadcast over (KV, G)
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])  # masked cols underflow to exactly 0
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum(
        "bkgqc,bckv->bkgqv", p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B, KV, G, C, vd)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, C, H, v.shape[-1]).astype(q.dtype)


def gqa_prefill_chunk(p, x, cache, cfg, bt_row, start, n_real):
    """One page-backed prefill chunk for a SINGLE slot (B == 1).

    x: (1, C, d) normed chunk activations at absolute positions
    ``start + [0, C)``; ``bt_row``: the slot's (n_tbl,) block-table row;
    ``n_real``: how many leading tokens are real (the last chunk of a
    prompt is right-padded to the static chunk shape — padded rows write to
    the trash page and their outputs are discarded by the caller).  Writes
    the chunk's K/V into the slot's pages FIRST, then attends over the
    gathered logical cache, so intra-chunk causality and attention to all
    previous chunks fall out of one absolute-position mask.

    ``start`` is an arbitrary mid-prompt position — nothing here assumes
    chunk 0 ran through this slot: positions ``< start`` are simply read
    from whatever pages ``bt_row`` maps, which is what lets shared-prefix
    admission skip straight to the first unshared token over READ-ONLY
    prefix pages another request prefilled (``bt_row`` entries before
    ``start // page`` are never written as long as ``start`` stays outside
    them; the engine COW-forks the boundary page when it does not).
    """
    from repro.kernels.ref import gather_pages

    B, C, _ = x.shape
    pos = start + jnp.arange(C, dtype=jnp.int32)  # (C,) absolute positions
    q, k, v = _qkv(p, x, cfg, pos[None, :], rope=True)
    live = jnp.arange(C) < n_real
    k_pool = _paged_write(cache["k"], bt_row, pos, k[0], live=live)
    v_pool = _paged_write(cache["v"], bt_row, pos, v[0], live=live)
    kk = gather_pages(k_pool, bt_row[None])
    vv = gather_pages(v_pool, bt_row[None])
    out = _chunk_masked_attention(q, kk, vv, pos[None, :])
    out = nn.dense(p["wo"], out.reshape(B, C, -1))
    return out, {"k": k_pool, "v": v_pool}


# --------------------------------------------------------------------------- #
# Cross-attention (VLM image layers, whisper decoder)
# --------------------------------------------------------------------------- #
def cross_attn_init(key, cfg, dtype):
    d = cfg.d_model
    H, hd = cfg.n_heads, cfg.head_dim
    ks = nn.split_key_tree(key, ["wq", "wk", "wv", "wo"])
    return {
        "wq": nn.dense_init(ks["wq"], d, H * hd, dtype),
        "wk": nn.dense_init(ks["wk"], d, H * hd, dtype),
        "wv": nn.dense_init(ks["wv"], d, H * hd, dtype),
        "wo": nn.dense_init(ks["wo"], H * hd, d, dtype, scale=(H * hd) ** -0.5),
    }


def cross_attn_kv(p, ctx, cfg):
    """Precompute cross K/V from the (stub-frontend) context embeddings."""
    B, T, _ = ctx.shape
    H, hd = cfg.n_heads, cfg.head_dim
    k = nn.dense(p["wk"], ctx).reshape(B, T, H, hd)
    v = nn.dense(p["wv"], ctx).reshape(B, T, H, hd)
    return k, v


def cross_attn(p, x, kv, cfg):
    B, S, _ = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    k, v = kv
    q = nn.dense(p["wq"], x).reshape(B, S, H, hd)
    out = chunked_attention(q, k, v, causal=False)
    return nn.dense(p["wo"], out.reshape(B, S, -1))


# --------------------------------------------------------------------------- #
# MLA — multi-head latent attention (deepseek-v2)
# --------------------------------------------------------------------------- #
def mla_init(key, cfg, dtype):
    d = cfg.d_model
    H = cfg.n_heads
    nope, rope_d, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    lq, lkv = cfg.q_lora_rank, cfg.kv_lora_rank
    ks = nn.split_key_tree(key, ["wq_a", "wq_b", "wkv_a", "wkv_b", "wo"])
    return {
        "wq_a": nn.dense_init(ks["wq_a"], d, lq, dtype),
        "q_norm": nn.rmsnorm_init(lq, dtype),
        "wq_b": nn.dense_init(ks["wq_b"], lq, H * (nope + rope_d), dtype),
        "wkv_a": nn.dense_init(ks["wkv_a"], d, lkv + rope_d, dtype),
        "kv_norm": nn.rmsnorm_init(lkv, dtype),
        "wkv_b": nn.dense_init(ks["wkv_b"], lkv, H * (nope + vd), dtype),
        "wo": nn.dense_init(ks["wo"], H * vd, d, dtype, scale=(H * vd) ** -0.5),
    }


def _mla_q(p, x, cfg, positions):
    B, S, _ = x.shape
    H, nope, rope_d = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    ql = nn.rmsnorm(p["q_norm"], nn.dense(p["wq_a"], x), cfg.norm_eps)
    q = nn.dense(p["wq_b"], ql).reshape(B, S, H, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = nn.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p, x, cfg, positions):
    """Returns (c_kv normed (B,S,lkv), k_rope (B,S,rope_d) rope-applied)."""
    lkv, rope_d = cfg.kv_lora_rank, cfg.qk_rope_dim
    kv_a = nn.dense(p["wkv_a"], x)
    c_kv = nn.rmsnorm(p["kv_norm"], kv_a[..., :lkv], cfg.norm_eps)
    k_rope = kv_a[..., lkv:]
    k_rope = nn.apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_forward(p, x, cfg, *, positions=None, return_cache=False):
    """Prefill/train MLA: materialize per-head K/V from the latent."""
    B, S, _ = x.shape
    H, nope, rope_d, vd = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    lkv = cfg.kv_lora_rank
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    c_kv, k_rope = _mla_latent(p, x, cfg, positions)
    kv = nn.dense(p["wkv_b"], c_kv).reshape(B, S, H, nope + vd)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, rope_d))], axis=-1
    )
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = chunked_attention(q, k, v, causal=True)
    out = nn.dense(p["wo"], out.reshape(B, S, -1))
    if not return_cache:
        return out
    return out, (c_kv, k_rope)


def mla_init_cache(cfg, batch: int, max_len: int, dtype):
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
    }


def _mla_absorbed_weights(p, cfg):
    """(w_uk (lkv,H,nope), w_uv (lkv,H,vd)) for the absorbed decode path."""
    H, nope, vd = cfg.n_heads, cfg.qk_nope_dim, cfg.v_head_dim
    lkv = cfg.kv_lora_rank
    w_kv = p["wkv_b"] if not isinstance(p["wkv_b"], dict) else None
    if w_kv is None:
        # factored (RSI-compressed) wkv_b: densify the small latent matrix —
        # lkv x H(nope+vd) is modest; the absorbed path needs the split views.
        from repro.core.lowrank import materialize

        w_kv = materialize(p["wkv_b"])
    w_kv = w_kv.reshape(lkv, H, nope + vd)
    return w_kv[..., :nope], w_kv[..., nope:]


def _mla_scores_and_context(p, cfg, q_nope, q_rope, c_cache, r_cache, valid):
    """Absorbed-weight latent attention shared by the flat decode, the paged
    decode and the paged chunk prefill.  q_nope/q_rope: (B, C, H, *) queries
    (C == 1 for decode); caches: (B, S, *) latent views; valid: (B, C, S)
    bool.  Returns (B, C, H * vd) context, pre-``wo``."""
    B, C, H, _ = q_nope.shape
    nope, rope_d, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    w_uk, w_uv = _mla_absorbed_weights(p, cfg)
    # Absorb: q_lat[b,c,h,l] = sum_n q_nope[b,c,h,n] * w_uk[l,h,n].
    # Caches stay in their storage dtype (fp32 accumulation via
    # preferred_element_type) — an astype would copy the whole latent cache.
    q_lat = jnp.einsum(
        "bchn,lhn->bchl", q_nope, w_uk, preferred_element_type=jnp.float32
    ).astype(c_cache.dtype)
    scale = (nope + rope_d) ** -0.5
    s = (
        jnp.einsum("bchl,bsl->bchs", q_lat, c_cache, preferred_element_type=jnp.float32)
        + jnp.einsum(
            "bchr,bsr->bchs", q_rope, r_cache, preferred_element_type=jnp.float32
        )
    ) * scale
    s = jnp.where(valid[:, :, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    ctx_lat = jnp.einsum(
        "bchs,bsl->bchl", w.astype(c_cache.dtype), c_cache,
        preferred_element_type=jnp.float32,
    ).astype(c_cache.dtype)
    out = jnp.einsum("bchl,lhv->bchv", ctx_lat, w_uv, preferred_element_type=jnp.float32)
    return out.reshape(B, C, H * vd)


def mla_decode(p, x, cache, pos, cfg):
    """Absorbed-weight MLA decode: attention entirely in latent space.
    ``pos``: scalar or (B,) per-slot positions (continuous batching)."""
    B = x.shape[0]
    pos_v = position_vector(pos, B)
    positions = pos_v[:, None]
    q_nope, q_rope = _mla_q(p, x, cfg, positions)  # (B,1,H,nope),(B,1,H,rope)
    c_new, kr_new = _mla_latent(p, x, cfg, positions)  # (B,1,lkv),(B,1,rope)
    b_idx = jnp.arange(B)
    c_cache = cache["c_kv"].at[b_idx, pos_v].set(c_new[:, 0])
    r_cache = cache["k_rope"].at[b_idx, pos_v].set(kr_new[:, 0])
    valid = jnp.arange(c_cache.shape[1])[None, :] <= pos_v[:, None]
    out = _mla_scores_and_context(
        p, cfg, q_nope, q_rope, c_cache, r_cache, valid[:, None]
    ).astype(x.dtype)
    out = nn.dense(p["wo"], out)
    return out, {"c_kv": c_cache, "k_rope": r_cache}


def mla_init_cache_paged(cfg, page_size: int, n_pages_phys: int, dtype):
    """Latent-space page pools (the MLA analogue of gqa_init_cache_paged)."""
    return {
        "c_kv": jnp.zeros((n_pages_phys, page_size, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((n_pages_phys, page_size, cfg.qk_rope_dim), dtype),
    }, True


def mla_decode_paged(p, x, cache, pos, cfg, block_table):
    """Paged-cache MLA decode: latent writes go through the block table and
    scoring runs over the gathered logical view (XLA gather-einsum — a
    Pallas latent-space kernel is a ROADMAP open item, same as the flat
    MLA decode path)."""
    from repro.kernels.ref import gather_pages

    B = x.shape[0]
    pos_v = position_vector(pos, B)
    positions = pos_v[:, None]
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    c_new, kr_new = _mla_latent(p, x, cfg, positions)
    c_pool = _paged_write(cache["c_kv"], block_table, pos_v, c_new[:, 0])
    r_pool = _paged_write(cache["k_rope"], block_table, pos_v, kr_new[:, 0])
    c_cache = gather_pages(c_pool, block_table)  # (B, S_log, lkv)
    r_cache = gather_pages(r_pool, block_table)
    valid = jnp.arange(c_cache.shape[1])[None, :] <= pos_v[:, None]
    out = _mla_scores_and_context(
        p, cfg, q_nope, q_rope, c_cache, r_cache, valid[:, None]
    ).astype(x.dtype)
    out = nn.dense(p["wo"], out)
    return out, {"c_kv": c_pool, "k_rope": r_pool}


def mla_prefill_chunk(p, x, cache, cfg, bt_row, start, n_real):
    """One page-backed MLA prefill chunk for a single slot (B == 1); see
    :func:`gqa_prefill_chunk` for the write-then-attend contract."""
    from repro.kernels.ref import gather_pages

    B, C, _ = x.shape
    pos = start + jnp.arange(C, dtype=jnp.int32)
    q_nope, q_rope = _mla_q(p, x, cfg, pos[None, :])  # (1,C,H,*)
    c_new, kr_new = _mla_latent(p, x, cfg, pos[None, :])  # (1,C,*)
    live = jnp.arange(C) < n_real
    c_pool = _paged_write(cache["c_kv"], bt_row, pos, c_new[0], live=live)
    r_pool = _paged_write(cache["k_rope"], bt_row, pos, kr_new[0], live=live)
    c_cache = gather_pages(c_pool, bt_row[None])
    r_cache = gather_pages(r_pool, bt_row[None])
    valid = jnp.arange(c_cache.shape[1])[None, None, :] <= pos[None, :, None]
    out = _mla_scores_and_context(
        p, cfg, q_nope, q_rope, c_cache, r_cache, valid
    ).astype(x.dtype)
    out = nn.dense(p["wo"], out)
    return out, {"c_kv": c_pool, "k_rope": r_pool}
