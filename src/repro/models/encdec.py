"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

The conv mel-spectrogram frontend is OUT of scope per the assignment: the
model consumes precomputed frame embeddings (B, n_frames, d_model) from
``input_specs()``.  Positions are sinusoidal (whisper uses sinusoidal on the
encoder, learned on the decoder; we use sinusoidal on both — noted deviation,
irrelevant to systems behaviour).  Pre-LayerNorm blocks with GELU FFN,
faithful to the architecture family.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import modules as nn
from repro.models import attention as attn
from repro.sharding.rules import maybe_constrain

__all__ = [
    "encdec_init",
    "encdec_forward",
    "encdec_encode",
    "encdec_init_cache",
    "encdec_init_cache_paged",
    "encdec_prefill",
    "encdec_decode_step",
]


def _ffn_init(key, d, f, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "w_up": nn.dense_init(k1, d, f, dtype),
        "w_down": nn.dense_init(k2, f, d, dtype, scale=f**-0.5),
    }


def _ffn(p, x):
    h = nn.dense(p["w_up"], x)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    h = maybe_constrain(h, ("batch", None, "tp"))
    return nn.dense(p["w_down"], h)


def _enc_layer_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": nn.layernorm_init(cfg.d_model, dtype),
        "attn": attn.gqa_init(k1, cfg, dtype),
        "mlp_norm": nn.layernorm_init(cfg.d_model, dtype),
        "mlp": _ffn_init(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _dec_layer_init(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "attn_norm": nn.layernorm_init(cfg.d_model, dtype),
        "attn": attn.gqa_init(k1, cfg, dtype),
        "cross_norm": nn.layernorm_init(cfg.d_model, dtype),
        "cross": attn.cross_attn_init(k2, cfg, dtype),
        "mlp_norm": nn.layernorm_init(cfg.d_model, dtype),
        "mlp": _ffn_init(k3, cfg.d_model, cfg.d_ff, dtype),
    }


def encdec_init(key, cfg):
    dtype = jnp.dtype(cfg.dtype)
    ks = nn.split_key_tree(key, ["embed", "enc", "dec", "head"])
    enc_keys = jax.random.split(ks["enc"], cfg.n_encoder_layers)
    dec_keys = jax.random.split(ks["dec"], cfg.n_layers)
    return {
        "embed": nn.embed_init(ks["embed"], cfg.vocab_padded, cfg.d_model, dtype),
        "enc_layers": jax.vmap(lambda k: _enc_layer_init(k, cfg, dtype))(enc_keys),
        "enc_norm": nn.layernorm_init(cfg.d_model, dtype),
        "dec_layers": jax.vmap(lambda k: _dec_layer_init(k, cfg, dtype))(dec_keys),
        "dec_norm": nn.layernorm_init(cfg.d_model, dtype),
        "lm_head": nn.dense_init(ks["head"], cfg.d_model, cfg.vocab_padded, dtype),
    }


def _scan(body, stack, x, remat):
    fn = jax.checkpoint(body, prevent_cse=False) if remat else body

    def step(c, lp):
        return fn(lp, c), None

    x, _ = jax.lax.scan(step, x, stack)
    return x


def encdec_encode(p, frames, cfg):
    """frames: (B, T, d_model) stub embeddings -> encoder states."""
    remat = cfg.remat == "block"
    B, T, d = frames.shape
    x = frames.astype(jnp.dtype(cfg.dtype)) + nn.sinusoidal_positions(T, d).astype(
        jnp.dtype(cfg.dtype)
    )

    def body(lp, h):
        hh = nn.layernorm(lp["attn_norm"], h, cfg.norm_eps)
        h = h + attn.gqa_forward(lp["attn"], hh, cfg, causal=False, rope=False)
        hh = nn.layernorm(lp["mlp_norm"], h, cfg.norm_eps)
        return h + _ffn(lp["mlp"], hh)

    x = _scan(body, p["enc_layers"], x, remat)
    return nn.layernorm(p["enc_norm"], x, cfg.norm_eps)


def encdec_forward_features(p, batch, cfg):
    """Teacher-forced trunk.  batch: frames (B,T,d), tokens (B,S)."""
    enc_out = encdec_encode(p, batch["frames"], cfg)
    remat = cfg.remat == "block"
    dtype = jnp.dtype(cfg.dtype)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = nn.embed_lookup(p["embed"], tokens) + nn.sinusoidal_positions(S, cfg.d_model)[
        None
    ].astype(dtype)

    def body(lp, h):
        hh = nn.layernorm(lp["attn_norm"], h, cfg.norm_eps)
        h = h + attn.gqa_forward(lp["attn"], hh, cfg, causal=True, rope=False)
        hh = nn.layernorm(lp["cross_norm"], h, cfg.norm_eps)
        kv = attn.cross_attn_kv(lp["cross"], enc_out, cfg)
        h = h + attn.cross_attn(lp["cross"], hh, kv, cfg)
        hh = nn.layernorm(lp["mlp_norm"], h, cfg.norm_eps)
        return h + _ffn(lp["mlp"], hh)

    x = _scan(body, p["dec_layers"], x, remat)
    return nn.layernorm(p["dec_norm"], x, cfg.norm_eps), 0.0


def encdec_head_apply(p, x, cfg):
    logits = nn.dense(p["lm_head"], x).astype(jnp.float32)
    spec = ("batch",) + (None,) * (x.ndim - 2) + ("tp_vocab",)
    return maybe_constrain(logits, spec)


def encdec_forward(p, batch, cfg):
    x, aux = encdec_forward_features(p, batch, cfg)
    return encdec_head_apply(p, x, cfg), aux


def encdec_init_cache(cfg, batch_size: int, max_len: int):
    dtype = jnp.dtype(cfg.dtype)
    L = cfg.n_layers
    H, hd, T = cfg.n_heads, cfg.head_dim, cfg.n_audio_frames
    self_c = attn.gqa_init_cache(cfg, batch_size, max_len, dtype)
    return {
        "self": jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (L,) + a.shape).copy(), self_c
        ),
        "cross_kv": {
            "k": jnp.zeros((L, batch_size, T, H, hd), dtype),
            "v": jnp.zeros((L, batch_size, T, H, hd), dtype),
        },
    }


def encdec_init_cache_paged(cfg, batch_size: int, max_len: int, *, page_size: int, n_pages: int):
    """Paged decoder cache: self-KV page pools + block table; the encoder
    cross-KV (fixed ``n_audio_frames`` per slot) stays slot-resident.
    Returns ``(cache, paged_mask)`` — see lm.lm_init_cache_paged."""
    dtype = jnp.dtype(cfg.dtype)
    L = cfg.n_layers
    H, hd, T = cfg.n_heads, cfg.head_dim, cfg.n_audio_frames
    max_pages = -(-max_len // page_size)
    self_c, paged = attn.gqa_init_cache_paged(cfg, page_size, n_pages + 1, dtype)
    assert paged, "whisper decoder self-attention has no sliding window"
    self_stack = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (L,) + a.shape).copy(), self_c
    )
    cross = {
        "k": jnp.zeros((L, batch_size, T, H, hd), dtype),
        "v": jnp.zeros((L, batch_size, T, H, hd), dtype),
    }
    cache = {
        "self": self_stack,
        "cross_kv": cross,
        "block_table": jnp.full((batch_size, max_pages), n_pages, jnp.int32),
    }
    mask = {
        "self": jax.tree_util.tree_map(lambda _: True, self_stack),
        "cross_kv": jax.tree_util.tree_map(lambda _: False, cross),
    }
    return cache, mask


def encdec_prefill(p, batch, cfg, max_len: int, *, last_index=None):
    """Encode frames + run the decoder prompt, building both caches.

    ``last_index``: optional (B,) per-sequence index of the last valid
    prompt token (right-padded ragged micro-batches; see lm_prefill).
    """
    enc_out = encdec_encode(p, batch["frames"], cfg)
    dtype = jnp.dtype(cfg.dtype)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = nn.embed_lookup(p["embed"], tokens) + nn.sinusoidal_positions(S, cfg.d_model)[
        None
    ].astype(dtype)
    remat = cfg.remat == "block"

    def body(lp, h):
        hh = nn.layernorm(lp["attn_norm"], h, cfg.norm_eps)
        a, kv = attn.gqa_forward(
            lp["attn"], hh, cfg, causal=True, rope=False, return_cache=True
        )
        h = h + a
        hh = nn.layernorm(lp["cross_norm"], h, cfg.norm_eps)
        ckv = attn.cross_attn_kv(lp["cross"], enc_out, cfg)
        h = h + attn.cross_attn(lp["cross"], hh, ckv, cfg)
        hh = nn.layernorm(lp["mlp_norm"], h, cfg.norm_eps)
        h = h + _ffn(lp["mlp"], hh)
        k, v = kv
        pad = [(0, 0), (0, max_len - S), (0, 0), (0, 0)]
        return h, {
            "self": {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)},
            "cross_kv": {"k": ckv[0], "v": ckv[1]},
        }

    fn = jax.checkpoint(body, prevent_cse=False) if remat else body

    def step(c, lp):
        h, cc = fn(lp, c)
        return h, cc

    x, cache = jax.lax.scan(step, x, p["dec_layers"])
    x = nn.layernorm(p["dec_norm"], x, cfg.norm_eps)
    if last_index is None:
        last = x[:, -1:]
    else:
        last = x[jnp.arange(B), jnp.asarray(last_index, jnp.int32)][:, None]
    logits = nn.dense(p["lm_head"], last).astype(jnp.float32)[:, 0]
    return logits, cache


def encdec_decode_step(p, cache, tokens, pos, cfg):
    """``pos``: scalar or (B,) per-slot positions (continuous batching).
    A ``block_table`` leaf in the cache (encdec_init_cache_paged) routes
    self-attention through the paged decode path."""
    dtype = jnp.dtype(cfg.dtype)
    B = tokens.shape[0]
    pos_v = attn.position_vector(pos, B)
    bt = cache.get("block_table")
    if bt is None:
        pe_len = cache["self"]["k"].shape[2]
    else:
        pe_len = bt.shape[1] * cache["self"]["k"].shape[2]  # pages * page_size
    pe = nn.sinusoidal_positions(pe_len, cfg.d_model)
    x = nn.embed_lookup(p["embed"], tokens) + pe[pos_v][:, None].astype(dtype)

    def step(carry, inp):
        lp, c = inp
        h = carry
        hh = nn.layernorm(lp["attn_norm"], h, cfg.norm_eps)
        if bt is None:
            a, c_self = attn.gqa_decode(lp["attn"], hh, c["self"], pos_v, cfg)
        else:
            a, c_self = attn.gqa_decode_paged(lp["attn"], hh, c["self"], pos_v, cfg, bt)
        h = h + a
        hh = nn.layernorm(lp["cross_norm"], h, cfg.norm_eps)
        kv = (c["cross_kv"]["k"], c["cross_kv"]["v"])
        h = h + attn.cross_attn(lp["cross"], hh, kv, cfg)
        hh = nn.layernorm(lp["mlp_norm"], h, cfg.norm_eps)
        h = h + _ffn(lp["mlp"], hh)
        return h, {"self": c_self, "cross_kv": c["cross_kv"]}

    layer_cache = {"self": cache["self"], "cross_kv": cache["cross_kv"]}
    x, new_layers = jax.lax.scan(step, x, (p["dec_layers"], layer_cache))
    x = nn.layernorm(p["dec_norm"], x, cfg.norm_eps)
    logits = nn.dense(p["lm_head"], x).astype(jnp.float32)[:, 0]
    new_cache = dict(new_layers)
    if bt is not None:
        new_cache["block_table"] = bt
    return logits, new_cache
