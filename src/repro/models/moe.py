"""Mixture-of-Experts with sort-based capacity dispatch + expert parallelism.

Scalable design (no GShard dense-dispatch einsum, which is O(T·E·Cap·d) and
collapses at 160 experts): tokens are routed with an argsort over expert ids,
scattered into a static (E, capacity, d) buffer (overflow tokens drop — the
standard capacity-factor contract), processed with one batched per-expert
GEMM (exactly the active FLOPs), and gathered back with their top-k gate
weights.  The expert buffer is sharded over the ``ep`` (model) mesh axis, so
the scatter/gather pair is where XLA materializes the MoE all-to-alls.

Includes the standard load-balance auxiliary loss and deepseek-style shared
experts (always-on dense FFN).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import modules as nn
from repro.sharding.rules import maybe_constrain

__all__ = ["moe_init", "moe_forward", "ffn_init", "ffn_forward", "moe_capacity"]


def ffn_init(key, d: int, f: int, dtype):
    ks = nn.split_key_tree(key, ["w_gate", "w_up", "w_down"])
    return {
        "w_gate": nn.dense_init(ks["w_gate"], d, f, dtype),
        "w_up": nn.dense_init(ks["w_up"], d, f, dtype),
        "w_down": nn.dense_init(ks["w_down"], f, d, dtype, scale=f**-0.5),
    }


def ffn_forward(p, x):
    g = nn.dense(p["w_gate"], x)
    u = nn.dense(p["w_up"], x)
    h = nn.swiglu(g, u)
    h = maybe_constrain(h, ("batch", None, "tp"))
    return nn.dense(p["w_down"], h)


def moe_init(key, cfg, dtype):
    E, d, f = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    ks = nn.split_key_tree(key, ["router", "w_gate", "w_up", "w_down", "shared"])
    p = {
        "router": {"gate_w": nn.dense_init(ks["router"], d, E, dtype, scale=d**-0.5)},
        "experts": {
            "w_gate": _expert_init(ks["w_gate"], E, d, f, dtype),
            "w_up": _expert_init(ks["w_up"], E, d, f, dtype),
            "w_down": _expert_init(ks["w_down"], E, f, d, dtype),
        },
    }
    if cfg.n_shared_experts:
        p["shared"] = ffn_init(ks["shared"], d, cfg.n_shared_experts * f, dtype)
    return p


def _expert_init(key, E, d_in, d_out, dtype):
    return (
        jax.random.normal(key, (E, d_in, d_out), dtype=jnp.float32) * d_in**-0.5
    ).astype(dtype)


def moe_capacity(tokens: int, cfg) -> int:
    cap = int(tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(((cap + 127) // 128) * 128, 128)  # lane-align


def _route(xf, gate_w, cfg):
    """fp32 routing: probs, normalized top-k gates, aux load-balance loss."""
    E, K = cfg.n_experts, cfg.top_k
    logits = jnp.matmul(
        xf, gate_w.astype(jnp.float32), preferred_element_type=jnp.float32
    )
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gate_vals, gate_ids = jax.lax.top_k(probs, K)  # (T, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(gate_ids, E, dtype=jnp.float32), axis=1), axis=0
    )
    aux = E * jnp.sum(me * ce)
    return gate_ids, gate_vals, aux


def _dispatch_compute_combine(xf, ids, gates, experts, C, E, dtype):
    """Capacity dispatch -> batched expert GEMMs -> weighted combine.

    Memory discipline: no (T*K, d) tensor is ever built.  Routing metadata
    stays 1-D int/float (cheap); activations exist only at capacity size:
    a slot->token index map gathers straight into the (E*C, d) buffer, and
    the combine scatter-adds (E*C, d) expert outputs back into (T, d).
    Works on LOCAL (per-shard) experts: ids must already be local ([0, E))
    with out-of-shard tokens set to E (the drop sentinel)."""
    T, d = xf.shape
    K = ids.shape[-1]
    ids_flat = ids.reshape(-1)  # (T*K,)
    order = jnp.argsort(ids_flat)
    sorted_ids = ids_flat[order]
    seg_start = jnp.searchsorted(sorted_ids, jnp.arange(E), side="left")
    pos_sorted = jnp.arange(T * K) - seg_start[jnp.minimum(sorted_ids, E - 1)]
    pos_flat = jnp.zeros((T * K,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))

    keep = (pos_flat < C) & (ids_flat < E)
    slot = jnp.where(keep, ids_flat * C + pos_flat, E * C)  # E*C == drop
    tok_idx = (jnp.arange(T * K) // K).astype(jnp.int32)

    # slot -> (token, gate, occupied); all 1-D, scatter mode="drop"
    slot_tok = jnp.zeros((E * C,), jnp.int32).at[slot].set(tok_idx, mode="drop")
    slot_gate = (
        jnp.zeros((E * C,), jnp.float32)
        .at[slot]
        .set(gates.reshape(-1).astype(jnp.float32), mode="drop")
    )
    occupied = jnp.zeros((E * C,), jnp.float32).at[slot].set(
        keep.astype(jnp.float32), mode="drop"
    )

    buf = (xf[slot_tok].astype(jnp.float32) * occupied[:, None]).astype(dtype)
    buf = buf.reshape(E, C, d)

    def emm(t, w):  # (E,C,a) @ (E,a,b)
        if isinstance(w, dict):  # RSI-compressed expert kernels: the stacked
            # (E, ...) factors route through the dispatcher, which can launch
            # ONE batched fused kernel over the expert axis instead of E
            # two-GEMM round-trips.
            from repro.core.lowrank import apply_linear

            return apply_linear(w, t)
        return jnp.einsum("eca,eab->ecb", t, w, preferred_element_type=jnp.float32).astype(
            dtype
        )

    h = nn.swiglu(emm(buf, experts["w_gate"]), emm(buf, experts["w_up"]))
    y = emm(h, experts["w_down"]).reshape(E * C, d)

    weighted = y.astype(jnp.float32) * (slot_gate * occupied)[:, None]  # (E*C, d)
    out = jnp.zeros((T, d), jnp.float32).at[slot_tok].add(weighted, mode="drop")
    return out  # fp32 (T, d)


def _moe_local(p, x, cfg):
    """Single-device / no-mesh path (tests, small runs)."""
    B, S, d = x.shape
    T = B * S
    xf = x.reshape(T, d)
    ids, gates, aux = _route(xf, p["router"]["gate_w"], cfg)
    C = moe_capacity(T, cfg)
    out = _dispatch_compute_combine(
        xf, ids, gates, p["experts"], C, cfg.n_experts, x.dtype
    ).astype(x.dtype)
    return out.reshape(B, S, d), aux


def _moe_expert_parallel(p, x, cfg, rules):
    """Expert-parallel MoE via shard_map (the production path).

    Layout: tokens sharded over the batch axes and REPLICATED over "model";
    experts sharded over "model" (E/m per shard).  Each (data, model) device
    selects the subset of ITS tokens routed to ITS expert shard, dispatches
    locally (no all-to-all!), runs the expert GEMMs, and scatters results
    back to token positions; a single psum over "model" sums each token's
    top-k expert outputs.  Communication per layer = one fp32 (T_local, d)
    all-reduce — the same volume as a standard TP activation reduce, and
    independent of E.  Routing is computed redundantly per model shard
    (d x E GEMM — negligible) to avoid broadcasting gate decisions.
    """
    mesh = rules.mesh
    m_size = mesh.shape["model"]
    E_loc = cfg.n_experts // m_size
    B, S, d = x.shape
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = 1
    for a in batch_axes:
        dp *= mesh.shape[a]
    T_loc = (B // dp if B % dp == 0 else B) * S
    # local per-expert capacity: tokens of ONE data shard to ONE expert;
    # higher slack than the global rule because local loads vary more.
    C = int(T_loc * cfg.top_k / cfg.n_experts * cfg.capacity_factor * 1.6)
    C = max(((C + 127) // 128) * 128, 128)

    from jax.sharding import PartitionSpec as P

    x_spec = P(batch_axes if B % dp == 0 else None, None, None)
    e_spec = jax.tree_util.tree_map(lambda _: P("model"), p["experts"])

    def block(gate_w, experts_loc, x_blk):
        Bl, Sl, _ = x_blk.shape
        xf = x_blk.reshape(Bl * Sl, d)
        ids, gates, aux = _route(xf, gate_w, cfg)
        for a in batch_axes:
            aux = jax.lax.pmean(aux, a)
        j = jax.lax.axis_index("model")
        lo = j * E_loc
        local = jnp.where(
            (ids >= lo) & (ids < lo + E_loc), ids - lo, E_loc
        )  # E_loc == drop sentinel
        out = _dispatch_compute_combine(
            xf, local, gates, experts_loc, C, E_loc, x_blk.dtype
        )
        out = jax.lax.psum(out, "model")
        return out.astype(x_blk.dtype).reshape(Bl, Sl, d), aux

    from repro.runtime.compat import shard_map

    out, aux = shard_map(
        block,
        mesh=mesh,
        in_specs=(P(), e_spec, x_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(p["router"]["gate_w"], p["experts"], x)
    return out, aux


def moe_forward(p, x, cfg):
    """x: (B, S, d).  Returns (out, aux_loss)."""
    from repro.sharding.rules import active_rules

    rules = active_rules()
    if (
        rules is not None
        and "model" in rules.mesh.shape
        and cfg.n_experts % rules.mesh.shape["model"] == 0
    ):
        out, aux = _moe_expert_parallel(p, x, cfg, rules)
    else:
        out, aux = _moe_local(p, x, cfg)

    if "shared" in p:
        out = out + ffn_forward(p["shared"], x)
    return out, aux
