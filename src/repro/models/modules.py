"""Shared pure-JAX model building blocks (no flax).

Params are nested dicts of arrays.  Every linear kernel goes through
``repro.core.lowrank.apply_linear`` so RSI-compressed (factored) checkpoints
are drop-in replacements.  All matmuls request fp32 accumulation
(``preferred_element_type``) — bf16 storage, fp32 MXU accumulate, the TPU
norm.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.lowrank import apply_linear

__all__ = [
    "dense_init",
    "dense",
    "rmsnorm_init",
    "rmsnorm",
    "layernorm_init",
    "layernorm",
    "embed_init",
    "embed_lookup",
    "rope_freqs",
    "apply_rope",
    "sinusoidal_positions",
    "swiglu",
    "split_key_tree",
]


def dense_init(key, d_in: int, d_out: int, dtype, *, scale: float | None = None):
    scale = (d_in**-0.5) if scale is None else scale
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale).astype(dtype)


def dense(p, x):
    """x @ W with dense or factored kernels; backend selection is owned by
    repro.runtime.dispatch (see core/lowrank.apply_linear)."""
    return apply_linear(p, x)


def rmsnorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(p, x, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype=dtype), "bias": jnp.zeros((d,), dtype=dtype)}


def layernorm(p, x, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(x.dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), dtype=jnp.float32) * (d**-0.5)).astype(dtype)


def embed_lookup(table, ids):
    return jnp.take(table, ids, axis=0)


def rope_freqs(head_dim: int, theta: float):
    """Inverse frequencies for rotary embeddings (half of head_dim pairs)."""
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x, positions, theta: float):
    """Rotary position embedding.  x: (..., seq, heads, head_dim); positions
    (..., seq) int32."""
    head_dim = x.shape[-1]
    inv = rope_freqs(head_dim, theta)  # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv[None, :]  # (..., S, hd/2)
    sin = jnp.sin(ang)[..., :, None, :]  # broadcast over heads
    cos = jnp.cos(ang)[..., :, None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = jnp.split(x32, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int):
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def swiglu(gate, up):
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


def split_key_tree(key, names):
    ks = jax.random.split(key, len(names))
    return dict(zip(names, ks))
