"""Model dispatch: one API over all ten architectures.

``build_model(cfg)`` returns a :class:`ModelApi` of pure functions; the
launcher, trainer, server, dry-run, compression CLI and tests all go through
this interface, so RSI-compressed parameter trees work everywhere.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import lm as lm_mod
from repro.models import encdec as ed_mod

__all__ = ["ModelApi", "build_model", "analytic_param_count", "batch_spec_template"]


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ArchConfig
    init: Callable[[jax.Array], Any]
    forward: Callable[[Any, dict], tuple]  # (params, batch) -> (logits, aux)
    init_cache: Callable[[int, int], Any]  # (batch, max_len) -> cache
    # (params, batch, max_len, *, last_index=None) — last_index: per-seq
    # index of the last valid prompt token for right-padded micro-batches
    prefill: Callable[..., tuple]
    # (params, cache, tokens, pos) — pos: scalar or (B,) per-slot vector.
    # Caches built by init_cache_paged (block_table leaf) route per-token
    # attention through the paged decode path automatically.
    decode_step: Callable[[Any, Any, jax.Array, jax.Array], tuple]
    # chunked-loss training path: trunk features + per-chunk head apply
    forward_features: Any = None  # (params, batch) -> (feats (B,S,d), aux)
    head_apply: Any = None  # (params, x) -> logits fp32
    # (batch, max_len, page_size, n_pages) -> (paged cache, paged_mask):
    # physical page pools + block table for the paged serving engine
    init_cache_paged: Any = None
    # (params, cache, tokens (1,C), bt_row, start, n_real) -> (logits,
    # cache): one page-aligned prefill chunk writing through the slot's
    # block-table row; None for families whose prefill carries cross-chunk
    # recurrent/window state (ssm, hybrid, swa, vlm, audio) — the engine
    # falls back to monolithic prefill there
    prefill_chunk: Any = None


def build_model(cfg: ArchConfig) -> ModelApi:
    if cfg.family == "audio":
        return ModelApi(
            cfg=cfg,
            init=lambda key: ed_mod.encdec_init(key, cfg),
            forward=lambda p, b: ed_mod.encdec_forward(p, b, cfg),
            init_cache=lambda bs, ml: ed_mod.encdec_init_cache(cfg, bs, ml),
            prefill=lambda p, b, ml, **kw: ed_mod.encdec_prefill(p, b, cfg, ml, **kw),
            decode_step=lambda p, c, t, pos: ed_mod.encdec_decode_step(p, c, t, pos, cfg),
            forward_features=lambda p, b: ed_mod.encdec_forward_features(p, b, cfg),
            head_apply=lambda p, x: ed_mod.encdec_head_apply(p, x, cfg),
            init_cache_paged=lambda bs, ml, ps, npg: ed_mod.encdec_init_cache_paged(
                cfg, bs, ml, page_size=ps, n_pages=npg
            ),
        )
    chunkable = cfg.family in ("dense", "moe") and cfg.sliding_window is None
    return ModelApi(
        cfg=cfg,
        init=lambda key: lm_mod.lm_init(key, cfg),
        forward=lambda p, b: lm_mod.lm_forward(p, b, cfg),
        init_cache=lambda bs, ml: lm_mod.lm_init_cache(cfg, bs, ml),
        prefill=lambda p, b, ml, **kw: lm_mod.lm_prefill(p, b, cfg, ml, **kw),
        decode_step=lambda p, c, t, pos: lm_mod.lm_decode_step(p, c, t, pos, cfg),
        forward_features=lambda p, b: lm_mod.lm_forward_features(p, b, cfg),
        head_apply=lambda p, x: lm_mod.lm_head_apply(p, x, cfg),
        init_cache_paged=lambda bs, ml, ps, npg: lm_mod.lm_init_cache_paged(
            cfg, bs, ml, page_size=ps, n_pages=npg
        ),
        prefill_chunk=(
            (
                lambda p, c, t, bt_row, start, n_real: lm_mod.lm_prefill_chunk(
                    p, c, t, cfg, bt_row=bt_row, start=start, n_real=n_real
                )
            )
            if chunkable
            else None
        ),
    )


# --------------------------------------------------------------------------- #
# batch templates (shared by data pipeline + dry-run input_specs)
# --------------------------------------------------------------------------- #
def batch_spec_template(cfg: ArchConfig, batch: int, seq: int, *, kind: str) -> dict:
    """Shapes/dtypes of one batch, as (shape, dtype) tuples."""
    d = {}
    if cfg.family == "audio":
        d["frames"] = ((batch, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        d["image_embed"] = ((batch, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16)
    if kind == "decode":
        d["tokens"] = ((batch, 1), jnp.int32)
    else:
        d["tokens"] = ((batch, seq), jnp.int32)
        if kind == "train":
            d["targets"] = ((batch, seq), jnp.int32)
    return d


# --------------------------------------------------------------------------- #
# analytic parameter counts (MODEL_FLOPS = 6 * N * tokens)
# --------------------------------------------------------------------------- #
def analytic_param_count(cfg: ArchConfig, *, active_only: bool = False) -> int:
    d, V = cfg.d_model, cfg.vocab_padded
    n = 0
    # embeddings (+ head)
    n += V * d if cfg.tie_embeddings else 2 * V * d

    def attn_params():
        if cfg.kv_lora_rank:  # MLA
            lq, lkv = cfg.q_lora_rank, cfg.kv_lora_rank
            nope, rd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
            H = cfg.n_heads
            return (
                d * lq
                + lq * H * (nope + rd)
                + d * (lkv + rd)
                + lkv * H * (nope + vd)
                + H * vd * d
            )
        H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        return d * H * hd + 2 * d * KV * hd + H * hd * d

    def ffn_params(f):
        if cfg.family == "audio":
            return 2 * d * f
        return 3 * d * f

    def mamba_params():
        din, s, nh = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
        return 2 * d * din + 2 * d * s + d * nh + din * d

    fam = cfg.family
    if fam == "dense":
        n += cfg.n_layers * (attn_params() + ffn_params(cfg.d_ff))
    elif fam == "moe":
        n_moe = cfg.n_layers - cfg.first_dense_layers
        n += cfg.n_layers * attn_params()
        n += cfg.first_dense_layers * ffn_params(cfg.dense_d_ff or cfg.d_ff)
        experts = cfg.top_k if active_only else cfg.n_experts
        n += n_moe * (
            experts * 3 * d * cfg.moe_d_ff
            + cfg.n_shared_experts * 3 * d * cfg.moe_d_ff
            + d * cfg.n_experts
        )
    elif fam == "vlm":
        n_groups = cfg.n_layers // cfg.cross_attn_every
        n_self = n_groups * (cfg.cross_attn_every - 1)
        n += n_self * (attn_params() + ffn_params(cfg.d_ff))
        Hhd = cfg.n_heads * cfg.head_dim
        cross = 4 * d * Hhd
        n += n_groups * (cross + ffn_params(cfg.d_ff))
    elif fam == "hybrid":
        n += cfg.n_layers * mamba_params()
        n += attn_params() + ffn_params(cfg.d_ff)  # shared (counted once)
    elif fam == "ssm":
        n += cfg.n_layers * mamba_params()
    elif fam == "audio":
        n += cfg.n_encoder_layers * (attn_params() + ffn_params(cfg.d_ff))
        Hhd = cfg.n_heads * cfg.head_dim
        n += cfg.n_layers * (attn_params() + 4 * d * Hhd + ffn_params(cfg.d_ff))
    return n
