"""Continuous-batching serving subsystem.

Public surface:
  * :class:`Engine` / :class:`Request` — KV-pool engine (flat slots or a
    paged pool with block tables + chunked prefill via ``page_size=``)
  * :class:`SamplingParams` — greedy / temperature / top-k, explicit PRNG
  * :class:`SlotAllocator` / :class:`PageAllocator` / :class:`Scheduler` —
    admission control (slot- and page-gated)
"""

from repro.serving.engine import Engine, Request
from repro.serving.sampling import SamplingParams, sample_tokens
from repro.serving.scheduler import PageAllocator, Scheduler, SlotAllocator

__all__ = [
    "Engine",
    "Request",
    "SamplingParams",
    "sample_tokens",
    "Scheduler",
    "SlotAllocator",
    "PageAllocator",
]
