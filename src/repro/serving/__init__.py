"""Continuous-batching serving subsystem.

Public surface:
  * :class:`Engine` / :class:`Request` — KV-pool engine (flat slots or a
    paged pool with block tables + chunked prefill via ``page_size=``,
    plus refcounted copy-on-write prompt-prefix sharing via
    ``share_prefix=``)
  * :class:`SamplingParams` — greedy / temperature / top-k, explicit PRNG
  * :class:`SlotAllocator` / :class:`PageAllocator` / :class:`Scheduler` —
    admission control (slot- and page-gated, refcounted pages)
  * :class:`PrefixIndex` / :class:`PageGrant` — prompt-prefix page index
    and the reservation record shared-prefix admission hands the scheduler
  * :class:`Cluster` / :class:`EventLog` — N thread-backed engine replicas
    behind one shared queue: heartbeat failure detection, bit-exact
    failover with capped-backoff retry budgets, JSON-lines event log
  * :class:`RoutingPolicy` / :class:`FailoverBudget` — the cluster's
    least-loaded routing and per-request failover accounting
"""

from repro.serving.cluster import Cluster, EventLog
from repro.serving.engine import Engine, Request
from repro.serving.sampling import SamplingParams, sample_tokens
from repro.serving.scheduler import (
    FailoverBudget,
    PageAllocator,
    PageGrant,
    PrefixIndex,
    RoutingPolicy,
    Scheduler,
    SlotAllocator,
)

__all__ = [
    "Engine",
    "Request",
    "SamplingParams",
    "sample_tokens",
    "Scheduler",
    "SlotAllocator",
    "PageAllocator",
    "PageGrant",
    "PrefixIndex",
    "Cluster",
    "EventLog",
    "RoutingPolicy",
    "FailoverBudget",
]
