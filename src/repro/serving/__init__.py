"""Continuous-batching serving subsystem.

Public surface:
  * :class:`Engine` / :class:`Request` — slotted KV-cache pool engine
  * :class:`SamplingParams` — greedy / temperature / top-k, explicit PRNG
  * :class:`SlotAllocator` / :class:`Scheduler` — admission control
"""

from repro.serving.engine import Engine, Request
from repro.serving.sampling import SamplingParams, sample_tokens
from repro.serving.scheduler import Scheduler, SlotAllocator

__all__ = [
    "Engine",
    "Request",
    "SamplingParams",
    "sample_tokens",
    "Scheduler",
    "SlotAllocator",
]
