"""Continuous-batching serving subsystem.

Public surface:
  * :class:`Engine` / :class:`Request` — KV-pool engine (flat slots or a
    paged pool with block tables + chunked prefill via ``page_size=``,
    plus refcounted copy-on-write prompt-prefix sharing via
    ``share_prefix=``)
  * :class:`SamplingParams` — greedy / temperature / top-k, explicit PRNG
  * :class:`SlotAllocator` / :class:`PageAllocator` / :class:`Scheduler` —
    admission control (slot- and page-gated, refcounted pages)
  * :class:`PrefixIndex` / :class:`PageGrant` — prompt-prefix page index
    and the reservation record shared-prefix admission hands the scheduler
"""

from repro.serving.engine import Engine, Request
from repro.serving.sampling import SamplingParams, sample_tokens
from repro.serving.scheduler import (
    PageAllocator,
    PageGrant,
    PrefixIndex,
    Scheduler,
    SlotAllocator,
)

__all__ = [
    "Engine",
    "Request",
    "SamplingParams",
    "sample_tokens",
    "Scheduler",
    "SlotAllocator",
    "PageAllocator",
    "PageGrant",
    "PrefixIndex",
]
