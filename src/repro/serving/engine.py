"""Continuous-batching serving engine over a slotted or PAGED KV-cache pool.

The engine owns ONE batched decode cache of ``n_slots`` rows (the pool) and
runs an admit -> prefill -> fused-decode loop.  With ``page_size`` set, the
per-token cache leaves instead live in a shared pool of fixed-size PAGES
indexed through per-slot block tables: admission is gated on each request's
actual page need rather than an ``n_slots x max_len`` worst-case
reservation (so equal KV bytes admit strictly more concurrent requests),
decode attention goes through the block-table kernel path
("paged_decode_attention" in runtime/dispatch.py), and — with
``prefill_chunk`` — long prompts prefill in page-aligned chunks interleaved
with decode blocks, bounding the TTFT impact a long prefill has on running
requests.  The flat loop:

  * requests (prompt tokens, max_new_tokens, sampling params) enter a FIFO
    queue (:mod:`repro.serving.scheduler`) and are assigned cache slots as
    slots free up — slot exhaustion queues, it never crashes;
  * admitted requests are prefilled in right-padded micro-batches (causal
    masking keeps padded prefill exact for attention families; recurrent
    families group by exact length because SSM state integrates every input
    token) and their caches are scattered into the pool rows;
  * ALL active slots then share a DEVICE-RESIDENT fused decode block: a
    ``lax.scan`` runs ``decode_block`` tokens per host round-trip — decode
    step, per-slot sampling (:func:`sample_tokens`), stop-token/max-token
    detection, and position/token-buffer updates all on device.  The host
    syncs ONCE per block to drain the emitted (tokens, mask) stack, finish
    completed requests, and admit waiting ones;
  * finished sequences free their slot and the oldest waiting request is
    admitted at the next block boundary — the decode batch stays full under
    load.

Device-residency contract: the KV-cache pool and the per-slot token /
position / activity buffers are DONATED through the fused step (the jit
aliases them in place — no per-step cache copy is ever materialized), and
the cache never leaves the device.  Per-slot stop detection freezes a slot
the moment it emits its last token: a frozen slot keeps re-feeding its last
(token, position) pair, which makes its cache writes idempotent, while its
emit mask excludes everything after the stop from the drained results.

Kernel backend selection goes through the unified dispatch runtime (PR 1):
every prefill/fused-decode trace happens inside ``use_dispatch``, so
``--kernels`` applies per engine step exactly as it does to the static
path; on TPU the decode step's attention lowers to the Pallas flash-decode
kernel (kernels/decode_attention.py).

Greedy determinism contract: with temperature 0 the engine emits, per
request, bit-identical tokens to ``serve_step.greedy_generate`` run on that
prompt alone (tests/test_engine_parity.py) — the scheduler and the fused
block change WHEN a sequence advances, never WHAT it computes.

Prefix sharing (``share_prefix=True``, paged mode): admission matches a new
request's prompt against a host-side :class:`PrefixIndex` of full
prompt-prefix pages — live ones (still referenced by another slot) and
cached ones (released but not yet re-granted: refcount 0, contents intact
on the free list).  Matched pages are mapped into the slot's block table
as READ-ONLY shared entries (one allocator reference each, counted once in
``pages_in_use``); only the unshared tail is allocated and prefilled,
entering the model MID-PROMPT through the chunked-prefill program at the
first unshared position.  When the tail would re-enter a matched page (the
whole prompt is covered: the last prompt token must still run to produce
the sampling logits), that page is COW-FORKED — copied onto a private page
— so a writer never mutates shared storage.  Because both the block-table
Pallas kernel and the gather-einsum oracle index physical pages
indirectly, aliased page ids need zero kernel changes, and greedy outputs
stay bit-identical to the unshared paged run: sharing relocates bytes,
never changes what is attended.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bounds import CompressionCertificate, certify_tier
from repro.core.lowrank import is_lowrank, slice_rank
from repro.runtime.dispatch import DispatchConfig, use_dispatch
from repro.runtime.fault_tolerance import FaultInjector, StepWatchdog
from repro.serving.sampling import (
    SALT_MULT,
    SamplingParams,
    sample_tokens,
    token_salts,
)
from repro.serving.scheduler import (
    AdmissionPolicy,
    PageAllocator,
    PageGrant,
    PrefixIndex,
    RejectedOverload,
    Scheduler,
    SlotAllocator,
)

__all__ = [
    "Request",
    "Engine",
    "SamplingParams",
    "AdmissionPolicy",
    "RejectedOverload",
    "FaultInjector",
    "percentile",
]


def percentile(sorted_vals, frac: float):
    """Nearest-rank percentile of an ascending-sorted sequence.

    The ONE latency-percentile definition shared by the launcher and the
    serving benchmark, so their reported p50/p95 agree on identical data.
    """
    n = len(sorted_vals)
    if n == 0:
        raise ValueError("percentile of empty sequence")
    return sorted_vals[max(0, math.ceil(frac * n) - 1)]

# Families whose decode state integrates every prefill token (recurrent /
# convolutional state): right-padding would corrupt the carried state, so
# admission micro-batches group these by EXACT prompt length.
_EXACT_LEN_FAMILIES = ("ssm", "hybrid")

# eos sentinel for the fused stop check when no eos token is configured:
# sampled token ids are always >= 0, so -1 never matches.
_NO_EOS = -1


@dataclasses.dataclass
class Request:
    """One generation request plus its per-request results/latency record."""

    prompt: np.ndarray  # (S,) int32 prompt tokens
    max_new_tokens: int
    sampling: SamplingParams = SamplingParams()
    extras: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    # overload / QoS contract (all optional; defaults reproduce plain FIFO):
    deadline_ms: Optional[float] = None  # shed if not admitted within this
    min_tier: int = 0  # deepest rank tier the client accepts under pressure
    tier: int = 0  # tier actually served (admission may raise, never lower)
    priority: int = 0  # higher-priority waiters may preempt lower actives
    # filled in by the engine:
    uid: int = -1
    tokens: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0
    prefill_skipped: int = 0  # prompt tokens covered by shared prefix pages
    status: str = "ok"  # "ok" | "shed" | "error"
    rejected: Optional[RejectedOverload] = None  # set when status == "shed"
    error: Optional[str] = None  # set when status == "error"
    certificate: Optional[CompressionCertificate] = None  # served tier's bound
    # preemption internals: a resumed continuation points at the original
    # request, whose token stream it extends (never set by callers)
    _parent: Optional["Request"] = dataclasses.field(default=None, repr=False)

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size < 1:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.max_new_tokens

    @property
    def latency(self) -> Optional[float]:
        """Submit-to-completion seconds, or ``None`` before completion.

        The timestamps default to 0.0, so subtracting them blindly would
        yield a huge NEGATIVE number (−t_submit) for an in-flight request
        — garbage that sorts, averages and compares without error.  The
        ``None`` forces callers to handle incomplete requests explicitly.
        """
        if self.t_done == 0.0 or self.t_submit == 0.0:
            return None
        return self.t_done - self.t_submit

    @property
    def ttft(self) -> Optional[float]:
        """Submit-to-first-token seconds, or ``None`` before the first emit."""
        if self.t_first == 0.0 or self.t_submit == 0.0:
            return None
        return self.t_first - self.t_submit

    def _salt(self, token_index: int) -> int:
        return (self.sampling.seed * SALT_MULT + token_index) & 0x7FFFFFFF


def _cache_batch_axis(leaf) -> int:
    # Pool-cache layout convention (serve_step.cache_specs): the slot/batch
    # dim is axis 1 on every stacked leaf, except the 6-D VLM self-KV
    # (G, n_self, B, S, KV, hd) where it is axis 2.
    return 2 if leaf.ndim == 6 else 1


def _scatter_slot_leaf(pl, pr, idx, n_slots: int):
    """Write micro-batch rows of ONE slot-resident leaf into pool rows."""
    ax = _cache_batch_axis(pl)
    if pl.shape[ax] != n_slots:  # fail loudly if the layout rule drifts
        raise ValueError(
            f"cache leaf {pl.shape} does not carry the slot dim "
            f"({n_slots}) on axis {ax}; _cache_batch_axis out of date?"
        )
    rows = jnp.moveaxis(pr, ax, 0)[: idx.shape[0]]
    merged = jnp.moveaxis(pl, ax, 0).at[idx].set(rows)
    return jnp.moveaxis(merged, 0, ax)


def _scatter_slots(pool, part, slots, n_slots: int):
    """Write micro-batch cache rows into pool rows ``slots`` (leaf-wise).

    ``part`` may carry MORE rows than ``slots`` (batch-bucketed prefill pads
    with dummy rows); only the first ``len(slots)`` rows are written.
    """
    idx = jnp.asarray(slots, jnp.int32)
    return jax.tree_util.tree_map(
        lambda pl, pr: _scatter_slot_leaf(pl, pr, idx, n_slots), pool, part
    )


def _scatter_page_leaf(pl, pr, bt_rows, page: int):
    """Write micro-batch rows of ONE paged leaf into its page pool.

    pr: the flat prefill leaf — slot-batch at ``ax``, sequence (padded to
    max_len by the model) at ``ax + 1``; pl: the pool with (P_phys, page)
    at the same axes.  Row g's sequence is cut into page-sized runs and
    scattered to the page ids in ``bt_rows[g]`` — allocated pages for the
    admitted request, the trash page for dummy rows and the unallocated
    tail (collisions on trash are harmless; it is never read validly).
    Every allocated page gets fully overwritten (the model zero-pads prompt
    KV to max_len), so slot reuse can never leak a previous occupant's
    cache through recycled pages.
    """
    ax = _cache_batch_axis(pl)
    G, S = pr.shape[ax], pr.shape[ax + 1]
    n_chunk = -(-S // page)
    pr2 = jnp.moveaxis(pr, (ax, ax + 1), (0, 1))  # (G, S, rest...)
    if n_chunk * page != S:
        pad = [(0, n_chunk * page - S)] + [(0, 0)] * (pr2.ndim - 2)
        pr2 = jnp.pad(pr2, [(0, 0)] + pad)
    rows = pr2.reshape((G * n_chunk, page) + pr2.shape[2:])
    ids = bt_rows[:, :n_chunk].reshape(-1)
    pl2 = jnp.moveaxis(pl, (ax, ax + 1), (0, 1))  # (P_phys, page, rest...)
    merged = pl2.at[ids].set(rows)
    return jnp.moveaxis(merged, (0, 1), (ax, ax + 1))


def _scatter_mixed(pool, part, paged_mask, slots, n_slots, bt_rows, page):
    """Leaf-wise prefill scatter for a paged cache: page-pool leaves go
    through their block-table rows, slot-resident leaves (mamba state, SWA
    rings, cross-KV) through the classic row scatter."""
    idx = jnp.asarray(slots, jnp.int32)

    def leaf(pl, pr, is_paged):
        if is_paged:
            return _scatter_page_leaf(pl, pr, bt_rows, page)
        return _scatter_slot_leaf(pl, pr, idx, n_slots)

    return jax.tree_util.tree_map(leaf, pool, part, paged_mask)


def _next_pow2(n: int, floor: int) -> int:
    v = max(floor, 1)
    while v < n:
        v *= 2
    return v


def _seed32(seed: int) -> int:
    """Fold an arbitrary Python-int seed into signed int32 (low 32 bits).

    The fused loop computes salts with wrapping int32 arithmetic; keeping
    the low 32 bits preserves the low 31 salt bits the host path masks to
    (see sampling.SALT_MULT), so streams agree for any seed magnitude.
    """
    v = seed & 0xFFFFFFFF
    return v - 0x100000000 if v >= 0x80000000 else v


class Engine:
    """Continuous-batching engine binding (model, params) to a KV pool.

    ``decode_block``: decode tokens per host round-trip.  The fused step
    scans this many device decode iterations between host syncs; 1 recovers
    the classic token-at-a-time loop (useful for debugging), the default 8
    amortizes host dispatch/transfer to <= 1 sync per 8 decoded tokens per
    slot.

    ``page_size`` switches the pool to PAGED mode: per-token cache leaves
    live in a shared pool of ``kv_pages`` fixed-size pages (plus one trash
    page) indexed through per-slot block tables, and admission is gated on
    a request's ACTUAL page need (``ceil((prompt + max_new) / page_size)``,
    reserved up front so decode never strands) instead of an ``n_slots x
    max_len`` worst-case reservation — so at equal KV bytes the paged pool
    admits strictly more concurrent requests whenever real footprints are
    below worst case.  ``kv_pages`` defaults to flat-equivalent capacity
    (``n_slots * ceil(max_len / page_size)``); benchmarks lower it to bank
    the savings.  Greedy outputs are bit-identical to the flat engine (the
    block table only relocates bytes, never changes what is attended).

    ``prefill_chunk`` (paged mode, families without cross-chunk prefill
    state) additionally splits prompts longer than the chunk into fixed
    chunks processed ONE per engine step, interleaved with decode blocks —
    a long prompt's prefill no longer stalls running decodes for its whole
    length, bounding TTFT for short requests under long-prompt traffic.

    ``share_prefix`` (paged mode, chunk-capable families) turns on
    refcounted prompt-prefix sharing: requests whose prompts repeat an
    earlier prompt's leading full pages (the common-system-prompt traffic
    pattern) map those pages read-only instead of re-allocating and
    re-prefilling them, so equal KV bytes admit strictly more concurrent
    requests.  Inert (no behavior change) for families whose prefill
    cannot enter mid-prompt (ssm/hybrid/swa/vlm/audio).  See the module
    docstring for the matching / copy-on-write contract.

    SESSION reuse: when a shared-prefix slot finishes, its DECODE-FILLED
    full pages are registered too, keyed by the chained digest of prompt
    + generated tokens (minus the final token, whose K/V the fused loop
    does not guarantee to have written) — so a follow-up turn whose
    prompt extends the previous reply matches deep into the conversation
    and prefills only its new suffix.  Before registration the generated
    span is REMATERIALIZED through the chunk-prefill program (logits
    discarded): decode's single-query kernel and the prefill program
    round differently in the last bits, and indexed pages must hold
    bitwise the bytes a cold re-prefill would produce or a follow-up
    matching them can flip a greedy argmax vs an unshared run.
    ``warm_cache_pages`` caps how many refcount-0 pages stay matchable
    (LRU eviction inside the allocator); None keeps every released page
    matchable until a writer needs it.
    """

    def __init__(
        self,
        model,
        params,
        *,
        n_slots: int,
        max_len: int,
        dispatch: Optional[DispatchConfig] = None,
        eos_token: Optional[int] = None,
        decode_block: int = 8,
        page_size: Optional[int] = None,
        kv_pages: Optional[int] = None,
        prefill_chunk: Optional[int] = None,
        share_prefix: bool = False,
        warm_cache_pages: Optional[int] = None,
        tiers: Optional[Sequence[float]] = None,
        tier_q: int = 0,
        admission: Optional[AdmissionPolicy] = None,
        injector: Optional[FaultInjector] = None,
        preempt: bool = False,
        watchdog: Optional[StepWatchdog] = None,
        on_event: Optional[Callable[[str, dict], None]] = None,
    ):
        self.model, self.params = model, params
        self.cfg = model.cfg
        self.n_slots, self.max_len = n_slots, max_len
        self.eos_token = eos_token
        if decode_block < 1:
            raise ValueError(f"decode_block must be >= 1, got {decode_block}")
        self.decode_block = decode_block
        self._dcfg = dispatch if dispatch is not None else DispatchConfig.from_arch(self.cfg)

        # ---- elastic rank tiers (nested prefix slices of one checkpoint) --
        tiers = tuple(float(f) for f in tiers) if tiers else (1.0,)
        if tiers[0] != 1.0:
            raise ValueError(f"tiers[0] must be 1.0 (the serving rank), got {tiers}")
        if any(not 0.0 < f <= 1.0 for f in tiers) or any(
            a <= b for a, b in zip(tiers, tiers[1:])
        ):
            raise ValueError(f"tiers must be strictly decreasing in (0, 1]: {tiers}")
        if len(tiers) > 1 and self.cfg.family in _EXACT_LEN_FAMILIES:
            # recurrent state rows of frozen slots DRIFT during another
            # tier's fused pass (re-fed tokens integrate into the state),
            # so multi-tier decode would corrupt live recurrent slots —
            # attention K/V is rewritten before it is read, recurrent
            # state is not
            raise ValueError(
                f"multi-tier serving is not supported for the "
                f"{self.cfg.family} family (recurrent decode state)"
            )
        self.tiers = tiers
        # tier 0 aliases the stored params; every other tier is a trace-time
        # prefix slice — zero extra parameter memory, one jitted program per
        # tier (jit re-traces per sliced shape through the same callables)
        self._tier_params = [params] + [slice_rank(params, f) for f in tiers[1:]]
        self.tier_certificates = self._build_tier_certificates(tier_q)
        self.admission = admission
        self.injector = injector
        self.preempt = preempt
        # health instrumentation: the watchdog times every step() so a
        # stalled fused block flags instead of hanging run() silently; the
        # cluster reads .median/.durations as the heartbeat baseline.  A
        # plain attribute — the cluster may attach one post-construction.
        self.watchdog = watchdog
        # structured-event sink shared with the scheduler (see Cluster's
        # EventLog); None keeps the hot path branch-free in spirit — one
        # `is not None` check per event site.
        self.on_event = on_event

        self.paged = page_size is not None
        self.page_size = page_size
        if prefill_chunk is not None:
            if not self.paged:
                raise ValueError("prefill_chunk requires page_size (paged mode)")
            if prefill_chunk < 1:
                raise ValueError(f"prefill_chunk must be >= 1, got {prefill_chunk}")
        self.prefill_chunk = prefill_chunk
        if share_prefix and not self.paged:
            raise ValueError("share_prefix requires page_size (paged mode)")
        if warm_cache_pages is not None and not self.paged:
            raise ValueError("warm_cache_pages requires page_size (paged mode)")
        self.share_prefix = share_prefix
        self.warm_cache_pages = warm_cache_pages
        if self.paged:
            if page_size < 1:
                raise ValueError(f"page_size must be >= 1, got {page_size}")
            if model.init_cache_paged is None:
                raise ValueError(f"{self.cfg.family} model has no paged cache builder")
            self.max_pages = -(-max_len // page_size)
            self.kv_pages = kv_pages if kv_pages is not None else n_slots * self.max_pages
            with use_dispatch(self._dcfg):
                self.cache, self._paged_mask = model.init_cache_paged(
                    n_slots, max_len, page_size, self.kv_pages
                )
            self._has_pages = any(jax.tree_util.tree_leaves(self._paged_mask))
            self._trash = self.kv_pages  # trash page id (attention.trash_page)
            self._bt = np.full((n_slots, self.max_pages), self._trash, np.int32)
            self._bt_dirty = True
            # sharing needs (a) something actually paged to share and (b) a
            # mid-prompt prefill entry point (the chunk program) for the
            # unshared tail — otherwise the flag is inert, not an error, so
            # one launcher config can cover mixed arch fleets
            self._share = (
                share_prefix and self._has_pages and model.prefill_chunk is not None
            )
            # ONE index per tier: a page's K/V bytes depend on the params
            # that computed them, so the same tokens served at different
            # ranks must never alias pages across tiers
            self._prefix = (
                [PrefixIndex(page_size) for _ in self.tiers] if self._share else None
            )
            # one chunk shape for BOTH long-prompt chunking and shared-tail
            # prefill (two C values would compile two chunk programs)
            self._chunk_C = (
                prefill_chunk
                if prefill_chunk is not None
                else (page_size if self._share else None)
            )
            # the allocator owns warm-cache lifetime: its on_evict callback
            # is the ONLY place index keys are dropped outside an explicit
            # reset, so keys and storage can never disagree
            self.page_pool = PageAllocator(
                self.kv_pages,
                cache_budget=warm_cache_pages,
                on_evict=self._on_evict,
            )
            self.scheduler = Scheduler(
                SlotAllocator(n_slots),
                reserve=self._reserve,
                release_grant=self._release_grant,
                policy=admission,
                pressure=self._free_page_frac,
            )
        else:
            self.kv_pages = self.max_pages = 0
            self._paged_mask = None
            self._has_pages = False
            self._share = False
            self._prefix = None
            self._chunk_C = None
            self.page_pool = None
            self.scheduler = Scheduler(SlotAllocator(n_slots), policy=admission)
            with use_dispatch(self._dcfg):
                self.cache = model.init_cache(n_slots, max_len)
        self.scheduler.on_event = on_event
        # byte accounting: paged leaves are banked per PAGE, everything else
        # (slot-resident leaves, flat pools) is resident up front
        paged_leaves = (
            jax.tree_util.tree_leaves(self._paged_mask) if self.paged else []
        )
        cache_leaves = jax.tree_util.tree_leaves(
            {k: v for k, v in self.cache.items() if k != "block_table"}
        )
        self._bytes_per_page = sum(
            l.nbytes // l.shape[_cache_batch_axis(l)]
            for l, m in zip(cache_leaves, paged_leaves)
            if m
        ) if self.paged else 0
        self._bytes_resident = sum(l.nbytes for l in cache_leaves) - (
            self._bytes_per_page * (self.kv_pages + 1) if self.paged else 0
        )
        self.kv_bytes_capacity = sum(l.nbytes for l in cache_leaves)
        self._chunking: Dict[int, list] = {}  # slot -> [request, next_start, row]
        self._chunk_jit = None
        self._cow_fn = None  # jitted COW page copy (built on first fork)
        self._prefill_jit = jax.jit(
            lambda p, b, li: model.prefill(p, b, max_len, last_index=li)
        )
        # all-greedy fast path: skip the top-k/categorical machinery (two
        # (B,V) argsorts + B categorical draws) on the per-token hot path
        self._argmax_jit = jax.jit(lambda l: jnp.argmax(l, axis=-1).astype(jnp.int32))
        self._base_key = jax.random.PRNGKey(0)
        self._fused_cache: Dict[bool, Any] = {}  # greedy? -> jitted block fn

        # per-slot host state (None = slot idle); the int/bool arrays are
        # MIRRORS of the device buffers the fused step owns — the host only
        # rewrites them at admission/finish boundaries, between blocks.
        self._reqs: List[Optional[Request]] = [None] * n_slots
        self._pos = np.zeros((n_slots,), np.int32)  # next write position
        self._tokens = np.zeros((n_slots, 1), np.int32)  # last emitted token
        self._active = np.zeros((n_slots,), bool)
        self._emitted = np.zeros((n_slots,), np.int32)  # == len(req.tokens)
        self._max_new = np.zeros((n_slots,), np.int32)
        self._seeds = np.zeros((n_slots,), np.int32)
        self._temps = np.zeros((n_slots,), np.float32)
        self._topks = np.zeros((n_slots,), np.int32)
        self._next_uid = 0
        # perf accounting (benchmarks/serving.py --csv columns)
        self.steps = 0  # device decode steps executed
        self.host_syncs = 0  # fused-block host round-trips
        self.decoded_tokens = 0  # tokens emitted by decode (excl. prefill)
        self.peak_active = 0  # max concurrently admitted requests
        self.prefill_chunks = 0  # chunked-prefill chunks executed
        # prefix-sharing accounting: pages mapped read-only instead of
        # allocated+prefilled, COW forks taken, and admissions that matched
        self.shared_page_hits = 0
        self.cow_forks = 0
        self.shared_admissions = 0
        # prompt tokens admissions did NOT have to re-prefill because the
        # matched prefix's K/V was already resident (sum of grant.start)
        self.skipped_prefill_tokens = 0
        # overload/robustness accounting
        self.preemptions = 0  # slots preempted for higher-priority waiters
        self.quarantined = 0  # requests errored out on non-finite logits
        # shared lock-free: the cluster monitor polls this from another
        # thread (check_health straggler detection); single-writer (the
        # engine thread), monotonically increasing, so a stale read only
        # delays detection by one monitor pass — never corrupts it
        self.straggler_flags = 0  # watchdog-flagged slow steps
        self.exported = 0  # in-flight requests evicted via export_inflight
        self._step_idx = 0  # engine step() invocations (injector clock)

    def _free_page_frac(self) -> float:
        """Free-page fraction in [0, 1] — the admission policy's pressure
        signal (1.0 for flat/zero-page engines: no page pressure exists)."""
        if not self.paged or self.kv_pages == 0:
            return 1.0
        return self.page_pool.n_free / self.kv_pages

    def _build_tier_certificates(self, tier_q: int):
        """Per-tier Thm-3.2 certificates off the compressed LM head.

        The certified quantity is the softmax deviation the TIER introduces
        over the stored serving rank: the spectral norm of the factor tail
        each slice drops.  Head-less or uncompressed checkpoints get a
        zero-error certificate (slicing them is the identity).
        """
        if len(self.tiers) == 1:
            return [None]
        head = None

        def walk(node):
            nonlocal head
            if is_lowrank(node):
                a, b = node["a"], node["b"]
                # prefer the classifier head (projects to vocab, 2-D); else
                # keep the widest factor pair as the certified proxy layer
                if b.ndim == 2 and b.shape[-1] == self.cfg.vocab:
                    head = (a, b, True)
                elif head is None or (not head[2] and a.size > head[0].size):
                    head = (a, b, False)
                return
            if isinstance(node, dict):
                for v in node.values():
                    walk(v)
            elif isinstance(node, (list, tuple)):
                for v in node:
                    walk(v)

        walk(self.params)
        certs = []
        key = jax.random.PRNGKey(0)
        for f in self.tiers:
            if head is None:
                certs.append(
                    CompressionCertificate(0.0, 1.0, 0.0, rank=0, q=tier_q)
                )
                continue
            a, b, _ = head
            r = a.shape[-1]
            k = max(1, min(r, int(math.ceil(f * r))))
            certs.append(certify_tier(a, b, k, key, q=tier_q))
        return certs

    # ------------------------------------------------------------------ #
    # submission / introspection
    # ------------------------------------------------------------------ #
    def _page_need(self, request) -> int:
        """Pages a request must reserve: its WHOLE footprint (prompt plus
        max_new_tokens), taken at admission so decode can never run out of
        pages mid-stream (no preemption machinery needed)."""
        if not self._has_pages:
            return 0
        return -(-(int(request.prompt.size) + request.max_new_tokens) // self.page_size)

    def _reserve(self, request) -> Optional[PageGrant]:
        """All-or-nothing page reservation for one request (Scheduler hook).

        Matches the prompt's leading FULL pages against the prefix index,
        takes one allocator reference per hit (reviving cached pages off
        the free list), and allocates only the unshared remainder.  On
        allocation failure every acquired reference is rolled back, so
        admission stays atomic and strictly FIFO.  A shared page is
        counted ONCE in ``pages_in_use`` no matter how many slots map it
        (refcounts); zero-page archs get an EMPTY grant, which is a real
        admission — only ``None`` means exhaustion.
        """
        if self.injector is not None and self.injector.deny_reserve(self._step_idx):
            return None  # injected pool exhaustion: admission queues/sheds
        need = self._page_need(request)
        L = int(request.prompt.size)
        peak0 = self.page_pool.peak_used  # restored if this transaction fails
        acquired: List[int] = []
        # L >= 2 keeps the mid-prompt entry at start >= 1: a fully-matched
        # single-token prompt would otherwise degenerate to start == 0
        if self._share and L >= 2:
            for p in self._prefix[request.tier].match(request.prompt):
                if len(acquired) >= need or not self.page_pool.acquire(p):
                    break
                acquired.append(p)
        k = len(acquired)
        start = k * self.page_size if k else 0
        if k and start == L:
            # the whole prompt is covered by matched pages — but the last
            # prompt token must still run (its logits seed sampling), so
            # re-enter mid-page and COW-fork the page it re-writes
            start = L - 1
        fork = bool(k) and (start // self.page_size) < k
        fresh = self.page_pool.alloc(need - k + (1 if fork else 0))
        if fresh is None and fork:
            # The fork wants one page BEYOND the request's declared
            # footprint, but submit() only guarantees need <= kv_pages —
            # retrying the identical transaction could LIVELOCK (a full
            # pool never grows).  Degrade instead: un-share the boundary
            # page (its tail prefills like any unshared page) and retry
            # at exactly ``need``, which the pool can always eventually
            # satisfy.
            self.page_pool.free([acquired.pop()])
            k -= 1
            start = k * self.page_size
            fork = False
            fresh = self.page_pool.alloc(need - k)
        if fresh is None:
            if acquired:
                self.page_pool.free(acquired)
            # atomic: with every ref rolled back (including one the COW
            # degrade gave back above), restore the high-water mark any
            # revive raised — those pages never backed admitted work, and
            # the head-of-queue retry re-runs this every step.  A no-op
            # when nothing was revived.
            self.page_pool.rollback_peak(peak0)
            return None
        # fresh pages are about to be WRITTEN, but no index scrub is needed
        # here: the allocator only grants an index-backed page through its
        # eviction path, which already dropped the keys via _on_evict
        if fork:
            grant = PageGrant(
                pages=acquired[:-1] + [fresh[0]] + fresh[1:],
                n_shared=k - 1,
                start=start,
                cow=(acquired[-1], fresh[0]),
                refs=acquired + fresh,  # pin the COW source until release
            )
        else:
            grant = PageGrant(pages=acquired + fresh, n_shared=k, start=start)
        if k:
            self.shared_admissions += 1
            self.shared_page_hits += grant.n_shared
            self.skipped_prefill_tokens += grant.start
            request.prefill_skipped = grant.start
            # credit the matched pages' warm-cache value ONLY on a grant
            # that sticks — a starved head-of-queue retry acquires and
            # rolls back every step and must not inflate eviction scores
            self.page_pool.record_saved(acquired[: grant.n_shared])
        return grant

    def _on_evict(self, pages: List[int]) -> None:
        """PageAllocator eviction callback: a cached page is being handed
        to a writer (or swept by the cache budget), so its index keys must
        die in the same operation — no stale ``match`` hits."""
        if self._prefix is not None:
            for index in self._prefix:
                index.drop_pages(pages)

    def _release_grant(self, grant: PageGrant) -> None:
        """Drop one reference on every page the grant holds (Scheduler
        hook).  Shared pages survive until their LAST reader releases;
        an index-backed page hitting refcount 0 becomes a warm-cache
        entry inside the allocator (LRU-ordered, evicted via _on_evict
        when re-granted for writing or swept by the cache budget)."""
        if grant.refs:
            self.page_pool.free(grant.refs)

    def reset_prefix_cache(self) -> None:
        """Forget every prefix-index entry (benchmark warmup boundary).

        Refcounts and live allocations are untouched — already-admitted
        slots keep their shared pages; only FUTURE admissions stop
        matching until new prompts re-register.  The allocator's cache
        bookkeeping is flushed in the same operation (without counting
        evictions: this is a policy reset, not cache pressure)."""
        if self._prefix is not None:
            for index in self._prefix:
                index.clear()
            self.page_pool.flush_cache()

    def submit(self, request: Request) -> Request:
        if request.prompt.size + request.max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt ({request.prompt.size}) + max_new_tokens "
                f"({request.max_new_tokens}) exceeds max_len ({self.max_len})"
            )
        if self.paged and self._page_need(request) > self.kv_pages:
            raise ValueError(
                f"request needs {self._page_need(request)} pages but the pool "
                f"holds {self.kv_pages} — it could never be admitted"
            )
        request.uid = self._next_uid
        self._next_uid += 1
        request.t_submit = time.perf_counter()
        self.scheduler.enqueue(request)
        return request

    # n_active / n_waiting / pages_in_use are polled lock-free by the
    # cluster's routing pass from the monitor thread while the engine
    # thread mutates the underlying scheduler state.  That is deliberate:
    # they are single-writer load ESTIMATES — a stale value can only
    # misroute one admission to a slightly busier replica, and taking the
    # engine's step-loop hot path through a lock to sharpen a heuristic
    # would invert the cost/benefit.  Correctness-bearing cluster state is
    # what the `# guarded by:` annotations in serving/cluster.py cover.
    @property
    def n_active(self) -> int:
        return self.scheduler.allocator.n_active

    @property
    def n_waiting(self) -> int:
        return self.scheduler.n_waiting

    @property
    def has_work(self) -> bool:
        return self.n_active > 0 or self.n_waiting > 0

    @property
    def batch_utilization(self) -> float:
        """Fraction of executed decode-step rows that emitted a real token."""
        return self.decoded_tokens / (self.steps * self.n_slots) if self.steps else 0.0

    @property
    def tokens_per_sync(self) -> float:
        """Decoded tokens amortized per host round-trip."""
        return self.decoded_tokens / self.host_syncs if self.host_syncs else 0.0

    @property
    def pages_in_use(self) -> int:
        """Distinct physical pages currently referenced — a page shared by
        several slots is counted ONCE (it occupies one page of HBM)."""
        return self.page_pool.n_used if self.paged else 0

    @property
    def prefix_evictions(self) -> int:
        """Warm-cache pages evicted (writer re-grant or budget sweep)."""
        return self.page_pool.evictions if self.paged else 0

    @property
    def prefix_cached_pages(self) -> int:
        """Refcount-0 pages currently matchable in the prefix index."""
        return self.page_pool.n_cached if self.paged else 0

    @property
    def peak_pages_in_use(self) -> int:
        """Allocator-owned high-water page count: raised inside every
        allocation-changing operation (admission alloc, prefix acquire,
        COW fork), so pages held across chunked-prefill-only steps — or
        across a ``reset_counters`` boundary — are always observed."""
        return self.page_pool.peak_used if self.paged else 0

    @property
    def kv_bytes_in_use(self) -> int:
        """ACTUAL cache bytes backing admitted work: allocated pages (plus
        slot-resident leaves) in paged mode; in flat mode the whole pool is
        committed up front, so in-use == capacity regardless of load.

        Scope: the PERSISTENT pool only.  Both engines additionally
        materialize a transient per-admission prefill cache (one
        (G, max_len) micro-batch, freed after the scatter) that this metric
        — and ``kv_bytes_peak`` — deliberately exclude; size real HBM
        headroom as pool + one prefill micro-batch.  Chunked prefill
        shrinks that transient for long prompts to a single (1, chunk)
        slice."""
        if not self.paged:
            return self.kv_bytes_capacity
        return self._bytes_resident + self._bytes_per_page * self.pages_in_use

    @property
    def kv_bytes_peak(self) -> int:
        """High-water cache bytes actually backing admitted work."""
        if not self.paged:
            return self.kv_bytes_capacity
        return self._bytes_resident + self._bytes_per_page * self.peak_pages_in_use

    def reset_counters(self):
        """Re-arm the perf/accounting counters (benchmark warmup boundary).

        Peaks re-arm to CURRENT usage, not zero: allocations held across
        the boundary (a request mid-chunked-prefill, live slots) would
        otherwise peak unobserved if no later admission re-sampled them,
        under-reporting ``kv_bytes_peak``.
        """
        self.steps = self.host_syncs = self.decoded_tokens = 0
        self.prefill_chunks = 0
        self.shared_page_hits = self.cow_forks = self.shared_admissions = 0
        self.skipped_prefill_tokens = 0
        self.straggler_flags = 0
        self.exported = 0
        self.peak_active = self.scheduler.allocator.n_active
        if self.paged:
            self.page_pool.reset_peak()
            self.page_pool.evictions = 0

    # ------------------------------------------------------------------ #
    # admission + prefill
    # ------------------------------------------------------------------ #
    def _admission_groups(self, placed):
        """Split (slot, req) placements into prefill micro-batches.

        A micro-batch runs ONE prefill program with ONE params pytree, so
        placements split by TIER first (each tier's sliced factors are a
        distinct pytree), then by the family's shape constraints.
        """
        by_tier: Dict[int, list] = {}
        for slot, req in placed:
            by_tier.setdefault(req.tier, []).append((slot, req))
        groups = []
        for tier in sorted(by_tier):
            tier_placed = by_tier[tier]
            exact = self.cfg.family in _EXACT_LEN_FAMILIES
            if not exact and self.cfg.sliding_window is not None:
                # SWA ring layout rotates by the PADDED length once it
                # exceeds the window — shorter requests in the pad would
                # land in wrong ring slots, so group by exact length there.
                exact = (
                    max(req.prompt.size for _, req in tier_placed)
                    > self.cfg.sliding_window
                )
            if exact:
                by_len: Dict[int, list] = {}
                for slot, req in tier_placed:
                    by_len.setdefault(req.prompt.size, []).append((slot, req))
                groups.extend(by_len.values())
            else:
                groups.append(tier_placed)
        return groups

    def _prefill_shape(self, n_reqs: int, max_prompt: int):
        """Bucket the micro-batch shape so live traffic triggers a BOUNDED
        number of prefill compiles: batch rows up to the next power of two
        (capped at n_slots, dummy rows are discarded by the scatter), and —
        for attention families, where last_index makes right-padding exact —
        prompt length up to the next power of two (capped at max_len and at
        the sliding window, past which the ring layout forbids padding)."""
        G = min(_next_pow2(n_reqs, 1), self.n_slots)
        P = max_prompt
        if self.cfg.family not in _EXACT_LEN_FAMILIES:
            cap = self.max_len
            if self.cfg.sliding_window is not None:
                cap = min(cap, self.cfg.sliding_window)
            P = max(max_prompt, min(_next_pow2(max_prompt, 8), cap))
        return G, P

    def _prefill_group(self, group):
        slots = [slot for slot, _ in group]
        reqs = [req for _, req in group]
        lens = np.array([r.prompt.size for r in reqs], np.int32)
        G, P = self._prefill_shape(len(reqs), int(lens.max()))
        toks = np.zeros((G, P), np.int32)
        for i, r in enumerate(reqs):
            toks[i, : r.prompt.size] = r.prompt
        last_index = np.zeros((G,), np.int32)
        last_index[: len(reqs)] = lens - 1
        batch = {"tokens": jnp.asarray(toks)}
        for name in reqs[0].extras:
            rows = [r.extras[name] for r in reqs]
            rows += [np.zeros_like(rows[0])] * (G - len(reqs))
            batch[name] = jnp.asarray(np.stack(rows))

        padded_reqs = reqs + [None] * (G - len(reqs))
        tier = reqs[0].tier  # _admission_groups splits by tier
        with use_dispatch(self._dcfg):
            logits, part = self._prefill_jit(
                self._tier_params[tier], batch, jnp.asarray(last_index)
            )
            if self.paged:
                # dummy rows (and each slot's unallocated table tail) scatter
                # to the trash page; allocated pages are fully overwritten
                bt_rows = np.full((G, self.max_pages), self._trash, np.int32)
                bt_rows[: len(slots)] = self._bt[slots]
                pools = {k: v for k, v in self.cache.items() if k != "block_table"}
                merged = _scatter_mixed(
                    pools, part, self._paged_mask, slots, self.n_slots,
                    jnp.asarray(bt_rows), self.page_size,
                )
                merged["block_table"] = self.cache["block_table"]
                self.cache = merged
                if self._share:
                    # registration is DEFERRED to here (not admission) so a
                    # match can never alias pages whose prefill has not
                    # landed on device yet — same-round admissions simply
                    # miss the sharing opportunity once
                    for slot, req in group:
                        backing = self._prefix[req.tier].register(
                            req.prompt, self._bt[slot]
                        )
                        self.page_pool.mark_indexed(backing)
            else:
                self.cache = _scatter_slots(self.cache, part, slots, self.n_slots)
            first = self._sample(logits, padded_reqs, [0] * G)

        now = time.perf_counter()
        finished = []
        for i, (slot, req) in enumerate(group):
            self._activate_slot(slot, req, int(lens[i]), int(first[i]), now)
        for slot, _ in group:
            done = self._maybe_finish(slot)
            if done is not None:
                finished.append(done)
        return finished

    def _activate_slot(self, slot: int, req: Request, pos: int, first_tok: int, now: float):
        """Post-prefill bookkeeping shared by grouped and chunked prefill."""
        self._reqs[slot] = req
        self._pos[slot] = pos
        self._tokens[slot, 0] = first_tok
        self._active[slot] = True
        self._emitted[slot] = 1
        self._max_new[slot] = req.max_new_tokens
        self._seeds[slot] = _seed32(req.sampling.seed)
        self._temps[slot] = req.sampling.temperature
        self._topks[slot] = req.sampling.top_k
        req.certificate = self.tier_certificates[req.tier]
        req.t_first = now
        req.tokens.append(first_tok)

    # ------------------------------------------------------------------ #
    # sampling / completion
    # ------------------------------------------------------------------ #
    def _sample(self, logits, reqs, token_indices):
        """Sample one token per logits row for the given requests (prefill
        boundary; the decode hot path samples inside the fused block)."""
        if all(r is None or r.sampling.temperature == 0 for r in reqs):
            return np.asarray(self._argmax_jit(logits))
        B = logits.shape[0]
        temps = np.zeros((B,), np.float32)
        topks = np.zeros((B,), np.int32)
        salts = np.zeros((B,), np.int32)
        for i, (req, ti) in enumerate(zip(reqs, token_indices)):
            if req is None:
                continue
            temps[i] = req.sampling.temperature
            topks[i] = req.sampling.top_k
            salts[i] = req._salt(ti)
        out = sample_tokens(
            logits,
            self._base_key,
            jnp.asarray(salts),
            jnp.asarray(temps),
            jnp.asarray(topks),
        )
        return np.asarray(out)

    def _clear_slot(self, slot: int) -> None:
        """Reset one slot's host mirrors and hand it back to the scheduler
        (the shared tail of finish / preempt / quarantine)."""
        self._reqs[slot] = None
        self._pos[slot] = 0
        self._tokens[slot, 0] = 0
        self._active[slot] = False
        self._emitted[slot] = 0
        self._max_new[slot] = 0
        self._seeds[slot] = 0
        self._topks[slot] = 0
        self._temps[slot] = 0.0
        self.scheduler.release(slot)
        if self.paged:
            # Compact the table row back to all-trash BEFORE the next
            # device launch: the freed pages may be re-granted to another
            # slot, and a stale row would let this (now inactive) slot's
            # idempotent re-writes land in pages it no longer owns.
            self._bt[slot] = self._trash
            self._bt_dirty = True

    def _finalize(self, req: Request) -> Request:
        """Fold a finished CONTINUATION back into its original request.

        A preempted request's client holds the ORIGINAL object; the
        continuation's tokens extend its stream and its terminal state
        (timestamps, status) transfers, so callers never see the internal
        re-queue.  Non-continuations pass through untouched.
        """
        root = req._parent
        if root is None:
            return req
        root.tokens.extend(req.tokens)
        root.t_done = req.t_done
        root.status = req.status
        root.error = req.error
        return root

    def _maybe_finish(self, slot: int) -> Optional[Request]:
        req = self._reqs[slot]
        if req is None:
            return None
        hit_eos = self.eos_token is not None and req.tokens and req.tokens[-1] == self.eos_token
        if req.done or hit_eos:
            req.t_done = time.perf_counter()
            if self._share:
                # Register the DECODE-FILLED pages before the slot releases:
                # a follow-up turn whose prompt extends (prompt + reply)
                # matches them read-only and prefills only its new suffix.
                # The registered sequence stops one token short of the
                # reply — token k's K/V is written while producing token
                # k+1, so the LAST token's K/V is only (maybe) written by
                # frozen-slot re-feeds; likewise an EOS tail ends at the
                # EOS token itself, which is tokens[-1] and thus excluded.
                # register() only keys FULL pages, so the partial last
                # page is never offered.  Must precede release(): free()
                # can only turn these pages into warm-cache entries if
                # they are already marked as indexed.
                seq = np.concatenate(
                    [req.prompt, np.asarray(req.tokens[:-1], np.int32)]
                )
                full_end = (seq.size // self.page_size) * self.page_size
                if full_end > req.prompt.size:
                    self._rematerialize(
                        slot, seq, int(req.prompt.size), full_end, req.tier
                    )
                backing = self._prefix[req.tier].register(seq, self._bt[slot])
                self.page_pool.mark_indexed(backing)
            self._clear_slot(slot)
            return self._finalize(req)
        return None

    def _sync_block_table(self):
        """Push host block-table edits to the device cache (pre-launch)."""
        if self.paged and self._bt_dirty:
            self.cache["block_table"] = jnp.asarray(self._bt)
            self._bt_dirty = False

    def _cow_fork(self, src: int, dst: int):
        """Copy physical page ``src`` onto ``dst`` in every paged leaf.

        The copy-on-write step of shared-prefix admission: the forked slot
        writes its last prompt token (and nothing else) into ``dst``, so
        the shared original is never mutated.  ``src``'s content is pinned
        by the grant's extra reference until release, so the copy can
        never race a re-grant.  One jitted program per engine (page ids
        are runtime data), pools donated — no pool copy materializes.
        """
        from repro.models.attention import copy_page

        if self._cow_fn is None:
            mask = self._paged_mask

            def cow(pools, s, d):
                return jax.tree_util.tree_map(
                    lambda pl, m: (
                        copy_page(pl, s, d, axis=_cache_batch_axis(pl)) if m else pl
                    ),
                    pools,
                    mask,
                )

            self._cow_fn = jax.jit(cow, donate_argnums=(0,))
        pools = {k: v for k, v in self.cache.items() if k != "block_table"}
        with use_dispatch(self._dcfg):
            pools = self._cow_fn(pools, jnp.int32(src), jnp.int32(dst))
        pools["block_table"] = self.cache["block_table"]
        self.cache = pools
        self.cow_forks += 1

    # ------------------------------------------------------------------ #
    # chunked prefill (paged mode): one chunk per engine step
    # ------------------------------------------------------------------ #
    def _chunk_step(self):
        """Run ONE prefill chunk for the oldest chunking request; returns
        ``(finished, n_real)`` — the requests completed by this chunk and
        how many REAL prompt tokens it processed (the step loop's budget
        currency).

        Chunks are a fixed (1, chunk) shape (the last chunk of a prompt is
        right-padded; ``n_real`` masks the tail), so live traffic compiles
        exactly one chunk program per arch.  The final chunk's logits
        sample the request's first token and the slot joins the decode
        batch at the next block.
        """
        slot = next(iter(self._chunking))  # dict preserves admission order
        req, start, row = self._chunking[slot]
        C = self._chunk_C
        plen = int(req.prompt.size)
        n = min(C, plen - start)
        toks = np.zeros((1, C), np.int32)
        toks[0, :n] = req.prompt[start : start + n]
        if self._chunk_jit is None:
            model = self.model
            self._chunk_jit = jax.jit(
                lambda p, c, t, bt, st, nr: model.prefill_chunk(p, c, t, bt, st, nr),
                donate_argnums=(1,),
            )
        with use_dispatch(self._dcfg):
            logits, self.cache = self._chunk_jit(
                self._tier_params[req.tier],
                self.cache,
                jnp.asarray(toks),
                jnp.asarray(row),
                jnp.int32(start),
                jnp.int32(n),
            )
        self.prefill_chunks += 1
        start += n
        if start < plen:
            self._chunking[slot][1] = start
            return [], n
        del self._chunking[slot]
        # last chunk landed: publish the row so the decode block (and its
        # page writes) see the slot's pages from here on
        self._bt[slot] = row
        self._bt_dirty = True
        if self._share:
            # the prompt's full pages are now completely written on device:
            # safe to offer them to future admissions
            backing = self._prefix[req.tier].register(req.prompt, row)
            self.page_pool.mark_indexed(backing)
        first = self._sample(logits, [req], [0])
        self._activate_slot(slot, req, plen, int(first[0]), time.perf_counter())
        done = self._maybe_finish(slot)
        return ([done] if done is not None else []), n

    def _rematerialize(
        self, slot: int, seq: np.ndarray, start: int, end: int, tier: int = 0
    ):
        """Rewrite positions ``[start, end)`` of the slot's pages through
        the (1, C) chunk-prefill program, discarding the logits.

        Decode filled those K/V entries via the single-query decode path,
        whose floating-point reduction order differs from the prefill
        program's in the last bits.  Pages offered to the prefix index
        must hold bitwise the bytes a cold re-prefill of the same tokens
        would produce, or a follow-up turn that matches them can flip a
        greedy argmax relative to an unshared run.  Re-feeding the
        generated tokens through the canonical prefill program restores
        those bytes; the cost is O(reply length) at release, OFF any
        follow-up's TTFT path.  Only ``[start, end)`` needs rewriting:
        ``end`` is the last full-page boundary (partial tails are never
        indexed) and positions ``< start`` were prefill-written at
        admission.  Reuses the one compiled chunk program — no extra
        compilation, and pad positions past ``n_real`` write to the
        trash page, so nothing outside the slot's own pages is touched.
        """
        C = self._chunk_C
        row = self._bt[slot]
        if self._chunk_jit is None:
            model = self.model
            self._chunk_jit = jax.jit(
                lambda p, c, t, bt, st, nr: model.prefill_chunk(p, c, t, bt, st, nr),
                donate_argnums=(1,),
            )
        while start < end:
            n = min(C, end - start)
            toks = np.zeros((1, C), np.int32)
            toks[0, :n] = seq[start : start + n]
            with use_dispatch(self._dcfg):
                _, self.cache = self._chunk_jit(
                    self._tier_params[tier],
                    self.cache,
                    jnp.asarray(toks),
                    jnp.asarray(row),
                    jnp.int32(start),
                    jnp.int32(n),
                )
            self.prefill_chunks += 1
            start += n

    # ------------------------------------------------------------------ #
    # the fused decode block (device-resident inner loop)
    # ------------------------------------------------------------------ #
    def _fused_fn(self, greedy: bool):
        """Build (once per greedy/sampling variant) the jitted fused block.

        The block scans ``decode_block`` decode iterations on device.  Per
        iteration: decode_step -> sample -> per-slot stop detection ->
        position/token updates, with NO host involvement.  Frozen (finished
        or empty) slots re-feed their last (token, position) pair, so their
        attention-cache writes are idempotent; recurrent (SSM) state rows of
        frozen slots do drift, but a slot's state is fully overwritten by
        the prefill scatter before reuse, and rows are independent across
        the batch, so live slots never observe it.

        Donation: the cache pool and every per-slot buffer are donated —
        XLA aliases them in place, so the multi-GB pool is never copied per
        block, let alone per token.
        """
        fn = self._fused_cache.get(greedy)
        if fn is not None:
            return fn
        model = self.model
        n_steps = self.decode_block
        eos = _NO_EOS if self.eos_token is None else int(self.eos_token)

        def fused(params, cache, tokens, pos, active, emitted, max_new, seeds,
                  temps, topks, base_key, poison_slot, poison_rel):
            sids = jnp.arange(tokens.shape[0], dtype=jnp.int32)

            def body(carry, i):
                cache, tokens, pos, active, emitted, quar = carry
                logits, cache = model.decode_step(params, cache, tokens, pos)
                # fault injection rides two runtime scalars ((-1, -1) when
                # unarmed selects nothing) — the compiled program is
                # byte-identical armed or not, so injection tests exercise
                # exactly the production quarantine path
                hit = (sids == poison_slot) & (i == poison_rel)
                logits = jnp.where(hit[:, None], jnp.nan, logits)
                if greedy:
                    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                else:
                    # salt from the CURRENT emitted count == the host path's
                    # token_index (len(req.tokens) before this append)
                    nxt = sample_tokens(
                        logits, base_key, token_salts(seeds, emitted), temps, topks
                    )
                # QUARANTINE: a slot whose logits went non-finite freezes
                # THIS step — the garbage token is never emitted, never fed
                # back, and the rest of the batch keeps decoding.  The host
                # drain errors the request out after the block.
                bad = active & ~jnp.isfinite(logits).all(axis=-1)
                quar = quar | bad
                emit = active & ~bad
                # frozen slots re-feed their last token at their frozen
                # position (idempotent cache rewrite, masked out of emits)
                nxt = jnp.where(emit, nxt, tokens[:, 0])
                step = emit.astype(jnp.int32)
                pos = pos + step
                emitted = emitted + step
                active = emit & (emitted < max_new) & (nxt != eos)
                return (cache, nxt[:, None], pos, active, emitted, quar), (nxt, emit)

            carry, (toks, emits) = jax.lax.scan(
                body,
                (cache, tokens, pos, active, emitted, jnp.zeros_like(active)),
                jnp.arange(n_steps, dtype=jnp.int32),
            )
            cache, tokens, pos, active, emitted, quar = carry
            return cache, tokens, pos, active, emitted, quar, toks, emits

        fn = jax.jit(fused, donate_argnums=(1, 2, 3, 4, 5))
        self._fused_cache[greedy] = fn
        return fn

    # ------------------------------------------------------------------ #
    # the engine step
    # ------------------------------------------------------------------ #
    def step(self) -> List[Request]:
        """Admit waiting requests (paged mode: gated on free PAGES, with
        long prompts routed to the chunked-prefill queue), run at most one
        prefill chunk, then one fused decode block (up to ``decode_block``
        tokens per active slot with a single host round-trip); returns the
        requests that finished during this step.

        When a :class:`StepWatchdog` is attached, every step is timed and
        fed to it: a step slower than the watchdog's straggler threshold
        bumps ``straggler_flags`` and emits a ``"straggler"`` event — the
        health signal the cluster's heartbeat monitor consumes, instead of
        a stalled fused block hanging ``run()`` silently.
        """
        if self.watchdog is None:
            return self._step_inner()
        t0 = time.monotonic()
        finished = self._step_inner()
        if self.watchdog.observe(self._step_idx, time.monotonic() - t0):
            self.straggler_flags += 1
            if self.on_event is not None:
                self.on_event(
                    "straggler",
                    {"step": self._step_idx,
                     "seconds": round(self.watchdog.durations[-1], 6),
                     "median_s": round(self.watchdog.median, 6)},
                )
        return finished

    def _step_inner(self) -> List[Request]:
        finished: List[Request] = []
        self._step_idx += 1
        if self.injector is not None:
            self.injector.on_step(self._step_idx)

        placed = self.scheduler.admit()
        if self.preempt:
            placed.extend(self._preempt_for_waiters())
        # deadline-expired waiters shed by admission surface as finished
        # requests with status "shed" and a structured rejection attached
        finished.extend(self.scheduler.drain_shed())
        if placed:
            # page peaks are tracked INSIDE the allocator at every
            # allocation-changing site; only the admitted-request peak is
            # engine-level state
            self.peak_active = max(self.peak_active, self.scheduler.allocator.n_active)

        chunking = (
            self.paged
            and self.prefill_chunk is not None
            and self.model.prefill_chunk is not None
        )
        direct = []
        for slot, req in placed:
            row = None
            grant = None
            if self.paged:
                grant = self.scheduler.slot_pages[slot]
                row = np.full((self.max_pages,), self._trash, np.int32)
                row[: len(grant.pages)] = grant.pages
            if grant is not None and grant.start > 0:
                # Shared-prefix admission: the matched pages' K/V is already
                # resident, so prefill SKIPS them entirely and enters the
                # model mid-prompt (grant.start) through the chunk program.
                # When the tail re-enters the last matched page (whole
                # prompt covered), COW-fork it first so the re-write of the
                # final prompt token never lands in shared storage.
                if grant.cow is not None:
                    self._cow_fork(*grant.cow)
                self._chunking[slot] = [req, grant.start, row]
            elif chunking and req.prompt.size > self.prefill_chunk:
                # The slot's DEVICE table row stays on trash until the last
                # chunk lands: the fused block's frozen-slot re-feeds write
                # through the table at position 0, and a published row would
                # let them corrupt the half-prefilled pages.  The chunk
                # program gets the real row as an explicit argument instead.
                self._chunking[slot] = [req, 0, row]
            else:
                if row is not None:
                    self._bt[slot] = row
                    self._bt_dirty = True
                direct.append((slot, req))

        for group in self._admission_groups(direct):
            if group:
                # requests whose single token came from prefill finish here
                finished.extend(self._prefill_group(group))

        if self._chunking:
            # Prefill budget of ~C REAL tokens per step: a full long-prompt
            # chunk consumes it whole (the classic one-chunk-per-step
            # interleave, so a long prefill still never stalls running
            # decodes), while SHORT tails — shared-prefix admissions
            # prefilling only their unshared suffix — pack into one step
            # instead of trickling one admission per decode block.
            budget = self._chunk_C
            while self._chunking and budget > 0:
                done, n_real = self._chunk_step()
                finished.extend(done)
                budget -= max(n_real, 1)

        if not self._active.any():
            return finished

        # One fused pass per distinct ACTIVE tier: a pass's params must
        # match every row it advances, so other tiers' slots ride along
        # FROZEN (masked inactive).  Their idempotent K/V re-feeds do land
        # with this pass's params — wrong bytes at their frozen position —
        # but each such slot's own next active decode REWRITES that
        # position with its tier's params before anything attends to it
        # (write-before-read), so attention-family state self-repairs;
        # recurrent families are rejected at construction.  Single-tier
        # engines take exactly one pass — the PR-4 hot path unchanged.
        slot_tiers = np.array(
            [r.tier if r is not None else 0 for r in self._reqs], np.int32
        )
        poison_slot, poison_rel = self._poison_for()
        for tier in sorted({int(t) for t in slot_tiers[self._active]}):
            mask = self._active & (slot_tiers == tier)
            finished.extend(self._fused_pass(tier, mask, poison_slot, poison_rel))
        return finished

    def _fused_pass(self, tier, mask, poison_slot, poison_rel) -> List[Request]:
        """Run one fused decode block over the slots in ``mask`` (one tier)."""
        self._sync_block_table()
        greedy = not (self._temps[mask] > 0).any()
        fused = self._fused_fn(greedy)
        with use_dispatch(self._dcfg):
            (
                self.cache,
                tokens_d,
                pos_d,
                active_d,
                emitted_d,
                quar_d,
                toks_d,
                emits_d,
            ) = fused(
                self._tier_params[tier],
                self.cache,
                jnp.asarray(self._tokens),
                jnp.asarray(self._pos),
                jnp.asarray(mask),
                jnp.asarray(self._emitted),
                jnp.asarray(self._max_new),
                jnp.asarray(self._seeds),
                jnp.asarray(self._temps),
                jnp.asarray(self._topks),
                self._base_key,
                jnp.int32(poison_slot),
                jnp.int32(poison_rel),
            )
        # THE host sync for this block: drain the (n_steps, n_slots) emit
        # stack plus the final per-slot state in one transfer batch.
        toks = np.asarray(toks_d)
        emits = np.asarray(emits_d)
        quar = np.asarray(quar_d)
        # merge ONLY this pass's rows into the host mirrors: rows of other
        # tiers were masked inactive for this pass, and their final device
        # "active" (False) must not clobber the real liveness state
        self._tokens[mask] = np.asarray(tokens_d)[mask]
        self._pos[mask] = np.asarray(pos_d)[mask]
        self._active[mask] = np.asarray(active_d)[mask]
        self._emitted[mask] = np.asarray(emitted_d)[mask]
        self.steps += self.decode_block
        self.host_syncs += 1
        self.decoded_tokens += int(emits.sum())

        finished: List[Request] = []
        for s in np.nonzero(emits.any(axis=0) | quar)[0]:
            s = int(s)
            req = self._reqs[s]
            for tok, emit in zip(toks[:, s], emits[:, s]):
                if emit:
                    req.tokens.append(int(tok))
            if quar[s]:
                finished.append(self._quarantine_slot(s))
                continue
            done = self._maybe_finish(s)
            if done is not None:
                finished.append(done)
        return finished

    # ------------------------------------------------------------------ #
    # overload machinery: preemption, quarantine, session close
    # ------------------------------------------------------------------ #
    @property
    def degraded_admissions(self) -> int:
        """Admissions the policy moved to a cheaper tier under pressure."""
        return self.scheduler.degraded

    def _poison_for(self):
        """Resolve the injector's NaN fault to (slot, step-within-block)
        for the next fused block; (-1, -1) selects nothing."""
        if self.injector is None:
            return -1, -1
        return self.injector.poison_for(
            lambda s: self._reqs[s].uid if self._reqs[s] is not None else None,
            self.n_slots,
            self.steps,
            self.decode_block,
        )

    def _pick_victim(self, head) -> Optional[int]:
        """Cheapest active slot strictly outranked by the queue head:
        lowest priority first, then fewest emitted tokens (least sunk
        work), then slot id (deterministic traces).  Mid-chunking slots
        are never victims (their pages are half-written)."""
        best, key = None, None
        for s in range(self.n_slots):
            req = self._reqs[s]
            if req is None or not self._active[s] or s in self._chunking:
                continue
            if req.priority >= head.priority:
                continue
            k = (req.priority, int(self._emitted[s]), s)
            if key is None or k < key:
                best, key = s, k
        return best

    def _preempt_for_waiters(self):
        """While the queue head outranks a running request, preempt the
        cheapest victim and retry admission.  Stops the moment an eviction
        fails to admit anyone (freeing more victims could not help: pages
        come back as warm cache, not free pages, until evicted — and the
        continuation re-queues right behind the preemptor anyway)."""
        placed = []
        while self.scheduler.queue:
            victim = self._pick_victim(self.scheduler.queue[0])
            if victim is None:
                break
            self._preempt_slot(victim)
            more = self.scheduler.admit()
            if not more:
                break
            placed.extend(more)
        return placed

    def _evict_slot(self, slot: int) -> Request:
        """Evict one running request, preserving its work, and return the
        CONTINUATION that resumes it (the caller decides where it goes —
        back into this engine's queue for preemption, or onto another
        replica for failover).

        The evicted slot's decode-filled FULL pages go through the
        standard release path — rematerialized through the prefill program
        and registered in the tier's prefix index — so the continuation's
        admission matches them read-only and prefills ONLY the unshared
        tail (plus the partial last page).  The continuation extends the
        original request's stream under the original uid/submit-time/tier:
        under greedy decoding the resumed stream is bit-identical to an
        uninterrupted run, because prefilling the extended prompt
        reproduces the same argmax chain.  Sampled (temperature > 0)
        streams resume with a fresh salt chain — eviction guarantees
        greedy parity, not sampled-stream parity.
        """
        req = self._reqs[slot]
        if self._share and len(req.tokens) > 1:
            seq = np.concatenate([req.prompt, np.asarray(req.tokens[:-1], np.int32)])
            full_end = (seq.size // self.page_size) * self.page_size
            if full_end > req.prompt.size:
                self._rematerialize(
                    slot, seq, int(req.prompt.size), full_end, req.tier
                )
            backing = self._prefix[req.tier].register(seq, self._bt[slot])
            self.page_pool.mark_indexed(backing)
        self._clear_slot(slot)
        root = req._parent if req._parent is not None else req
        if req._parent is not None:
            # fold this segment's tokens into the root NOW — the next
            # continuation starts a fresh token list of its own
            root.tokens.extend(req.tokens)
        cont = Request(
            prompt=np.concatenate([req.prompt, np.asarray(req.tokens, np.int32)]),
            max_new_tokens=req.max_new_tokens - len(req.tokens),
            sampling=req.sampling,
            extras=req.extras,
            deadline_ms=req.deadline_ms,
            min_tier=req.min_tier,
            tier=req.tier,
            priority=req.priority,
        )
        cont.uid = req.uid
        cont.t_submit = req.t_submit
        cont._parent = root
        return cont

    def _preempt_slot(self, slot: int) -> None:
        """Preempt one running request for a higher-priority waiter: evict
        it and re-queue the continuation right behind the preemptor
        (index 1)."""
        cont = self._evict_slot(slot)
        self.scheduler.queue.insert(1, cont)
        self.preemptions += 1
        if self.on_event is not None:
            self.on_event(
                "preempt",
                {"uid": cont.uid, "emitted": len(cont._parent.tokens),
                 "remaining": cont.max_new_tokens},
            )

    def snapshot_inflight(self) -> List[dict]:
        """Non-destructive view of every in-flight request (active slots
        plus mid-chunked-prefill ones) — the cluster monitor's source for
        failover accounting; touches no engine state."""
        out = []
        for slot, entry in self._chunking.items():
            req = entry[0]
            out.append(
                {"uid": req.uid, "slot": slot, "emitted": 0,
                 "remaining": req.max_new_tokens, "tier": req.tier,
                 "chunking": True}
            )
        for slot in range(self.n_slots):
            req = self._reqs[slot]
            if req is None:
                continue
            out.append(
                {
                    "uid": req.uid,
                    "slot": slot,
                    "emitted": len(req.tokens),
                    "remaining": req.max_new_tokens - len(req.tokens),
                    "tier": req.tier,
                    "chunking": False,
                }
            )
        return out

    def take_queue(self) -> List[Request]:
        """Remove and return every QUEUED (never admitted) request — the
        first half of an externally-driven drain.  The caller now owns
        their completion (re-route or shed); this engine will not touch
        them again."""
        out = list(self.scheduler.queue)
        self.scheduler.queue.clear()
        return out

    def export_inflight(self) -> List[Request]:
        """Evict EVERY in-flight request and return the requests to resume
        elsewhere — the second half of an externally-driven drain (cluster
        failover path).

        Mid-chunked-prefill slots have emitted nothing, so their ORIGINAL
        request is returned verbatim (a cold re-prefill elsewhere loses no
        work); active decode slots go through :meth:`_evict_slot`, whose
        continuation resumes bit-exactly under greedy decoding.  All slots
        and pages are released — the engine is left with no in-flight
        state, so ``PageAllocator`` invariants hold even when the export
        happens mid-fault.
        """
        out: List[Request] = []
        for slot in list(self._chunking):
            req = self._chunking.pop(slot)[0]
            self._clear_slot(slot)
            out.append(req)
            self.exported += 1
        for slot in range(self.n_slots):
            if self._reqs[slot] is not None:
                out.append(self._evict_slot(slot))
                self.exported += 1
        return out

    def _quarantine_slot(self, slot: int) -> Request:
        """Error-out one request whose decode went non-finite.

        The fused block froze the row the moment the bad logits appeared,
        so no garbage token was emitted or fed back, and the REST of the
        batch kept decoding unaffected.  The request's pages are NEVER
        registered in the prefix index — possibly-poisoned K/V must not
        back a future match — they just free for clean reuse.
        """
        req = self._reqs[slot]
        req.t_done = time.perf_counter()
        req.status = "error"
        req.error = "non-finite logits during decode"
        self._clear_slot(slot)
        self.quarantined += 1
        if self.on_event is not None:
            self.on_event(
                "quarantine",
                {"uid": req.uid, "emitted": len(req.tokens), "slot": slot},
            )
        return self._finalize(req)

    def drop_session(self, prompt) -> int:
        """Close an abandoned conversation branch NOW: drop its prefix-index
        chain (every tier) plus all registered extensions, and release the
        matching warm-cache pages for clean reuse — instead of waiting for
        LRU pressure to reclaim them.  Returns cached pages freed."""
        if self._prefix is None:
            return 0
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        freed = 0
        for index in self._prefix:
            freed += self.page_pool.drop_cached(index.drop_branch(prompt))
        return freed

    def shed_queue(self, reason: str = "shutdown") -> List[Request]:
        """Shed every QUEUED (never admitted) request with a structured
        rejection; in-flight slots are untouched.  The graceful-shutdown
        primitive: stop admitting, finish what is running."""
        while self.scheduler.queue:
            self.scheduler.shed_request(self.scheduler.queue.popleft(), reason)
        return self.scheduler.drain_shed()

    # ------------------------------------------------------------------ #
    # convenience drain loop
    # ------------------------------------------------------------------ #
    def run(
        self,
        requests: Sequence[Request],
        arrivals: Optional[Sequence[float]] = None,
        *,
        max_idle_wait: float = 0.05,
        stop: Optional[Callable[[], bool]] = None,
    ) -> List[Request]:
        """Submit ``requests`` (optionally at wall-clock ``arrivals`` offsets,
        seconds) and step until all complete.  Returns them in finish order.

        Idle handling: when no request is active and the next arrival is in
        the future, sleep EXACTLY to that arrival — but never longer than
        ``max_idle_wait`` seconds per nap, so ``has_work`` transitions from
        concurrent ``submit()`` callers are noticed promptly and a long gap
        neither busy-spins nor oversleeps past new work.

        ``stop`` (optional) is polled once per loop; the first True begins
        a GRACEFUL DRAIN: not-yet-submitted requests are dropped, queued
        ones shed with a structured ``"shutdown"`` rejection, and every
        in-flight slot decodes to completion before the loop returns — the
        SIGINT contract of launch/serve.py.
        """
        order = sorted(range(len(requests)), key=lambda i: arrivals[i] if arrivals else 0)
        t0 = time.perf_counter()
        pending = list(order)
        finished: List[Request] = []
        while pending or self.has_work:
            if stop is not None and stop():
                pending.clear()
                finished.extend(self.shed_queue("shutdown"))
                stop = None  # drained once; keep stepping in-flight slots
            now = time.perf_counter() - t0
            while pending and (arrivals is None or arrivals[pending[0]] <= now):
                self.submit(requests[pending[0]])
                pending.pop(0)
            if self.has_work:
                finished.extend(self.step())
                continue
            if pending:  # idle until the next arrival, in capped naps
                wait = arrivals[pending[0]] - (time.perf_counter() - t0)
                if wait > 0:
                    time.sleep(min(wait, max_idle_wait))
        return finished
