"""Continuous-batching serving engine over a slotted KV-cache pool.

The engine owns ONE batched decode cache of ``n_slots`` rows (the pool) and
runs an admit -> prefill -> shared-decode loop:

  * requests (prompt tokens, max_new_tokens, sampling params) enter a FIFO
    queue (:mod:`repro.serving.scheduler`) and are assigned cache slots as
    slots free up — slot exhaustion queues, it never crashes;
  * admitted requests are prefilled in right-padded micro-batches (causal
    masking keeps padded prefill exact for attention families; recurrent
    families group by exact length because SSM state integrates every input
    token) and their caches are scattered into the pool rows;
  * ALL active slots then share a single fixed-shape decode step per token,
    with per-slot positions threaded through ``decode_attention`` /
    ``mla_decode`` / SSM state, so variable-length sequences coexist in one
    cache tensor;
  * finished sequences free their slot and the oldest waiting request is
    admitted mid-stream — the decode batch stays full under load.

Kernel backend selection goes through the unified dispatch runtime (PR 1):
every prefill/decode call runs inside ``use_dispatch``, so ``--kernels``
applies per engine step exactly as it does to the static path.

Greedy determinism contract: with temperature 0 the engine emits, per
request, bit-identical tokens to ``serve_step.greedy_generate`` run on that
prompt alone (tests/test_engine_parity.py) — the scheduler changes WHEN a
sequence advances, never WHAT it computes.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.dispatch import DispatchConfig, use_dispatch
from repro.serving.sampling import SamplingParams, sample_tokens
from repro.serving.scheduler import Scheduler, SlotAllocator

__all__ = ["Request", "Engine", "SamplingParams", "percentile"]


def percentile(sorted_vals, frac: float):
    """Nearest-rank percentile of an ascending-sorted sequence.

    The ONE latency-percentile definition shared by the launcher and the
    serving benchmark, so their reported p50/p95 agree on identical data.
    """
    n = len(sorted_vals)
    if n == 0:
        raise ValueError("percentile of empty sequence")
    return sorted_vals[max(0, math.ceil(frac * n) - 1)]

# Families whose decode state integrates every prefill token (recurrent /
# convolutional state): right-padding would corrupt the carried state, so
# admission micro-batches group these by EXACT prompt length.
_EXACT_LEN_FAMILIES = ("ssm", "hybrid")

_SALT_MULT = 1_000_003  # salt = seed * MULT + token_index (mod int32)


@dataclasses.dataclass
class Request:
    """One generation request plus its per-request results/latency record."""

    prompt: np.ndarray  # (S,) int32 prompt tokens
    max_new_tokens: int
    sampling: SamplingParams = SamplingParams()
    extras: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    # filled in by the engine:
    uid: int = -1
    tokens: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size < 1:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.max_new_tokens

    @property
    def latency(self) -> float:
        return self.t_done - self.t_submit

    @property
    def ttft(self) -> float:
        return self.t_first - self.t_submit

    def _salt(self, token_index: int) -> int:
        return (self.sampling.seed * _SALT_MULT + token_index) & 0x7FFFFFFF


def _cache_batch_axis(leaf) -> int:
    # Pool-cache layout convention (serve_step.cache_specs): the slot/batch
    # dim is axis 1 on every stacked leaf, except the 6-D VLM self-KV
    # (G, n_self, B, S, KV, hd) where it is axis 2.
    return 2 if leaf.ndim == 6 else 1


def _scatter_slots(pool, part, slots, n_slots: int):
    """Write micro-batch cache rows into pool rows ``slots`` (leaf-wise).

    ``part`` may carry MORE rows than ``slots`` (batch-bucketed prefill pads
    with dummy rows); only the first ``len(slots)`` rows are written.
    """
    idx = jnp.asarray(slots, jnp.int32)

    def leaf(pl, pr):
        ax = _cache_batch_axis(pl)
        if pl.shape[ax] != n_slots:  # fail loudly if the layout rule drifts
            raise ValueError(
                f"cache leaf {pl.shape} does not carry the slot dim "
                f"({n_slots}) on axis {ax}; _cache_batch_axis out of date?"
            )
        rows = jnp.moveaxis(pr, ax, 0)[: idx.shape[0]]
        merged = jnp.moveaxis(pl, ax, 0).at[idx].set(rows)
        return jnp.moveaxis(merged, 0, ax)

    return jax.tree_util.tree_map(leaf, pool, part)


def _next_pow2(n: int, floor: int) -> int:
    v = max(floor, 1)
    while v < n:
        v *= 2
    return v


class Engine:
    """Continuous-batching engine binding (model, params) to a slot pool."""

    def __init__(
        self,
        model,
        params,
        *,
        n_slots: int,
        max_len: int,
        dispatch: Optional[DispatchConfig] = None,
        eos_token: Optional[int] = None,
    ):
        self.model, self.params = model, params
        self.cfg = model.cfg
        self.n_slots, self.max_len = n_slots, max_len
        self.eos_token = eos_token
        self._dcfg = dispatch if dispatch is not None else DispatchConfig.from_arch(self.cfg)
        self.scheduler = Scheduler(SlotAllocator(n_slots))

        with use_dispatch(self._dcfg):
            self.cache = model.init_cache(n_slots, max_len)
        self._decode_jit = jax.jit(model.decode_step)
        self._prefill_jit = jax.jit(
            lambda p, b, li: model.prefill(p, b, max_len, last_index=li)
        )
        # all-greedy fast path: skip the top-k/categorical machinery (two
        # (B,V) argsorts + B categorical draws) on the per-token hot path
        self._argmax_jit = jax.jit(lambda l: jnp.argmax(l, axis=-1).astype(jnp.int32))
        self._base_key = jax.random.PRNGKey(0)

        # per-slot host state (None = slot idle)
        self._reqs: List[Optional[Request]] = [None] * n_slots
        self._pos = np.zeros((n_slots,), np.int32)  # next write position
        self._tokens = np.zeros((n_slots, 1), np.int32)  # last emitted token
        self._next_uid = 0
        self.steps = 0  # decode steps executed (for utilization stats)

    # ------------------------------------------------------------------ #
    # submission / introspection
    # ------------------------------------------------------------------ #
    def submit(self, request: Request) -> Request:
        if request.prompt.size + request.max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt ({request.prompt.size}) + max_new_tokens "
                f"({request.max_new_tokens}) exceeds max_len ({self.max_len})"
            )
        request.uid = self._next_uid
        self._next_uid += 1
        request.t_submit = time.perf_counter()
        self.scheduler.enqueue(request)
        return request

    @property
    def n_active(self) -> int:
        return self.scheduler.allocator.n_active

    @property
    def n_waiting(self) -> int:
        return self.scheduler.n_waiting

    @property
    def has_work(self) -> bool:
        return self.n_active > 0 or self.n_waiting > 0

    # ------------------------------------------------------------------ #
    # admission + prefill
    # ------------------------------------------------------------------ #
    def _admission_groups(self, placed):
        """Split (slot, req) placements into prefill micro-batches."""
        exact = self.cfg.family in _EXACT_LEN_FAMILIES
        if not exact and self.cfg.sliding_window is not None and placed:
            # SWA ring layout rotates by the PADDED length once it exceeds
            # the window — shorter requests in the pad would land in wrong
            # ring slots, so fall back to exact-length grouping there.
            exact = max(req.prompt.size for _, req in placed) > self.cfg.sliding_window
        if exact:
            by_len: Dict[int, list] = {}
            for slot, req in placed:
                by_len.setdefault(req.prompt.size, []).append((slot, req))
            return list(by_len.values())
        return [placed]

    def _prefill_shape(self, n_reqs: int, max_prompt: int):
        """Bucket the micro-batch shape so live traffic triggers a BOUNDED
        number of prefill compiles: batch rows up to the next power of two
        (capped at n_slots, dummy rows are discarded by the scatter), and —
        for attention families, where last_index makes right-padding exact —
        prompt length up to the next power of two (capped at max_len and at
        the sliding window, past which the ring layout forbids padding)."""
        G = min(_next_pow2(n_reqs, 1), self.n_slots)
        P = max_prompt
        if self.cfg.family not in _EXACT_LEN_FAMILIES:
            cap = self.max_len
            if self.cfg.sliding_window is not None:
                cap = min(cap, self.cfg.sliding_window)
            P = max(max_prompt, min(_next_pow2(max_prompt, 8), cap))
        return G, P

    def _prefill_group(self, group):
        slots = [slot for slot, _ in group]
        reqs = [req for _, req in group]
        lens = np.array([r.prompt.size for r in reqs], np.int32)
        G, P = self._prefill_shape(len(reqs), int(lens.max()))
        toks = np.zeros((G, P), np.int32)
        for i, r in enumerate(reqs):
            toks[i, : r.prompt.size] = r.prompt
        last_index = np.zeros((G,), np.int32)
        last_index[: len(reqs)] = lens - 1
        batch = {"tokens": jnp.asarray(toks)}
        for name in reqs[0].extras:
            rows = [r.extras[name] for r in reqs]
            rows += [np.zeros_like(rows[0])] * (G - len(reqs))
            batch[name] = jnp.asarray(np.stack(rows))

        padded_reqs = reqs + [None] * (G - len(reqs))
        with use_dispatch(self._dcfg):
            logits, part = self._prefill_jit(self.params, batch, jnp.asarray(last_index))
            self.cache = _scatter_slots(self.cache, part, slots, self.n_slots)
            first = self._sample(logits, padded_reqs, [0] * G)

        now = time.perf_counter()
        finished = []
        for i, (slot, req) in enumerate(group):
            self._reqs[slot] = req
            self._pos[slot] = lens[i]
            self._tokens[slot, 0] = first[i]
            req.t_first = now
            req.tokens.append(int(first[i]))
        for slot, _ in group:
            done = self._maybe_finish(slot)
            if done is not None:
                finished.append(done)
        return finished

    # ------------------------------------------------------------------ #
    # sampling / completion
    # ------------------------------------------------------------------ #
    def _sample(self, logits, reqs, token_indices):
        """Sample one token per logits row for the given requests."""
        if all(r is None or r.sampling.temperature == 0 for r in reqs):
            return np.asarray(self._argmax_jit(logits))
        B = logits.shape[0]
        temps = np.zeros((B,), np.float32)
        topks = np.zeros((B,), np.int32)
        salts = np.zeros((B,), np.int32)
        for i, (req, ti) in enumerate(zip(reqs, token_indices)):
            if req is None:
                continue
            temps[i] = req.sampling.temperature
            topks[i] = req.sampling.top_k
            salts[i] = req._salt(ti)
        out = sample_tokens(
            logits,
            self._base_key,
            jnp.asarray(salts),
            jnp.asarray(temps),
            jnp.asarray(topks),
        )
        return np.asarray(out)

    def _maybe_finish(self, slot: int) -> Optional[Request]:
        req = self._reqs[slot]
        if req is None:
            return None
        hit_eos = self.eos_token is not None and req.tokens and req.tokens[-1] == self.eos_token
        if req.done or hit_eos:
            req.t_done = time.perf_counter()
            self._reqs[slot] = None
            self._pos[slot] = 0
            self._tokens[slot, 0] = 0
            self.scheduler.release(slot)
            return req
        return None

    # ------------------------------------------------------------------ #
    # the engine step
    # ------------------------------------------------------------------ #
    def step(self) -> List[Request]:
        """Admit waiting requests, run one shared decode step; returns the
        requests that finished during this step."""
        finished: List[Request] = []

        for group in self._admission_groups(self.scheduler.admit()):
            if group:
                # requests whose single token came from prefill finish here
                finished.extend(self._prefill_group(group))

        active = [s for s in range(self.n_slots) if self._reqs[s] is not None]
        if not active:
            return finished

        with use_dispatch(self._dcfg):
            logits, self.cache = self._decode_jit(
                self.params, self.cache, jnp.asarray(self._tokens), jnp.asarray(self._pos)
            )
            nxt = self._sample(
                logits,
                self._reqs,
                [len(r.tokens) if r is not None else 0 for r in self._reqs],
            )
        self.steps += 1

        for s in active:
            req = self._reqs[s]
            self._pos[s] += 1
            self._tokens[s, 0] = nxt[s]
            req.tokens.append(int(nxt[s]))
            done = self._maybe_finish(s)
            if done is not None:
                finished.append(done)
        return finished

    # ------------------------------------------------------------------ #
    # convenience drain loop
    # ------------------------------------------------------------------ #
    def run(
        self,
        requests: Sequence[Request],
        arrivals: Optional[Sequence[float]] = None,
    ) -> List[Request]:
        """Submit ``requests`` (optionally at wall-clock ``arrivals`` offsets,
        seconds) and step until all complete.  Returns them in finish order."""
        order = sorted(range(len(requests)), key=lambda i: arrivals[i] if arrivals else 0)
        t0 = time.perf_counter()
        pending = list(order)
        finished: List[Request] = []
        while pending or self.has_work:
            now = time.perf_counter() - t0
            while pending and (arrivals is None or arrivals[pending[0]] <= now):
                self.submit(requests[pending[0]])
                pending.pop(0)
            if not self.has_work:
                if pending:  # idle until the next arrival
                    time.sleep(max(0.0, arrivals[pending[0]] - (time.perf_counter() - t0)))
                continue
            finished.extend(self.step())
        return finished
