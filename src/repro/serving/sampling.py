"""Token sampling for the serving engine: greedy, temperature, top-k.

All randomness flows through EXPLICIT PRNG keys: a request's sample stream
is a pure function of (request seed, token index), so a trace replays
bit-identically regardless of how requests were interleaved across engine
steps — the sampling analogue of the synthetic-data determinism contract.

``sample_tokens`` is the vectorized per-slot entry point the engine jits:
each row of the logits batch gets its own (temperature, top_k, salt), so
greedy and stochastic requests coexist in one decode batch.  temperature 0
is EXACT argmax — bit-identical to ``greedy_generate``'s token choice.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

__all__ = ["SamplingParams", "sample_tokens", "token_salts", "SALT_MULT"]

# salt = seed * SALT_MULT + token_index, truncated to the low 31 bits.  The
# host computes this with Python bignums and the fused decode loop with
# wrapping int32 arithmetic: a bitwise AND with 0x7FFFFFFF extracts the low
# 31 bits, which every mod-2^k (k >= 31) representation agrees on, so both
# paths fold the SAME salt into the PRNG and sampled traces replay
# bit-identically whichever loop executed them.
SALT_MULT = 1_000_003


def token_salts(seeds, token_index):
    """Vectorized per-slot salts: (B,) int32 seeds x (B,) int32 token indices."""
    seeds = jnp.asarray(seeds, jnp.int32)
    token_index = jnp.asarray(token_index, jnp.int32)
    return (seeds * jnp.int32(SALT_MULT) + token_index) & jnp.int32(0x7FFFFFFF)


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling configuration.

    temperature: 0.0 => greedy (exact argmax); > 0 => softmax sampling.
    top_k: 0 => full vocabulary; k > 0 => restrict to the k highest logits.
    seed: PRNG seed for this request's sample stream.
    """

    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")


@functools.partial(jax.jit, donate_argnums=())
def sample_tokens(logits, base_key, salts, temperature, top_k):
    """Sample one token per row with per-row sampling params.

    Args:
      logits: (B, V) fp32 next-token logits.
      base_key: PRNG key shared by the engine.
      salts: (B,) int32 per-row fold_in salts — the engine derives them from
        (request seed, token index), so streams are request-deterministic.
      temperature: (B,) fp32; rows with 0 take the argmax.
      top_k: (B,) int32; rows with 0 sample the full vocabulary.

    Returns: (B,) int32 token ids.
    """
    B, V = logits.shape
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    t = jnp.where(temperature > 0, temperature, 1.0).astype(jnp.float32)
    scaled = logits.astype(jnp.float32) / t[:, None]
    # per-row top-k mask via double argsort rank (k differs per row, so
    # lax.top_k's static k doesn't apply)
    ranks = jnp.argsort(jnp.argsort(-scaled, axis=-1), axis=-1)
    k_eff = jnp.where(top_k > 0, top_k, V)
    masked = jnp.where(ranks < k_eff[:, None], scaled, -jnp.inf)

    keys = jax.vmap(lambda s: jax.random.fold_in(base_key, s))(salts)
    sampled = jax.vmap(jax.random.categorical)(keys, masked).astype(jnp.int32)
    return jnp.where(temperature > 0, sampled, greedy)
