"""Replicated serving cluster: N engines, one front door, failover.

A :class:`Cluster` runs N :class:`~repro.serving.engine.Engine` replicas —
each thread-backed with its own paged pool and jitted programs (the CPU
emulation of N accelerator hosts; the mesh machinery in ``sharding/rules``
shards WITHIN a replica, this layer replicates ACROSS them) — behind one
shared admission queue with load-aware routing
(:class:`~repro.serving.scheduler.RoutingPolicy`: least queue depth, then
least pages in use).

Health is heartbeat-based.  Every replica thread beats after each engine
step (and while idle); each engine carries a
:class:`~repro.runtime.fault_tolerance.StepWatchdog`, so the monitor's
per-replica deadline adapts to that replica's OBSERVED step times
(``max(heartbeat, straggler_factor x median, 1.25 x recent max)``) instead
of a fleet-wide constant.  A replica is declared dead when it (a) misses
its deadline (hung device), (b) throws from its step loop (killed
process — :class:`~repro.runtime.fault_tolerance.ReplicaKilled` via the
injector, or a genuine bug), or (c) the watchdog flags a straggler step
above an absolute floor (slow device).

Failover is BIT-EXACT under greedy decoding.  The cluster owns every
request's token stream: each submitted root request is served through
cluster-built SEGMENTS (fresh Request copies), and the tokens a dying
replica already emitted are credited to the root before a new segment —
``prompt = root.prompt + credited tokens``, ``max_new`` reduced — re-enters
the shared queue after capped-exponential backoff
(:class:`~repro.serving.scheduler.FailoverBudget`, jitter salted by the
root uid).  Prefilling the extended prompt rematerializes the lost
KV (the same mechanism engine preemption uses), so the survivor resumes
DETERMINISTICALLY: the resumed tail is bit-identical to what any healthy
engine emits for that continuation — through a prefix match when it
shares cached pages (``prefill_skipped > 0``), through a cold re-prefill
otherwise.  (Bit-exactness is per compute path: prefill-written and
decode-written KV can differ in low-order bits, so a resumed tail may
legitimately diverge from the UNINTERRUPTED replay at an argmax near-tie
— ``resume_points`` records every split so a verifier can replay each
continuation and check the resume exactly.)  A request that exhausts its
budget surfaces a structured ``RejectedOverload(reason="replica_lost")``
instead of vanishing.

A dead-but-recovered replica (hang ended, straggler drained) re-enters
through PROBATION: its thread cooperatively drains the engine
(``take_queue`` + ``export_inflight``, results discarded — the cluster
already owns those streams, the drain just releases slots and pages so
the allocator's invariants hold), beats while parked, and rejoins the
router after ``probation_s`` of clean beats.  A KILLED replica's thread
is gone; :meth:`Cluster.restart_replica` rebuilds its engine from the
factory and walks it through the same probation path.
"""

from __future__ import annotations

import dataclasses
import json
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.analysis import sanitize as _sanitize
from repro.runtime.fault_tolerance import FaultInjector, StepWatchdog
from repro.serving.engine import Engine, Request
from repro.serving.scheduler import FailoverBudget, RejectedOverload, RoutingPolicy

__all__ = ["Cluster", "EventLog"]


class EventLog:
    """Thread-safe JSON-lines event sink (``serve.py --event-log PATH``).

    One line per event: ``{"t_ms": ..., "event": kind, ...fields}``.
    ``sink(**tags)`` returns an ``on_event(kind, fields)`` callable with
    the tags pre-bound — the engine/scheduler hook shape — so every
    replica's events carry its id without the engine knowing about
    replicas.  Never raises into the serving path: a failed write drops
    the event, not the request.
    """

    def __init__(self, path: str):
        self._f = open(path, "a", encoding="utf-8")
        self._lock = threading.Lock()
        self._t0 = time.perf_counter()

    def emit(self, kind: str, fields: Optional[dict] = None) -> None:
        rec: dict = {"t_ms": round((time.perf_counter() - self._t0) * 1e3, 3),
                     "event": kind}
        if fields:
            rec.update(fields)
        try:
            line = json.dumps(rec, default=str)
            with self._lock:
                self._f.write(line + "\n")
                self._f.flush()
        except (OSError, ValueError):
            pass

    def sink(self, **tags) -> Callable[[str, dict], None]:
        def on_event(kind: str, fields: dict) -> None:
            merged = dict(tags)
            merged.update(fields)
            self.emit(kind, merged)

        return on_event

    def close(self) -> None:
        with self._lock:
            try:
                self._f.close()
            except OSError:
                pass


class _Replica:
    """One engine + its stepping thread + health bookkeeping."""

    def __init__(self, rid: int, eng: Engine):
        self.id = rid
        self.eng = eng
        self.thread: Optional[threading.Thread] = None
        self.inbox_lock = threading.Lock()
        self.health_lock = threading.Lock()
        self.inbox: List[Request] = []  # guarded by: inbox_lock
        # Health fields cross the replica-thread/monitor boundary in both
        # directions; everything below health_lock's annotations is
        # single-writer and confined to one side of that boundary.
        self.state_cmd = "run"  # guarded by: health_lock
        self.drained = False  # guarded by: health_lock
        self.step_error: Optional[BaseException] = None  # guarded by: health_lock
        self.last_beat = time.monotonic()  # guarded by: health_lock
        # monitor-thread-confined ("healthy" | "dead" | "probation"):
        # only check_health/_mark_dead/restart_replica transition it
        self.state = "healthy"
        self.step_count = 0  # replica-thread-confined (injector clock)
        self.straggler_seen = 0  # monitor-confined: flags already examined
        self.deaths = 0  # monitor-confined
        self.rejoin_t = 0.0  # monitor-confined

    @property
    def thread_alive(self) -> bool:
        return self.thread is not None and self.thread.is_alive()


class _Tracked:
    """Cluster-side record of one root request's serving state."""

    __slots__ = ("root", "emitted", "attempts", "cur", "replica",
                 "retry_at", "done", "t_first", "tier", "prefix_hit")

    def __init__(self, root: Request):
        self.root = root
        self.emitted: List[int] = []  # tokens credited from prior segments
        self.attempts = 0  # failovers consumed
        self.cur: Optional[Request] = None  # live segment (engine-owned copy)
        self.replica = -1
        self.retry_at = 0.0  # monotonic time the next segment may route
        self.done = False
        self.t_first = 0.0
        self.tier = root.tier
        self.prefix_hit = False  # a resumed segment prefix-matched pages


class Cluster:
    """N engine replicas behind one shared admission queue.

    ``factory(replica_id) -> Engine`` builds each replica's engine (its
    own pool and programs); the cluster attaches a
    :class:`StepWatchdog` and the event sink if the factory did not.
    ``injector`` is shared across replicas — replica-level faults
    (``kill_replica`` / ``hang_replica`` / ``slow_replica``) key on the
    replica id and that replica's LOCAL step counter via
    ``on_replica_step``.
    """

    def __init__(
        self,
        factory: Callable[[int], Engine],
        n_replicas: int,
        *,
        heartbeat_ms: float = 1000.0,
        budget: Optional[FailoverBudget] = None,
        routing: Optional[RoutingPolicy] = None,
        injector: Optional[FaultInjector] = None,
        probation_s: float = 0.25,
        cold_grace_s: float = 30.0,
        straggler_min_s: float = 0.5,
        event_log: Optional[EventLog] = None,
        poll_s: float = 0.002,
    ):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        self._factory = factory
        self.heartbeat_ms = heartbeat_ms
        self.budget = budget if budget is not None else FailoverBudget()
        self.routing = routing if routing is not None else RoutingPolicy()
        self.injector = injector
        self.probation_s = probation_s
        self.cold_grace_s = cold_grace_s
        self.straggler_min_s = straggler_min_s
        self.event_log = event_log
        self._poll_s = poll_s

        self._lock = threading.Lock()
        self._uid = 0  # guarded by: _lock
        self._tracked: List[_Tracked] = []  # guarded by: _lock
        self._by_seg: Dict[int, _Tracked] = {}  # guarded by: _lock
        self._pending: List[_Tracked] = []  # guarded by: _lock
        self._finished: List[Request] = []  # guarded by: _lock
        # one-way lock-free flags: set once by the controlling thread,
        # polled by replica threads (a stale read costs one extra loop)
        self._shutdown = False
        self._draining = False

        # cluster-level accounting (benchmarks/serving.py --trace failover);
        # read live via stats() — raw attribute reads need _lock
        self.failovers = 0  # guarded by: _lock
        self.failovers_prefix_match = 0  # guarded by: _lock
        self.heartbeat_misses = 0  # guarded by: _lock
        self.replica_deaths = 0  # guarded by: _lock
        self.rejoins = 0  # guarded by: _lock
        self.exhausted = 0  # guarded by: _lock
        # uid -> emitted-lengths at each failover, in order: the resume
        # split points a verifier needs to replay each continuation
        self.resume_points: Dict[int, List[int]] = {}  # guarded by: _lock

        self.replicas = [
            _Replica(rid, self._prepare(self._factory(rid), rid))
            for rid in range(n_replicas)
        ]

    # ------------------------------------------------------------------ #
    # wiring
    # ------------------------------------------------------------------ #
    def _prepare(self, eng: Engine, rid: int) -> Engine:
        if eng.watchdog is None:
            eng.watchdog = StepWatchdog()
        if self.event_log is not None and eng.on_event is None:
            sink = self.event_log.sink(replica=rid)
            eng.on_event = sink
            eng.scheduler.on_event = sink
        return eng

    def _log(self, kind: str, **fields) -> None:
        if self.event_log is not None:
            self.event_log.emit(kind, fields)

    def start(self) -> None:
        """Spawn any replica thread not already running."""
        if _sanitize.enabled():
            # arm only while threads run: construction and post-join
            # teardown are single-threaded and intentionally lock-free
            _sanitize.arm(self)
            for rep in self.replicas:
                _sanitize.arm(rep)
        for rep in self.replicas:
            if not rep.thread_alive:
                rep.thread = threading.Thread(
                    target=self._replica_loop, args=(rep,), daemon=True
                )
                rep.thread.start()

    def close(self) -> None:
        """Stop every replica thread (idempotent)."""
        self._shutdown = True
        for rep in self.replicas:
            if rep.thread is not None:
                rep.thread.join(timeout=5.0)
        if _sanitize.enabled():
            _sanitize.disarm(self)
            for rep in self.replicas:
                _sanitize.disarm(rep)

    # ------------------------------------------------------------------ #
    # submission / segments
    # ------------------------------------------------------------------ #
    def submit(self, request: Request) -> Request:
        """Accept one root request; returns it (uid/t_submit assigned).

        The root object is the CLIENT's handle — it is never handed to an
        engine (engines mutate what they serve); segments are fresh
        copies and the root's stream/terminal state is written back by
        the cluster at completion.
        """
        with self._lock:
            request.uid = self._uid
            self._uid += 1
            request.t_submit = time.perf_counter()
            tr = _Tracked(request)
            self._tracked.append(tr)
            self._pending.append(tr)
        return request

    def _make_segment(self, tr: _Tracked) -> Request:
        root = tr.root
        if tr.emitted:
            prompt = np.concatenate(
                [root.prompt, np.asarray(tr.emitted, np.int32)]
            )
        else:
            prompt = root.prompt
        return Request(
            prompt=prompt,
            max_new_tokens=root.max_new_tokens - len(tr.emitted),
            sampling=root.sampling,
            extras=root.extras,
            # a resumed segment already delivered tokens — shedding it on
            # admission latency would discard work (same exemption the
            # engine gives its internal preemption continuations)
            deadline_ms=root.deadline_ms if not tr.emitted else None,
            min_tier=root.min_tier,
            tier=tr.tier,
            priority=root.priority,
        )

    # ------------------------------------------------------------------ #
    # replica thread
    # ------------------------------------------------------------------ #
    def _replica_loop(self, rep: _Replica) -> None:
        eng = rep.eng
        while not self._shutdown:
            with rep.health_lock:
                cmd = rep.state_cmd
                drained = rep.drained
            if cmd == "drain":
                if not drained:
                    with rep.inbox_lock:
                        rep.inbox = []
                    try:
                        # release every slot/page; the cluster owns the
                        # streams, so the drained work is DISCARDED here
                        eng.take_queue()
                        eng.export_inflight()
                    except Exception as e:  # engine too broken to drain
                        with rep.health_lock:
                            rep.step_error = rep.step_error or e
                    with rep.health_lock:
                        rep.drained = True
                    self._log("replica_drained", replica=rep.id,
                              pages_used=eng.pages_in_use if eng.paged else 0)
                with rep.health_lock:
                    rep.last_beat = time.monotonic()
                time.sleep(self._poll_s)
                continue

            with rep.inbox_lock:
                inbox, rep.inbox = rep.inbox, []
            for seg in inbox:
                eng.submit(seg)
            if self._draining:
                for req in eng.shed_queue("shutdown"):
                    self._on_done(rep, req)

            if eng.has_work:
                try:
                    rep.step_count += 1
                    if self.injector is not None:
                        self.injector.on_replica_step(rep.id, rep.step_count)
                    with rep.health_lock:
                        cmd = rep.state_cmd
                    if cmd == "drain":
                        # a hang fault parked us long enough for the
                        # monitor to declare us dead — do NOT step a
                        # replica whose work already failed over
                        continue
                    finished = eng.step()
                except Exception as e:
                    with rep.health_lock:
                        rep.step_error = e
                    return  # thread dies; the monitor declares us dead
                with rep.health_lock:
                    rep.last_beat = time.monotonic()
                for req in finished:
                    self._on_done(rep, req)
            else:
                with rep.health_lock:
                    rep.last_beat = time.monotonic()
                time.sleep(self._poll_s)

    def _on_done(self, rep: _Replica, req: Request) -> None:
        """Replica thread: one segment finished (completed, errored, or
        shed by the engine's own admission layer)."""
        with self._lock:
            tr = self._by_seg.pop(id(req), None)
            if tr is None and req._parent is not None:
                # an engine-internal preemption continuation shed at
                # shutdown surfaces raw; its root is the tracked segment
                tr = self._by_seg.pop(id(req._parent), None)
                if tr is not None:
                    req._parent.status = req.status
                    req._parent.rejected = req.rejected
                    req = req._parent
            if tr is None or tr.done:
                return  # zombie: this segment already failed over
            self._credit(tr, req)
            if req.status == "shed":
                self._finish_root(tr, status="shed",
                                  rejected=req.rejected, t_done=req.t_done)
            else:
                if tr.attempts > 0:
                    self._log("failover_resumed", uid=tr.root.uid,
                              replica=rep.id, attempt=tr.attempts,
                              prefix_match=req.prefill_skipped > 0)
                self._finish_root(tr, status=req.status, error=req.error,
                                  certificate=req.certificate,
                                  t_done=req.t_done)

    def _credit(self, tr: _Tracked, seg: Request) -> None:
        """Fold a segment's delivered tokens/metadata into the record
        (lock held)."""
        tr.emitted.extend(seg.tokens)
        tr.tier = max(tr.tier, seg.tier)
        if seg.t_first and not tr.t_first:
            tr.t_first = seg.t_first
        if tr.attempts > 0 and seg.prefill_skipped > 0 and not tr.prefix_hit:
            tr.prefix_hit = True
            self.failovers_prefix_match += 1

    def _finish_root(self, tr: _Tracked, *, status: str,
                     rejected: Optional[RejectedOverload] = None,
                     error: Optional[str] = None,
                     certificate=None, t_done: Optional[float] = None) -> None:
        """Write the record back onto the client's root object (lock held)."""
        root = tr.root
        root.tokens[:] = tr.emitted
        root.status = status
        root.error = error
        root.tier = tr.tier
        if certificate is not None:
            root.certificate = certificate
        if rejected is not None:
            root.rejected = dataclasses.replace(rejected, uid=root.uid)
        if tr.t_first:
            root.t_first = tr.t_first
        root.t_done = t_done if t_done else time.perf_counter()
        tr.done = True
        tr.cur = None
        self._finished.append(root)

    # ------------------------------------------------------------------ #
    # monitor: routing + health (main thread)
    # ------------------------------------------------------------------ #
    def _healthy(self) -> List[_Replica]:
        return [r for r in self.replicas if r.state == "healthy"]

    def _route_due(self) -> None:
        now = time.monotonic()
        healthy = self._healthy()
        if not healthy:
            # nothing to route to; pending work waits for a probation
            # rejoin/restart, or check_health sheds it when every replica
            # is dead for good
            return
        by_id = {r.id: r for r in healthy}
        while True:
            with self._lock:
                idx = next(
                    (i for i, tr in enumerate(self._pending)
                     if tr.retry_at <= now),
                    None,
                )
                if idx is None:
                    return
                tr = self._pending.pop(idx)
                seg = self._make_segment(tr)
                loads = []
                for r in healthy:
                    # the replica thread swaps its inbox concurrently; an
                    # unlocked len() here raced that swap (flagged by the
                    # lock-discipline pass, pinned in test_cluster)
                    with r.inbox_lock:
                        depth = len(r.inbox)
                    loads.append((
                        r.id,
                        depth + r.eng.n_waiting,
                        r.eng.pages_in_use if r.eng.paged else r.eng.n_active,
                    ))
                rid = self.routing.pick(loads)
                tr.cur = seg
                tr.replica = rid
                self._by_seg[id(seg)] = tr
            rep = by_id[rid]
            with rep.inbox_lock:
                rep.inbox.append(seg)

    def _deadline_s(self, rep: _Replica) -> float:
        base = self.heartbeat_ms / 1e3
        wd = rep.eng.watchdog
        if wd is None or not wd.durations:
            # cold replica: jitted programs may still be compiling —
            # don't declare death on XLA's first-trace latency
            return max(base, self.cold_grace_s)
        recent = wd.durations[-wd.window:]
        return max(base, wd.straggler_factor * wd.median, 1.25 * max(recent))

    def check_health(self) -> None:
        """One monitor pass: detect deaths, walk recoveries through
        probation back to healthy.  Called from the run loop; callable
        directly by tests driving the cluster manually."""
        now = time.monotonic()
        for rep in self.replicas:
            # snapshot the thread-shared health fields once, then decide
            with rep.health_lock:
                err = rep.step_error
                beat = rep.last_beat
                drained = rep.drained
            if rep.state == "healthy":
                reason = None
                if err is not None:
                    reason = f"step-error:{type(err).__name__}"
                elif now - beat > self._deadline_s(rep):
                    with self._lock:
                        self.heartbeat_misses += 1
                    reason = "heartbeat-miss"
                else:
                    flags = rep.eng.straggler_flags
                    if flags > rep.straggler_seen:
                        rep.straggler_seen = flags
                        wd = rep.eng.watchdog
                        if wd is not None and wd.durations and (
                            wd.durations[-1] > self.straggler_min_s
                        ):
                            reason = "straggler"
                if reason is not None:
                    self._mark_dead(rep, reason)
            elif rep.state == "dead":
                if rep.thread_alive and drained and err is None and (
                    now - beat <= self._deadline_s(rep)
                ):
                    rep.state = "probation"
                    rep.rejoin_t = now + self.probation_s
                    self._log("replica_probation", replica=rep.id)
            elif rep.state == "probation":
                if now >= rep.rejoin_t:
                    rep.state = "healthy"
                    rep.straggler_seen = rep.eng.straggler_flags
                    with rep.health_lock:
                        rep.state_cmd = "run"
                        rep.last_beat = now
                    with self._lock:
                        self.rejoins += 1
                    self._log("replica_rejoin", replica=rep.id)
        if not any(r.state != "dead" for r in self.replicas):
            self._shed_all("replica_lost")

    def _mark_dead(self, rep: _Replica, reason: str) -> None:
        rep.state = "dead"
        with rep.health_lock:
            rep.state_cmd = "drain"
            rep.drained = False
        rep.deaths += 1
        self._log("replica_dead", replica=rep.id, reason=reason)
        now = time.monotonic()
        with self._lock:
            self.replica_deaths += 1
            victims = [
                (key, tr) for key, tr in self._by_seg.items()
                if tr.replica == rep.id
            ]
            for key, tr in victims:
                del self._by_seg[key]
                self._fail_over(tr, reason, now)

    def _fail_over(self, tr: _Tracked, reason: str, now: float) -> None:
        """Credit the dying segment's tokens and re-enqueue or reject
        (lock held)."""
        seg = tr.cur
        if seg is not None:
            # list() under the GIL: the replica thread appends tokens but
            # never removes, so a snapshot is always a valid prefix
            self._credit(tr, seg)
        tr.cur = None
        tr.replica = -1
        root = tr.root
        if len(tr.emitted) >= root.max_new_tokens:
            # the replica died BETWEEN the last token and its completion
            # bookkeeping — everything was delivered, so finish, not retry
            self._finish_root(tr, status="ok")
            return
        if tr.attempts >= self.budget.max_failovers:
            self.exhausted += 1
            pc = time.perf_counter()
            self._finish_root(
                tr,
                status="shed",
                rejected=RejectedOverload(
                    uid=root.uid,
                    reason="replica_lost",
                    waited_ms=(pc - root.t_submit) * 1e3,
                    queue_depth=len(self._pending),
                    deadline_ms=root.deadline_ms,
                ),
                t_done=pc,
            )
            self._log("failover_exhausted", uid=root.uid,
                      attempts=tr.attempts, emitted=len(tr.emitted))
            return
        delay_ms = self.budget.backoff_ms(tr.attempts, salt=root.uid)
        tr.attempts += 1
        tr.retry_at = now + delay_ms / 1e3
        self.failovers += 1
        self.resume_points.setdefault(root.uid, []).append(len(tr.emitted))
        self._pending.append(tr)
        self._log("failover", uid=root.uid, attempt=tr.attempts,
                  emitted=len(tr.emitted), backoff_ms=round(delay_ms, 3),
                  reason=reason)

    def _shed_all(self, reason: str) -> None:
        """Every replica is dead: fail what is open rather than hang."""
        with self._lock:
            open_now = [tr for tr in self._tracked if not tr.done]
            self._pending = []
            self._by_seg.clear()
            pc = time.perf_counter()
            for tr in open_now:
                if tr.cur is not None:
                    self._credit(tr, tr.cur)
                    tr.cur = None
                self.exhausted += 1
                self._finish_root(
                    tr,
                    status="shed",
                    rejected=RejectedOverload(
                        uid=tr.root.uid,
                        reason=reason,
                        waited_ms=(pc - tr.root.t_submit) * 1e3,
                        queue_depth=0,
                        deadline_ms=tr.root.deadline_ms,
                    ),
                    t_done=pc,
                )

    def restart_replica(self, rid: int) -> None:
        """Rebuild a KILLED replica (thread dead) from the factory and
        re-enter it through the probation path."""
        rep = self.replicas[rid]
        if rep.thread_alive:
            raise RuntimeError(f"replica {rid} thread is still alive")
        rep.eng = self._prepare(self._factory(rid), rid)
        rep.step_count = 0
        rep.straggler_seen = 0
        rep.state = "dead"
        with rep.health_lock:
            rep.step_error = None
            rep.state_cmd = "drain"
            rep.drained = True  # fresh engine holds nothing to drain
            rep.last_beat = time.monotonic()
        with rep.inbox_lock:
            rep.inbox = []
        rep.thread = threading.Thread(
            target=self._replica_loop, args=(rep,), daemon=True
        )
        rep.thread.start()
        self._log("replica_restart", replica=rid)

    # ------------------------------------------------------------------ #
    # drive loop
    # ------------------------------------------------------------------ #
    @property
    def n_open(self) -> int:
        with self._lock:
            return sum(1 for tr in self._tracked if not tr.done)

    def stats(self) -> Dict[str, object]:
        """Locked snapshot of the failover accounting — the safe way to
        read the counters while replica threads are live (raw attribute
        reads are flagged by the lock-discipline pass / sanitizer)."""
        with self._lock:
            return {
                "failovers": self.failovers,
                "failovers_prefix_match": self.failovers_prefix_match,
                "heartbeat_misses": self.heartbeat_misses,
                "replica_deaths": self.replica_deaths,
                "rejoins": self.rejoins,
                "exhausted": self.exhausted,
                "resume_points": {
                    uid: list(pts) for uid, pts in self.resume_points.items()
                },
            }

    def run(
        self,
        requests: Sequence[Request],
        arrivals: Optional[Sequence[float]] = None,
        *,
        stop: Optional[Callable[[], bool]] = None,
        timeout_s: Optional[float] = None,
    ) -> List[Request]:
        """Submit ``requests`` (optionally at ``arrivals`` offsets) and
        route/monitor until every root completes; returns roots in finish
        order.  Mirrors ``Engine.run``'s contract, including graceful
        shutdown: the first ``stop() == True`` drops unsubmitted
        requests, sheds the shared queue with ``"shutdown"`` rejections,
        and lets in-flight segments decode to completion.  ``timeout_s``
        is a test guard — expiry sheds everything open and returns
        (a wedged cluster fails an assertion instead of hanging CI).
        """
        self.start()
        order = sorted(
            range(len(requests)), key=lambda i: arrivals[i] if arrivals else 0
        )
        pending = list(order)
        t0 = time.perf_counter()
        while True:
            now_rel = time.perf_counter() - t0
            if timeout_s is not None and now_rel > timeout_s:
                self._shed_all("cluster_timeout")
                break
            if stop is not None and stop():
                pending.clear()
                self._begin_drain()
                stop = None
            while pending and (
                arrivals is None or arrivals[pending[0]] <= now_rel
            ):
                self.submit(requests[pending[0]])
                pending.pop(0)
            self._route_due()
            self.check_health()
            if not pending and self.n_open == 0:
                break
            time.sleep(self._poll_s)
        with self._lock:
            out, self._finished = self._finished, []
        return out

    def _begin_drain(self) -> None:
        """Graceful shutdown: shed everything not yet on a replica; the
        replica threads shed their engine queues and finish in-flight."""
        self._draining = True
        with self._lock:
            waiting, self._pending = self._pending, []
            pc = time.perf_counter()
            for tr in waiting:
                self._finish_root(
                    tr,
                    status="shed",
                    rejected=RejectedOverload(
                        uid=tr.root.uid,
                        reason="shutdown",
                        waited_ms=(pc - tr.root.t_submit) * 1e3,
                        queue_depth=len(waiting),
                        deadline_ms=tr.root.deadline_ms,
                    ),
                    t_done=pc,
                )


# Under REPRO_SANITIZE=1 the `# guarded by:` annotations above become
# runtime descriptors asserting lock ownership on every access (no-op and
# zero-overhead otherwise).
_sanitize.maybe_install(Cluster, _Replica)
