"""Slot + page allocation and request scheduling for the serving engine.

The engine owns a fixed pool of ``n_slots`` cache slots (rows of the batched
decode cache).  Requests queue FIFO; whenever a slot frees up, the scheduler
admits the oldest waiting request.  Slot exhaustion therefore QUEUES work —
it never errors — and freed slots are recycled immediately, which is what
keeps the decode batch full under sustained traffic.

Paged mode adds a :class:`PageAllocator` over the engine's physical KV page
pool: admission is then gated on PAGES, not slots — a request is admitted
only when its actual need (``ceil((prompt + max_new) / page_size)`` pages,
reserved up front so decode can never strand mid-stream) fits the free
list, so total admitted concurrency tracks real footprints instead of
``n_slots`` worst-case reservations.  Page exhaustion queues exactly like
slot exhaustion; admission stays strictly FIFO (a large request at the head
waits rather than being bypassed — deterministic traces over throughput
tricks).

Pure host-side bookkeeping: no jax imports, trivially unit-testable
(tests/test_scheduler.py).
"""

from __future__ import annotations

import collections
from typing import Callable, Deque, List, Optional, Tuple

__all__ = ["SlotAllocator", "PageAllocator", "Scheduler"]


class SlotAllocator:
    """Free-list allocator over ``n_slots`` cache slots.

    ``alloc`` returns the lowest free slot id (deterministic reuse order —
    important for reproducible traces) or None when exhausted.
    """

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.n_slots = n_slots
        self._free = list(range(n_slots - 1, -1, -1))  # stack, lowest id on top
        self._active = [False] * n_slots

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self._free)

    def is_active(self, slot: int) -> bool:
        return self._active[slot]

    def alloc(self) -> Optional[int]:
        if not self._free:
            return None
        slot = self._free.pop()
        self._active[slot] = True
        return slot

    def free(self, slot: int) -> None:
        if not (0 <= slot < self.n_slots):
            raise ValueError(f"slot {slot} out of range [0, {self.n_slots})")
        if not self._active[slot]:
            raise ValueError(f"double free of slot {slot}")
        self._active[slot] = False
        # keep the free list sorted so reuse order stays deterministic
        self._free.append(slot)
        self._free.sort(reverse=True)


class PageAllocator:
    """Free-list allocator over ``n_pages`` fixed-size KV-cache pages.

    ``alloc(n)`` is ALL-OR-NOTHING: it returns the ``n`` lowest free page
    ids (deterministic reuse order, mirroring :class:`SlotAllocator`) or
    None — never a partial grant, so a request can never be admitted into a
    half-backed cache.  Pages are unit-sized, so the pool cannot fragment:
    any ``n <= n_free`` request succeeds, and ``free`` reclaims a slot's
    whole page set at once.  ``extend`` grows an existing allocation with
    the same all-or-nothing contract.
    """

    def __init__(self, n_pages: int):
        if n_pages < 0:
            raise ValueError(f"n_pages must be >= 0, got {n_pages}")
        self.n_pages = n_pages
        self._free = list(range(n_pages - 1, -1, -1))  # stack, lowest id on top
        self._owned = [False] * n_pages

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return self.n_pages - len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        if n < 0:
            raise ValueError(f"cannot alloc {n} pages")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._owned[p] = True
        return pages

    def extend(self, pages: List[int], n: int) -> Optional[List[int]]:
        """Grow an allocation in place by ``n`` pages (all-or-nothing).

        The engine's current admission policy reserves a request's whole
        footprint up front (no mid-stream growth, hence no preemption), so
        today only tests exercise this; it is the hook an incremental
        reservation policy (grow per decode block, preempt on failure)
        would build on.
        """
        more = self.alloc(n)
        if more is None:
            return None
        pages.extend(more)
        return pages

    def free(self, pages: List[int]) -> None:
        for p in pages:
            if not (0 <= p < self.n_pages):
                raise ValueError(f"page {p} out of range [0, {self.n_pages})")
            if not self._owned[p]:
                raise ValueError(f"double free of page {p}")
        for p in pages:
            self._owned[p] = False
            self._free.append(p)
        self._free.sort(reverse=True)  # deterministic reuse order


class Scheduler:
    """FIFO admission control on top of a :class:`SlotAllocator`.

    ``enqueue`` never blocks; ``admit`` drains the queue into free slots and
    returns the (slot, request) placements made this round.

    With ``pages``/``page_need`` (paged engine), admission additionally
    reserves each request's page set up front — both resources or neither —
    and ``release`` returns pages with the slot.  ``slot_pages[slot]`` holds
    the admitted request's page ids (the engine writes them into its block
    table).
    """

    def __init__(
        self,
        allocator: SlotAllocator,
        *,
        pages: Optional[PageAllocator] = None,
        page_need: Optional[Callable[[object], int]] = None,
    ):
        if (pages is None) != (page_need is None):
            raise ValueError("pages and page_need come together")
        self.allocator = allocator
        self.pages = pages
        self.page_need = page_need
        self.slot_pages: dict = {}
        self.queue: Deque = collections.deque()

    @property
    def n_waiting(self) -> int:
        return len(self.queue)

    def enqueue(self, request) -> None:
        self.queue.append(request)

    def admit(self) -> List[Tuple[int, object]]:
        placed = []
        while self.queue and self.allocator.n_free:
            if self.pages is not None:
                pg = self.pages.alloc(self.page_need(self.queue[0]))
                if pg is None:  # page exhaustion queues; strict FIFO
                    break
                slot = self.allocator.alloc()
                self.slot_pages[slot] = pg
            else:
                slot = self.allocator.alloc()
            placed.append((slot, self.queue.popleft()))
        return placed

    def release(self, slot: int) -> None:
        if self.pages is not None:
            self.pages.free(self.slot_pages.pop(slot))
        self.allocator.free(slot)
