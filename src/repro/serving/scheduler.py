"""Slot + page allocation and request scheduling for the serving engine.

The engine owns a fixed pool of ``n_slots`` cache slots (rows of the batched
decode cache).  Requests queue FIFO; whenever a slot frees up, the scheduler
admits the oldest waiting request.  Slot exhaustion therefore QUEUES work —
it never errors — and freed slots are recycled immediately, which is what
keeps the decode batch full under sustained traffic.

Paged mode adds a :class:`PageAllocator` over the engine's physical KV page
pool: admission is then gated on PAGES, not slots — a request is admitted
only when its actual need (``ceil((prompt + max_new) / page_size)`` pages,
reserved up front so decode can never strand mid-stream) fits the free
list, so total admitted concurrency tracks real footprints instead of
``n_slots`` worst-case reservations.  Page exhaustion queues exactly like
slot exhaustion; admission stays strictly FIFO (a large request at the head
waits rather than being bypassed — deterministic traces over throughput
tricks).

The allocator is REFCOUNTED: one physical page may back several slots'
block tables at once (shared prompt-prefix pages — see
:class:`PrefixIndex` and the engine's copy-on-write admission path).
``alloc`` grants fresh pages at refcount 1, ``acquire`` adds a reader (or
revives a cached, refcount-0 page off the free list with its contents
intact), and ``free`` decrements — a page returns to the free list only
when its LAST reader releases it.  Refcounting also structurally closes
the boolean-owned allocator's duplicate-free bug: a single ``free`` call
rejects duplicate ids before mutating anything, so a page can never be
pushed onto the free list twice and later granted to two slots (silent KV
aliasing).

The allocator additionally owns the WARM-CACHE eviction policy: pages
whose content is indexed (``mark_indexed``) become LRU-ordered cache
entries when their last reader releases them.  ``alloc`` grants clean
(unindexed) free pages first and only then EVICTS cached pages —
least-recently-used first, announced through ``on_evict`` so the owner
drops the matching :class:`PrefixIndex` keys in the same operation (a
``match`` can therefore never hit a page after a writer re-granted it).
``cache_budget`` caps how many refcount-0 pages stay matchable; the
excess is evicted eagerly, again LRU-first.  This replaces the PR-5
behavior where cached entries were dropped only when a writer happened
to re-grant the page (lowest-id-first, i.e. the warm cache decayed in an
order unrelated to its value).

Pure host-side bookkeeping: no jax imports, trivially unit-testable
(tests/test_scheduler.py).
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
import time
from typing import Callable, Deque, Iterator, List, Optional, Tuple

import numpy as np

__all__ = [
    "SlotAllocator",
    "PageAllocator",
    "PageGrant",
    "PrefixIndex",
    "Scheduler",
    "AdmissionPolicy",
    "RejectedOverload",
    "RoutingPolicy",
    "FailoverBudget",
]


class SlotAllocator:
    """Free-list allocator over ``n_slots`` cache slots.

    ``alloc`` returns the lowest free slot id (deterministic reuse order —
    important for reproducible traces) or None when exhausted.
    """

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.n_slots = n_slots
        self._free = list(range(n_slots - 1, -1, -1))  # stack, lowest id on top
        self._active = [False] * n_slots

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self._free)

    def is_active(self, slot: int) -> bool:
        return self._active[slot]

    def alloc(self) -> Optional[int]:
        if not self._free:
            return None
        slot = self._free.pop()
        self._active[slot] = True
        return slot

    def free(self, slot: int) -> None:
        if not (0 <= slot < self.n_slots):
            raise ValueError(f"slot {slot} out of range [0, {self.n_slots})")
        if not self._active[slot]:
            raise ValueError(f"double free of slot {slot}")
        self._active[slot] = False
        # keep the free list sorted so reuse order stays deterministic
        self._free.append(slot)
        self._free.sort(reverse=True)


class PageAllocator:
    """Refcounted allocator over ``n_pages`` fixed-size KV-cache pages.

    ``alloc(n)`` is ALL-OR-NOTHING: it returns the ``n`` lowest free page
    ids at refcount 1 (deterministic reuse order, mirroring
    :class:`SlotAllocator`) or None — never a partial grant, so a request
    can never be admitted into a half-backed cache.  Pages are unit-sized,
    so the pool cannot fragment: any ``n <= n_free`` request succeeds.
    ``extend`` grows an existing allocation with the same all-or-nothing
    contract.

    ``acquire(p)`` adds one READER to page ``p``: a live page
    (refcount >= 1) gets one more reference; a cached page (refcount 0 —
    back on the free list, contents still intact because only a fresh
    ``alloc`` hands a page to a writer) is revived off the free list to
    refcount 1.  This is the substrate for shared prompt-prefix pages: a
    shared page is counted ONCE in ``n_used`` no matter how many block
    tables map it.

    ``free`` DECREMENTS: a page returns to the free list only when its
    last reader releases it.  A single call validates the WHOLE list —
    range, liveness, and no duplicate ids — before mutating anything.
    (The boolean-owned predecessor also validated before mutating, but
    had no duplicate check: ``free([p, p])`` passed ownership twice and
    pushed ``p`` onto the free list twice, so a later ``alloc`` granted
    the same physical page to two slots — silent KV aliasing.)

    ``peak_used`` is the allocator-owned high-water mark, raised inside
    the only two operations that can grow usage (``alloc`` / ``acquire``)
    — so peaks are observed no matter which engine path allocated
    (admission, chunked prefill, COW fork), rather than being sampled on
    one engine code path.  ``reset_peak`` re-arms it to CURRENT usage,
    not zero: pages held across a counter reset stay observed.

    WARM CACHE.  ``mark_indexed(pages)`` declares that a page's contents
    are keyed in a content index (:class:`PrefixIndex`); when such a
    page's last reader releases it, it becomes a CACHED entry — still on
    the free list, contents intact, tracked in LRU order.  ``alloc``
    then prefers clean (never-indexed) free pages and only EVICTS cached
    entries when the clean supply runs out, least-recently-used first;
    every eviction is announced through ``on_evict`` before the page is
    handed to the writer, so index keys and list entries die together
    and a later ``match`` can never alias rewritten storage.  Recency is
    CHAIN-AWARE: pages listed earlier in a ``free``/``mark_indexed``
    call are cached as more recent than later ones (callers pass
    block-table order, and a chained prefix index loses everything below
    a missing page — evicting a chain's deep tail costs a few matched
    pages, evicting its head costs the whole chain).
    ``cache_budget`` (None = unbounded) caps the number of resident
    cached entries; the excess is evicted eagerly on release.  The
    invariant the engine relies on: an indexed page at refcount 0 is
    ALWAYS a cached entry, so a page can never leave the index's control
    silently.  With ``mark_indexed`` never called the allocator behaves
    exactly like the PR-5 one (pure lowest-id-first reuse).
    """

    def __init__(
        self,
        n_pages: int,
        *,
        cache_budget: Optional[int] = None,
        on_evict: Optional[Callable[[List[int]], None]] = None,
    ):
        if n_pages < 0:
            raise ValueError(f"n_pages must be >= 0, got {n_pages}")
        if cache_budget is not None and cache_budget < 0:
            raise ValueError(f"cache_budget must be >= 0, got {cache_budget}")
        self.n_pages = n_pages
        self.cache_budget = cache_budget
        self.on_evict = on_evict
        # THREAD CONFINEMENT: every field below is owned by the engine
        # thread that drives step()/admit(); nothing here is read across
        # threads (the cluster monitor only polls derived counts via
        # Engine properties, documented there).  If allocator state ever
        # crosses a thread boundary, add a lock and `# guarded by:`
        # annotations so the lock-discipline pass + sanitizer cover it.
        self._free = list(range(n_pages - 1, -1, -1))  # stack, lowest id on top
        self._ref = [0] * n_pages
        self._peak = 0
        # LRU-ordered cached pages (ref 0, contents indexed): oldest first
        self._cached: "collections.OrderedDict[int, None]" = collections.OrderedDict()
        self._indexed: set = set()  # pages whose contents are index-keyed
        # pages-saved accounting: how many times each indexed page was
        # re-acquired through a prefix match (each hit is one page of
        # prefill the warm cache saved).  Eviction uses it as a COST-AWARE
        # weight on the LRU order: hot chains (system prompts re-matched
        # every admission) outlive cold ones even when less recent.
        self._hits: dict = {}
        self.evictions = 0

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return self.n_pages - len(self._free)

    @property
    def n_cached(self) -> int:
        return len(self._cached)

    @property
    def peak_used(self) -> int:
        return self._peak

    def refcount(self, page: int) -> int:
        return self._ref[page]

    def reset_peak(self) -> None:
        self._peak = self.n_used

    def rollback_peak(self, peak: int) -> None:
        """Restore a pre-transaction high-water mark after an all-or-nothing
        reservation FAILED and every reference it took was rolled back.

        Without this, a reservation that acquires k shared pages and then
        fails its tail alloc would leave ``peak_used`` inflated by pages
        that never backed any admitted work — and the head-of-queue retry
        in the scheduler re-runs that transaction every step.  Only valid
        when usage is actually back to (or below) the saved mark.
        """
        if not (self.n_used <= peak <= self._peak):
            raise ValueError(
                f"rollback_peak({peak}) with n_used={self.n_used}, "
                f"peak_used={self._peak}: references were not rolled back"
            )
        self._peak = peak

    def mark_indexed(self, pages) -> None:
        """Declare that ``pages`` back content-index entries.

        A marked page that is (or later falls to) refcount 0 becomes a
        cached entry instead of an anonymous free page: ``alloc`` skips
        it while clean pages remain and announces its eviction through
        ``on_evict`` when it finally is re-granted.  Idempotent; marking
        an already-cached page refreshes its LRU recency.
        """
        pages = [int(p) for p in pages]
        for p in pages:
            if not (0 <= p < self.n_pages):
                raise ValueError(f"page {p} out of range [0, {self.n_pages})")
            self._indexed.add(p)
        # reverse order: see free() — earlier-listed pages outlive later ones
        for p in reversed(pages):
            if self._ref[p] == 0:
                self._cached.pop(p, None)
                self._cached[p] = None  # most-recently-used position
        self._enforce_budget()

    def flush_cache(self) -> None:
        """Forget every cached/indexed page WITHOUT counting evictions.

        For owner-initiated index resets (``Engine.reset_prefix_cache``):
        the owner clears its index itself, so no ``on_evict`` callback
        fires and the eviction counter stays a policy-pressure metric.
        """
        self._cached.clear()
        self._indexed.clear()
        self._hits.clear()

    def drop_cached(self, pages) -> int:
        """Explicitly forget specific cached/indexed pages (session close).

        The pages are already on the free list (refcount 0) — they simply
        stop being matchable and become clean free pages, reusable by the
        next writer with no eviction work.  Pages still referenced just
        lose their indexed mark (on release they free plain, not cached).
        No ``on_evict`` fires — the caller is the index owner and drops
        its own keys — and no eviction is counted (this is an explicit
        close, not cache pressure).  Returns how many cached entries died.
        """
        n = 0
        for p in pages:
            p = int(p)
            self._indexed.discard(p)
            self._hits.pop(p, None)
            if p in self._cached:
                del self._cached[p]
                n += 1
        return n

    def _evict_victim(self) -> int:
        """Pick + remove the next cached page to evict.

        COST-AWARE LRU: the victim is the cached page with the FEWEST
        rematch hits (pages historically saved by keeping it), oldest
        first within a hit count.  With no hits recorded this degrades to
        exact LRU (insertion order), the PR-7 policy.
        """
        victim = None
        best = None
        for p in self._cached:  # insertion order == LRU order
            score = self._hits.get(p, 0)
            if score == 0:
                victim = p  # oldest never-rematched page: cannot do better
                break
            if best is None or score < best:
                victim, best = p, score
        del self._cached[victim]
        self._indexed.discard(victim)
        self._hits.pop(victim, None)
        return victim

    def _enforce_budget(self) -> None:
        """Evict cached entries beyond ``cache_budget`` (stay on free list)."""
        if self.cache_budget is None:
            return
        evicted = []
        while len(self._cached) > self.cache_budget:
            evicted.append(self._evict_victim())
        if evicted:
            self.evictions += len(evicted)
            if self.on_evict is not None:
                self.on_evict(evicted)

    def alloc(self, n: int) -> Optional[List[int]]:
        if n < 0:
            raise ValueError(f"cannot alloc {n} pages")
        if n > len(self._free):
            return None
        if not self._cached or n == 0:
            pages = [self._free.pop() for _ in range(n)]
        else:
            # clean-first: spend never-indexed free pages (lowest id first)
            # before evicting warm-cache entries, LRU first
            clean = sorted(p for p in self._free if p not in self._cached)
            pages = clean[:n]
            evicted = []
            while len(pages) < n:
                page = self._evict_victim()  # cost-aware LRU
                evicted.append(page)
                pages.append(page)
            if evicted:
                self.evictions += len(evicted)
                if self.on_evict is not None:
                    # index keys die BEFORE the writer sees the page
                    self.on_evict(list(evicted))
            granted = set(pages)
            self._free = [p for p in self._free if p not in granted]
        for p in pages:
            self._ref[p] = 1
        self._peak = max(self._peak, self.n_used)
        return pages

    def acquire(self, page: int) -> bool:
        """Add a reader to ``page`` (share a live page / revive a cached one)."""
        if not (0 <= page < self.n_pages):
            return False
        if self._ref[page] == 0:
            # cached page: still on the free list, contents intact — revive
            try:
                self._free.remove(page)
            except ValueError:  # not free and not referenced: cannot happen
                return False
            self._ref[page] = 1
            self._cached.pop(page, None)  # live again; re-cached on release
            self._peak = max(self._peak, self.n_used)
        else:
            self._ref[page] += 1
        return True

    def record_saved(self, pages) -> None:
        """Credit one rematch hit per page: each was mapped instead of
        re-prefilled by an ADMITTED reservation (callers must not credit
        rolled-back transactions — a starved head-of-queue retry re-acquires
        its matches every step and would pump the weights for free).  The
        hit count is the cost-aware weight ``_evict_victim`` keeps hot
        chains resident by."""
        for p in pages:
            p = int(p)
            if p in self._indexed:
                self._hits[p] = self._hits.get(p, 0) + 1

    def extend(self, pages: List[int], n: int) -> Optional[List[int]]:
        """Grow an allocation in place by ``n`` pages (all-or-nothing).

        The engine's current admission policy reserves a request's whole
        footprint up front (no mid-stream growth, hence no preemption), so
        today only tests exercise this; it is the hook an incremental
        reservation policy (grow per decode block, preempt on failure)
        would build on.
        """
        more = self.alloc(n)
        if more is None:
            return None
        pages.extend(more)
        return pages

    def free(self, pages: List[int]) -> None:
        """Release one reference on every page in ``pages``.

        Validates the whole list BEFORE mutating — including rejecting
        duplicate ids within the call, which is what makes the
        validate-then-mutate order safe (see class docstring).
        """
        seen = set()
        for p in pages:
            if not (0 <= p < self.n_pages):
                raise ValueError(f"page {p} out of range [0, {self.n_pages})")
            if p in seen:
                raise ValueError(f"duplicate page {p} in free()")
            seen.add(p)
            if self._ref[p] < 1:
                raise ValueError(f"double free of page {p}")
        for p in pages:
            self._ref[p] -= 1
            if self._ref[p] == 0:
                self._free.append(p)
        # Re-cache in REVERSE list order, so earlier-listed pages end up
        # more recently used and outlive later ones.  Callers list pages
        # in block-table (chain) order, and the chained prefix index
        # loses every page BELOW a missing link — a chain's deep tail is
        # always the cheaper eviction, its head the costlier one.
        for p in reversed(pages):
            if self._ref[p] == 0 and p in self._indexed:
                # last reader gone, contents indexed: warm-cache entry
                self._cached.pop(p, None)
                self._cached[p] = None  # most-recently-used position
        self._free.sort(reverse=True)  # deterministic reuse order
        self._enforce_budget()


@dataclasses.dataclass
class PageGrant:
    """One admitted request's page reservation (the reserve-hook currency).

    ``pages`` — the slot's block-table entries in logical order (length ==
    the request's page need).  The leading ``n_shared`` entries are
    READ-ONLY shared prefix pages (refcounted; possibly backing other
    slots too) — the engine never writes through them.  ``start`` — first
    prompt position the engine must still prefill (0 when nothing was
    shared; the matched prefix's K/V is already resident).  ``cow`` —
    optional ``(src, dst)`` physical pair: the engine must copy page
    ``src`` onto ``dst`` BEFORE any write lands in ``dst`` (the
    copy-on-write fork of the last prefix page, taken when the tail
    re-enters a matched page).  ``refs`` — every page id holding one of
    this grant's allocator references, freed together on release:
    ``pages`` plus the COW source, whose content must stay pinned at
    least until the fork copy has run.

    An EMPTY grant (``pages == []``) is a real admission — zero-page
    archs (mamba state, SWA rings: nothing paged) reserve nothing but
    still occupy a slot.  Exhaustion is signalled by ``reserve`` returning
    ``None``, never by emptiness.
    """

    pages: List[int]
    n_shared: int = 0
    start: int = 0
    cow: Optional[Tuple[int, int]] = None
    refs: Optional[List[int]] = None

    def __post_init__(self):
        if self.refs is None:
            self.refs = list(self.pages)


class PrefixIndex:
    """Content index of FULL prompt-prefix pages for cross-request sharing.

    A page's K/V depends on every token at or before it, so the key for
    page ``i`` is the ENTIRE token prefix it closes over —
    ``prompt[: (i + 1) * page_size]`` — not just the page's own tokens.
    ``match`` walks the longest chain of indexed full pages from the
    prompt's head; only pages fully covered by the prompt participate
    (a partial last page is never indexed: its storage still gets written
    by the owner's decode stream).

    Entries PERSIST after the owning request releases its pages: a
    refcount-0 page sits on the allocator free list with contents intact
    — a warm prefix cache.  Lifetime is now allocator-driven: the engine
    marks every registered page via ``PageAllocator.mark_indexed``, and
    the allocator's ``on_evict`` callback invokes :meth:`drop_pages`
    whenever a cached page is re-granted to a writer or swept by the
    cache budget — keys and storage die together, so a match can never
    alias rewritten storage.  Registration is deferred until the owner's
    K/V has actually landed on device (the engine registers post-scatter
    / post-last-chunk for prompts and at slot release for decode-filled
    pages), so a match never reads pages that are still being computed.

    Host-side bookkeeping only.  Keys are CHAINED digests — page ``i``'s
    key hashes page ``i - 1``'s key together with page ``i``'s own token
    bytes — so a key still commits to the entire prefix while
    registration/matching stay O(pages) in time and memory (materializing
    ``prompt[:(i + 1) * page_size]`` per page would be quadratic: ~130 MB
    of key bytes for a 32k prompt at 64-token pages).  One key maps to at
    most one page and vice versa.
    """

    def __init__(self, page_size: int):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.page_size = page_size
        # engine-thread-confined, like PageAllocator: lookups and inserts
        # happen only from admission/release paths on the owning engine
        self._by_key: dict = {}
        self._by_page: dict = {}
        # chain linkage (parent key -> child keys) for subtree drops:
        # sessions extend a prefix, so closing one is a branch delete
        self._children: dict = {}

    def __len__(self) -> int:
        return len(self._by_key)

    def _page_keys(self, prompt: np.ndarray) -> Iterator[bytes]:
        """Chained per-full-page keys; each commits to the whole prefix."""
        arr = np.ascontiguousarray(np.asarray(prompt, np.int32))
        P = self.page_size
        key = b""
        for i in range(len(arr) // P):
            key = hashlib.blake2b(
                key + arr[i * P : (i + 1) * P].tobytes(), digest_size=16
            ).digest()
            yield key

    def register(self, prompt: np.ndarray, pages) -> List[int]:
        """Index every FULL page of ``prompt`` backed by ``pages``.

        ``pages[i]`` must be the physical page holding positions
        ``[i * page_size, (i + 1) * page_size)`` (the slot's block-table
        row works verbatim).  First registration wins: an existing entry
        for the same key is kept — its page already holds identical
        content, and churning entries would invalidate live matches for
        no gain.  Returns the physical pages NOW backing the chain (the
        kept page where an entry already existed) so the caller can hand
        exactly those to ``PageAllocator.mark_indexed``.
        """
        backing: List[int] = []
        prev = b""
        for i, key in enumerate(self._page_keys(prompt)):
            # linkage is key-derived (prev + tokens), so recording it even
            # for kept entries is idempotent and keeps branches walkable
            self._children.setdefault(prev, set()).add(key)
            prev = key
            page = self._by_key.get(key)
            if page is not None:  # first registration won; same content
                backing.append(page)
                continue
            page = int(pages[i])
            old = self._by_page.pop(page, None)
            if old is not None:  # page re-registered under new content
                del self._by_key[old]
            self._by_key[key] = page
            self._by_page[page] = key
            backing.append(page)
        return backing

    def match(self, prompt: np.ndarray) -> List[int]:
        """Longest chain of indexed full-prefix pages for ``prompt``."""
        chain: List[int] = []
        for key in self._page_keys(prompt):
            page = self._by_key.get(key)
            if page is None:
                break
            chain.append(page)
        return chain

    def drop_pages(self, pages) -> None:
        """Forget entries whose physical page is being re-granted to a writer."""
        for p in pages:
            key = self._by_page.pop(int(p), None)
            if key is not None:
                del self._by_key[key]
                self._children.pop(key, None)

    def drop_branch(self, prompt: np.ndarray) -> List[int]:
        """Forget the prompt's full-page chain AND every registered
        extension of it (session close: the conversation's own pages plus
        all replies/turns built on top).  Returns the physical pages whose
        entries died, so the owner can release them from the allocator's
        warm cache in the same operation.

        Callers pass the SESSION's prompt, not a shared system prefix —
        pages keyed at or below the given prefix die for every session
        that shared them (correctness is unaffected: they re-prefill on
        next use).  If an interior page was already evicted, the chain
        walk stops there; the now-unreachable deeper entries decay through
        the allocator's LRU instead.
        """
        chain: List[bytes] = []
        for key in self._page_keys(prompt):
            if key not in self._by_key:
                break
            chain.append(key)
        if not chain:
            return []
        kill = list(chain)
        stack = [chain[-1]]
        while stack:
            for child in self._children.get(stack.pop(), ()):
                if child in self._by_key:  # linkage may outlive evictions
                    kill.append(child)
                    stack.append(child)
        dropped: List[int] = []
        for key in kill:
            page = self._by_key.pop(key, None)
            if page is not None:
                del self._by_page[page]
                dropped.append(page)
            self._children.pop(key, None)
        return dropped

    def clear(self) -> None:
        self._by_key.clear()
        self._by_page.clear()
        self._children.clear()


@dataclasses.dataclass(frozen=True)
class RejectedOverload:
    """Structured shed record attached to a request the admission policy
    dropped instead of admitting — the overload contract is an explicit
    rejection the client can retry against, never silent starvation.

    ``reason`` — why it was shed (``"deadline-expired"``, ``"shutdown"``).
    ``waited_ms`` — how long the request sat in the queue before shedding.
    ``queue_depth`` — waiters (including this one) at the shed decision.
    ``deadline_ms`` — the request's own admission deadline, if it had one.
    """

    uid: int
    reason: str
    waited_ms: float
    queue_depth: int
    deadline_ms: Optional[float] = None


@dataclasses.dataclass
class AdmissionPolicy:
    """Overload-aware admission: degrade rank tier under pressure, shed
    deadline-expired waiters.

    Tier semantics: tier 0 is the full serving rank; higher indices are
    NESTED cheaper ranks (prefix slices of the same factors — see
    ``core.lowrank.slice_rank``).  Under pressure a new admission is
    degraded to the deepest tier its ``min_tier`` allows instead of
    queueing behind work the pool cannot hold — quality sheds before
    latency does, and every degraded response carries the tier's
    spectral-bound certificate so the delta is reported, not silent.

    Pressure is EITHER signal: queue depth at/above
    ``degrade_queue_depth`` waiters, or the free-page fraction below
    ``degrade_free_frac``.  ``None`` disables a signal; with both None
    (the default) tiers are only ever what the request pinned itself.
    ``shed_deadlines`` — drop waiters whose ``deadline_ms`` expired
    before admission, with a :class:`RejectedOverload` attached.
    """

    n_tiers: int = 1
    degrade_queue_depth: Optional[int] = None
    degrade_free_frac: Optional[float] = None
    shed_deadlines: bool = True

    def choose_tier(self, request, queue_depth: int, free_frac: float) -> int:
        base = int(getattr(request, "tier", 0))
        if self.n_tiers <= 1:
            return base
        if getattr(request, "_parent", None) is not None:
            # a preempted request resumes at the tier it started on — its
            # registered K/V bytes and its emitted tokens are tier-specific,
            # and mid-request degradation would break bit-exact resume
            return base
        pressured = (
            self.degrade_queue_depth is not None
            and queue_depth >= self.degrade_queue_depth
        ) or (
            self.degrade_free_frac is not None and free_frac < self.degrade_free_frac
        )
        if not pressured:
            return base
        cap = min(int(getattr(request, "min_tier", 0)), self.n_tiers - 1)
        return max(base, cap)


@dataclasses.dataclass
class RoutingPolicy:
    """Load-aware replica routing for the cluster front door.

    ``pick`` receives one ``(replica_id, queue_depth, pages_used)`` triple
    per HEALTHY replica and returns the replica id to route the next
    request to: least queue depth first (waiters dominate TTFT), then
    least pages used (KV footprint approximates outstanding decode work),
    then lowest id — a total order, so routing is deterministic for a
    deterministic trace.
    """

    def pick(self, loads: List[Tuple[int, int, int]]) -> int:
        if not loads:
            raise ValueError("no healthy replicas to route to")
        return min(loads, key=lambda t: (t[1], t[2], t[0]))[0]


@dataclasses.dataclass
class FailoverBudget:
    """Per-request failover accounting for the cluster.

    A request whose replica dies is re-enqueued at most ``max_failovers``
    times; each re-enqueue is delayed by capped exponential backoff with
    deterministic jitter (same formula as ``runtime.fault_tolerance``,
    duplicated here because the scheduler layer stays jax-import-free):
    attempt ``k`` waits ``min(base_ms * 2**k, cap_ms)`` scaled by a factor
    in [0.5, 1.0] hashed from ``(salt, k)`` — typically salted with the
    request uid so concurrent failovers of different requests spread out
    instead of thundering back in lockstep.
    """

    max_failovers: int = 2
    base_ms: float = 0.0
    cap_ms: float = 250.0

    def backoff_ms(self, attempt: int, salt: int = 0) -> float:
        if self.base_ms <= 0:
            return 0.0
        raw = min(self.base_ms * (2.0 ** max(attempt, 0)), self.cap_ms)
        h = hashlib.blake2b(f"{salt}:{attempt}".encode(), digest_size=8).digest()
        frac = 0.5 + (int.from_bytes(h, "big") / 2.0**64) * 0.5
        return raw * frac


class Scheduler:
    """FIFO admission control on top of a :class:`SlotAllocator`.

    ``enqueue`` never blocks; ``admit`` drains the queue into free slots and
    returns the (slot, request) placements made this round.

    Paged engines additionally pass ``reserve``/``release_grant`` hooks:
    ``reserve(req)`` returns an opaque grant (:class:`PageGrant` in
    practice — possibly EMPTY for zero-page archs) or ``None`` on
    exhaustion; the grant lands in ``slot_pages[slot]`` and is handed back
    to ``release_grant`` when the slot frees.  Hook-shaped reservation is
    what lets admission do prefix matching + copy-on-write page
    reservation atomically while this class stays resource-agnostic.

    Exhaustion is detected with ``is None`` EXCLUSIVELY — an empty grant
    (``[]`` / ``PageGrant(pages=[])``) admits normally (zero-page archs).

    An optional :class:`AdmissionPolicy` adds the overload layer on top
    of plain FIFO: before each admission round, deadline-expired waiters
    are SHED (popped with a :class:`RejectedOverload` attached, collected
    via :meth:`drain_shed`), and each head-of-queue request is assigned
    its serving TIER from the policy's pressure signals before ``reserve``
    sees it.  ``pressure`` is a callable returning the free-resource
    fraction in [0, 1] (the engine passes its page-pool headroom); with no
    policy the scheduler behaves exactly as before — queue forever, tier
    untouched.
    """

    def __init__(
        self,
        allocator: SlotAllocator,
        *,
        reserve: Optional[Callable[[object], Optional[object]]] = None,
        release_grant: Optional[Callable[[object], None]] = None,
        policy: Optional[AdmissionPolicy] = None,
        pressure: Optional[Callable[[], float]] = None,
    ):
        if (reserve is None) != (release_grant is None):
            raise ValueError("reserve and release_grant come together")
        self.allocator = allocator
        self.reserve = reserve
        self.release_grant = release_grant
        self.policy = policy
        self.pressure = pressure
        # engine-thread-confined (admission state mutated only from the
        # owning engine's step loop).  `len(queue)` is additionally polled
        # lock-free by the cluster router via Engine.n_waiting — a
        # single-reader load estimate, see the note on that property.
        self.slot_pages: dict = {}
        self.queue: Deque = collections.deque()
        self.shed: List = []
        self.degraded = 0  # admissions the policy moved to a cheaper tier
        # optional structured-event sink: on_event(kind, fields_dict).
        # Installed by Engine/Cluster when an event log is configured;
        # must never raise (post-mortem plumbing, not control flow).
        self.on_event: Optional[Callable[[str, dict], None]] = None

    @property
    def n_waiting(self) -> int:
        return len(self.queue)

    def enqueue(self, request) -> None:
        self.queue.append(request)

    def drain_shed(self) -> List:
        """Hand back (and clear) the requests shed since the last drain."""
        out, self.shed = self.shed, []
        return out

    def shed_request(self, request, reason: str) -> None:
        """Mark one waiter shed with a structured rejection (already popped)."""
        now = time.perf_counter()
        request.status = "shed"
        request.t_done = now
        request.rejected = RejectedOverload(
            uid=request.uid,
            reason=reason,
            waited_ms=(now - request.t_submit) * 1e3,
            queue_depth=len(self.queue) + 1,
            deadline_ms=getattr(request, "deadline_ms", None),
        )
        self.shed.append(request)
        if self.on_event is not None:
            self.on_event(
                "shed",
                {
                    "uid": request.uid,
                    "reason": reason,
                    "waited_ms": round(request.rejected.waited_ms, 3),
                    "queue_depth": request.rejected.queue_depth,
                },
            )

    def _shed_expired(self) -> None:
        now = time.perf_counter()
        kept: Deque = collections.deque()
        while self.queue:
            req = self.queue.popleft()
            dl = getattr(req, "deadline_ms", None)
            if getattr(req, "_parent", None) is not None:
                # preempted continuations are exempt: the deadline governs
                # ADMISSION latency, and this request already emitted its
                # first token before being preempted — shedding it now
                # would silently discard delivered work
                dl = None
            if dl is not None and (now - req.t_submit) * 1e3 > dl:
                self.shed_request(req, "deadline-expired")
            else:
                kept.append(req)
        self.queue = kept

    def admit(self) -> List[Tuple[int, object]]:
        if self.policy is not None and self.policy.shed_deadlines:
            self._shed_expired()
        placed = []
        while self.queue and self.allocator.n_free:
            req = self.queue[0]
            if self.policy is not None:
                free_frac = self.pressure() if self.pressure is not None else 1.0
                tier = self.policy.choose_tier(req, len(self.queue), free_frac)
                if tier > getattr(req, "tier", 0):
                    self.degraded += 1
                    req.tier = tier
                    if self.on_event is not None:
                        self.on_event(
                            "degrade",
                            {"uid": req.uid, "tier": tier,
                             "queue_depth": len(self.queue), "free_frac": round(free_frac, 4)},
                        )
            if self.reserve is not None:
                grant = self.reserve(req)
                if grant is None:  # page exhaustion queues; strict FIFO
                    break
                slot = self.allocator.alloc()
                self.slot_pages[slot] = grant
            else:
                slot = self.allocator.alloc()
            placed.append((slot, self.queue.popleft()))
        return placed

    def release(self, slot: int) -> None:
        if self.reserve is not None:
            self.release_grant(self.slot_pages.pop(slot))
        self.allocator.free(slot)
