"""Slot allocation + request scheduling for the continuous-batching engine.

The engine owns a fixed pool of ``n_slots`` cache slots (rows of the batched
decode cache).  Requests queue FIFO; whenever a slot frees up, the scheduler
admits the oldest waiting request.  Slot exhaustion therefore QUEUES work —
it never errors — and freed slots are recycled immediately, which is what
keeps the decode batch full under sustained traffic.

Pure host-side bookkeeping: no jax imports, trivially unit-testable
(tests/test_scheduler.py).
"""

from __future__ import annotations

import collections
from typing import Deque, List, Optional, Tuple

__all__ = ["SlotAllocator", "Scheduler"]


class SlotAllocator:
    """Free-list allocator over ``n_slots`` cache slots.

    ``alloc`` returns the lowest free slot id (deterministic reuse order —
    important for reproducible traces) or None when exhausted.
    """

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.n_slots = n_slots
        self._free = list(range(n_slots - 1, -1, -1))  # stack, lowest id on top
        self._active = [False] * n_slots

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self._free)

    def is_active(self, slot: int) -> bool:
        return self._active[slot]

    def alloc(self) -> Optional[int]:
        if not self._free:
            return None
        slot = self._free.pop()
        self._active[slot] = True
        return slot

    def free(self, slot: int) -> None:
        if not (0 <= slot < self.n_slots):
            raise ValueError(f"slot {slot} out of range [0, {self.n_slots})")
        if not self._active[slot]:
            raise ValueError(f"double free of slot {slot}")
        self._active[slot] = False
        # keep the free list sorted so reuse order stays deterministic
        self._free.append(slot)
        self._free.sort(reverse=True)


class Scheduler:
    """FIFO admission control on top of a :class:`SlotAllocator`.

    ``enqueue`` never blocks; ``admit`` drains the queue into free slots and
    returns the (slot, request) placements made this round.
    """

    def __init__(self, allocator: SlotAllocator):
        self.allocator = allocator
        self.queue: Deque = collections.deque()

    @property
    def n_waiting(self) -> int:
        return len(self.queue)

    def enqueue(self, request) -> None:
        self.queue.append(request)

    def admit(self) -> List[Tuple[int, object]]:
        placed = []
        while self.queue and self.allocator.n_free:
            slot = self.allocator.alloc()
            placed.append((slot, self.queue.popleft()))
        return placed

    def release(self, slot: int) -> None:
        self.allocator.free(slot)
