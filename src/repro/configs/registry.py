"""Architecture registry: ``--arch <id>`` resolution + paper-model configs."""

from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig, ShapeCell, SHAPE_CELLS

_ARCH_MODULES = {
    "llama3.2-1b": "repro.configs.llama3_2_1b",
    "h2o-danube-1.8b": "repro.configs.h2o_danube_1_8b",
    "qwen2-72b": "repro.configs.qwen2_72b",
    "minitron-4b": "repro.configs.minitron_4b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi3_5_moe",
    "llama-3.2-vision-11b": "repro.configs.llama3_2_vision_11b",
    "zamba2-1.2b": "repro.configs.zamba2_1_2b",
    "whisper-small": "repro.configs.whisper_small",
    "mamba2-130m": "repro.configs.mamba2_130m",
}

ARCH_IDS = tuple(_ARCH_MODULES)

# Cells that require sub-quadratic / bounded-window decode memory.  Pure
# full-attention archs skip long_500k (see DESIGN.md §6 skip table).
LONG_CONTEXT_ARCHS = {"mamba2-130m", "zamba2-1.2b", "h2o-danube-1.8b"}


def get_arch(arch_id: str, *, reduced: bool = False) -> ArchConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(_ARCH_MODULES[arch_id])
    return mod.REDUCED if reduced else mod.CONFIG


def get_shape(name: str) -> ShapeCell:
    return SHAPE_CELLS[name]


def cell_is_runnable(arch_id: str, shape_name: str) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) for one (arch x shape) cell."""
    if shape_name == "long_500k" and arch_id not in LONG_CONTEXT_ARCHS:
        return False, "full-attention arch: 500k-token KV decode is out of regime (DESIGN.md §6)"
    return True, ""


def all_cells() -> list[tuple[str, str]]:
    """Every runnable (arch, shape) cell, in registry order."""
    out = []
    for a in ARCH_IDS:
        for s in SHAPE_CELLS:
            ok, _ = cell_is_runnable(a, s)
            if ok:
                out.append((a, s))
    return out
