"""phi3.5-moe-42b-a6.6b — MoE, 32L d4096 32H (GQA kv=8, head_dim 128).

16 experts top-2, expert d_ff=6400, vocab=32064.
[hf:microsoft/Phi-3.5-MoE-instruct]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    vocab=32064,
    n_experts=16,
    n_shared_experts=0,
    top_k=2,
    moe_d_ff=6400,
    rope_theta=10_000.0,
)

REDUCED = ArchConfig(
    name="phi3.5-moe-reduced",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    vocab=256,
    n_experts=4,
    top_k=2,
    moe_d_ff=48,
)
