"""mamba2-130m — attention-free SSM with SSD (state-space duality).

24L d768, d_inner 1536 (expand 2, head_dim 64 -> 24 ssm heads),
ssm_state=128, vocab=50280 (padded to 50432).  [arXiv:2405.21060; unverified]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    vocab=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv_width=4,
    tie_embeddings=True,
)

REDUCED = ArchConfig(
    name="mamba2-130m-reduced",
    family="ssm",
    n_layers=2,
    d_model=64,
    vocab=256,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=16,
    ssm_chunk=16,
    tie_embeddings=True,
)
