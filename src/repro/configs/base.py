"""Architecture + shape-cell configuration system.

Every assigned architecture is an :class:`ArchConfig`; every assigned input
shape is a :class:`ShapeCell`.  Configs are plain frozen dataclasses so they
are hashable (usable as static jit args) and trivially serializable.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["ArchConfig", "ShapeCell", "SHAPE_CELLS", "pad_to_multiple"]


def pad_to_multiple(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """Superset config covering dense / moe / vlm / hybrid / audio / ssm families."""

    name: str
    family: str  # dense | moe | vlm | hybrid | audio | ssm
    n_layers: int
    d_model: int
    vocab: int

    # --- attention ---
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    sliding_window: Optional[int] = None  # SWA (h2o-danube)
    rope_theta: float = 500_000.0

    # --- FFN ---
    d_ff: int = 0
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0  # deepseek-v2: layer 0 is a dense FFN
    dense_d_ff: int = 0  # FFN width of those dense layers
    capacity_factor: float = 1.25

    # --- MLA (deepseek-v2) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- VLM (llama-3.2-vision) ---
    cross_attn_every: int = 0  # every Nth layer is cross-attention
    n_image_tokens: int = 0  # stub frontend: precomputed patch embeddings

    # --- SSM (mamba2 / zamba2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256  # SSD chunk length
    attn_every: int = 0  # zamba2: shared attention block every N mamba blocks

    # --- enc-dec (whisper) ---
    n_encoder_layers: int = 0
    n_audio_frames: int = 1500  # stub conv frontend output length

    # --- numerics / training ---
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    remat: str = "block"  # none | block (checkpoint each scanned block)
    remat_group: int = 1  # layers per activation checkpoint (memory knob)
    # kernel backend policy, consumed by repro.runtime.dispatch:
    #   auto      — shape/platform selection table (fused Pallas on TPU when
    #               it fits VMEM, XLA two-GEMM / dense-remat elsewhere)
    #   xla | pallas | reference — pin every op to one backend
    kernels: str = "auto"
    # DEPRECATED alias for kernels="pallas"; folded into ``kernels`` below.
    use_pallas: bool = False
    optimizer: str = "adamw"  # adamw | adafactor (memory-bound giants) | sgdm
    accum_steps: int = 1  # microbatch gradient accumulation (train memory knob)

    # ------------------------------------------------------------------ #
    def __post_init__(self):
        if self.kernels not in ("auto", "xla", "pallas", "reference"):
            raise ValueError(f"kernels={self.kernels!r} not in auto|xla|pallas|reference")
        if self.use_pallas and self.kernels == "auto":
            # legacy configs: use_pallas=True meant "force the Pallas path"
            object.__setattr__(self, "kernels", "pallas")

    @property
    def d_inner(self) -> int:  # ssm inner width
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def vocab_padded(self) -> int:
        """Vocab padded to a multiple of 256 (TPU lane alignment + mesh
        divisibility).  Logits over padding are masked to -inf in the loss."""
        return pad_to_multiple(self.vocab, 256)

    @property
    def qk_head_dim(self) -> int:
        return (self.qk_nope_dim + self.qk_rope_dim) if self.kv_lora_rank else self.head_dim

    def param_count(self) -> int:
        """Analytic parameter count N (for MODEL_FLOPS = 6 N D)."""
        from repro.models.model import analytic_param_count

        return analytic_param_count(self)

    def active_param_count(self) -> int:
        from repro.models.model import analytic_param_count

        return analytic_param_count(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        # decode cells process ONE new token per sequence; train/prefill the
        # full sequence.
        return self.global_batch * (1 if self.kind == "decode" else self.seq_len)


SHAPE_CELLS = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}
