"""llama-3.2-vision-11b — VLM backbone: 40L d4096 32H (GQA kv=8, head_dim 128).

d_ff=14336 vocab=128256; cross-attention image layers every 5th layer.
The vision encoder is a STUB: input_specs() provides precomputed patch
embeddings (global_batch, n_image_tokens, d_model).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=128256,
    cross_attn_every=5,
    n_image_tokens=1600,
    rope_theta=500_000.0,
)

REDUCED = ArchConfig(
    name="llama-3.2-vision-11b-reduced",
    family="vlm",
    n_layers=5,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    cross_attn_every=5,
    n_image_tokens=16,
)
