"""h2o-danube-1.8b — dense llama+mistral mix with sliding-window attention.

24L d2560 32H (GQA kv=8, head_dim 80) d_ff=6912 vocab=32000.  [arXiv:2401.16818]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab=32000,
    sliding_window=4096,
    rope_theta=10_000.0,
)

REDUCED = ArchConfig(
    name="h2o-danube-1.8b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab=256,
    sliding_window=32,
)
