"""qwen2-72b — dense, 80L d8192 64H (GQA kv=8, head_dim 128), QKV bias.

d_ff=29568 vocab=152064.  [arXiv:2407.10671]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    optimizer="adafactor",
    accum_steps=4,  # microbatch the 256-seq global batch: activations /4  # 72B: factored stats keep HBM/chip in budget
)

REDUCED = ArchConfig(
    name="qwen2-72b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=160,
    vocab=256,
    qkv_bias=True,
)
