"""deepseek-v2-236b — MoE with multi-head latent attention (MLA).

60L d5120 128H, MLA kv_lora=512 q_lora=1536 (nope 128 / rope 64 / v 128),
2 shared + 160 routed experts top-6, expert d_ff=1536, first layer dense
(d_ff 12288), vocab=102400.  [arXiv:2405.04434]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,  # MLA: all heads share one latent — no GQA reduction
    head_dim=128,
    vocab=102400,
    n_experts=160,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1536,
    first_dense_layers=1,
    dense_d_ff=12288,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    rope_theta=10_000.0,
    optimizer="adafactor",
    accum_steps=8,  # microbatch the 256-seq global batch: activations /8
)

REDUCED = ArchConfig(
    name="deepseek-v2-236b-reduced",
    family="moe",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    vocab=256,
    n_experts=8,
    n_shared_experts=1,
    top_k=2,
    moe_d_ff=32,
    first_dense_layers=1,
    dense_d_ff=128,
    q_lora_rank=32,
    kv_lora_rank=16,
    qk_nope_dim=16,
    qk_rope_dim=8,
    v_head_dim=16,
)
