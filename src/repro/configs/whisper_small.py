"""whisper-small — encoder-decoder, 12L enc + 12L dec, d768 12H d_ff=3072.

vocab=51865 (padded to 52224 for sharding).  Conv audio frontend is a STUB:
input_specs() provides precomputed frame embeddings (batch, 1500, d_model).
[arXiv:2212.04356; unverified]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,  # decoder layers
    n_encoder_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab=51865,
    n_audio_frames=1500,
    rope_theta=10_000.0,  # unused: whisper uses learned/sinusoidal pos emb
)

REDUCED = ArchConfig(
    name="whisper-small-reduced",
    family="audio",
    n_layers=2,
    n_encoder_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab=256,
    n_audio_frames=32,
)
