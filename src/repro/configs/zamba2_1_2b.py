"""zamba2-1.2b — hybrid: Mamba2 backbone + SHARED attention block.

38 mamba2 layers d2048 (d_inner 4096, ssm_state 64, head_dim 64), one shared
attention+MLP block (32H MHA, d_ff 8192) applied every 6 mamba layers with
tied weights across applications.  vocab=32000.  [arXiv:2411.15242]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,
    rope_theta=10_000.0,
)

REDUCED = ArchConfig(
    name="zamba2-1.2b-reduced",
    family="hybrid",
    n_layers=5,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab=256,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=16,
    ssm_chunk=16,
    attn_every=2,
)
