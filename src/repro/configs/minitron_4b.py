"""minitron-4b — pruned nemotron, dense, 32L d3072 24H (GQA kv=8, head_dim 128).

d_ff=9216 vocab=256000.  [arXiv:2407.14679]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab=256000,
    rope_theta=10_000.0,
)

REDUCED = ArchConfig(
    name="minitron-4b-reduced",
    family="dense",
    n_layers=2,
    d_model=48,
    n_heads=3,
    n_kv_heads=1,
    head_dim=16,
    d_ff=144,
    vocab=512,
)
