"""Pallas TPU kernel: flash-decode — one-token GQA attention over a KV cache.

The serving decode hot path: every active slot attends its single new query
against its whole cache row.  The kernel streams the cache's SEQUENCE dim
through VMEM in blocks (split-KV online softmax: running (m, l, acc) live in
scratch across the sequential grid axis), so no (B, H, S) score tensor is
ever materialized and the cache itself is never copied or transposed — the
BlockSpec index maps read (bs, hd) tiles straight out of the (B, S, KV, hd)
pool layout.

GQA-aware tiling: the grid is (B, KV, S/bs) and each program computes all
``G = H // KV`` query heads that share one KV head, so the (G, hd) @
(hd, bs) score matmul feeds the MXU one tile per KV head instead of
re-reading K per query head.

Masking is STRICT per slot: a ``valid`` (B, S) mask (built by the caller
from per-slot ``n_valid`` or a ring-buffer ``rotate_mask``) gates both the
scores and the probabilities.  Fully-masked rows — empty or inactive slots
in the continuous-batching pool — produce ZEROS, not NaN: probabilities are
re-masked after the exp so the running denominator stays 0 (``exp(s - m)``
alone would be 1 on all-masked rows where m == NEG_INF).

The dense einsum in kernels/ref.decode_attention_ref (wrapped by
models/attention.decode_attention) is the parity oracle; backend selection
lives in runtime/dispatch.py like every other op.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "decode_attention_kernel",
    "decode_attention_pallas",
    "paged_decode_attention_pallas",
]

NEG_INF = -1e30

# Declared worst-case block dims for the static VMEM gate
# (repro.analysis pallas-contract).  G = query heads per KV head, hd/vd =
# head dims, page = KV page size.  Growing a model config past these must
# come back here — the budget math below is checked against them in CI.
VMEM_ANALYSIS_BOUNDS = {"G": 16, "hd": 256, "vd": 256, "page": 128}


def decode_attention_kernel(
    q_ref, k_ref, v_ref, valid_ref, o_ref, m_ref, l_ref, acc_ref, *, scale: float, n_s: int
):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]  # (G, hd)
    k = k_ref[0, :, 0, :]  # (bs, hd)
    v = v_ref[0, :, 0, :]  # (bs, vd)
    live = valid_ref[0] != 0  # (bs,)

    # Same dtype discipline as the reference: scale in fp32, cast back to the
    # cache dtype, accumulate scores in fp32 on the MXU.
    qs = (q.astype(jnp.float32) * scale).astype(k.dtype)
    s = jnp.dot(qs, k.T, preferred_element_type=jnp.float32)  # (G, bs)
    s = jnp.where(live[None, :], s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    # exp(s - m) is 1, not 0, on fully-masked rows (m == NEG_INF); re-masking
    # keeps l at 0 there so empty slots flush to zeros instead of NaN.
    p = jnp.where(live[None, :], jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(j == n_s - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def decode_attention_pallas(
    q: jax.Array,  # (B, 1, H, hd)
    k_cache: jax.Array,  # (B, S, KV, hd)
    v_cache: jax.Array,  # (B, S, KV, vd)
    valid: jax.Array,  # (B, S) bool — per-slot cache validity mask
    *,
    bs: int = 512,
    interpret: bool = False,
):
    B, one, H, hd = q.shape
    if one != 1:
        raise ValueError(f"decode query must be one token, got q {q.shape}")
    S, KV = k_cache.shape[1], k_cache.shape[2]
    vd = v_cache.shape[-1]
    if H % KV:
        raise ValueError(f"H={H} not a multiple of KV={KV}")
    if valid.shape != (B, S):
        raise ValueError(f"valid mask {valid.shape} != (B, S)=({B}, {S})")
    G = H // KV
    bs_ = min(bs, S)
    while S % bs_:
        bs_ //= 2
    qg = q.reshape(B, KV, G, hd)
    valid_i = valid.astype(jnp.int32)
    grid = (B, KV, S // bs_)

    out = pl.pallas_call(
        functools.partial(decode_attention_kernel, scale=hd**-0.5, n_s=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, k, j: (b, k, 0, 0)),
            pl.BlockSpec((1, bs_, 1, hd), lambda b, k, j: (b, j, k, 0)),
            pl.BlockSpec((1, bs_, 1, vd), lambda b, k, j: (b, j, k, 0)),
            pl.BlockSpec((1, bs_), lambda b, k, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, vd), lambda b, k, j: (b, k, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, vd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, vd), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k_cache, v_cache, valid_i)
    return out.reshape(B, 1, H, vd)


def _paged_decode_kernel(
    bt_ref, q_ref, k_ref, v_ref, valid_ref, o_ref, m_ref, l_ref, acc_ref, *, scale, n_s
):
    # The block table was consumed by the BlockSpec index maps (scalar
    # prefetch); the body is EXACTLY the flat split-KV online softmax — one
    # page of the slot's cache per sequential grid step.
    del bt_ref
    decode_attention_kernel(
        q_ref, k_ref, v_ref, valid_ref, o_ref, m_ref, l_ref, acc_ref,
        scale=scale, n_s=n_s,
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_pallas(
    q: jax.Array,  # (B, 1, H, hd)
    k_pool: jax.Array,  # (P, page, KV, hd) physical pages
    v_pool: jax.Array,  # (P, page, KV, vd)
    block_table: jax.Array,  # (B, n_tbl) int32 page ids
    n_valid: jax.Array,  # (B,) int32 valid logical positions per slot
    *,
    interpret: bool = False,
):
    """Block-table flash-decode: one-token GQA attention over a PAGED cache.

    Identical split-KV online-softmax / GQA-tiling structure to
    :func:`decode_attention_pallas`, but the cache's sequence dim is
    virtualized: the grid's sequential axis walks the slot's BLOCK TABLE
    (one fixed-size page per step), and the K/V BlockSpec index maps — with
    the table as a scalar-prefetch operand — DMA each page straight out of
    the shared physical pool.  No (B, S, KV, hd) per-slot gather is ever
    materialized, which is the whole point: the flat engine's worst-case
    per-slot reservation becomes a pool of pages shared by every slot.

    Entries of ``block_table`` beyond a slot's allocation may point at the
    pool's trash page; the (B, S_logical) validity mask built from
    ``n_valid`` zeroes their probabilities, so trash contents are never
    observed (fully-masked rows produce zeros, same contract as the flat
    kernel).  The gather-einsum oracle is kernels/ref.paged_decode_attention_ref.
    """
    B, one, H, hd = q.shape
    if one != 1:
        raise ValueError(f"decode query must be one token, got q {q.shape}")
    P, page, KV, _ = k_pool.shape
    vd = v_pool.shape[-1]
    if H % KV:
        raise ValueError(f"H={H} not a multiple of KV={KV}")
    if block_table.shape[0] != B:
        raise ValueError(f"block_table {block_table.shape} != (B, n_tbl), B={B}")
    G = H // KV
    n_tbl = block_table.shape[1]
    S = n_tbl * page
    qg = q.reshape(B, KV, G, hd)
    valid_i = (jnp.arange(S)[None, :] < n_valid[:, None]).astype(jnp.int32)
    grid = (B, KV, n_tbl)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # the block table steers the K/V index maps
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, k, j, bt: (b, k, 0, 0)),
            pl.BlockSpec((1, page, 1, hd), lambda b, k, j, bt: (bt[b, j], 0, k, 0)),
            pl.BlockSpec((1, page, 1, vd), lambda b, k, j, bt: (bt[b, j], 0, k, 0)),
            pl.BlockSpec((1, page), lambda b, k, j, bt: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, vd), lambda b, k, j, bt: (b, k, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, vd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_decode_kernel, scale=hd**-0.5, n_s=n_tbl),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, vd), q.dtype),
        interpret=interpret,
    )(block_table.astype(jnp.int32), qg, k_pool, v_pool, valid_i)
    return out.reshape(B, 1, H, vd)
