"""Pallas TPU kernel: Mamba2 SSD chunked scan (inner chunk computation).

TPU-native adaptation of the CUDA selective-scan: the sequence is tiled into
chunks of Q steps; the kernel walks chunks SEQUENTIALLY on the second grid
axis (TPU grids iterate the last axis innermost, and the VMEM scratch
``state_ref`` (nh, hd, s) persists across grid steps — it carries the
inter-chunk recurrence).  Within a chunk everything is dense MXU work:

    y_intra = (C B^T ∘ decay-mask) x̄      — (Q x Q) masked matmul
    y_inter = (C · state) ∘ exp(lcum)
    state   = state * exp(l_last) + (B ∘ w)^T x̄

Grid: (batch, n_chunks).  Block shapes: x̄ (Q, nh, hd), dt/lcum (Q, nh),
B/C (Q, s).  VMEM @ Q=256, nh=24, hd=64, s=128: x 768KiB + state 768KiB(f32)
+ masks ~256KiB — comfortable.  Head dim nh*hd maps to the 8x128 VREG lanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan_kernel", "ssd_scan_pallas"]

# Declared worst-case dims for the static VMEM gate (repro.analysis
# pallas-contract): nh = SSD heads, hd = head dim, s = state dim.  The
# chunk length resolves from its keyword default; these are the knobs a
# bigger model would turn, so growing them must re-run the budget math.
VMEM_ANALYSIS_BOUNDS = {"nh": 32, "hd": 128, "s": 128}


def ssd_scan_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, state_out_ref, state_ref):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    xq = x_ref[0].astype(jnp.float32)  # (Q, nh, hd)
    dt = dt_ref[0].astype(jnp.float32)  # (Q, nh)
    bq = b_ref[0].astype(jnp.float32)  # (Q, s)
    cq = c_ref[0].astype(jnp.float32)  # (Q, s)
    A = a_ref[...].astype(jnp.float32)  # (nh,)
    Q = xq.shape[0]

    da = dt * A[None, :]  # (Q, nh) negative
    lcum = jnp.cumsum(da, axis=0)  # (Q, nh)
    xbar = xq * dt[:, :, None]

    # intra-chunk masked quadratic
    cb = jnp.dot(cq, bq.T, preferred_element_type=jnp.float32)  # (Q, Q)
    seg = lcum[:, None, :] - lcum[None, :, :]  # (Q, Q, nh) l_t - l_u
    tri = jnp.tril(jnp.ones((Q, Q), dtype=jnp.bool_))
    m = jnp.exp(jnp.where(tri[:, :, None], seg, -1e30))  # (Q, Q, nh)
    y_intra = jnp.einsum("tu,tuh,uhd->thd", cb, m, xbar)

    # inter-chunk from carried state
    state = state_ref[...]  # (nh, hd, s) fp32
    y_inter = jnp.einsum("ts,hds,th->thd", cq, state, jnp.exp(lcum))

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update
    l_last = lcum[-1:, :]  # (1, nh)
    w_in = jnp.exp(l_last - lcum)  # (Q, nh)
    state_new = state * jnp.exp(l_last)[0, :, None, None] + jnp.einsum(
        "us,uh,uhd->hds", bq, w_in, xbar
    )
    state_ref[...] = state_new
    state_out_ref[0] = state_new


def _pad_chunk(x, Q, axis):
    pad = (-x.shape[axis]) % Q
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(
    x: jax.Array,  # (B, L, nh, hd)  raw inputs (NOT dt-scaled; kernel scales)
    dt: jax.Array,  # (B, L, nh) fp32 post-softplus
    B_in: jax.Array,  # (B, L, s)
    C_in: jax.Array,  # (B, L, s)
    A: jax.Array,  # (nh,) negative reals
    *,
    chunk: int = 128,
    interpret: bool = False,
):
    """Returns (y (B,L,nh,hd), final_state (B,nh,hd,s) fp32)."""
    Bsz, L, nh, hd = x.shape
    s = B_in.shape[-1]
    Q = min(chunk, L)
    while L % Q:
        Q //= 2
    nc = L // Q

    out, states = pl.pallas_call(
        ssd_scan_kernel,
        grid=(Bsz, nc),
        in_specs=[
            pl.BlockSpec((1, Q, nh, hd), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, Q, nh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Q, s), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Q, s), lambda b, c: (b, c, 0)),
            pl.BlockSpec((nh,), lambda b, c: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, nh, hd), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, nh, hd, s), lambda b, c: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, L, nh, hd), x.dtype),
            jax.ShapeDtypeStruct((Bsz, nh, hd, s), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((nh, hd, s), jnp.float32)],
        interpret=interpret,
    )(x, dt.astype(jnp.float32), B_in, C_in, A.astype(jnp.float32))
    return out, states
