"""Pallas TPU kernel: fused low-rank linear  y = (x @ A) @ B.

The serving hot path of an RSI-compressed model.  Two XLA GEMMs would
round-trip the (M, r) intermediate through HBM; here it lives in a VMEM
scratch accumulator for the whole reduction:

  grid (M/bm, K/bk)  — K is the reduction (sequential) axis
    t[bm, r]   += x[bm, bk] @ A[bk, r]          (fp32 scratch)
    on last k:  y[bm, N]    = t @ B[r, N]       (B resident in VMEM)

VMEM budget @ bf16, bm=256, bk=512, r<=256, N<=8192:
  x 256KiB + A 256KiB + B 4MiB + t 256KiB(f32) + y 4MiB(f32->bf16) ~= 9MiB.
The ops.py wrapper falls back to two tiled GEMMs when r/N exceed the
residency limits (checked statically).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["lowrank_matmul_kernel", "lowrank_matmul_pallas", "fits_fused"]

# conservative VMEM residency limits for the fused path
_MAX_RANK = 512
_MAX_N = 8192


def fits_fused(r: int, n: int) -> bool:
    return r <= _MAX_RANK and n <= _MAX_N


def lowrank_matmul_kernel(x_ref, a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], a_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        t = acc_ref[...].astype(x_ref.dtype)
        o_ref[...] = jnp.dot(
            t, b_ref[...], preferred_element_type=jnp.float32
        ).astype(o_ref.dtype)


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "interpret"))
def lowrank_matmul_pallas(
    x: jax.Array,
    A: jax.Array,
    B: jax.Array,
    *,
    bm: int = 256,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """y = (x @ A) @ B.  x: (M, K); A: (K, r); B: (r, N)."""
    M, K = x.shape
    K2, r = A.shape
    r2, N = B.shape
    assert K == K2 and r == r2, (x.shape, A.shape, B.shape)
    assert fits_fused(r, N), "use the two-GEMM fallback (ops.lowrank_matmul)"
    bm_, bk_ = min(bm, M), min(bk, K)
    x_p = _pad_to(_pad_to(x, bm_, 0), bk_, 1)
    a_p = _pad_to(A, bk_, 0)
    Mp, Kp = x_p.shape
    grid = (Mp // bm_, Kp // bk_)

    out = pl.pallas_call(
        functools.partial(lowrank_matmul_kernel, n_k=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda m, k: (m, k)),
            pl.BlockSpec((bk_, r), lambda m, k: (k, 0)),
            pl.BlockSpec((r, N), lambda m, k: (0, 0)),  # B resident
        ],
        out_specs=pl.BlockSpec((bm_, N), lambda m, k: (m, 0)),
        out_shape=jax.ShapeDtypeStruct((Mp, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm_, r), jnp.float32)],
        interpret=interpret,
    )(x_p, a_p, B)
    return out[:M]
