"""Pallas TPU kernel: fused low-rank linear  y = (x @ A) @ B.

The serving hot path of an RSI-compressed model.  Two XLA GEMMs would
round-trip the (M, r) intermediate through HBM; here it lives in a VMEM
scratch accumulator for the whole reduction:

  grid (M/bm, K/bk)  — K is the reduction (sequential) axis
    t[bm, r]   += x[bm, bk] @ A[bk, r]          (fp32 scratch)
    on last k:  y[bm, N]    = t @ B[r, N]       (B resident in VMEM)

The batched variant adds a leading stack axis (grid (L, M/bm, K/bk)) so
lax.scan-stacked layer params and (E, ...) expert factors hit the fused
kernel instead of falling back to per-slice XLA GEMMs.

Residency is checked against a DTYPE-AWARE byte budget (``fused_vmem_bytes``)
rather than static rank/N constants; the runtime dispatcher
(repro.runtime.dispatch) consults the same budget when choosing a path, so a
shape that reaches these kernels has already been certified to fit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "lowrank_matmul_kernel",
    "lowrank_matmul_pallas",
    "lowrank_matmul_batched_pallas",
    "fused_vmem_bytes",
    "fits_fused",
    "DEFAULT_VMEM_LIMIT",
]

# Leave ~2 MiB of the 16 MiB/core VMEM for Mosaic's own double-buffering and
# semaphores; everything the kernel touches must fit under this.
DEFAULT_VMEM_LIMIT = 14 * 2**20


def fused_vmem_bytes(r: int, n: int, dtype, *, bm: int = 256, bk: int = 512) -> int:
    """Worst-case VMEM residency of one fused-kernel grid step.

    x block (bm, bk) + A block (bk, r) + resident B (r, n) + output block
    (bm, n) in the storage dtype, plus the fp32 accumulator (bm, r) and the
    fp32 t @ B product (bm, n) before the output cast.
    """
    s = jnp.dtype(dtype).itemsize
    return (bm * bk + bk * r + r * n + bm * n) * s + (bm * r + bm * n) * 4


def fits_fused(
    r: int,
    n: int,
    dtype=jnp.bfloat16,
    *,
    bm: int = 256,
    bk: int = 512,
    limit: int = DEFAULT_VMEM_LIMIT,
) -> bool:
    """Dtype-aware residency check for the fused (B-in-VMEM) path."""
    return fused_vmem_bytes(r, n, dtype, bm=bm, bk=bk) <= limit


def lowrank_matmul_kernel(x_ref, a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], a_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        t = acc_ref[...].astype(x_ref.dtype)
        o_ref[...] = jnp.dot(
            t, b_ref[...], preferred_element_type=jnp.float32
        ).astype(o_ref.dtype)


def lowrank_matmul_batched_kernel(x_ref, a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    """Stacked variant: blocks carry a leading length-1 stack axis.

    Grid (L, M/bm, K/bk); K iterates innermost, so the fp32 accumulator is
    private to each (l, m) tile exactly as in the 2-D kernel.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[0], a_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        t = acc_ref[...].astype(x_ref.dtype)
        o_ref[0] = jnp.dot(
            t, b_ref[0], preferred_element_type=jnp.float32
        ).astype(o_ref.dtype)


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _check_shapes(x_shape, a_shape, b_shape):
    K, r = a_shape[-2], a_shape[-1]
    if x_shape[-1] != K:
        raise ValueError(
            f"lowrank_matmul: x contraction dim {x_shape[-1]} != A rows {K} "
            f"(x {x_shape}, A {a_shape})"
        )
    if b_shape[-2] != r:
        raise ValueError(
            f"lowrank_matmul: A rank {r} != B rows {b_shape[-2]} "
            f"(A {a_shape}, B {b_shape})"
        )


def _check_fits(r, n, dtype, bm, bk, limit):
    if not fits_fused(r, n, dtype, bm=bm, bk=bk, limit=limit):
        raise ValueError(
            f"lowrank_matmul: fused path needs "
            f"{fused_vmem_bytes(r, n, dtype, bm=bm, bk=bk)} bytes of VMEM "
            f"(r={r}, N={n}, dtype={jnp.dtype(dtype).name}, bm={bm}, bk={bk}) "
            f"> limit {limit}; use the two-GEMM fallback "
            f"(repro.runtime.dispatch routes this automatically)"
        )


@functools.partial(
    jax.jit, static_argnames=("bm", "bk", "interpret", "vmem_limit")
)
def lowrank_matmul_pallas(
    x: jax.Array,
    A: jax.Array,
    B: jax.Array,
    *,
    bm: int = 256,
    bk: int = 512,
    interpret: bool = False,
    vmem_limit: int = DEFAULT_VMEM_LIMIT,
) -> jax.Array:
    """y = (x @ A) @ B.  x: (M, K); A: (K, r); B: (r, N)."""
    if x.ndim != 2 or A.ndim != 2 or B.ndim != 2:
        raise ValueError(
            f"lowrank_matmul_pallas expects 2-D operands, got "
            f"x {x.shape}, A {A.shape}, B {B.shape}"
        )
    _check_shapes(x.shape, A.shape, B.shape)
    M, K = x.shape
    r, N = B.shape
    bm_, bk_ = min(bm, M), min(bk, K)
    _check_fits(r, N, x.dtype, bm_, bk_, vmem_limit)
    x_p = _pad_to(_pad_to(x, bm_, 0), bk_, 1)
    a_p = _pad_to(A, bk_, 0)
    Mp, Kp = x_p.shape
    grid = (Mp // bm_, Kp // bk_)

    out = pl.pallas_call(
        functools.partial(lowrank_matmul_kernel, n_k=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda m, k: (m, k)),
            pl.BlockSpec((bk_, r), lambda m, k: (k, 0)),
            pl.BlockSpec((r, N), lambda m, k: (0, 0)),  # B resident
        ],
        out_specs=pl.BlockSpec((bm_, N), lambda m, k: (m, 0)),
        out_shape=jax.ShapeDtypeStruct((Mp, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm_, r), jnp.float32)],
        interpret=interpret,
    )(x_p, a_p, B)
    return out[:M]


@functools.partial(
    jax.jit, static_argnames=("bm", "bk", "interpret", "vmem_limit")
)
def lowrank_matmul_batched_pallas(
    x: jax.Array,
    A: jax.Array,
    B: jax.Array,
    *,
    bm: int = 256,
    bk: int = 512,
    interpret: bool = False,
    vmem_limit: int = DEFAULT_VMEM_LIMIT,
) -> jax.Array:
    """Stacked fused low-rank matmul: y[l] = (x[l] @ A[l]) @ B[l].

    x: (L, M, K); A: (L, K, r); B: (L, r, N).  One fused kernel launch for
    the whole stack — the path taken by scan-stacked layer params and MoE
    expert factors (flatten (L, E, ...) leading dims to one L first).
    """
    if x.ndim != 3 or A.ndim != 3 or B.ndim != 3:
        raise ValueError(
            f"lowrank_matmul_batched_pallas expects 3-D operands, got "
            f"x {x.shape}, A {A.shape}, B {B.shape}"
        )
    if not (x.shape[0] == A.shape[0] == B.shape[0]):
        raise ValueError(
            f"lowrank_matmul_batched_pallas: stack dims disagree "
            f"(x {x.shape}, A {A.shape}, B {B.shape})"
        )
    _check_shapes(x.shape, A.shape, B.shape)
    L, M, K = x.shape
    r, N = B.shape[-2:]
    bm_, bk_ = min(bm, M), min(bk, K)
    _check_fits(r, N, x.dtype, bm_, bk_, vmem_limit)
    x_p = _pad_to(_pad_to(x, bm_, 1), bk_, 2)
    a_p = _pad_to(A, bk_, 1)
    Mp, Kp = x_p.shape[1:]
    grid = (L, Mp // bm_, Kp // bk_)

    out = pl.pallas_call(
        functools.partial(lowrank_matmul_batched_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm_, bk_), lambda l, m, k: (l, m, k)),
            pl.BlockSpec((1, bk_, r), lambda l, m, k: (l, k, 0)),
            pl.BlockSpec((1, r, N), lambda l, m, k: (l, 0, 0)),  # B[l] resident
        ],
        out_specs=pl.BlockSpec((1, bm_, N), lambda l, m, k: (l, m, 0)),
        out_shape=jax.ShapeDtypeStruct((L, Mp, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm_, r), jnp.float32)],
        interpret=interpret,
    )(x_p, a_p, B)
    return out[:, :M]
