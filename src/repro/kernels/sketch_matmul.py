"""Pallas TPU kernel: tiled GEMM tuned for the RSI sketch shapes.

The hot loop of Alg 3.1 is ``X = W @ Y`` with W (C, D) large and Y (D, l)
tall-skinny (l = k + oversample, usually 64..1024).  Strategy:

  * grid (C/bm, l/bn, D/bk) with the reduction axis LAST (sequential on TPU);
  * fp32 VMEM scratch accumulator, written out on the final reduction step;
  * bn pads the skinny dim to the 128-lane width so the MXU stays dense;
  * blocks default to (256, 128, 512): VMEM footprint
    bm*bk + bk*bn + bm*bn(fp32) = 256KiB + 128KiB + 128KiB @ bf16 — well
    under the ~16 MiB/core budget, leaving room for double buffering.

The same kernel serves both directions of the power iteration (W @ Y and
W^T @ X) — the wrapper transposes via index maps, never materializing W^T.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["sketch_matmul_kernel", "sketch_matmul_pallas"]


def sketch_matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def sketch_matmul_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = 256,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """C = A @ B via pl.pallas_call.  A: (M, K), B: (K, N)."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm_, bn_, bk_ = min(bm, M), min(bn, N), min(bk, K)
    a_p = _pad_to(_pad_to(a, bm_, 0), bk_, 1)
    b_p = _pad_to(_pad_to(b, bk_, 0), bn_, 1)
    Mp, Kp = a_p.shape
    Np = b_p.shape[1]
    grid = (Mp // bm_, Np // bn_, Kp // bk_)

    out = pl.pallas_call(
        functools.partial(sketch_matmul_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk_, bn_), lambda m, n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        interpret=interpret,
    )(a_p, b_p)
    return out[:M, :N]
