"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sketch_matmul_ref(a, b):
    """(M,K) @ (K,N) with fp32 accumulation — RSI sketch GEMM oracle."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def lowrank_matmul_ref(x, A, B):
    """y = (x @ A) @ B — compressed-linear serving oracle."""
    t = jnp.matmul(x, A, preferred_element_type=jnp.float32).astype(x.dtype)
    return jnp.matmul(t, B, preferred_element_type=jnp.float32).astype(x.dtype)


def ssd_scan_ref(xbar, dt, B_in, C_in, A):
    """Sequential (non-chunked) SSD recurrence oracle.

    xbar: (B, L, nh, hd) dt-scaled inputs; dt: (B, L, nh); B_in/C_in: (B, L, s);
    A: (nh,) negative.  Returns (y (B,L,nh,hd), final_state (B,nh,hd,s))."""
    Bsz, L, nh, hd = xbar.shape
    s = B_in.shape[-1]

    def step(state, inp):
        xb_t, dt_t, b_t, c_t = inp  # (B,nh,hd),(B,nh),(B,s),(B,s)
        decay = jnp.exp(dt_t * A[None, :])  # (B,nh)
        state = state * decay[:, :, None, None] + jnp.einsum(
            "bs,bhd->bhds", b_t.astype(jnp.float32), xb_t.astype(jnp.float32)
        )
        y = jnp.einsum("bs,bhds->bhd", c_t.astype(jnp.float32), state)
        return state, y

    state0 = jnp.zeros((Bsz, nh, hd, s), jnp.float32)
    inputs = (
        xbar.swapaxes(0, 1),
        dt.astype(jnp.float32).swapaxes(0, 1),
        B_in.swapaxes(0, 1),
        C_in.swapaxes(0, 1),
    )
    state, ys = jax.lax.scan(step, state0, inputs)
    return ys.swapaxes(0, 1).astype(xbar.dtype), state


def decode_attention_ref(q, k_cache, v_cache, valid):
    """Dense one-token GQA attention over a cache — flash-decode oracle.

    q: (B, 1, H, hd); k_cache: (B, S, KV, hd); v_cache: (B, S, KV, vd);
    valid: (B, S) bool per-slot cache validity (strict: slot b never attends
    a position where valid[b] is False).

    Numerically this IS the masked softmax ``jax.nn.softmax`` computes —
    bit-identical on every row with at least one valid position (masked
    entries underflow to exactly 0 either way) — except that fully-masked
    rows (empty/inactive slots in the continuous-batching pool) produce
    ZEROS instead of attending uniformly over garbage: probabilities are
    re-masked after the exp, so the denominator stays 0 and is clamped.

    Memory discipline: the cache is NEVER cast — scores use fp32 MXU
    accumulation via preferred_element_type (an astype would materialize a
    fp32 copy of the whole multi-GB cache).
    """
    B, _, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qh = (q.reshape(B, KV, G, hd).astype(jnp.float32) * hd**-0.5).astype(k_cache.dtype)
    s = jnp.einsum("bkgh,bskh->bkgs", qh, k_cache, preferred_element_type=jnp.float32)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(valid[:, None, None, :], jnp.exp(s - m), 0.0)
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum(
        "bkgs,bskv->bkgv",
        p.astype(v_cache.dtype),
        v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, 1, H, v_cache.shape[-1]).astype(q.dtype)


def gather_pages(pool, block_table):
    """Reassemble a slot-contiguous cache view from a paged pool.

    pool: (P, page, ...) physical pages; block_table: (B, n_tbl) int32 page
    ids (entries may point at the pool's trash page — callers mask by
    ``n_valid``, so trash contents are never observed).  Returns
    (B, n_tbl * page, ...): logical position ``t`` of slot ``b`` lives at
    ``pool[block_table[b, t // page], t % page]``.

    When the logical depth equals a flat cache's ``max_len``, the gathered
    tensor is BIT-identical to the flat per-slot cache holding the same
    writes — which is what makes the paged serving engine's greedy outputs
    bit-identical to the flat engine's (tests/test_engine_parity.py).
    """
    B, n_tbl = block_table.shape
    page = pool.shape[1]
    g = pool[block_table]  # (B, n_tbl, page, ...)
    return g.reshape((B, n_tbl * page) + pool.shape[2:])


def paged_decode_attention_ref(q, k_pool, v_pool, block_table, n_valid):
    """Gather-einsum oracle for the paged flash-decode kernel.

    q: (B, 1, H, hd); pools: (P, page, KV, hd/vd) physical pages shared by
    all slots; block_table: (B, n_tbl) int32; n_valid: (B,) int32 number of
    valid logical positions per slot.  Materializes the per-slot gather the
    Pallas kernel avoids, then defers to :func:`decode_attention_ref` — so
    the paged and flat paths share one masking/zero-row contract.
    """
    k = gather_pages(k_pool, block_table)
    v = gather_pages(v_pool, block_table)
    S = k.shape[1]
    valid = jnp.arange(S)[None, :] < n_valid[:, None]
    return decode_attention_ref(q, k, v, valid)


def flash_attention_ref(q, k, v, *, causal=True):
    """Plain softmax attention oracle.  q/k/v: (B, S, H, hd) (same H)."""
    B, S, H, hd = q.shape
    s = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * (hd**-0.5)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
