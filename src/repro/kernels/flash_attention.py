"""Pallas TPU kernel: causal flash attention (prefill forward).

Standard online-softmax tiling: grid (batch*kv_heads*groups, Sq/bq, Skv/bkv)
with the KV axis innermost (sequential); running (m, l, acc) live in VMEM
scratch across KV steps.  The XLA path in models/attention.py remains the
autodiff/dry-run reference; this kernel is the TPU serving/prefill hot path
(forward only — training uses the custom-vjp XLA flash).

Block sizes default to (bq, bkv) = (256, 512): MXU-aligned (both multiples
of 128) and ~2.5 MiB VMEM at hd=128.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel", "flash_attention_pallas"]

NEG_INF = -1e30

# Declared worst-case head dims for the static VMEM gate
# (repro.analysis pallas-contract); block sizes bq/bkv resolve from their
# keyword defaults.  Raising a model past these must revisit the tiling.
VMEM_ANALYSIS_BOUNDS = {"hd": 256, "vd": 256}


def flash_attention_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale: float, causal: bool, n_kv: int, bq: int, bkv: int
):
    iq = pl.program_id(1)
    jkv = pl.program_id(2)

    @pl.when(jkv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]  # (bq, hd)
    k = k_ref[0]  # (bkv, hd)
    v = v_ref[0]  # (bkv, vd)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (bq, bkv)
    if causal:
        q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        k_pos = jkv * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(jkv == n_kv - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bkv", "interpret"))
def flash_attention_pallas(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Skv, H, hd)  (same head count: repeat GQA upstream)
    v: jax.Array,  # (B, Skv, H, vd)
    *,
    causal: bool = True,
    bq: int = 256,
    bkv: int = 512,
    interpret: bool = False,
):
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    vd = v.shape[-1]
    bq_ = min(bq, Sq)
    bkv_ = min(bkv, Skv)
    while Sq % bq_:
        bq_ //= 2
    while Skv % bkv_:
        bkv_ //= 2
    # (B*H, S, hd) layout so the head axis rides the parallel grid dim
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kt = k.transpose(0, 2, 1, 3).reshape(B * H, Skv, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(B * H, Skv, vd)
    grid = (B * H, Sq // bq_, Skv // bkv_)

    out = pl.pallas_call(
        functools.partial(
            flash_attention_kernel,
            scale=hd**-0.5,
            causal=causal,
            n_kv=grid[2],
            bq=bq_,
            bkv=bkv_,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq_, hd), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bkv_, hd), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, bkv_, vd), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, vd), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, vd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_, 1), jnp.float32),
            pltpu.VMEM((bq_, 1), jnp.float32),
            pltpu.VMEM((bq_, vd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(B, H, Sq, vd).transpose(0, 2, 1, 3)
