"""jit'd public wrappers for the Pallas kernels with XLA fallbacks.

On CPU (this container) Pallas-TPU kernels cannot lower natively, so the
wrappers run them with ``interpret=True`` when the backend is CPU — the
kernel *body* executes (all BlockSpec index maps, scratch semantics, grid
order), which is what the allclose tests validate.  On TPU backends they
compile for real.  Shapes outside kernel residency limits fall back to the
reference implementations (which are themselves production-grade XLA).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.lowrank_matmul import fits_fused, lowrank_matmul_pallas
from repro.kernels.sketch_matmul import sketch_matmul_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas
from repro.kernels.flash_attention import flash_attention_pallas

__all__ = ["lowrank_matmul", "sketch_matmul", "ssd_scan", "flash_attention"]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def sketch_matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """(M,K) @ (K,N) — RSI sketch GEMM."""
    return sketch_matmul_pallas(a, b, interpret=_interpret())


def lowrank_matmul(x: jax.Array, A: jax.Array, B: jax.Array) -> jax.Array:
    """y = (x @ A) @ B with the (., r) intermediate fused in VMEM.

    Accepts leading batch dims on x (flattened internally).
    """
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if not fits_fused(A.shape[-1], B.shape[-1]):
        y = _ref.lowrank_matmul_ref(x2, A, B)
    else:
        y = lowrank_matmul_pallas(x2, A, B, interpret=_interpret())
    return y.reshape(lead + (B.shape[-1],))


def ssd_scan(x, dt, B_in, C_in, A, *, chunk: int = 128):
    """Mamba2 SSD chunked scan.  Returns (y, final_state)."""
    return ssd_scan_pallas(x, dt, B_in, C_in, A, chunk=chunk, interpret=_interpret())


def flash_attention(q, k, v, *, causal: bool = True):
    """Forward-only flash attention (prefill hot path)."""
    return flash_attention_pallas(q, k, v, causal=causal, interpret=_interpret())
