"""Public kernel entry points, routed through the unified dispatch runtime.

Historically these wrappers owned backend selection themselves (interpret
detection, ``fits_fused`` residency checks, XLA fallbacks).  All of that
policy now lives in :mod:`repro.runtime.dispatch`; this module remains as the
stable ``kernels.ops`` import surface.  Pin a backend with::

    from repro.runtime.dispatch import use_dispatch
    with use_dispatch(backend="pallas"):   # or "xla" / "reference" / "auto"
        y = ops.lowrank_matmul(x, A, B)
"""

from __future__ import annotations

import jax

from repro.runtime import dispatch as _dispatch

__all__ = [
    "lowrank_matmul",
    "sketch_matmul",
    "ssd_scan",
    "flash_attention",
    "decode_attention",
]


def sketch_matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """(M,K) @ (K,N) — RSI sketch GEMM."""
    return _dispatch.sketch_matmul(a, b)


def lowrank_matmul(x: jax.Array, A: jax.Array, B: jax.Array) -> jax.Array:
    """y = (x @ A) @ B via the dispatch table (fused VMEM kernel, batched
    fused kernel for stacked factors, two tiled GEMMs, or dense remat).

    Accepts leading batch dims on x, and stacked (L, ...) factors.
    """
    return _dispatch.lowrank_apply(x, A, B)


def ssd_scan(x, dt, B_in, C_in, A, *, chunk: int = 128):
    """Mamba2 SSD chunked scan.  Returns (y, final_state)."""
    return _dispatch.ssd_scan(x, dt, B_in, C_in, A, chunk=chunk)


def flash_attention(q, k, v, *, causal: bool = True):
    """Forward-only flash attention (prefill hot path)."""
    return _dispatch.flash_attention(q, k, v, causal=causal)


def decode_attention(q, k_cache, v_cache, valid):
    """One-token GQA attention over a KV cache (serving decode hot path).

    valid: (B, S) bool strict per-slot mask; fully-masked rows yield zeros.
    """
    return _dispatch.decode_attention(q, k_cache, v_cache, valid)
