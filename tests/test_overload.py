"""Overload-resilience machinery: nested rank tiers (slice_rank + per-tier
certificates), admission policy (tier degradation + deadline shedding),
cost-aware warm-cache eviction, session close (prefix-branch drop), NaN
quarantine, fault injection, and graceful shutdown."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_arch
from repro.core.bounds import certify_tier
from repro.core.lowrank import is_lowrank, min_rank, slice_rank
from repro.models.model import build_model
from repro.runtime.fault_tolerance import FaultInjector
from repro.serving.engine import AdmissionPolicy, Engine, Request
from repro.serving.scheduler import PageAllocator, PrefixIndex, Scheduler, SlotAllocator


# --------------------------------------------------------------------------- #
# slice_rank: nested tiers are prefix slices
# --------------------------------------------------------------------------- #
def _factored(shape_a, shape_b, seed=0):
    k = jax.random.PRNGKey(seed)
    ka, kb = jax.random.split(k)
    return {
        "a": jax.random.normal(ka, shape_a, jnp.float32),
        "b": jax.random.normal(kb, shape_b, jnp.float32),
    }


def test_slice_rank_is_prefix_slice():
    """Tier factors are EXACT prefix slices of the stored factors — the RSI
    nesting property (singular directions sorted descending) is what makes
    one checkpoint serve every tier."""
    params = {"layer": {"w": _factored((32, 8), (8, 48))}}
    out = slice_rank(params, 0.5)
    a, b = params["layer"]["w"]["a"], params["layer"]["w"]["b"]
    np.testing.assert_array_equal(np.asarray(out["layer"]["w"]["a"]), np.asarray(a[:, :4]))
    np.testing.assert_array_equal(np.asarray(out["layer"]["w"]["b"]), np.asarray(b[:4, :]))


def test_slice_rank_stacked_factors_and_dense_passthrough():
    """Stacked scan/MoE factors slice on the RANK axis only; dense leaves and
    non-factored subtrees pass through untouched (same objects — zero copy)."""
    dense = jnp.ones((16, 16))
    params = {
        "stack": {"w": _factored((4, 32, 8), (4, 8, 48))},
        "moe": {"w": _factored((2, 3, 32, 8), (2, 3, 8, 48))},
        "dense": dense,
        "nested": {"leaf": dense},
    }
    out = slice_rank(params, 0.25)
    assert out["stack"]["w"]["a"].shape == (4, 32, 2)
    assert out["stack"]["w"]["b"].shape == (4, 2, 48)
    assert out["moe"]["w"]["a"].shape == (2, 3, 32, 2)
    assert out["moe"]["w"]["b"].shape == (2, 3, 2, 48)
    assert out["dense"] is dense
    assert out["nested"]["leaf"] is dense


def test_slice_rank_fraction_validation_and_identity():
    params = {"w": _factored((8, 4), (4, 8))}
    assert slice_rank(params, 1.0) is params  # identity, not a copy
    with pytest.raises(ValueError):
        slice_rank(params, 0.0)
    with pytest.raises(ValueError):
        slice_rank(params, 1.5)
    # a tiny fraction never slices below rank 1
    out = slice_rank(params, 1e-6)
    assert out["w"]["a"].shape[-1] == 1


def test_min_rank_reports_smallest_factored_rank():
    params = {
        "w1": _factored((8, 6), (6, 8)),
        "w2": _factored((8, 3), (3, 8), seed=1),
        "dense": jnp.ones((4, 4)),
    }
    assert min_rank(params) == 3
    assert min_rank({"dense": jnp.ones((4, 4))}) == 0
    assert is_lowrank(params["w1"]) and not is_lowrank(params)


# --------------------------------------------------------------------------- #
# certify_tier: Thm 3.2 on the sliced-off tail
# --------------------------------------------------------------------------- #
def test_certify_tier_bound_matches_dropped_tail():
    """The tier's extra deviation over the stored rank is the spectral norm
    of the dropped factor tail; full rank certifies EXACTLY zero, and deeper
    slices certify monotonically larger bounds."""
    a, b = _factored((32, 8), (8, 48))["a"], _factored((32, 8), (8, 48))["b"]
    key = jax.random.PRNGKey(0)
    full = certify_tier(a, b, 8, key, q=2)
    assert full.spectral_error == 0.0 and full.prob_deviation_bound == 0.0
    c4 = certify_tier(a, b, 4, key, q=2)
    c2 = certify_tier(a, b, 2, key, q=2)
    tail4 = np.asarray(a[:, 4:] @ b[4:, :])
    ref4 = np.linalg.svd(tail4, compute_uv=False)[0]
    assert c4.spectral_error == pytest.approx(ref4, rel=1e-3)
    assert 0.0 < c4.prob_deviation_bound <= c2.prob_deviation_bound
    assert c4.rank == 4 and c4.q == 2


def test_certify_tier_stacked_takes_worst_slice():
    p = _factored((3, 16, 6), (3, 6, 20))
    key = jax.random.PRNGKey(1)
    cert = certify_tier(p["a"], p["b"], 3, key, q=1)
    worst = max(
        np.linalg.svd(np.asarray(p["a"][i, :, 3:] @ p["b"][i, 3:, :]),
                      compute_uv=False)[0]
        for i in range(3)
    )
    assert cert.spectral_error == pytest.approx(worst, rel=1e-3)


# --------------------------------------------------------------------------- #
# AdmissionPolicy + deadline shedding (pure scheduler level)
# --------------------------------------------------------------------------- #
def _req(prompt_len=4, max_new=4, **kw):
    return Request(
        prompt=np.arange(prompt_len, dtype=np.int32), max_new_tokens=max_new, **kw
    )


def test_policy_degrades_only_under_pressure():
    pol = AdmissionPolicy(n_tiers=3, degrade_queue_depth=4, degrade_free_frac=0.25)
    r = _req(min_tier=2)
    assert pol.choose_tier(r, queue_depth=1, free_frac=1.0) == 0  # no pressure
    assert pol.choose_tier(r, queue_depth=4, free_frac=1.0) == 2  # queue depth
    assert pol.choose_tier(r, queue_depth=0, free_frac=0.1) == 2  # page pressure
    # a request that pins min_tier=0 is NEVER degraded
    assert pol.choose_tier(_req(min_tier=0), 9, 0.0) == 0
    # min_tier beyond the engine's tiers clamps to the deepest real tier
    assert pol.choose_tier(_req(min_tier=7), 9, 0.0) == 2


def test_policy_never_degrades_resumed_continuations():
    pol = AdmissionPolicy(n_tiers=2, degrade_queue_depth=1)
    cont = _req(min_tier=1)
    cont._parent = _req()
    assert pol.choose_tier(cont, queue_depth=9, free_frac=0.0) == 0


def test_scheduler_sheds_expired_waiters_with_structured_rejection():
    sched = Scheduler(
        SlotAllocator(1), policy=AdmissionPolicy(n_tiers=1, shed_deadlines=True)
    )
    live = _req()
    live.t_submit = time.perf_counter()
    stale = _req(deadline_ms=5.0)
    stale.t_submit = time.perf_counter() - 1.0  # expired 995 ms ago
    stale.uid = 7
    sched.enqueue(stale)
    sched.enqueue(live)
    placed = sched.admit()
    assert [r.uid for _, r in placed] == [live.uid]
    shed = sched.drain_shed()
    assert len(shed) == 1 and shed[0] is stale
    assert stale.status == "shed" and stale.t_done > 0
    rej = stale.rejected
    assert rej.uid == 7 and rej.reason == "deadline-expired"
    assert rej.waited_ms > 900 and rej.deadline_ms == 5.0 and rej.queue_depth >= 1
    assert sched.drain_shed() == []  # drained exactly once


def test_scheduler_without_policy_ignores_deadlines():
    """Plain FIFO engines (the benchmark baseline) must not shed: deadlines
    are policy semantics, not request semantics."""
    sched = Scheduler(SlotAllocator(1))
    stale = _req(deadline_ms=1.0)
    stale.t_submit = time.perf_counter() - 1.0
    sched.enqueue(stale)
    placed = sched.admit()
    assert len(placed) == 1 and placed[0][1] is stale
    assert stale.status == "ok" and sched.drain_shed() == []


def test_scheduler_degrades_tier_at_admission():
    sched = Scheduler(
        SlotAllocator(2),
        policy=AdmissionPolicy(n_tiers=2, degrade_free_frac=0.5),
        pressure=lambda: 0.1,
    )
    a, b = _req(min_tier=1), _req(min_tier=0)
    sched.enqueue(a)
    sched.enqueue(b)
    sched.admit()
    assert a.tier == 1 and b.tier == 0
    assert sched.degraded == 1


# --------------------------------------------------------------------------- #
# cost-aware warm-cache eviction
# --------------------------------------------------------------------------- #
def test_eviction_prefers_never_rematched_pages():
    """A colder-but-newer page dies before a hot chain: eviction weight is
    pages-saved-on-rematch, LRU only breaks ties."""
    pool = PageAllocator(4)
    pages = pool.alloc(4)
    pool.mark_indexed(pages)
    pool.free(pages)  # all 4 cached; LRU order after reversed re-cache: 3,2,1,0
    pool.record_saved([0, 1])  # pages 0 and 1 are a hot chain
    pool.record_saved([0, 1])
    got = pool.alloc(3)  # no clean pages left: must evict 3 of 4
    # the two never-rematched pages (3, 2) die first, then the colder end
    # of the hot chain — page 0/1 with 2 hits each falls back to LRU
    assert set(got) == {3, 2, 1} or set(got) == {3, 2, 0}
    assert pool.n_cached == 1


def test_eviction_without_hits_degrades_to_exact_lru():
    pool = PageAllocator(3)
    pages = pool.alloc(3)
    pool.mark_indexed(pages)
    pool.free(pages)  # cached recency (old->new): 2, 1, 0
    assert pool.alloc(1) == [2]
    assert pool.alloc(1) == [1]


def test_record_saved_ignores_unindexed_pages():
    pool = PageAllocator(2)
    pool.record_saved([0, 1])  # never indexed: no weights accrue
    assert pool._hits == {}


def test_drop_cached_releases_without_eviction_accounting():
    pool = PageAllocator(3)
    pages = pool.alloc(3)
    pool.mark_indexed(pages)
    pool.free(pages)
    assert pool.n_cached == 3
    n = pool.drop_cached([0, 1, 99 % 3])  # page 0, 1, 0 -> 2 distinct entries
    assert n >= 2 and pool.evictions == 0
    assert pool.n_cached <= 1


# --------------------------------------------------------------------------- #
# PrefixIndex.drop_branch: session close
# --------------------------------------------------------------------------- #
def test_drop_branch_kills_chain_and_extensions():
    idx = PrefixIndex(4)
    base = np.arange(8, dtype=np.int32)  # 2 full pages
    turn2 = np.concatenate([base, np.arange(100, 108, dtype=np.int32)])  # 4 pages
    other = np.arange(200, 208, dtype=np.int32)  # unrelated session
    idx.register(base, [0, 1])
    idx.register(turn2, [0, 1, 2, 3])
    idx.register(other, [4, 5])
    dropped = idx.drop_branch(base)
    assert sorted(dropped) == [0, 1, 2, 3]
    assert idx.match(turn2) == [] and idx.match(base) == []
    assert idx.match(other) == [4, 5]  # the unrelated session survives
    assert idx.drop_branch(base) == []  # idempotent


def test_drop_branch_unknown_prefix_is_noop():
    idx = PrefixIndex(4)
    idx.register(np.arange(8, dtype=np.int32), [0, 1])
    assert idx.drop_branch(np.arange(50, 58, dtype=np.int32)) == []
    assert len(idx) == 2


def test_engine_drop_session_frees_cached_pages():
    """Closing a session drops its branch from every tier index AND releases
    the warm-cache pages immediately — a follow-up on the dropped session
    re-prefills cold while other sessions keep matching."""
    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(21)
    p_a = rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32)
    p_b = rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32)
    eng = Engine(
        model, params, n_slots=2, max_len=32, page_size=4, share_prefix=True
    )
    ra = eng.run([Request(prompt=p_a, max_new_tokens=5)])[0]
    rb = eng.run([Request(prompt=p_b, max_new_tokens=5)])[0]
    cached_before = eng.prefix_cached_pages
    assert cached_before > 0
    freed = eng.drop_session(p_a)
    assert freed > 0 and eng.prefix_cached_pages == cached_before - freed
    assert eng.drop_session(p_a) == 0  # idempotent until the session returns
    # session A re-prefills cold; session B still matches warm pages
    fa = np.concatenate([p_a, np.asarray(ra.tokens, np.int32)])
    fb = np.concatenate([p_b, np.asarray(rb.tokens, np.int32)])
    r2a = eng.run([Request(prompt=fa, max_new_tokens=3)])[0]
    r2b = eng.run([Request(prompt=fb, max_new_tokens=3)])[0]
    assert r2a.prefill_skipped == 0
    assert r2b.prefill_skipped > 0
    # flat engines: structurally a no-op
    flat = Engine(model, params, n_slots=1, max_len=32)
    assert flat.drop_session(p_a) == 0


# --------------------------------------------------------------------------- #
# engine-level overload behavior
# --------------------------------------------------------------------------- #
def test_engine_degrades_admission_under_page_pressure():
    """With the pool nearly full, a min_tier=1 request admits DEGRADED
    instead of queueing at full rank, and carries the tier certificate."""
    from repro.core import CompressionPolicy, compress_tree, spectralize_params

    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = spectralize_params(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(9))
    params, _, _ = compress_tree(
        params, CompressionPolicy(alpha=0.5, q=2, min_dim=16), jax.random.PRNGKey(1)
    )
    rng = np.random.default_rng(30)
    eng = Engine(
        model, params, n_slots=2, max_len=32, page_size=4, kv_pages=10,
        decode_block=2, tiers=(1.0, 0.5), tier_q=2,
        admission=AdmissionPolicy(n_tiers=2, degrade_free_frac=0.9),
    )
    r0 = eng.submit(Request(
        prompt=rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32),
        max_new_tokens=16, min_tier=0,
    ))
    eng.step()  # r0 stays resident holding 6/10 pages: pressure is on
    assert eng.n_active == 1 and eng._free_page_frac() < 0.9
    r1 = eng.submit(Request(
        prompt=rng.integers(0, cfg.vocab, size=(6,)).astype(np.int32),
        max_new_tokens=4, min_tier=1,
    ))
    while eng.has_work:
        eng.step()
    assert r0.tier == 0  # min_tier=0 pins full rank even under pressure
    assert r1.tier == 1
    assert eng.degraded_admissions == 1
    assert r1.certificate is not None
    assert r1.certificate.prob_deviation_bound > 0.0
    assert r1.status == "ok" and len(r1.tokens) == 4
    assert r0.status == "ok" and len(r0.tokens) == 16


def test_engine_sheds_expired_waiters_in_step():
    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(31)
    eng = Engine(
        model, params, n_slots=1, max_len=32, page_size=4, kv_pages=4,
        admission=AdmissionPolicy(n_tiers=1),
    )
    r0 = eng.submit(Request(
        prompt=rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32),
        max_new_tokens=8,
    ))
    waiter = eng.submit(Request(
        prompt=rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32),
        max_new_tokens=4, deadline_ms=1.0,
    ))
    eng.step()  # r0 admitted (whole pool); waiter queues with a 1 ms deadline
    time.sleep(0.01)
    finished = []
    while eng.has_work:
        finished.extend(eng.step())
    assert waiter in finished
    assert waiter.status == "shed"
    assert waiter.rejected is not None
    assert waiter.rejected.reason == "deadline-expired"
    assert r0.status == "ok" and len(r0.tokens) == 8


def test_engine_graceful_drain_on_stop():
    """run(stop=...): queued work sheds with a "shutdown" rejection, active
    slots decode to completion — never killed mid-stream."""
    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(32)
    reqs = [
        Request(prompt=rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32),
                max_new_tokens=6)
        for _ in range(3)
    ]
    eng = Engine(model, params, n_slots=1, max_len=32, page_size=4, kv_pages=4)
    calls = {"n": 0}

    def stop():
        calls["n"] += 1
        return calls["n"] > 2  # let the first request admit, then drain

    finished = eng.run(reqs, stop=stop)
    assert not eng.has_work
    done = [r for r in finished if r.status == "ok"]
    shed = [r for r in finished if r.status == "shed"]
    assert len(done) >= 1 and len(shed) >= 1 and len(done) + len(shed) <= 3
    for r in done:
        assert len(r.tokens) == 6  # in-flight work finished, not truncated
    for r in shed:
        assert r.rejected.reason == "shutdown"


def test_engine_graceful_drain_under_active_fault_injection():
    """SIGINT mid-incident: a stop() drain lands while a nan_logits fault
    is quarantining a request.  Every request must still reach a terminal
    status (ok / error / shed-"shutdown"), nothing vanishes, and the page
    pool holds zero orphaned pages at exit — quarantine frees its pages
    even when the engine is simultaneously draining."""
    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(35)
    reqs = [
        Request(prompt=rng.integers(0, cfg.vocab, size=(6,)).astype(np.int32),
                max_new_tokens=8)
        for _ in range(4)
    ]
    inj = FaultInjector(nan_logits=(0, 4))  # uid 0 poisoned mid-decode
    eng = Engine(model, params, n_slots=2, max_len=32, page_size=4,
                 decode_block=2, injector=inj)
    calls = {"n": 0}

    def stop():
        calls["n"] += 1
        return calls["n"] > 3  # flip while uid 0/1 decode, 2/3 still queued

    finished = eng.run(reqs, stop=stop)
    assert not eng.has_work
    assert inj.fired.get("nan_logits") == 1
    assert eng.quarantined == 1
    assert len(finished) == 4  # zero silently lost
    by_status: dict = {}
    for r in finished:
        by_status.setdefault(r.status, []).append(r)
    assert set(by_status) <= {"ok", "error", "shed"}
    assert len(by_status.get("error", [])) == 1
    assert "non-finite" in by_status["error"][0].error
    assert len(by_status.get("shed", [])) >= 1  # the drain genuinely shed
    for r in by_status.get("shed", []):
        assert r.rejected is not None and r.rejected.reason == "shutdown"
    for r in by_status.get("ok", []):
        assert len(r.tokens) == 8  # in-flight work finished, not truncated
    # allocator invariants at exit: no orphaned pages, full free list
    assert eng.pages_in_use == 0
    assert eng.page_pool.n_free == eng.kv_pages
    assert eng.scheduler.allocator.n_active == 0


# --------------------------------------------------------------------------- #
# fault injection + quarantine
# --------------------------------------------------------------------------- #
def test_injector_deny_pages_window_and_slow_steps():
    inj = FaultInjector(deny_pages=(2, 4), slow_steps=(1, 2), slow_ms=1.0)
    assert not inj.deny_reserve(1)
    assert inj.deny_reserve(2) and inj.deny_reserve(3)
    assert not inj.deny_reserve(4)
    t0 = time.perf_counter()
    inj.on_step(1)
    assert time.perf_counter() - t0 >= 1e-3
    inj.on_step(5)  # outside the window: no sleep
    assert inj.fired == {"deny_pages": 2, "slow_step": 1}


def test_injector_poison_resolves_to_slot_and_block_step():
    inj = FaultInjector(nan_logits=(7, 10))
    uid_of = lambda s: {0: 3, 1: 7}.get(s)
    assert inj.poison_for(uid_of, 2, 0, 8) == (-1, -1)  # step 10 not in [0, 8)
    assert inj.poison_for(uid_of, 2, 8, 8) == (1, 2)  # 10 - 8 = 2, slot 1
    assert inj.fired.get("nan_logits") == 1
    assert FaultInjector().poison_for(uid_of, 2, 0, 8) == (-1, -1)


def test_engine_quarantines_poisoned_request_others_unaffected():
    """The acceptance contract: the poisoned request errors out with a
    structured status, every OTHER request's tokens are bit-identical to an
    uninjected run, and the engine keeps serving afterwards."""
    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(33)
    prompts = [rng.integers(0, cfg.vocab, size=(6,)).astype(np.int32) for _ in range(3)]
    steps = [8, 8, 8]

    clean = Engine(model, params, n_slots=3, max_len=32, decode_block=4)
    refs = clean.run(
        [Request(prompt=p.copy(), max_new_tokens=s) for p, s in zip(prompts, steps)]
    )
    refs = {r.uid: r.tokens for r in refs}

    inj = FaultInjector(nan_logits=(1, 5))  # uid 1, global decode step 5
    eng = Engine(model, params, n_slots=3, max_len=32, decode_block=4, injector=inj)
    reqs = [
        Request(prompt=p.copy(), max_new_tokens=s) for p, s in zip(prompts, steps)
    ]
    out = eng.run(reqs)
    assert inj.fired.get("nan_logits") == 1
    assert eng.quarantined == 1
    by_uid = {r.uid: r for r in out}
    bad = by_uid[1]
    assert bad.status == "error" and "non-finite" in bad.error
    assert 0 < len(bad.tokens) < 8  # froze mid-stream, kept pre-fault tokens
    assert bad.tokens == refs[1][: len(bad.tokens)]  # nothing corrupt emitted
    for uid in (0, 2):
        assert by_uid[uid].status == "ok"
        assert by_uid[uid].tokens == refs[uid], "quarantine leaked into the batch"
    # the engine keeps serving after a quarantine
    again = eng.run([Request(prompt=prompts[0].copy(), max_new_tokens=4)])[0]
    assert again.status == "ok" and len(again.tokens) == 4


def test_injector_deny_pages_starves_admission_then_recovers():
    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(34)
    inj = FaultInjector(deny_pages=(1, 3))
    eng = Engine(
        model, params, n_slots=1, max_len=32, page_size=4, kv_pages=8,
        injector=inj,
    )
    r = eng.submit(Request(
        prompt=rng.integers(0, cfg.vocab, size=(6,)).astype(np.int32),
        max_new_tokens=4,
    ))
    eng.step()  # step 1: reservation denied
    assert eng.n_waiting == 1 and inj.fired.get("deny_pages", 0) >= 1
    eng.step()  # step 2: still denied
    assert eng.n_waiting == 1
    while eng.has_work:
        eng.step()  # step 3+: window closed, admission recovers
    assert r.status == "ok" and len(r.tokens) == 4
