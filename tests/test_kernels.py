"""Per-kernel allclose tests vs the ref.py jnp oracles (interpret mode),
sweeping shapes and dtypes, plus hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.lowrank_matmul import lowrank_matmul_pallas
from repro.kernels.sketch_matmul import sketch_matmul_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas
from repro.kernels.flash_attention import flash_attention_pallas

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5), jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


def _rand(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) / shape[-1] ** 0.25).astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "M,K,N", [(64, 128, 32), (100, 257, 65), (256, 512, 128), (33, 70, 200)]
)
def test_sketch_matmul_allclose(M, K, N, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    a, b = _rand(k1, (M, K), dtype), _rand(k2, (K, N), dtype)
    got = sketch_matmul_pallas(a, b, bm=32, bn=32, bk=64, interpret=True)
    want = ref.sketch_matmul_ref(a, b)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **TOL[dtype]
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("M,K,r,N", [(64, 128, 16, 64), (100, 250, 32, 48), (256, 512, 64, 128)])
def test_lowrank_matmul_allclose(M, K, r, N, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    x, A, B = _rand(ks[0], (M, K), dtype), _rand(ks[1], (K, r), dtype), _rand(ks[2], (r, N), dtype)
    got = lowrank_matmul_pallas(x, A, B, bm=32, bk=64, interpret=True)
    want = ref.lowrank_matmul_ref(x, A, B)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **TOL[dtype]
    )


def test_lowrank_matmul_wrapper_batched():
    from repro.runtime.dispatch import use_dispatch

    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    x = _rand(ks[0], (2, 5, 96), jnp.float32)
    A = _rand(ks[1], (96, 8), jnp.float32)
    B = _rand(ks[2], (8, 40), jnp.float32)
    # pin the Pallas backend: auto on CPU would route to the two-GEMM
    # fallback, which IS the reference — the test would compare ref to ref
    with use_dispatch(backend="pallas"):
        got = ops.lowrank_matmul(x, A, B)
    want = ref.lowrank_matmul_ref(x.reshape(-1, 96), A, B).reshape(2, 5, 40)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,L,nh,hd,s,chunk", [(2, 64, 4, 16, 16, 16), (1, 128, 2, 8, 32, 32), (2, 96, 3, 16, 8, 32)])
def test_ssd_scan_allclose(B, L, nh, hd, s, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = _rand(ks[0], (B, L, nh, hd), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, nh), jnp.float32))
    B_in = _rand(ks[2], (B, L, s), dtype)
    C_in = _rand(ks[3], (B, L, s), dtype)
    A = -jnp.exp(jax.random.normal(ks[4], (nh,), jnp.float32) * 0.3)
    xbar = (x.astype(jnp.float32) * dt[..., None]).astype(x.dtype)
    got_y, got_state = ssd_scan_pallas(x, dt, B_in, C_in, A, chunk=chunk, interpret=True)
    want_y, want_state = ref.ssd_scan_ref(xbar, dt, B_in, C_in, A)
    np.testing.assert_allclose(
        np.asarray(got_y, np.float32), np.asarray(want_y, np.float32),
        rtol=0.06 if dtype == jnp.bfloat16 else 1e-4,
        atol=0.06 if dtype == jnp.bfloat16 else 1e-4,
    )
    np.testing.assert_allclose(
        np.asarray(got_state), np.asarray(want_state),
        rtol=0.06 if dtype == jnp.bfloat16 else 1e-4,
        atol=0.06 if dtype == jnp.bfloat16 else 1e-4,
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("B,S,H,hd", [(1, 64, 2, 16), (2, 128, 4, 32)])
def test_flash_attention_allclose(B, S, H, hd, causal, dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = _rand(ks[0], (B, S, H, hd), dtype)
    k = _rand(ks[1], (B, S, H, hd), dtype)
    v = _rand(ks[2], (B, S, H, hd), dtype)
    got = flash_attention_pallas(q, k, v, causal=causal, bq=32, bkv=32, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **TOL[dtype]
    )


try:  # property tests only where the optional dep is installed
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        M=st.integers(8, 80),
        K=st.integers(8, 120),
        r=st.integers(1, 16),
        N=st.integers(8, 64),
    )
    def test_lowrank_matmul_property(seed, M, K, r, N):
        """Property: fused kernel == two exact matmuls for arbitrary shapes."""
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        x, A, B = (
            jax.random.normal(ks[0], (M, K)),
            jax.random.normal(ks[1], (K, r)),
            jax.random.normal(ks[2], (r, N)),
        )
        got = lowrank_matmul_pallas(x, A, B, bm=16, bk=32, interpret=True)
        want = (x @ A) @ B
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_ragged_n_valid(dtype):
    """Regression: ``decode_attention`` masks strictly by PER-SEQUENCE
    n_valid.  Sequences of different lengths share one cache tensor; stale
    garbage beyond each sequence's n_valid must never leak into its output
    (the continuous-batching invariant)."""
    from repro.models.attention import decode_attention

    B, S, KV, G, hd = 3, 12, 2, 2, 16
    H = KV * G
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = _rand(ks[0], (B, 1, H, hd), dtype)
    k = _rand(ks[1], (B, S, KV, hd), dtype)
    v = _rand(ks[2], (B, S, KV, hd), dtype)
    n_valid = jnp.array([3, 12, 7], jnp.int32)

    # poison every slot past each sequence's n_valid with huge values: if the
    # mask were batch-wide (or off by one), the softmax would latch onto them
    tail = jnp.arange(S)[None, :, None, None] >= n_valid[:, None, None, None]
    k_poison = jnp.where(tail, jnp.asarray(1e4, dtype), k)
    v_poison = jnp.where(tail, jnp.asarray(1e4, dtype), v)

    got = decode_attention(q, k_poison, v_poison, n_valid)
    assert bool(jnp.all(jnp.isfinite(got)))
    # per-sequence reference: each row attends over ONLY its valid prefix
    for b in range(B):
        nb = int(n_valid[b])
        want = decode_attention(
            q[b : b + 1], k[b : b + 1, :nb], v[b : b + 1, :nb], nb
        )
        np.testing.assert_allclose(
            np.asarray(got[b : b + 1], np.float32),
            np.asarray(want, np.float32),
            **TOL[dtype],
        )
    # scalar n_valid (the classic fixed-shape path) still broadcasts
    uniform = decode_attention(q, k, v, 5)
    uniform_vec = decode_attention(q, k, v, jnp.full((B,), 5, jnp.int32))
    np.testing.assert_array_equal(np.asarray(uniform), np.asarray(uniform_vec))


def test_kernel_flops_match_roofline_model():
    """rsi_flops bookkeeping consistency (used by the benchmark layer)."""
    from repro.core.rsi import rsi_flops

    assert rsi_flops(4096, 25088, 200, 2) > rsi_flops(4096, 25088, 200, 1)
    assert rsi_flops(100, 100, 10, 1) > 0
