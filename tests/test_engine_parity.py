"""Greedy-parity contract of the continuous-batching engine.

For temperature-0 requests the engine must emit, PER REQUEST, exactly the
tokens ``serve_step.greedy_generate`` produces for that prompt alone —
bit-identical, for every architecture in the reduced registry, both for a
single request and for staggered multi-request admission (ragged prompt
lengths, mid-stream slot handoff).  The scheduler may change WHEN a
sequence advances, never WHAT it computes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_arch
from repro.data.synthetic import modality_extras
from repro.models.model import build_model
from repro.serving import Engine, Request, SamplingParams
from repro.train.serve_step import greedy_generate

MAX_LEN = 16


def _reference(model, params, prompt, extras, steps):
    batch = {"tokens": jnp.asarray(prompt[None])}
    batch.update({k: jnp.asarray(v[None]) for k, v in extras.items()})
    out = greedy_generate(model, params, batch, steps=steps, max_len=MAX_LEN)
    return np.asarray(out)[0].tolist()


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_engine_greedy_parity(arch_id):
    """Single request AND staggered 2-request admission, one arch each."""
    cfg = get_arch(arch_id, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    # ragged prompts: r1 shorter than r0, so staggered admission exercises
    # padded-micro-batch prefill (attention) / exact-length grouping (ssm)
    prompts = [
        rng.integers(0, cfg.vocab, size=(6,)).astype(np.int32),
        rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32),
    ]
    extras = [modality_extras(cfg, rng), modality_extras(cfg, rng)]
    steps = [5, 6]
    refs = [
        _reference(model, params, p, e, s)
        for p, e, s in zip(prompts, extras, steps)
    ]

    # --- single request through the engine --------------------------------
    eng = Engine(model, params, n_slots=2, max_len=MAX_LEN)
    r = eng.submit(
        Request(prompt=prompts[0], max_new_tokens=steps[0], extras=extras[0])
    )
    while eng.has_work:
        eng.step()
    assert r.tokens == refs[0], f"single-request parity broken for {arch_id}"

    # --- staggered multi-request admission on a FRESH engine ---------------
    eng = Engine(model, params, n_slots=2, max_len=MAX_LEN)
    r0 = eng.submit(
        Request(prompt=prompts[0], max_new_tokens=steps[0], extras=extras[0])
    )
    eng.step()
    eng.step()  # r0 is mid-decode when r1 arrives
    r1 = eng.submit(
        Request(prompt=prompts[1], max_new_tokens=steps[1], extras=extras[1])
    )
    while eng.has_work:
        eng.step()
    assert r0.tokens == refs[0], f"staggered parity broken for {arch_id} (r0)"
    assert r1.tokens == refs[1], f"staggered parity broken for {arch_id} (r1)"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_paged_engine_greedy_parity(arch_id):
    """PAGED pool + chunked prefill: still bit-identical to greedy_generate,
    for every arch, at two page sizes x two prefill-chunk sizes — including
    chunk boundaries not aligned to the prompt length (prompts 6 and 4 vs
    chunks 3 and 5: 6 = 3+3 aligned, 6 = 5+1 ragged; the 4-prompt rides the
    monolithic path under chunk 5, covering the fallback).  Staggered
    admission exercises page allocation against a half-occupied pool."""
    cfg = get_arch(arch_id, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(0, cfg.vocab, size=(6,)).astype(np.int32),
        rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32),
    ]
    extras = [modality_extras(cfg, rng), modality_extras(cfg, rng)]
    steps = [5, 6]
    refs = [
        _reference(model, params, p, e, s)
        for p, e, s in zip(prompts, extras, steps)
    ]
    for page_size, chunk in ((4, 3), (8, 5)):
        eng = Engine(
            model, params, n_slots=2, max_len=MAX_LEN,
            page_size=page_size, prefill_chunk=chunk,
        )
        r0 = eng.submit(
            Request(prompt=prompts[0], max_new_tokens=steps[0], extras=extras[0])
        )
        eng.step()
        eng.step()  # r0 mid-decode (or mid-chunk) when r1 arrives
        r1 = eng.submit(
            Request(prompt=prompts[1], max_new_tokens=steps[1], extras=extras[1])
        )
        while eng.has_work:
            eng.step()
        assert r0.tokens == refs[0], (
            f"paged parity broken for {arch_id} (page={page_size}, chunk={chunk}, r0)"
        )
        assert r1.tokens == refs[1], (
            f"paged parity broken for {arch_id} (page={page_size}, chunk={chunk}, r1)"
        )


def test_paged_engine_parity_under_page_pressure():
    """3 requests against a pool that cannot hold them all at once: page
    exhaustion queues, pages recycle mid-trace, chunked prefill interleaves
    with running decodes — and every request still matches its solo
    reference exactly, at an unaligned chunk size."""
    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    prompts = [
        rng.integers(0, cfg.vocab, size=(n,)).astype(np.int32) for n in (9, 7, 4)
    ]
    steps = [3, 6, 6]
    refs = [
        _reference(model, params, p, {}, s) for p, s in zip(prompts, steps)
    ]
    # needs: ceil(12/4)=3, ceil(13/4)=4, ceil(10/4)=3 pages; 7 pages < 10
    eng = Engine(
        model, params, n_slots=3, max_len=MAX_LEN,
        page_size=4, kv_pages=7, prefill_chunk=5, decode_block=3,
    )
    reqs = [
        eng.submit(Request(prompt=p, max_new_tokens=s))
        for p, s in zip(prompts, steps)
    ]
    eng.step()
    assert eng.n_waiting >= 1  # the pool can't hold all three at once
    while eng.has_work:
        eng.step()
    assert eng.prefill_chunks >= 2  # the 9- and 7-token prompts chunked
    for i, (req, ref) in enumerate(zip(reqs, refs)):
        assert req.tokens == ref, f"request {i} diverged under page pressure"
    assert eng.pages_in_use == 0


def test_paged_engine_chunk_and_block_sizes_agree():
    """Page size, prefill chunk, and decode block are PURE layout/cadence
    knobs: emitted tokens are identical across all combinations."""
    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompts = [
        rng.integers(0, cfg.vocab, size=(n,)).astype(np.int32) for n in (11, 6)
    ]
    steps = [5, 4]
    outs = {}
    for key, kwargs in {
        "flat": dict(),
        "p4c3b1": dict(page_size=4, prefill_chunk=3, decode_block=1),
        "p4c4b8": dict(page_size=4, prefill_chunk=4, decode_block=8),
        "p8c5b3": dict(page_size=8, prefill_chunk=5, decode_block=3),
    }.items():
        eng = Engine(model, params, n_slots=2, max_len=MAX_LEN, **kwargs)
        reqs = [
            eng.submit(Request(prompt=p, max_new_tokens=s))
            for p, s in zip(prompts, steps)
        ]
        while eng.has_work:
            eng.step()
        outs[key] = [r.tokens for r in reqs]
    assert outs["flat"] == outs["p4c3b1"] == outs["p4c4b8"] == outs["p8c5b3"]


@pytest.mark.parametrize("decode_block", [1, 8])
def test_engine_parity_under_slot_churn(decode_block):
    """3 requests on 2 slots: the queued request is admitted into a REUSED
    slot mid-stream and must still match its solo reference exactly — both
    token-at-a-time (decode_block=1) and through the fused 8-token block."""
    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    prompts = [
        rng.integers(0, cfg.vocab, size=(n,)).astype(np.int32) for n in (5, 7, 4)
    ]
    steps = [3, 8, 6]
    refs = [
        _reference(model, params, p, {}, s) for p, s in zip(prompts, steps)
    ]
    eng = Engine(model, params, n_slots=2, max_len=MAX_LEN, decode_block=decode_block)
    reqs = [
        eng.submit(Request(prompt=p, max_new_tokens=s))
        for p, s in zip(prompts, steps)
    ]
    eng.step()  # admits the first two; slot exhaustion queues the third
    if decode_block == 1:
        # per-token stepping: both admitted requests are still mid-decode
        assert eng.n_active == 2 and eng.n_waiting == 1
    else:
        # the fused block may complete admitted requests within one step();
        # the third request must still be queued, never dropped
        assert eng.n_waiting == 1
    while eng.has_work:
        eng.step()
    for i, (req, ref) in enumerate(zip(reqs, refs)):
        assert req.tokens == ref, f"request {i} diverged under slot churn"


def test_engine_fused_block_matches_per_token_stepping():
    """decode_block is a PURE host-sync cadence knob: for identical traffic
    the emitted tokens are bit-identical across block sizes."""
    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompts = [
        rng.integers(0, cfg.vocab, size=(n,)).astype(np.int32) for n in (6, 4, 5)
    ]
    steps = [7, 5, 9]
    outs = {}
    for block in (1, 3, 8):
        eng = Engine(model, params, n_slots=2, max_len=MAX_LEN, decode_block=block)
        reqs = [
            eng.submit(Request(prompt=p, max_new_tokens=s))
            for p, s in zip(prompts, steps)
        ]
        while eng.has_work:
            eng.step()
        outs[block] = [r.tokens for r in reqs]
    assert outs[1] == outs[3] == outs[8]


def test_engine_host_sync_amortization():
    """The fused loop's whole point: one long greedy request decodes >= 8
    tokens per host round-trip (the acceptance cadence), and the emit masks
    account for every token exactly once."""
    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32)
    eng = Engine(model, params, n_slots=1, max_len=32, decode_block=8)
    req = eng.submit(Request(prompt=prompt, max_new_tokens=17))  # 1 prefill + 16 decode
    while eng.has_work:
        eng.step()
    assert len(req.tokens) == 17
    assert eng.decoded_tokens == 16
    assert eng.host_syncs == 2  # 16 decode tokens in two 8-token blocks
    assert eng.tokens_per_sync >= 8.0
    assert 0.0 < eng.batch_utilization <= 1.0
    # and the tokens still match the per-token reference
    out = greedy_generate(
        model, params, {"tokens": jnp.asarray(prompt[None])}, steps=17, max_len=32
    )
    assert req.tokens == np.asarray(out)[0].tolist()


def test_engine_parity_swa_beyond_window():
    """Ragged prompts LONGER than the sliding window: admission falls back
    to exact-length prefill groups (the ring layout rotates by the padded
    length), and parity must still hold."""
    cfg = get_arch("h2o-danube-1.8b", reduced=True)
    assert cfg.sliding_window is not None
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    W = cfg.sliding_window
    prompts = [
        rng.integers(0, cfg.vocab, size=(W + 8,)).astype(np.int32),
        rng.integers(0, cfg.vocab, size=(W + 3,)).astype(np.int32),
    ]
    steps = [5, 6]
    max_len = W + 16
    refs = []
    for p, s in zip(prompts, steps):
        out = greedy_generate(
            model, params, {"tokens": jnp.asarray(p[None])}, steps=s, max_len=max_len
        )
        refs.append(np.asarray(out)[0].tolist())
    eng = Engine(model, params, n_slots=2, max_len=max_len)
    reqs = [
        eng.submit(Request(prompt=p, max_new_tokens=s))
        for p, s in zip(prompts, steps)
    ]
    while eng.has_work:
        eng.step()
    assert reqs[0].tokens == refs[0]
    assert reqs[1].tokens == refs[1]


def test_engine_eos_stops_inside_fused_block():
    """Device-side stop detection: a request hitting its eos token mid-block
    stops emitting EXACTLY there — tokens after the stop never surface."""
    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(6)
    prompt = rng.integers(0, cfg.vocab, size=(5,)).astype(np.int32)

    probe = Engine(model, params, n_slots=1, max_len=MAX_LEN, decode_block=8)
    ref = probe.submit(Request(prompt=prompt, max_new_tokens=9))
    while probe.has_work:
        probe.step()
    assert len(ref.tokens) == 9
    # pick a mid-stream token as eos (first index whose token value hasn't
    # appeared earlier, so the truncation point is unambiguous)
    eos_idx = next(
        i for i in range(1, len(ref.tokens) - 1) if ref.tokens[i] not in ref.tokens[:i]
    )
    eos = ref.tokens[eos_idx]

    eng = Engine(
        model, params, n_slots=1, max_len=MAX_LEN, decode_block=8, eos_token=eos
    )
    req = eng.submit(Request(prompt=prompt, max_new_tokens=9))
    while eng.has_work:
        eng.step()
    assert req.tokens == ref.tokens[: eos_idx + 1]
    assert req.tokens[-1] == eos


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_shared_prefix_engine_greedy_parity(arch_id):
    """share_prefix is a pure MEMORY knob: with a common system prefix (2
    full pages) the sharing engine must emit tokens bit-identical to the
    unshared paged run — forked suffixes diverge where their tokens
    diverge and nowhere else — for every arch.  Chunk-capable attention
    families actually map shared pages (asserted via the hit counter);
    recurrent / window / cross-modal families run the same engine with
    sharing inert, which must change nothing."""
    cfg = get_arch(arch_id, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    sys = rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32)
    prompts = [
        np.concatenate([sys, rng.integers(0, cfg.vocab, size=(2,)).astype(np.int32)]),
        np.concatenate([sys, rng.integers(0, cfg.vocab, size=(3,)).astype(np.int32)]),
    ]
    extras = [modality_extras(cfg, rng), modality_extras(cfg, rng)]
    steps = [4, 5]

    outs = {}
    for share in (False, True):
        eng = Engine(
            model, params, n_slots=2, max_len=MAX_LEN, page_size=4,
            share_prefix=share,
        )
        r0 = eng.submit(
            Request(prompt=prompts[0], max_new_tokens=steps[0], extras=extras[0])
        )
        eng.step()
        eng.step()  # r0 mid-decode (its prefix pages registered) when r1 arrives
        r1 = eng.submit(
            Request(prompt=prompts[1], max_new_tokens=steps[1], extras=extras[1])
        )
        while eng.has_work:
            eng.step()
        outs[share] = [r0.tokens, r1.tokens]
        chunkable = cfg.family in ("dense", "moe") and cfg.sliding_window is None
        if share and chunkable:
            # r1 mapped the two full sys pages read-only
            assert eng.shared_page_hits == 2, f"no sharing for {arch_id}"
        elif share:
            assert eng.shared_page_hits == 0  # inert, by design
    assert outs[True] == outs[False], f"shared-prefix parity broken for {arch_id}"
    # and both agree with the solo reference
    assert outs[True][0] == _reference(model, params, prompts[0], extras[0], steps[0])
    assert outs[True][1] == _reference(model, params, prompts[1], extras[1], steps[1])


def test_shared_prefix_cow_fork_exact_page_boundary():
    """A follower whose ENTIRE prompt is covered by matched pages (prompt
    length an exact page multiple) re-runs only its final token — after
    COW-forking the last prefix page, so the re-write lands in a private
    copy and never in shared storage.  Its tokens, the donor's continued
    decode, and a third same-prefix request admitted after both finish
    (warm-cache revive) must all match the unshared run bit-exactly."""
    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32)  # 2 pages exactly
    steps = [6, 5, 4]

    outs = {}
    for share in (False, True):
        eng = Engine(
            model, params, n_slots=2, max_len=MAX_LEN, page_size=4,
            share_prefix=share, decode_block=1,
        )
        r0 = eng.submit(Request(prompt=prompt, max_new_tokens=steps[0]))
        eng.step()
        eng.step()
        r1 = eng.submit(Request(prompt=prompt, max_new_tokens=steps[1]))
        while eng.has_work:
            eng.step()
        r2 = eng.submit(Request(prompt=prompt, max_new_tokens=steps[2]))
        while eng.has_work:
            eng.step()
        outs[share] = [r0.tokens, r1.tokens, r2.tokens]
        if share:
            # r1 forked the partially-re-written last prefix page; r2
            # matched the CACHED pages after everyone released them
            assert eng.cow_forks == 2 and eng.shared_admissions == 2
    assert outs[True] == outs[False]


def test_shared_prefix_parity_under_page_churn():
    """Same-prefix requests against a pool too small for all of them:
    admission queues on pages, shared pages recycle only after their last
    reader releases, and a foreign-prefix request interleaves — every
    request still matches its solo reference exactly."""
    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(9)
    sys = rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32)
    prompts = [
        np.concatenate([sys, rng.integers(0, cfg.vocab, size=(2,)).astype(np.int32)]),
        np.concatenate([sys, rng.integers(0, cfg.vocab, size=(3,)).astype(np.int32)]),
        np.concatenate([sys, rng.integers(0, cfg.vocab, size=(1,)).astype(np.int32)]),
        rng.integers(0, cfg.vocab, size=(9,)).astype(np.int32),  # foreign prefix
    ]
    steps = [4, 5, 3, 3]
    refs = [
        _reference(model, params, p, {}, s) for p, s in zip(prompts, steps)
    ]
    eng = Engine(
        model, params, n_slots=4, max_len=MAX_LEN, page_size=4, kv_pages=8,
        share_prefix=True, decode_block=1,
    )
    reqs = [eng.submit(Request(prompt=prompts[0], max_new_tokens=steps[0]))]
    eng.step()  # donor registered (4 pages held)
    for p, s in zip(prompts[1:], steps[1:]):
        reqs.append(eng.submit(Request(prompt=p, max_new_tokens=s)))
    eng.step()
    # r1 shares 2 + allocs 2 (6 used), r2 shares 2 + allocs 1 (7 used);
    # the foreign request needs 3 fresh pages -> queues on the 1 free page
    assert eng.n_waiting == 1 and eng.pages_in_use == 7
    assert eng.shared_page_hits == 4 and eng.shared_admissions == 2
    while eng.has_work:
        eng.step()
    for i, (req, ref) in enumerate(zip(reqs, refs)):
        assert req.tokens == ref, f"request {i} diverged under shared-page churn"
    assert eng.pages_in_use == 0


def test_engine_sampling_deterministic_across_interleavings():
    """A stochastic request's tokens are a pure function of (seed, prompt) —
    independent of what else shares the batch."""
    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab, size=(6,)).astype(np.int32)
    sp = SamplingParams(temperature=0.7, top_k=20, seed=123)

    eng = Engine(model, params, n_slots=2, max_len=MAX_LEN)
    alone = eng.submit(Request(prompt=prompt, max_new_tokens=6, sampling=sp))
    while eng.has_work:
        eng.step()

    eng = Engine(model, params, n_slots=2, max_len=MAX_LEN)
    other = eng.submit(
        Request(prompt=rng.integers(0, cfg.vocab, size=(5,)).astype(np.int32),
                max_new_tokens=8)
    )
    eng.step()
    shared = eng.submit(Request(prompt=prompt, max_new_tokens=6, sampling=sp))
    while eng.has_work:
        eng.step()
    assert shared.tokens == alone.tokens


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_generated_page_reuse_parity(arch_id):
    """Follow-up-turn reuse (the session cache): after a request finishes,
    its DECODE-FILLED full pages are registered, so a second turn whose
    prompt extends (prompt + reply) matches THROUGH the generated span and
    prefills only its new suffix — emitting tokens bit-identical to a cold
    engine that re-prefills the whole conversation.  Chunk-capable
    families must actually skip past the first turn's prompt; the rest
    run with sharing inert, which must change nothing."""
    cfg = get_arch(arch_id, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, size=(6,)).astype(np.int32)
    extras = modality_extras(cfg, rng)
    warm = Engine(
        model, params, n_slots=2, max_len=32, page_size=4, share_prefix=True
    )
    r1 = warm.run([Request(prompt=prompt, max_new_tokens=7, extras=extras)])[0]
    # turn 2: the previous reply plus fresh user tokens
    follow = np.concatenate(
        [prompt, np.asarray(r1.tokens, np.int32),
         rng.integers(0, cfg.vocab, size=(3,)).astype(np.int32)]
    )
    fextras = modality_extras(cfg, rng)
    r2 = warm.run([Request(prompt=follow.copy(), max_new_tokens=4, extras=fextras)])[0]
    chunkable = cfg.family in ("dense", "moe") and cfg.sliding_window is None
    if chunkable:
        # 3 registered full pages cover positions 0..11; the first turn's
        # PROMPT only reaches position 5 — the match ran through pages
        # the donor's decode stream filled
        assert r2.prefill_skipped == 12, f"no generated-page reuse for {arch_id}"
    else:
        assert r2.prefill_skipped == 0  # inert, by design
    cold = Engine(
        model, params, n_slots=2, max_len=32, page_size=4, share_prefix=True
    )
    ref = cold.run([Request(prompt=follow.copy(), max_new_tokens=4, extras=fextras)])[0]
    assert r2.tokens == ref.tokens, f"generated-page reuse diverged for {arch_id}"


def test_eviction_churn_no_stale_matches():
    """Warm-cache eviction under a tight budget: cached pages are swept
    (budget) and re-granted (writer pressure), every eviction dropping its
    index keys with it.  A follow-up on the NEWEST conversation still
    reuses pages; a follow-up on the OLDEST — whose pages were evicted and
    refilled with other content — must match nothing stale and still
    decode bit-identically to a cold engine."""
    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(11)
    prompts = [
        rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32) for _ in range(3)
    ]
    warm = Engine(
        model, params, n_slots=1, max_len=32, page_size=4, kv_pages=5,
        share_prefix=True, warm_cache_pages=2, decode_block=1,
    )
    firsts = [warm.run([Request(prompt=p, max_new_tokens=5)])[0] for p in prompts]
    # 3 pages indexed per finish against a budget of 2, and each next
    # admission needs 4 of 5 pages: both eviction paths (budget sweep,
    # writer re-grant) have fired by now
    assert warm.prefix_evictions > 0
    assert warm.prefix_cached_pages <= 2 and warm.pages_in_use == 0

    def followup(i):
        return np.concatenate(
            [prompts[i], np.asarray(firsts[i].tokens, np.int32),
             rng.integers(0, cfg.vocab, size=(2,)).astype(np.int32)]
        )

    # newest conversation: its pages survived the churn — real reuse
    f2 = followup(2)
    r2 = warm.run([Request(prompt=f2.copy(), max_new_tokens=4)])[0]
    assert r2.prefill_skipped > 0
    # oldest conversation: its pages were evicted and refilled with other
    # requests' KV — a stale index entry would alias that storage
    f0 = followup(0)
    r0 = warm.run([Request(prompt=f0.copy(), max_new_tokens=4)])[0]
    for f, r in ((f2, r2), (f0, r0)):
        cold = Engine(
            model, params, n_slots=1, max_len=32, page_size=4, kv_pages=5,
            decode_block=1, prefill_chunk=4,
        )
        ref = cold.run([Request(prompt=f.copy(), max_new_tokens=4)])[0]
        assert r.tokens == ref.tokens, "stale warm-cache match corrupted decode"
    assert warm.pages_in_use == 0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_tiered_engine_greedy_self_consistency(arch_id):
    """Elastic-rank tiers: a request served at tier f must emit tokens
    bit-identical to ``greedy_generate`` on a model STATICALLY compressed
    with ``slice_rank(params, f)`` — the tier is a trace-time view of the
    same factors, never a different model.  Both tiers run CONCURRENTLY on
    one engine (separate fused passes over one paged pool), and degraded
    responses carry the tier's certificate."""
    cfg = get_arch(arch_id, reduced=True)
    if cfg.family not in ("dense", "moe") or cfg.sliding_window is not None:
        pytest.skip("tier parity is pinned on the chunk-capable families")
    from repro.core import CompressionPolicy, compress_tree, spectralize_params
    from repro.core.lowrank import slice_rank

    model = build_model(cfg)
    params = spectralize_params(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(9))
    params, _, rep = compress_tree(
        params, CompressionPolicy(alpha=0.5, q=2, min_dim=16), jax.random.PRNGKey(1)
    )
    assert any(l.compressed for l in rep.layers)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, size=(6,)).astype(np.int32)
    extras = modality_extras(cfg, rng)
    tiers = (1.0, 0.5)

    eng = Engine(
        model, params, n_slots=2, max_len=MAX_LEN, page_size=4,
        share_prefix=True, tiers=tiers, tier_q=2,
    )
    reqs = [
        eng.submit(
            Request(prompt=prompt.copy(), max_new_tokens=5, extras=extras, tier=t)
        )
        for t in range(len(tiers))
    ]
    while eng.has_work:
        eng.step()
    for t, req in enumerate(reqs):
        ref = _reference(model, slice_rank(params, tiers[t]), prompt, extras, 5)
        assert req.tokens == ref, f"tier {t} diverged for {arch_id}"
        assert req.certificate is not None
        assert np.isfinite(req.certificate.prob_deviation_bound)
    # the degraded tier's certified bound strictly dominates the full tier's
    assert reqs[1].certificate.prob_deviation_bound >= reqs[0].certificate.prob_deviation_bound
    assert reqs[0].certificate.prob_deviation_bound == 0.0


def test_tiered_engine_rejects_recurrent_families():
    """Multi-tier decode would corrupt live recurrent state rows (frozen
    slots' re-feeds integrate into SSM state with the WRONG tier's params
    and never self-repair), so construction must refuse."""
    cfg = get_arch("mamba2-130m", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="recurrent"):
        Engine(model, params, n_slots=2, max_len=MAX_LEN, tiers=(1.0, 0.5))
    # single-tier construction stays allowed
    Engine(model, params, n_slots=2, max_len=MAX_LEN, tiers=(1.0,))


@pytest.mark.parametrize("share", [True, False])
def test_preempt_resume_greedy_parity(share):
    """Preemption is invisible in the token stream: a request preempted
    mid-decode (its pages reclaimed for a higher-priority waiter) resumes
    via a re-queued continuation and must finish with tokens bit-identical
    to an uninterrupted run — with prefix sharing (warm-restore of its
    decode-filled pages) AND without (full re-prefill of the extension)."""
    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(12)
    prompts = [
        rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32),
        rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32),
    ]
    steps = [10, 6]
    refs = [_reference2(model, params, p, s) for p, s in zip(prompts, steps)]

    # pool sized so both requests can never run together: r0 holds all 5
    # pages, so admitting r1 REQUIRES preempting r0
    eng = Engine(
        model, params, n_slots=2, max_len=32, page_size=4, kv_pages=5,
        share_prefix=share, preempt=True, decode_block=2,
    )
    r0 = eng.submit(Request(prompt=prompts[0], max_new_tokens=steps[0], priority=0))
    eng.step()
    eng.step()  # r0 is mid-decode with several tokens emitted
    assert 0 < len(r0.tokens) < steps[0]
    r1 = eng.submit(Request(prompt=prompts[1], max_new_tokens=steps[1], priority=1))
    while eng.has_work:
        eng.step()
    assert eng.preemptions == 1
    assert r1.tokens == refs[1], "preemptor diverged"
    assert r0.tokens == refs[0], "preempted request did not resume bit-identically"
    assert r0.status == "ok" and r0.uid == 0
    assert eng.pages_in_use == 0


def test_preemption_requires_higher_priority():
    """Equal-priority waiters never preempt: plain FIFO queueing is the
    default behavior and stays byte-for-byte intact with preempt=True."""
    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(13)
    prompts = [
        rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32),
        rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32),
    ]
    eng = Engine(
        model, params, n_slots=2, max_len=32, page_size=4, kv_pages=5,
        preempt=True, decode_block=2,
    )
    r0 = eng.submit(Request(prompt=prompts[0], max_new_tokens=10))
    eng.step()
    r1 = eng.submit(Request(prompt=prompts[1], max_new_tokens=6))
    while eng.has_work:
        eng.step()
    assert eng.preemptions == 0
    assert r0.tokens == _reference2(model, params, prompts[0], 10)
    assert r1.tokens == _reference2(model, params, prompts[1], 6)


def _reference2(model, params, prompt, steps):
    out = greedy_generate(
        model, params, {"tokens": jnp.asarray(prompt[None])}, steps=steps, max_len=32
    )
    return np.asarray(out)[0].tolist()
