"""Multi-device tests (8 fake CPU devices via subprocess: XLA device count
must be set before jax initializes, so these run in child processes)."""

import subprocess
import sys
import textwrap

import pytest


def _run(code: str, devices: int = 8):
    env = {
        "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
        "PYTHONPATH": "src",
        "PATH": "/usr/bin:/bin",
        "HOME": "/root",
    }
    import os

    env.update({k: v for k, v in os.environ.items() if k not in env})
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        cwd="/root/repo",
        env=env,
        timeout=900,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


def test_distributed_rsi_matches_single_device():
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.core.distributed_rsi import distributed_rsi
        from repro.core import rsi, synth_spectrum_matrix, vgg_like_spectrum
        from repro.runtime.compat import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        W = synth_spectrum_matrix(jax.random.PRNGKey(0), 256, 512, vgg_like_spectrum(256))
        Wsh = jax.device_put(W, NamedSharding(mesh, P("data", "model")))
        d = distributed_rsi(Wsh, 32, 3, jax.random.PRNGKey(1), mesh)
        s = rsi(W, 32, 3, jax.random.PRNGKey(1))
        ad = (d.U * d.S[None]) @ d.Vt
        as_ = (s.U * s.S[None]) @ s.Vt
        err = float(jnp.linalg.norm(ad - as_) / jnp.linalg.norm(as_))
        assert err < 1e-4, err
        # older jax normalizes away trailing Nones in PartitionSpec
        assert d.U.sharding.spec in (P("data", None), P("data")), d.U.sharding
        assert d.Vt.sharding.spec == P(None, "model"), d.Vt.sharding
        print("OK", err)
    """)
    assert "OK" in out


def test_moe_expert_parallel_matches_local():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.registry import get_arch
        from repro.models import moe
        from repro.sharding.rules import MeshRules, use_rules
        import dataclasses
        cfg = get_arch("phi3.5-moe-42b-a6.6b", reduced=True)
        cfg = dataclasses.replace(cfg, n_experts=8, capacity_factor=8.0)  # no drops
        p = moe.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model), jnp.float32)
        ref, aux_ref = moe._moe_local(p, x, cfg)
        from repro.runtime.compat import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        rules = MeshRules(mesh)
        with use_rules(rules):
            got, aux = jax.jit(lambda p, x: moe.moe_forward(p, x, cfg))(p, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-4, atol=2e-4)
        # aux is a per-data-shard estimator in EP mode (mean of per-shard
        # load-balance terms) vs the global estimator locally: close, not equal
        np.testing.assert_allclose(float(aux), float(aux_ref), rtol=0.3)
        print("OK")
    """)
    assert "OK" in out


def test_pipeline_parallel_matches_sequential():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.train.pipeline_parallel import gpipe_apply
        from repro.runtime.compat import make_mesh
        mesh = make_mesh((4,), ("pod",))
        L, d = 8, 16
        ws = jax.random.normal(jax.random.PRNGKey(0), (L, d, d)) / d**0.5
        def block(w, x):
            return jnp.tanh(x @ w)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, d))
        # sequential reference
        ref = x
        for i in range(L):
            ref = block(ws[i], ref)
        fn = gpipe_apply(lambda lp, h: block(lp["w"], h), mesh, n_microbatches=4)
        got = jax.jit(fn)({"w": ws}, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-5)
        print("OK")
    """)
    assert "OK" in out


def test_elastic_checkpoint_reshard():
    """Save on a 2x4 mesh, restore on 8x1 — the elastic restart path."""
    out = _run("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.checkpoint import checkpointer as ckpt
        from repro.runtime.compat import make_mesh
        m1 = make_mesh((2, 4), ("data", "model"))
        m2 = make_mesh((8, 1), ("data", "model"))
        W = jnp.arange(64*32, dtype=jnp.float32).reshape(64, 32)
        state = {"w": jax.device_put(W, NamedSharding(m1, P("data", "model")))}
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(state, d, 3)
            sh2 = {"w": NamedSharding(m2, P("data", "model"))}
            restored, _ = ckpt.restore(state, d, shardings=sh2)
            np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(W))
            assert restored["w"].sharding.mesh.shape["data"] == 8
        print("OK")
    """)
    assert "OK" in out


def test_powersgd_compressed_allreduce():
    """Compressed DP all-reduce approximates the dense mean and cuts bytes."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, functools
        from repro.core.gradient_compression import (
            PowerSGDConfig, init_powersgd, compress_allreduce, comm_bytes)
        from repro.runtime.compat import make_mesh
        mesh = make_mesh((8,), ("data",))
        cfg = PowerSGDConfig(rank=8, min_size=1024)
        # shared low-rank signal + small per-device noise: a rank-8 sketch of
        # the MEAN must capture the signal (pure-noise means are full-rank and
        # only converge via error feedback over steps)
        key = jax.random.PRNGKey(0)
        u = jax.random.normal(key, (64, 8)); v = jax.random.normal(jax.random.PRNGKey(2), (8, 96))
        noise = 0.05 * jax.random.normal(jax.random.PRNGKey(3), (8, 64, 96))
        grads_per_dev = (u @ v)[None] + noise  # (8, 64, 96)
        state = init_powersgd({"w": grads_per_dev[0]}, jax.random.PRNGKey(1), cfg)
        def body(g, st):
            out, st2 = compress_allreduce({"w": g}, st, "data", cfg)
            return out["w"], None
        from repro.runtime.compat import shard_map
        f = shard_map(lambda g: body(g[0], state)[0][None],
                      mesh=mesh,
                      in_specs=jax.sharding.PartitionSpec("data"),
                      out_specs=jax.sharding.PartitionSpec("data"),
                      check_vma=False)
        got = f(grads_per_dev)
        dense_mean = jnp.mean(grads_per_dev, axis=0)
        # error feedback handles the residual over steps; single step should
        # still correlate strongly for these low-rank grads
        corr = float(jnp.sum(got[0]*dense_mean) /
                     (jnp.linalg.norm(got[0])*jnp.linalg.norm(dense_mean)+1e-9))
        assert corr > 0.7, corr
        dense_b, comp_b = comm_bytes({"w": grads_per_dev[0]}, cfg)
        assert comp_b < dense_b / 3
        print("OK", corr)
    """)
    assert "OK" in out
