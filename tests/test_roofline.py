"""Unit tests for the trip-count-aware HLO analyzer (§Roofline foundation)."""

import numpy as np

from repro.roofline.analysis import parse_collectives, roofline_terms
from repro.roofline.hlo_stats import analyze_hlo

_TOY_HLO = """
HloModule toy

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups=[16,16]<=[256], to_apply=%addc
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]{1,0}) tuple(%ip, %ar)
}

%addc (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (w: f32[8,8]) -> (s32[], f32[8,8]) {
  %w = f32[8,8]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,8]{1,0}) tuple(%z, %w)
  ROOT %wl = (s32[], f32[8,8]{1,0}) while(%t0), condition=%cond, body=%body
}
"""


def test_trip_count_multiplication():
    st = analyze_hlo(_TOY_HLO, world=256)
    # dot: 2*8*8*8 = 1024 flops per iteration x 10 trips (+ 1-flop adds)
    assert 10 * 1024 <= st.flops < 10 * 1024 + 2000, st.flops
    # all-reduce of 8x8 f32 = 256 B; ring 2*(n-1)/n with n=16 -> 480 B x 10
    np.testing.assert_allclose(st.coll_bytes["all-reduce"], 4800.0, rtol=1e-6)
    assert st.coll_ops == 10


def test_collective_formulas():
    hlo = """
ENTRY %main (x: f32[64]) -> f32[1024] {
  %x = f32[64]{0} parameter(0)
  ROOT %ag = f32[1024]{0} all-gather(%x), replica_groups=[16,16]<=[256], dimensions={0}
}
"""
    st = analyze_hlo(hlo, world=256)
    # gathered result 4096 B x (n-1)/n with n=16
    np.testing.assert_allclose(st.coll_bytes["all-gather"], 4096 * 15 / 16, rtol=1e-6)
    c = parse_collectives(hlo, world=256)
    np.testing.assert_allclose(c.by_kind["all-gather"], 4096 * 15 / 16, rtol=1e-6)


def test_roofline_terms_and_bottleneck():
    r = roofline_terms(
        flops=197e12,  # exactly 1 s of compute
        hbm_bytes=819e9 / 2,  # 0.5 s of memory
        coll_bytes=100e9 * 2,  # 2 s of collective at 2x50GB/s
        chips=256,
        model_flops_global=197e12 * 256 * 0.5,
    )
    assert r.bottleneck == "collective"
    np.testing.assert_allclose(r.t_compute, 1.0)
    np.testing.assert_allclose(r.t_memory, 0.5)
    np.testing.assert_allclose(r.t_collective, 2.0)
    np.testing.assert_allclose(r.useful_flops_ratio, 0.5)
    np.testing.assert_allclose(r.roofline_fraction, 0.25)  # 0.5s useful / 2s bound


def test_slice_fusion_effective_bytes():
    hlo = """
%fused_slice (param_0.1: f32[1000,64], param_1.2: s32[]) -> f32[1,64] {
  %param_0.1 = f32[1000,64]{1,0} parameter(0)
  %param_1.2 = s32[] parameter(1)
  %z = s32[] constant(0)
  ROOT %ds = f32[1,64]{1,0} dynamic-slice(%param_0.1, %param_1.2, %z), dynamic_slice_sizes={1,64}
}

ENTRY %main (big: f32[1000,64], i: s32[]) -> f32[1,64] {
  %big = f32[1000,64]{1,0} parameter(0)
  %i = s32[] parameter(1)
  ROOT %f = f32[1,64]{1,0} fusion(%big, %i), kind=kLoop, calls=%fused_slice
}
"""
    st = analyze_hlo(hlo, world=8)
    # must count the 256-B slice (x2-ish incl. result), NOT the 256-KB buffer
    assert st.hbm_bytes < 2048, st.hbm_bytes
