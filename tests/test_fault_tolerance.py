"""Fault-tolerance machinery beyond the test_substrate.py smoke: retry
exhaustion, non-retryable passthrough, median-step regression detection,
and one engine-integration case wrapping the jitted fused decode block."""

import jax
import numpy as np
import pytest

from repro.runtime.fault_tolerance import RetryableStep, StepWatchdog, backoff_s


# --------------------------------------------------------------------------- #
# RetryableStep
# --------------------------------------------------------------------------- #
def test_retry_exhaustion_propagates_after_budget():
    """An always-failing step is attempted ``max_retries + 1`` times, every
    failure is counted, and the LAST error propagates to the restart loop."""
    calls = {"n": 0}

    def always_down():
        calls["n"] += 1
        raise RuntimeError(f"flap {calls['n']}")

    r = RetryableStep(always_down, max_retries=2)
    with pytest.raises(RuntimeError, match="flap 3"):
        r()
    assert calls["n"] == 3  # initial attempt + 2 retries
    assert r.total_retries == 3
    # the wrapper stays usable after exhaustion (restart-loop re-entry)
    with pytest.raises(RuntimeError, match="flap 6"):
        r()
    assert r.total_retries == 6


def test_non_retryable_error_passes_through_immediately():
    """Errors outside ``retryable`` are programming bugs, not link flaps:
    no retry, no counting — one attempt, straight up the stack."""
    calls = {"n": 0}

    def buggy():
        calls["n"] += 1
        raise TypeError("not a transient fault")

    r = RetryableStep(buggy, max_retries=5, retryable=(ValueError,))
    with pytest.raises(TypeError):
        r()
    assert calls["n"] == 1 and r.total_retries == 0


def test_retry_zero_budget_is_single_attempt():
    r = RetryableStep(lambda: (_ for _ in ()).throw(ValueError("x")), max_retries=0)
    with pytest.raises(ValueError):
        r()
    assert r.total_retries == 1


def test_retry_backoff_off_by_default_never_sleeps():
    """``base_delay_s=0`` preserves the historical hot-retry semantics:
    the injectable sleep is never invoked, the counters say so."""
    naps = []
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] <= 2:
            raise RuntimeError("flap")
        return 42

    r = RetryableStep(flaky, max_retries=3, sleep=naps.append)
    assert r() == 42
    assert naps == []
    assert r.backoffs == 0 and r.total_backoff_s == 0.0
    assert r.total_attempts == 3 and r.total_retries == 2


def test_retry_backoff_capped_exponential_deterministic_jitter():
    """Armed backoff sleeps the exact ``backoff_s`` schedule: exponential
    from ``base_delay_s``, capped at ``max_delay_s``, jitter in [raw/2, raw]
    hashed from (salt, attempt) — reproducible, no global RNG."""
    naps = []
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] <= 4:
            raise RuntimeError("flap")
        return "up"

    r = RetryableStep(
        flaky, max_retries=4, base_delay_s=0.1, max_delay_s=0.4,
        jitter_salt=7, sleep=naps.append,
    )
    assert r() == "up"
    assert r.backoffs == 4 and r.total_attempts == 5
    expected = [backoff_s(k, base_s=0.1, cap_s=0.4, salt=7) for k in range(4)]
    assert naps == expected  # deterministic: the schedule replays exactly
    for k, d in enumerate(naps):
        raw = min(0.1 * 2.0 ** k, 0.4)
        assert raw / 2 <= d <= raw <= 0.4
    assert r.total_backoff_s == pytest.approx(sum(naps))
    # different salts de-synchronize concurrent retriers
    assert backoff_s(2, base_s=0.1, cap_s=0.4, salt=8) != expected[2]


def test_retry_backoff_no_sleep_after_final_failure():
    """The terminal failure propagates immediately — sleeping after the
    last attempt would delay the restart loop for nothing."""
    naps = []
    r = RetryableStep(
        lambda: (_ for _ in ()).throw(ValueError("x")),
        max_retries=2, base_delay_s=0.05, sleep=naps.append,
    )
    with pytest.raises(ValueError):
        r()
    assert len(naps) == 2  # between attempts only


# --------------------------------------------------------------------------- #
# StepWatchdog median-regression detection
# --------------------------------------------------------------------------- #
def test_watchdog_no_flags_during_warmup():
    """The first 5 observations can never flag — the rolling median is not
    yet trustworthy, and a cold-compile first step is NOT a straggler."""
    w = StepWatchdog(straggler_factor=2.0)
    assert w.observe(0, 100.0) is False  # compile step
    for i in range(1, 5):
        assert w.observe(i, 100.0 if i % 2 else 0.01) is False
    assert w.straggler_steps == []


def test_watchdog_median_regression_and_rebaseline():
    """A step slower than factor x the rolling median flags; a SUSTAINED
    slowdown re-baselines once the window's median catches up, so only the
    regression edge is flagged — not every step of the new normal."""
    w = StepWatchdog(straggler_factor=3.0, window=8)
    for i in range(8):
        w.observe(i, 1.0)
    assert w.median == 1.0
    assert w.observe(8, 3.5) is True  # 3.5 > 3.0 x 1.0
    assert w.straggler_steps == [8]
    assert w.observe(9, 2.9) is False  # under the threshold
    # sustained 2.9s steps roll the 1.0s history out of the window...
    for i in range(10, 18):
        w.observe(i, 2.9)
    assert w.median == 2.9
    # ...so the SAME 3.5s duration is now ordinary, not a straggler
    assert w.observe(18, 3.5) is False
    assert w.straggler_steps == [8]


def test_watchdog_median_empty_and_window():
    w = StepWatchdog(window=4)
    assert w.median == 0.0
    for i, s in enumerate([10.0, 10.0, 1.0, 1.0, 1.0, 1.0]):
        w.observe(i, s)
    assert w.median == 1.0  # the 10s steps aged out of the window


# --------------------------------------------------------------------------- #
# Engine integration: retries around the donated fused decode block
# --------------------------------------------------------------------------- #
def test_retryable_step_wraps_engine_decode_block():
    """A transient failure raised BEFORE the fused block dispatches (the
    realistic pre-dispatch link flap — after dispatch, donation has
    consumed the buffers and the restart loop owns recovery) retries
    transparently: the request's tokens stay bit-identical to an
    undisturbed engine."""
    from repro.configs.registry import get_arch
    from repro.models.model import build_model
    from repro.serving import Engine, Request

    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab, size=(5,)).astype(np.int32)

    ref = Engine(model, params, n_slots=2, max_len=16, decode_block=4).run(
        [Request(prompt=prompt.copy(), max_new_tokens=6)]
    )[0]

    eng = Engine(model, params, n_slots=2, max_len=16, decode_block=4)
    real = eng._fused_fn(True)  # build + cache the jitted greedy block
    state = {"armed": True}

    def flaky(*args, **kw):
        if state["armed"]:
            state["armed"] = False
            raise RuntimeError("link flap before dispatch")
        return real(*args, **kw)

    wrapped = RetryableStep(flaky, max_retries=2, retryable=(RuntimeError,))
    eng._fused_cache[True] = wrapped
    out = eng.run([Request(prompt=prompt.copy(), max_new_tokens=6)])[0]
    assert wrapped.total_retries == 1
    assert not state["armed"]  # the failure really fired
    assert out.tokens == ref.tokens

# --------------------------------------------------------------------------- #
# ElasticReshard: host state -> (new) mesh round-trip
# --------------------------------------------------------------------------- #
def test_elastic_reshard_round_trips_host_state():
    """A checkpoint restored to host numpy re-lands on devices bit-exact,
    structure preserved, every leaf a committed device array on the
    requested sharding."""
    import jax.numpy as jnp
    from repro.runtime.fault_tolerance import ElasticReshard

    state_np = {
        "params": {"w": np.arange(12, dtype=np.float32).reshape(3, 4)},
        "opt": [np.float32(0.5), np.arange(4, dtype=np.int32)],
    }
    dev = jax.devices()[0]
    shardings = jax.tree_util.tree_map(lambda _: dev, state_np)
    out = ElasticReshard().apply(state_np, shardings)
    assert (
        jax.tree_util.tree_structure(out)
        == jax.tree_util.tree_structure(state_np)
    )
    for got, want in zip(jax.tree_util.tree_leaves(out),
                         jax.tree_util.tree_leaves(state_np)):
        assert isinstance(got, jax.Array) and got.committed
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        assert got.dtype == np.asarray(want).dtype
    # jnp inputs (a live train state, not a restored checkpoint) also work
    out2 = ElasticReshard().apply({"w": jnp.ones((2, 2))}, {"w": dev})
    np.testing.assert_array_equal(np.asarray(out2["w"]), np.ones((2, 2)))


# --------------------------------------------------------------------------- #
# TrainLoopRunner: restart loop, checkpoint cadence, watchdog wiring
# --------------------------------------------------------------------------- #
class _MemCheckpointer:
    def __init__(self):
        self.saved = []  # (step, state) in save order
        self.waits = 0

    def save_async(self, state, step):
        self.saved.append((step, int(np.asarray(state["acc"]))))

    def wait(self):
        self.waits += 1


def _counting_step(state, batch):
    import jax.numpy as jnp

    acc = state["acc"] + batch
    return {"acc": acc}, {"loss": jnp.float32(acc)}


def _runner(ckpt, save_every=2):
    from repro.runtime.fault_tolerance import StepWatchdog, TrainLoopRunner

    return TrainLoopRunner(
        step_fn=_counting_step,
        data_at_step=lambda step: np.int32(step + 1),
        checkpointer=ckpt,
        save_every=save_every,
        watchdog=StepWatchdog(window=8),
    )


def test_train_loop_runner_cadence_and_final_save():
    """Checkpoints land every ``save_every`` steps plus once at the end,
    and the runner blocks on the final save before returning."""
    import jax.numpy as jnp

    ckpt = _MemCheckpointer()
    runner = _runner(ckpt, save_every=2)
    state, metrics = runner.run({"acc": jnp.int32(0)}, 5)
    # acc after 5 steps of +1..+5 = 15
    assert int(np.asarray(state["acc"])) == 15
    assert float(np.asarray(metrics["loss"])) == 15.0
    assert [s for s, _ in ckpt.saved] == [2, 4, 5]
    assert ckpt.waits == 1
    assert len(runner.watchdog.durations) == 5


def test_train_loop_runner_restart_resumes_deterministically():
    """The restart contract end-to-end: an injected failure escapes, the
    caller restores the last checkpoint and re-enters with ``start_step``,
    and the final state is IDENTICAL to an undisturbed run — the data
    pipeline is deterministic in step, so retrained batches match."""
    import jax.numpy as jnp

    undisturbed = _runner(_MemCheckpointer(), save_every=3).run(
        {"acc": jnp.int32(0)}, 7
    )[0]

    ckpt = _MemCheckpointer()
    runner = _runner(ckpt, save_every=3)
    with pytest.raises(RuntimeError, match="injected failure at step 5"):
        runner.run({"acc": jnp.int32(0)}, 7, fail_at=lambda s: s == 5)
    # restore the latest checkpoint (step 3, acc=1+2+3=6) and resume
    step, acc = ckpt.saved[-1]
    assert (step, acc) == (3, 6)
    state, _ = runner.run({"acc": jnp.int32(acc)}, 7, start_step=step)
    assert int(np.asarray(state["acc"])) == int(np.asarray(undisturbed["acc"])) == 28


def test_train_loop_runner_retryable_step_and_metrics_hook():
    """RetryableStep composes as the runner's step_fn: a one-shot transient
    failure is absorbed (no restart), metrics stream per-step, and the
    watchdog still observes every completed step."""
    import jax.numpy as jnp

    state0 = {"acc": jnp.int32(0)}
    armed = {"on": True}

    def flaky(state, batch):
        if armed["on"] and int(np.asarray(batch)) == 3:
            armed["on"] = False
            raise RuntimeError("link flap")
        return _counting_step(state, batch)

    wrapped = RetryableStep(flaky, max_retries=1, retryable=(RuntimeError,))
    seen = []
    runner = _runner(_MemCheckpointer(), save_every=10)
    runner.step_fn = wrapped
    state, _ = runner.run(
        state0, 4, on_metrics=lambda step, m: seen.append((step, float(m["loss"])))
    )
    assert wrapped.total_retries == 1
    assert int(np.asarray(state["acc"])) == 10
    assert seen == [(1, 1.0), (2, 3.0), (3, 6.0), (4, 10.0)]


def test_train_loop_runner_no_checkpointer():
    import jax.numpy as jnp
    from repro.runtime.fault_tolerance import TrainLoopRunner

    runner = TrainLoopRunner(
        step_fn=_counting_step,
        data_at_step=lambda step: np.int32(1),
        checkpointer=None,
    )
    state, _ = runner.run({"acc": jnp.int32(0)}, 3)
    assert int(np.asarray(state["acc"])) == 3
