"""Per-architecture smoke tests: reduced config, one forward + prefill +
decode step on CPU, asserting output shapes and finiteness."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCH_IDS, get_arch
from repro.models.model import build_model, batch_spec_template


def _make_batch(cfg, batch, seq, kind, key):
    tmpl = batch_spec_template(cfg, batch, seq, kind=kind)
    out = {}
    for name, (shape, dtype) in tmpl.items():
        key, k = jax.random.split(key)
        if jnp.issubdtype(dtype, jnp.integer):
            out[name] = jax.random.randint(k, shape, 0, cfg.vocab, dtype=dtype)
        else:
            out[name] = jax.random.normal(k, shape, dtype=jnp.float32).astype(dtype)
    return out


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_shapes_and_finite(arch_id):
    cfg = get_arch(arch_id, reduced=True)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    B, S = 2, 32
    batch = _make_batch(cfg, B, S, "train", jax.random.PRNGKey(1))
    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits))), "NaN/Inf in logits"
    assert bool(jnp.isfinite(jnp.asarray(aux)))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_prefill_then_decode(arch_id):
    cfg = get_arch(arch_id, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S, max_len = 2, 16, 32
    batch = _make_batch(cfg, B, S, "prefill", jax.random.PRNGKey(1))
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, max_len))(params, batch)
    assert logits.shape == (B, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits)))
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    logits2, cache2 = jax.jit(model.decode_step)(params, cache, tok, jnp.int32(S))
    assert logits2.shape == (B, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits2)))
    # cache structure is stable (required for jit'd decode loops)
    assert jax.tree_util.tree_structure(cache) == jax.tree_util.tree_structure(cache2)


@pytest.mark.parametrize("arch_id", ["llama3.2-1b", "mamba2-130m", "h2o-danube-1.8b"])
def test_decode_matches_forward(arch_id):
    """Teacher-forced decode must reproduce the forward logits (causality +
    cache correctness)."""
    cfg = get_arch(arch_id, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 8
    batch = _make_batch(cfg, B, S, "train", jax.random.PRNGKey(1))
    ref_logits, _ = model.forward(params, batch)

    prefix = 4
    pre_batch = dict(batch, tokens=batch["tokens"][:, :prefix])
    pre_batch.pop("targets", None)
    logits, cache = model.prefill(params, pre_batch, S)
    got = [logits]
    step = jax.jit(model.decode_step)
    for i in range(prefix, S):
        tok = batch["tokens"][:, i : i + 1]
        logits, cache = step(params, cache, tok, jnp.int32(i))
        got.append(logits)
    # got[j] are logits after consuming token j+prefix-1 => compare to
    # ref_logits positions prefix-1 .. S-1
    import numpy as np

    got = jnp.stack(got[:-1], axis=1)  # (B, S-prefix, V)
    ref = ref_logits[:, prefix - 1 : S - 1]
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32), rtol=0.05, atol=0.05
    )
