"""Paper-core tests: Alg 3.1 quality claims, Eq (3.14) monotonicity,
factored forms, compression pipeline, low-rank apply."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CompressionPolicy,
    apply_linear,
    break_even_rank,
    cholesky_qr2,
    compress_tree,
    materialize,
    normalized_error,
    rsi,
    rsi_factors,
    rsvd,
    spectral_norm,
    synth_spectrum_matrix,
    vgg_like_spectrum,
)


@pytest.fixture(scope="module")
def slow_decay_matrix():
    key = jax.random.PRNGKey(0)
    C, D = 256, 768
    s = vgg_like_spectrum(C)
    W = synth_spectrum_matrix(key, C, D, s)
    return W, np.asarray(s)


def test_rsi_beats_rsvd_on_slow_decay(slow_decay_matrix):
    """Paper Fig 4.1/4.2: q=1 (RSVD) has large normalized error; q>=2 is
    near-optimal; error decreases monotonically in q."""
    W, s = slow_decay_matrix
    k = 32
    errs = {}
    for q in (1, 2, 3, 4):
        res = rsi(W, k, q, jax.random.PRNGKey(1))
        errs[q] = float(
            normalized_error(W, res.U, res.S, res.Vt, s[k], jax.random.PRNGKey(2))
        )
    assert errs[1] > 1.8, errs  # RSVD inadequate (paper: ~2-4)
    assert errs[4] < 1.25, errs  # near-optimal (paper: ~1.1)
    assert errs[1] > errs[2] > errs[4] - 0.05, errs  # improves with q
    # optimality floor: normalized error can never drop below ~1
    assert errs[4] > 0.98


def test_rsi_error_bound_eq_3_14(slow_decay_matrix):
    """E||W - W~||_2^2 <= s_{k+1}^2 * H^{1/(m-1)}: check expected squared
    spectral error approaches the optimal floor as m = 2q grows."""
    W, s = slow_decay_matrix
    k = 32
    trials = 5
    ratios = []
    for q in (1, 2, 4):
        errs = []
        for t in range(trials):
            res = rsi(W, k, q, jax.random.PRNGKey(10 + t))
            approx = (res.U * res.S[None, :]) @ res.Vt
            errs.append(float(spectral_norm(W - approx, jax.random.PRNGKey(99))) ** 2)
        ratios.append(np.mean(errs) / s[k] ** 2)
    assert ratios[0] > ratios[1] > ratios[2] >= 0.95
    assert ratios[2] < 1.6


def test_rsvd_is_rsi_q1(slow_decay_matrix):
    W, _ = slow_decay_matrix
    a = rsvd(W, 16, jax.random.PRNGKey(5))
    b = rsi(W, 16, 1, jax.random.PRNGKey(5))
    np.testing.assert_allclose(np.asarray(a.S), np.asarray(b.S), rtol=1e-6)


def test_oversampling_improves_rsvd(slow_decay_matrix):
    W, s = slow_decay_matrix
    k = 32
    base = rsi(W, k, 1, jax.random.PRNGKey(3))
    over = rsi(W, k, 1, jax.random.PRNGKey(3), oversample=16)
    e0 = float(normalized_error(W, base.U, base.S, base.Vt, s[k], jax.random.PRNGKey(4)))
    e1 = float(normalized_error(W, over.U, over.S, over.Vt, s[k], jax.random.PRNGKey(4)))
    assert e1 < e0


def test_cholesky_qr2_orthonormal():
    X = jax.random.normal(jax.random.PRNGKey(0), (512, 64)) * 10
    Q = cholesky_qr2(X)
    err = np.asarray(jnp.abs(Q.T @ Q - jnp.eye(64))).max()
    assert err < 1e-5


def test_qr_methods_agree(slow_decay_matrix):
    W, _ = slow_decay_matrix
    a = rsi(W, 16, 3, jax.random.PRNGKey(7), qr_method="cholesky_qr2")
    b = rsi(W, 16, 3, jax.random.PRNGKey(7), qr_method="householder")
    np.testing.assert_allclose(np.asarray(a.S), np.asarray(b.S), rtol=1e-4)


def test_factored_form_param_counts(slow_decay_matrix):
    W, _ = slow_decay_matrix
    C, D = W.shape
    k = 32
    A, B = rsi_factors(W, k, 3, jax.random.PRNGKey(0))
    assert A.shape == (C, k) and B.shape == (k, D)
    assert A.size + B.size < W.size
    assert break_even_rank(C, D) == (C * D - 1) // (C + D)
    # A@B approximates U S Vt
    res = rsi(W, k, 3, jax.random.PRNGKey(0))
    np.testing.assert_allclose(
        np.asarray(A @ B), np.asarray((res.U * res.S[None]) @ res.Vt), atol=1e-3
    )


def test_apply_linear_lowrank_equivalence():
    key = jax.random.PRNGKey(0)
    W = jax.random.normal(key, (64, 48))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 7, 64))
    dense_y = apply_linear(W, x)
    lr = {"a": W @ jnp.eye(48)[:, :48], "b": jnp.eye(48)}  # exact factorization
    np.testing.assert_allclose(
        np.asarray(apply_linear(lr, x)), np.asarray(dense_y), rtol=2e-5, atol=2e-5
    )
    np.testing.assert_allclose(np.asarray(materialize(lr)), np.asarray(W), atol=1e-6)


def test_compress_tree_end_to_end_quality():
    """Compressing a linear 'model' with q=4 hurts its outputs far less than
    q=1 at the same rank (the paper's end-to-end claim, matrix level)."""
    key = jax.random.PRNGKey(0)
    C, D = 200, 500
    W = synth_spectrum_matrix(key, C, D, vgg_like_spectrum(C)).T  # (in=500, out=200)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, D))
    y_ref = x @ W
    outs = {}
    for q in (1, 4):
        params = {"layer": {"wq": W}}
        policy = CompressionPolicy(alpha=0.2, q=q, min_dim=10)
        new, _, rep = compress_tree(params, policy, jax.random.PRNGKey(2))
        assert rep.layers[0].compressed, rep.layers[0]
        y = apply_linear(new["layer"]["wq"], x)
        outs[q] = float(jnp.linalg.norm(y - y_ref) / jnp.linalg.norm(y_ref))
    assert outs[4] < outs[1] * 0.8, outs


# --------------------------------------------------------------------------- #
# property tests: Alg 3.1 error monotonicity + factored-form consistency.
# hypothesis-driven where the optional dep is installed (importorskip idiom,
# cf. test_bounds); a deterministic seed sweep keeps the properties covered
# in minimal environments.
# --------------------------------------------------------------------------- #
def _rsi_fro_err(W, k, q, key, **kw):
    res = rsi(W, k, q, key, **kw)
    approx = (res.U * res.S[None, :]) @ res.Vt
    return float(jnp.linalg.norm(W - approx))


def _check_q_and_oversample_monotone(seed):
    """Shared property body: on a slow-decay matrix, RSI approximation error
    is non-increasing in power iterations q (same sketch) and in
    oversampling; rsi_factors' A @ B reproduces rsi's U S V^T."""
    C, D, k = 96, 160, 16
    W = synth_spectrum_matrix(jax.random.PRNGKey(seed), C, D, vgg_like_spectrum(C))
    key = jax.random.PRNGKey(seed + 1)
    errs = {q: _rsi_fro_err(W, k, q, key) for q in (1, 2, 4)}
    # same Omega, more power iterations: never (materially) worse
    assert errs[2] <= errs[1] * 1.02 + 1e-6, errs
    assert errs[4] <= errs[2] * 1.02 + 1e-6, errs
    # oversampling enlarges the sketch subspace: never (materially) worse
    e_plain = _rsi_fro_err(W, k, 2, key)
    e_over = _rsi_fro_err(W, k, 2, key, oversample=8)
    assert e_over <= e_plain * 1.02 + 1e-6, (e_plain, e_over)
    # factored form A @ B == U diag(S) V^T to numerical tolerance
    A, B = rsi_factors(W, k, 2, key)
    res = rsi(W, k, 2, key)
    np.testing.assert_allclose(
        np.asarray(A @ B),
        np.asarray((res.U * res.S[None, :]) @ res.Vt),
        rtol=1e-4,
        atol=1e-4,
    )


@pytest.mark.parametrize("seed", [0, 7, 1234])
def test_rsi_error_monotonicity_properties(seed):
    _check_q_and_oversample_monotone(seed)


try:  # hypothesis property sweep where the optional dep is installed
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_rsi_error_monotonicity_property_sweep(seed):
        _check_q_and_oversample_monotone(seed)

    @settings(max_examples=8, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        k=st.integers(4, 32),
        q=st.integers(1, 4),
    )
    def test_rsi_factors_reconstruction_property(seed, k, q):
        """For arbitrary (seed, rank, q): the paper's factored form A @ B
        matches the full U diag(S) V^T reconstruction to tolerance."""
        C, D = 64, 96
        W = synth_spectrum_matrix(
            jax.random.PRNGKey(seed), C, D, vgg_like_spectrum(C)
        )
        A, B = rsi_factors(W, k, q, jax.random.PRNGKey(seed + 1))
        res = rsi(W, k, q, jax.random.PRNGKey(seed + 1))
        assert A.shape == (C, k) and B.shape == (k, D)
        np.testing.assert_allclose(
            np.asarray(A @ B),
            np.asarray((res.U * res.S[None, :]) @ res.Vt),
            rtol=2e-4,
            atol=2e-4,
        )


def test_compress_tree_energy_rule():
    key = jax.random.PRNGKey(0)
    # sharp spectrum: energy rule should pick a tiny rank
    s = jnp.concatenate([jnp.full((8,), 100.0), jnp.full((248,), 0.01)])
    W = synth_spectrum_matrix(key, 256, 512, s).T
    params = {"layer": {"wq": W}}
    policy = CompressionPolicy(rank_rule="energy", energy=0.95, q=3, min_dim=10)
    _, _, rep = compress_tree(params, policy, jax.random.PRNGKey(1))
    assert rep.layers[0].compressed
    assert rep.layers[0].rank <= 16, rep.layers[0]
