"""Flash-decode kernel suite: Pallas kernel vs the dense einsum oracle.

Everything runs in interpret mode on CPU (the same contract as the other
kernel tests): parity across GQA ratios, ragged per-slot ``n_valid``,
sliding-window ``rotate_mask``, the fully-masked-row zero guard, and the
dispatch-table routing that picks the kernel by shape/platform.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.models.attention import decode_attention
from repro.runtime import dispatch
from repro.runtime.dispatch import DECODE_MIN_SEQ, DispatchConfig, use_dispatch

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5), jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


def _rand(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) / shape[-1] ** 0.25).astype(dtype)


def _inputs(B, S, KV, G, hd, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = _rand(ks[0], (B, 1, KV * G, hd), dtype)
    k = _rand(ks[1], (B, S, KV, hd), dtype)
    v = _rand(ks[2], (B, S, KV, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("G", [1, 4, 8])  # GQA ratio H/KV
def test_decode_kernel_gqa_ratios(G, dtype):
    B, S, KV, hd = 2, 64, 2, 16
    q, k, v = _inputs(B, S, KV, G, hd, dtype)
    valid = jnp.arange(S)[None, :] < jnp.array([[S], [S // 2]])
    got = decode_attention_pallas(q, k, v, valid, bs=32, interpret=True)
    want = ref.decode_attention_ref(q, k, v, valid)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **TOL[dtype]
    )


@pytest.mark.parametrize("bs", [8, 16, 64])
def test_decode_kernel_ragged_n_valid(bs):
    """Per-slot n_valid masking is STRICT: poison beyond each slot's valid
    prefix must never leak, for any block size (incl. bs > S)."""
    B, S, KV, G, hd = 4, 48, 2, 4, 16
    dtype = jnp.float32
    q, k, v = _inputs(B, S, KV, G, hd, dtype, seed=1)
    n_valid = jnp.array([1, 17, 48, 5], jnp.int32)
    valid = jnp.arange(S)[None, :] < n_valid[:, None]
    tail = ~valid[:, :, None, None]
    k_poison = jnp.where(tail, jnp.asarray(1e4, dtype), k)
    v_poison = jnp.where(tail, jnp.asarray(1e4, dtype), v)
    got = decode_attention_pallas(q, k_poison, v_poison, valid, bs=bs, interpret=True)
    assert bool(jnp.all(jnp.isfinite(got)))
    want = ref.decode_attention_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL[dtype])


def test_decode_kernel_rotate_mask_ring():
    """Sliding-window ring masks (arbitrary (B, S) validity patterns, not
    just prefixes) are honored position-by-position."""
    B, S, KV, G, hd = 3, 32, 1, 4, 16
    dtype = jnp.float32
    q, k, v = _inputs(B, S, KV, G, hd, dtype, seed=2)
    rng = np.random.default_rng(0)
    rotate = jnp.asarray(rng.integers(0, 2, size=(B, S)).astype(bool))
    rotate = rotate.at[:, 0].set(True)  # keep every row non-empty here
    got = decode_attention_pallas(q, k, v, rotate, bs=16, interpret=True)
    want = ref.decode_attention_ref(q, k, v, rotate)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_fully_masked_rows_are_zero(dtype):
    """Regression: a slot whose valid mask is all-False (empty/inactive pool
    slot) must produce ZEROS — not NaN, not a uniform average of garbage —
    from BOTH the kernel and the dense reference, while live rows are
    untouched."""
    B, S, KV, G, hd = 3, 16, 2, 2, 8
    q, k, v = _inputs(B, S, KV, G, hd, dtype, seed=3)
    n_valid = jnp.array([0, 7, 0], jnp.int32)
    valid = jnp.arange(S)[None, :] < n_valid[:, None]

    for got in (
        ref.decode_attention_ref(q, k, v, valid),
        decode_attention_pallas(q, k, v, valid, bs=8, interpret=True),
    ):
        got = np.asarray(got, np.float32)
        assert np.isfinite(got).all()
        np.testing.assert_array_equal(got[0], np.zeros_like(got[0]))
        np.testing.assert_array_equal(got[2], np.zeros_like(got[2]))
        assert np.abs(got[1]).sum() > 0  # the live row still attends

    # the model-layer entry point (n_valid / rotate_mask forms) gets the
    # same guard
    via_n_valid = decode_attention(q, k, v, n_valid)
    via_mask = decode_attention(q, k, v, 0, rotate_mask=valid)
    assert np.isfinite(np.asarray(via_n_valid, np.float32)).all()
    np.testing.assert_array_equal(
        np.asarray(via_n_valid, np.float32)[0], np.zeros((1, KV * G, hd), np.float32)
    )
    np.testing.assert_array_equal(
        np.asarray(via_mask, np.float32), np.asarray(via_n_valid, np.float32)
    )


def test_decode_kernel_odd_seq_falls_back_to_small_blocks():
    """S not divisible by the requested block: the wrapper shrinks bs until
    it tiles, staying exact."""
    B, S, KV, G, hd = 2, 24, 2, 2, 16  # 24 -> bs 16 -> 8
    q, k, v = _inputs(B, S, KV, G, hd, jnp.float32, seed=4)
    valid = jnp.arange(S)[None, :] < jnp.array([[24], [9]])
    got = decode_attention_pallas(q, k, v, valid, bs=16, interpret=True)
    want = ref.decode_attention_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL[jnp.float32])


# --------------------------------------------------------------------------- #
# dispatch routing
# --------------------------------------------------------------------------- #
def test_choose_decode_path_auto_table():
    q_shape, kv_deep, kv_shallow = (4, 1, 8, 64), (4, 2048, 2, 64), (4, 64, 2, 64)
    cfg = DispatchConfig()
    # auto: kernel on TPU for deep caches, einsum for shallow or off-TPU
    assert dispatch.choose_decode_path(q_shape, kv_deep, config=cfg, platform="tpu") == "pallas"
    assert dispatch.choose_decode_path(q_shape, kv_shallow, config=cfg, platform="tpu") == "xla"
    assert dispatch.choose_decode_path(q_shape, kv_deep, config=cfg, platform="cpu") == "xla"
    assert kv_shallow[1] < DECODE_MIN_SEQ <= kv_deep[1]
    # pins override the table everywhere
    pinned = DispatchConfig(backend="pallas")
    assert dispatch.choose_decode_path(q_shape, kv_shallow, config=pinned, platform="cpu") == "pallas"
    per_op = DispatchConfig(overrides=(("decode_attention", "xla"),))
    assert dispatch.choose_decode_path(q_shape, kv_deep, config=per_op, platform="tpu") == "xla"


def test_decode_attention_dispatch_entry_counts_and_matches():
    """The dispatch entry point routes to the kernel under a pallas pin
    (interpret mode on CPU), matches the reference, and records a hit."""
    B, S, KV, G, hd = 2, 32, 2, 4, 16
    q, k, v = _inputs(B, S, KV, G, hd, jnp.float32, seed=5)
    valid = jnp.arange(S)[None, :] < jnp.array([[32], [11]])
    dispatch.reset_counters()
    with use_dispatch(backend="pallas"):
        got = dispatch.decode_attention(q, k, v, valid)
    want = ref.decode_attention_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)
    hits = dispatch.counters_by_path()
    assert hits.get(("decode_attention", "pallas"), 0) >= 1


def test_engine_decode_runs_through_dispatch_counter():
    """End-to-end: a fused engine block records decode_attention sites in
    the dispatch counters (one per scanned attention call site)."""
    from repro.configs.registry import get_arch
    from repro.models.model import build_model
    from repro.serving import Engine, Request

    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dispatch.reset_counters()
    eng = Engine(model, params, n_slots=2, max_len=16, decode_block=4)
    eng.submit(Request(prompt=np.arange(4, dtype=np.int32), max_new_tokens=5))
    while eng.has_work:
        eng.step()
    hits = dispatch.counters_by_path()
    assert hits.get(("decode_attention", "xla"), 0) >= 1  # CPU auto -> einsum
