"""The analyzer analyzed: fixture snippets per pass + regression pins.

Three layers:

* **Fixtures** — known-bad snippets produce exactly the expected
  diagnostic for each pass (donation reuse, tracer ``.item()``, unguarded
  access, oversized BlockSpec, arity mismatches); the matching clean
  snippets produce none; suppression comments silence a finding.
* **Repo pins** — the passes hold on the real tree: ``src/`` is clean in
  strict mode, the cluster/dispatch annotations parse, and *sabotaged*
  copies of real modules (the original ``_route_due`` unlocked-inbox
  read) re-raise the finding — proving the pass would have caught the
  bug this PR fixed.
* **Runtime** — the sanitizer descriptors record unguarded accesses on
  armed instances (and only then), ``OwnedLock`` attributes ownership to
  the right thread, the fixed ``_route_due`` really takes ``inbox_lock``
  around the routing read, and ``FaultInjector._hit`` is exact under a
  thread hammer.
"""

import json
import pathlib
import sys
import textwrap
import threading

import numpy as np
import pytest

from repro.analysis import sanitize
from repro.analysis import donation, locks, pallas_contract, purity
from repro.analysis.__main__ import main as cli_main
from repro.analysis.core import SourceFile, run_analysis
from repro.runtime.fault_tolerance import FaultInjector
from repro.serving import Cluster, Request

REPO = pathlib.Path(__file__).resolve().parents[1]
SRC = REPO / "src"


def _check(mod, source, path="snippet.py"):
    src = SourceFile(path, textwrap.dedent(source))
    return [d for d in mod.check(src) if not src.suppressed(d.pass_id, d.line)]


# --------------------------------------------------------------------------- #
# donation-safety fixtures
# --------------------------------------------------------------------------- #
def test_donation_read_after_donate_flagged():
    diags = _check(donation, """
        import jax

        def use(x, w):
            f = jax.jit(lambda a, b: a + b, donate_argnums=(0,))
            y = f(x, w)
            return x + y
    """)
    assert len(diags) == 1
    assert diags[0].pass_id == "donation-safety"
    assert "`x` read after being donated" in diags[0].message
    assert diags[0].line == 7  # the `return x + y` line


def test_donation_in_loop_without_rebind_flagged():
    diags = _check(donation, """
        import jax

        def loop(x, w):
            f = jax.jit(lambda a, b: a + b, donate_argnums=(0,))
            for _ in range(3):
                y = f(x, w)
            return y
    """)
    assert len(diags) == 1
    assert "inside a loop without rebinding" in diags[0].message


def test_donation_rebind_at_call_is_clean():
    assert _check(donation, """
        import jax

        def ok(x, w):
            f = jax.jit(lambda a, b: a + b, donate_argnums=(0,))
            x = f(x, w)
            for _ in range(3):
                x = f(x, w)
            return x
    """) == []


def test_donation_factory_pattern_tracked():
    # the engine's `_fused_fn` shape: a method returns a locally-built
    # donating jit; calling through the bound result donates too
    diags = _check(donation, """
        import jax

        def make():
            fn = jax.jit(lambda a, b: a + b, donate_argnums=(1,))
            return fn

        def drive(p, cache):
            fused = make()
            out = fused(p, cache)
            return cache
    """)
    assert len(diags) == 1
    assert "`cache` read after being donated" in diags[0].message


def test_donation_attribute_donor_and_rebind():
    assert _check(donation, """
        import jax

        class Eng:
            def setup(self):
                self._jit = jax.jit(lambda p, c: (p, c), donate_argnums=(1,))

            def step(self, p):
                logits, self.cache = self._jit(p, self.cache)
                return logits
    """) == []


# --------------------------------------------------------------------------- #
# jit-purity fixtures
# --------------------------------------------------------------------------- #
def test_purity_item_print_time_flagged():
    diags = _check(purity, """
        import time
        import jax

        def traced(x):
            t = time.time()
            v = x.sum().item()
            print(v)
            return x

        f = jax.jit(traced)
    """)
    msgs = "\n".join(d.message for d in diags)
    assert len(diags) == 3
    assert "time.time" in msgs and ".item()" in msgs and "print" in msgs


def test_purity_reaches_through_call_graph():
    # the traced root calls a helper; the helper's side effect is flagged
    diags = _check(purity, """
        import jax

        def helper(x):
            print(x)
            return x

        def traced(x):
            return helper(x)

        f = jax.jit(traced)
    """)
    assert len(diags) == 1
    assert "print" in diags[0].message


def test_purity_global_mutation_and_attr_store_flagged():
    diags = _check(purity, """
        import jax
        CACHE = {}

        def traced(self, x):
            CACHE["k"] = x
            self.state = x
            return x

        f = jax.jit(traced)
    """)
    assert len(diags) == 2
    msgs = "\n".join(d.message for d in diags)
    assert "module-level `CACHE`" in msgs and "self.state" in msgs


def test_purity_pallas_ref_stores_are_clean():
    # `o_ref[...] = ...`, `acc_ref[...] +=`, and @pl.when nested stores
    # are the kernel idiom, not host mutation
    assert _check(purity, """
        import jax
        from jax.experimental import pallas as pl

        def kernel(x_ref, o_ref, acc_ref):
            @pl.when(pl.program_id(0) == 0)
            def _init():
                acc_ref[...] = 0.0

            acc_ref[...] += x_ref[...]
            o_ref[...] = acc_ref[...]

        def call(x):
            return pl.pallas_call(kernel, grid=(1,))(x)
    """) == []


def test_purity_untraced_function_not_flagged():
    # host-side code may print/measure freely
    assert _check(purity, """
        import time

        def host_loop(x):
            t = time.time()
            print(x, t)
            return x
    """) == []


# --------------------------------------------------------------------------- #
# lock-discipline fixtures
# --------------------------------------------------------------------------- #
LOCK_SNIPPET = """
    import threading

    class Box:
        def __init__(self):
            self.lock = threading.Lock()
            self.items = []  # guarded by: lock

        def bad_read(self):
            return len(self.items)

        def good_read(self):
            with self.lock:
                return len(self.items)

        def peek_locked(self):
            return self.items[0]

        def helper(self):
            return self.items.pop()

        def caller(self):
            with self.lock:
                return self.helper()
"""


def test_lock_unguarded_access_flagged_others_clean():
    diags = _check(locks, LOCK_SNIPPET)
    # exactly one finding: bad_read.  good_read is lexical, peek_locked
    # uses the caller-holds-it suffix, helper is dominated by caller,
    # __init__ is exempt.
    assert len(diags) == 1
    assert "`self.items` accessed without holding `lock`" in diags[0].message
    assert "in `bad_read`" in diags[0].message


def test_lock_suppression_comment_silences():
    silenced = LOCK_SNIPPET.replace(
        "return len(self.items)",
        "return len(self.items)  # repro-lint: ignore[lock-discipline]",
        1,
    )
    assert _check(locks, silenced) == []


def test_lock_module_global_guard():
    diags = _check(locks, """
        import threading

        COUNTS = {}  # guarded by: COUNTS_LOCK
        COUNTS_LOCK = threading.Lock()

        def record(k):
            with COUNTS_LOCK:
                COUNTS[k] = COUNTS.get(k, 0) + 1

        def bad_total():
            return sum(COUNTS.values())
    """)
    assert len(diags) == 1
    assert "bad_total" in diags[0].message


# --------------------------------------------------------------------------- #
# pallas-contract fixtures
# --------------------------------------------------------------------------- #
def test_pallas_oversized_blockspec_flagged():
    diags = _check(pallas_contract, """
        import jax
        from jax.experimental import pallas as pl

        def kern(a_ref, o_ref):
            o_ref[...] = a_ref[...]

        def big(x):
            return pl.pallas_call(
                kern,
                grid=(4,),
                in_specs=[pl.BlockSpec((4096, 4096), lambda i: (0, 0))],
                out_specs=pl.BlockSpec((4096, 4096), lambda i: (0, 0)),
            )(x)
    """)
    assert len(diags) == 1
    assert "exceeds" in diags[0].message and "budget" in diags[0].message


def test_pallas_index_map_arity_mismatch_flagged():
    diags = _check(pallas_contract, """
        import jax
        from jax.experimental import pallas as pl

        def kern(a_ref, o_ref):
            o_ref[...] = a_ref[...]

        def call(x):
            return pl.pallas_call(
                kern,
                grid=(2, 2),
                in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],
                out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),
            )(x)
    """)
    assert len(diags) == 1
    assert "index_map takes 1 args but grid has 2 axes" in diags[0].message


def test_pallas_kernel_arity_mismatch_flagged():
    diags = _check(pallas_contract, """
        import jax
        from jax.experimental import pallas as pl

        def kern(a_ref, o_ref):
            o_ref[...] = a_ref[...]

        def call(x):
            return pl.pallas_call(
                kern,
                grid=(1,),
                in_specs=[
                    pl.BlockSpec((8, 8), lambda i: (0, 0)),
                    pl.BlockSpec((8, 8), lambda i: (0, 0)),
                ],
                out_specs=pl.BlockSpec((8, 8), lambda i: (0, 0)),
            )(x, x)
    """)
    assert len(diags) == 1
    assert "kernel `kern` takes 2 positional refs" in diags[0].message
    assert "passes 3" in diags[0].message


def test_pallas_small_blocks_and_min_bound_clean():
    assert _check(pallas_contract, """
        import jax
        from jax.experimental import pallas as pl

        def kern(a_ref, o_ref):
            o_ref[...] = a_ref[...]

        def call(x, bm: int = 128):
            M = x.shape[0]
            bm_ = min(bm, M)
            return pl.pallas_call(
                kern,
                grid=(1,),
                in_specs=[pl.BlockSpec((bm_, 128), lambda i: (0, 0))],
                out_specs=pl.BlockSpec((bm_, 128), lambda i: (0, 0)),
            )(x)
    """) == []


def test_pallas_unbounded_dim_flagged_unless_runtime_checked():
    unbounded = """
        import jax
        from jax.experimental import pallas as pl

        def kern(a_ref, o_ref):
            o_ref[...] = a_ref[...]

        def call(x, n):
            {guard}return pl.pallas_call(
                kern,
                grid=(1,),
                in_specs=[pl.BlockSpec((n, 8), lambda i: (0, 0))],
                out_specs=pl.BlockSpec((n, 8), lambda i: (0, 0)),
            )(x)
    """
    template = textwrap.dedent(unbounded)  # dedent BEFORE inserting guard
    diags = _check(pallas_contract, template.format(guard=""))
    assert len(diags) == 1
    assert "cannot bound block dim(s) n" in diags[0].message
    # a runtime budget check in the same function is the escape hatch
    assert _check(
        pallas_contract, template.format(guard="_check_fits(n)\n    ")
    ) == []


def test_pallas_module_bounds_declaration_resolves():
    assert _check(pallas_contract, """
        import jax
        from jax.experimental import pallas as pl

        VMEM_ANALYSIS_BOUNDS = {"hd": 256}

        def kern(a_ref, o_ref):
            o_ref[...] = a_ref[...]

        def call(x, hd):
            return pl.pallas_call(
                kern,
                grid=(1,),
                in_specs=[pl.BlockSpec((8, hd), lambda i: (0, 0))],
                out_specs=pl.BlockSpec((8, hd), lambda i: (0, 0)),
            )(x)
    """) == []


# --------------------------------------------------------------------------- #
# repo pins: the real tree is clean, and sabotage re-raises the findings
# --------------------------------------------------------------------------- #
def test_full_repo_strict_clean():
    diags, errors, n_files = run_analysis([str(SRC)])
    assert errors == [], errors
    assert diags == [], "\n".join(d.format() for d in diags)
    assert n_files > 50  # the walk really covered the tree


def test_cluster_annotations_parse():
    src = SourceFile.read(str(SRC / "repro" / "serving" / "cluster.py"))
    attr_guards, _ = locks.parse_guards(src.lines)
    assert attr_guards["inbox"] == "inbox_lock"
    assert attr_guards["state_cmd"] == "health_lock"
    assert attr_guards["step_error"] == "health_lock"
    assert attr_guards["failovers"] == "_lock"
    assert attr_guards["resume_points"] == "_lock"
    assert locks.check(src) == []


def test_route_due_sabotage_reraises_original_race():
    """Pin: removing the inbox_lock around the routing-load read (the
    pre-PR code) is exactly what the lock-discipline pass flags."""
    src = SourceFile.read(str(SRC / "repro" / "serving" / "cluster.py"))
    sabotaged = src.text.replace(
        "                    with r.inbox_lock:\n"
        "                        depth = len(r.inbox)",
        "                    depth = len(r.inbox)",
    )
    assert sabotaged != src.text, "fixed _route_due read not found"
    diags = locks.check(SourceFile("cluster.py", sabotaged))
    assert any(
        "`self.inbox`" in d.message and "_route_due" in d.message
        for d in diags
    )


def test_dispatch_counters_annotated_and_clean():
    src = SourceFile.read(str(SRC / "repro" / "runtime" / "dispatch.py"))
    _, global_guards = locks.parse_guards(src.lines)
    assert global_guards["_COUNTS"] == "_COUNTS_LOCK"
    assert locks.check(src) == []


def test_fault_injector_fired_annotated_and_clean():
    src = SourceFile.read(
        str(SRC / "repro" / "runtime" / "fault_tolerance.py")
    )
    attr_guards, _ = locks.parse_guards(src.lines)
    assert attr_guards["fired"] == "_fired_lock"
    assert locks.check(src) == []
    # sabotage: the pre-PR unguarded read-modify-write is flagged
    sabotaged = src.text.replace(
        "        with self._fired_lock:\n"
        "            self.fired[kind] = self.fired.get(kind, 0) + 1",
        "        self.fired[kind] = self.fired.get(kind, 0) + 1",
    )
    assert sabotaged != src.text
    diags = locks.check(SourceFile("fault_tolerance.py", sabotaged))
    assert any("`self.fired`" in d.message for d in diags)


def test_engine_donation_sites_clean():
    src = SourceFile.read(str(SRC / "repro" / "serving" / "engine.py"))
    assert donation.check(src) == []


# --------------------------------------------------------------------------- #
# CLI: exit codes, --json, --baseline
# --------------------------------------------------------------------------- #
BAD_DONATION = """
import jax

def use(x, w):
    f = jax.jit(lambda a, b: a + b, donate_argnums=(0,))
    y = f(x, w)
    return x + y
"""


def test_cli_exit_codes(tmp_path):
    clean = tmp_path / "clean.py"
    clean.write_text("x = 1\n")
    assert cli_main([str(clean), "--strict"]) == 0

    bad = tmp_path / "bad.py"
    bad.write_text(BAD_DONATION)
    assert cli_main([str(bad)]) == 0  # findings, but not strict
    assert cli_main([str(bad), "--strict"]) == 1

    broken = tmp_path / "broken.py"
    broken.write_text("def broken(:\n")
    assert cli_main([str(broken)]) == 2  # parse failure = internal error


def test_cli_json_report(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(BAD_DONATION)
    report_path = tmp_path / "report.json"
    assert cli_main([str(bad), "--json", str(report_path)]) == 0
    report = json.loads(report_path.read_text())
    assert report["files"] == 1
    assert report["counts"] == {"donation-safety": 1}
    assert report["internal_errors"] == []
    (diag,) = report["diagnostics"]
    assert diag["pass"] == "donation-safety"
    assert diag["path"] == str(bad)


def test_cli_baseline_diff(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(BAD_DONATION)
    accept = tmp_path / "baseline_ok.json"
    accept.write_text(json.dumps({"counts": {"donation-safety": 1}}))
    zero = tmp_path / "baseline_zero.json"
    zero.write_text(json.dumps({"counts": {}}))
    assert cli_main([str(bad), "--strict", "--baseline", str(accept)]) == 0
    assert cli_main([str(bad), "--strict", "--baseline", str(zero)]) == 1
    assert cli_main([str(bad), "--strict", "--baseline",
                     str(tmp_path / "missing.json")]) == 2


def test_repo_baseline_is_all_zero():
    baseline = json.loads((REPO / "analysis" / "baseline.json").read_text())
    assert all(v == 0 for v in baseline["counts"].values())


# --------------------------------------------------------------------------- #
# runtime sanitizer
# --------------------------------------------------------------------------- #
class _Guarded:
    def __init__(self):
        self.lock = threading.Lock()
        self.val = 0  # guarded by: lock


def test_owned_lock_ownership_is_per_thread():
    lk = sanitize.OwnedLock()
    assert not lk.held_by_me()
    with lk:
        assert lk.held_by_me() and lk.locked()
    assert not lk.locked()
    lk.acquire()
    seen = []
    t = threading.Thread(target=lambda: seen.append(lk.held_by_me()))
    t.start()
    t.join()
    assert seen == [False]  # held, but not by that thread
    lk.release()
    assert not lk.held_by_me()


def test_sanitizer_descriptor_records_only_when_armed():
    installed = sanitize.install(_Guarded)
    try:
        assert installed == 1
        assert sanitize.install(_Guarded) == 0  # idempotent
        obj = _Guarded()  # construction unarmored: no violations
        sanitize.reset()

        sanitize.arm(obj)
        with obj.lock:
            obj.val = 5
            assert obj.val == 5
        assert sanitize.violations() == []

        _ = obj.val  # unguarded read on an armed instance
        obj.val = 7  # unguarded write
        found = sanitize.violations()
        assert len(found) == 2
        assert "val" in found[0] and "lock" in found[0]
        with pytest.raises(AssertionError):
            sanitize.check()
        assert sanitize.violations() == []  # check() drains

        sanitize.disarm(obj)
        _ = obj.val
        assert sanitize.violations() == []
    finally:
        sanitize.uninstall(_Guarded)
        sanitize.reset()
    obj2 = _Guarded()  # descriptors gone after uninstall
    assert obj2.val == 0


def test_sanitizer_records_cross_thread_violation():
    installed = sanitize.install(_Guarded)
    try:
        assert installed == 1
        obj = _Guarded()
        sanitize.reset()
        sanitize.arm(obj)

        def worker():
            obj.val = 1  # no lock, from another thread

        t = threading.Thread(target=worker)
        t.start()
        t.join()
        found = sanitize.violations()
        assert len(found) == 1 and "val" in found[0]
    finally:
        sanitize.uninstall(_Guarded)
        sanitize.reset()


# --------------------------------------------------------------------------- #
# runtime regression pins for the analyzer-surfaced fixes
# --------------------------------------------------------------------------- #
class _LockCheckedInbox(list):
    """A replica inbox that asserts inbox_lock is held on every read."""

    def set_lock(self, lock):
        self._lock = lock
        return self

    def __len__(self):
        assert self._lock.locked(), "inbox length read without inbox_lock"
        return super().__len__()


class _StubEngine:
    """Just enough surface for Cluster bookkeeping + routing loads."""

    watchdog = None
    on_event = None
    n_waiting = 0
    paged = False
    n_active = 0


def test_route_due_reads_inbox_under_lock():
    """Pin for the fixed race: _route_due's load probe must hold each
    replica's inbox_lock (the instrumented inbox raises if not)."""
    clu = Cluster(lambda rid: _StubEngine(), 1)
    rep = clu.replicas[0]
    rep.inbox = _LockCheckedInbox().set_lock(rep.inbox_lock)
    clu.submit(Request(prompt=np.asarray([1, 2, 3], np.int32),
                       max_new_tokens=4))
    clu._route_due()  # raises through _LockCheckedInbox on an unlocked read
    with rep.inbox_lock:
        assert len(rep.inbox) == 1  # the segment actually routed


def test_fault_injector_hit_exact_under_thread_hammer():
    """Pin for the _hit lost-update fix: concurrent increments are exact."""
    inj = FaultInjector()
    n_threads, per_thread = 8, 400
    barrier = threading.Barrier(n_threads)
    old = sys.getswitchinterval()
    sys.setswitchinterval(1e-5)  # force aggressive preemption
    try:
        def worker():
            barrier.wait()
            for _ in range(per_thread):
                inj._hit("hammer")

        threads = [threading.Thread(target=worker) for _ in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    finally:
        sys.setswitchinterval(old)
    assert inj.fired["hammer"] == n_threads * per_thread
