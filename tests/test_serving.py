"""Serving-path tests: compressed checkpoints are drop-in, and the paper's
bound machinery predicts their behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_arch
from repro.core import CompressionPolicy, compress_tree, spectralize_params
from repro.models.model import build_model
from repro.train.serve_step import greedy_generate


@pytest.mark.parametrize("arch_id", ["llama3.2-1b", "phi3.5-moe-42b-a6.6b"])
def test_compressed_params_serve_drop_in(arch_id):
    cfg = get_arch(arch_id, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # simulate PRETRAINED weights: fresh Gaussian kernels are near-full-rank,
    # which is not the paper's regime (see core.spectralize_params docstring)
    params = spectralize_params(params, jax.random.PRNGKey(9))
    cp, _, rep = compress_tree(
        params, CompressionPolicy(alpha=0.5, q=4, min_dim=16), jax.random.PRNGKey(1)
    )
    assert any(l.compressed for l in rep.layers)
    B, S = 2, 8
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)}
    out_d = greedy_generate(model, params, batch, steps=4, max_len=S + 4)
    out_c = greedy_generate(model, cp, batch, steps=4, max_len=S + 4)
    assert out_d.shape == out_c.shape == (B, 4)
    # logits of the two models stay close at this gentle alpha
    ld, _ = model.forward(params, dict(batch))
    lc, _ = model.forward(cp, dict(batch))
    rel = float(jnp.linalg.norm(ld - lc) / (jnp.linalg.norm(ld) + 1e-9))
    assert rel < 0.5, rel


def test_higher_q_gives_closer_logits():
    """Serving-level analogue of Table 4.1: q=4 approximates the dense model
    better than q=1 at the same rank."""
    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = spectralize_params(model.init(jax.random.PRNGKey(0)), jax.random.PRNGKey(9))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0, cfg.vocab)}
    ld, _ = model.forward(params, batch)
    errs = {}
    for q in (1, 4):
        cp, _, _ = compress_tree(
            params, CompressionPolicy(alpha=0.25, q=q, min_dim=16), jax.random.PRNGKey(3)
        )
        lc, _ = model.forward(cp, batch)
        errs[q] = float(jnp.linalg.norm(ld - lc))
    assert errs[4] <= errs[1] * 1.05, errs  # q=4 at least as good (usually much better)


def test_engine_run_idle_waits_for_arrivals():
    """Engine.run with a wall-clock arrival gap: the idle loop sleeps to the
    next arrival (in capped naps — no busy-spin, no oversleep past new work)
    and every request completes with arrival-consistent timestamps."""
    import time

    from repro.serving import Engine, Request

    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    reqs = [
        Request(
            prompt=rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32),
            max_new_tokens=3,
        )
        for _ in range(2)
    ]
    arrivals = [0.0, 0.4]
    eng = Engine(model, params, n_slots=2, max_len=16)
    t0 = time.perf_counter()
    done = eng.run(reqs, arrivals=arrivals, max_idle_wait=0.05)
    dt = time.perf_counter() - t0
    assert len(done) == 2
    assert all(len(r.tokens) == 3 for r in reqs)
    # the second request cannot have been submitted before its arrival
    assert reqs[1].t_submit - t0 >= arrivals[1] - 1e-3
    assert dt >= arrivals[1] - 1e-3  # the run really waited for it


def test_decode_with_compressed_cacheless_layers():
    """Factored kernels survive the full prefill+decode path incl. caches."""
    cfg = get_arch("h2o-danube-1.8b", reduced=True)  # exercises SWA ring cache
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cp, _, _ = compress_tree(
        params, CompressionPolicy(alpha=0.5, q=3, min_dim=16), jax.random.PRNGKey(1)
    )
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, cfg.vocab)}
    logits, cache = model.prefill(cp, batch, 16)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for i in range(3):
        logits, cache = model.decode_step(cp, cache, tok, jnp.int32(8 + i))
        assert bool(jnp.all(jnp.isfinite(logits)))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
