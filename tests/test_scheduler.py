"""Unit tests for the serving slot/page allocators and scheduler, plus
engine-level lifecycle properties (exhaustion queues, reuse, no cache
leakage) for both the flat and the paged KV pool — including refcounted
shared-prefix pages, the duplicate-free regression, zero-page-arch
lifecycles, and allocation-peak accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.scheduler import (
    PageAllocator,
    PrefixIndex,
    Scheduler,
    SlotAllocator,
)


# --------------------------------------------------------------------------- #
# SlotAllocator
# --------------------------------------------------------------------------- #
def test_allocator_exhaustion_returns_none():
    a = SlotAllocator(2)
    assert a.alloc() == 0 and a.alloc() == 1
    assert a.alloc() is None  # exhaustion is a soft condition, not an error
    assert a.n_free == 0 and a.n_active == 2


def test_allocator_free_and_reuse_lowest_first():
    a = SlotAllocator(3)
    s = [a.alloc() for _ in range(3)]
    assert s == [0, 1, 2]
    a.free(1)
    a.free(0)
    # deterministic reuse order: lowest free id first
    assert a.alloc() == 0
    assert a.alloc() == 1
    assert a.alloc() is None


def test_allocator_double_free_rejected():
    a = SlotAllocator(2)
    slot = a.alloc()
    a.free(slot)
    with pytest.raises(ValueError):
        a.free(slot)
    with pytest.raises(ValueError):
        a.free(99)


def test_allocator_bad_size_rejected():
    with pytest.raises(ValueError):
        SlotAllocator(0)


# --------------------------------------------------------------------------- #
# Scheduler
# --------------------------------------------------------------------------- #
def test_scheduler_fifo_admission_and_queueing():
    sched = Scheduler(SlotAllocator(2))
    for name in ("a", "b", "c", "d"):
        sched.enqueue(name)
    placed = sched.admit()
    assert [(s, r) for s, r in placed] == [(0, "a"), (1, "b")]
    assert sched.n_waiting == 2  # exhaustion queues rather than crashes
    assert sched.admit() == []  # no free slots -> nothing admitted
    sched.release(0)
    assert sched.admit() == [(0, "c")]  # freed slot reused, FIFO order kept
    sched.release(1)
    sched.release(0)
    assert sched.admit() == [(0, "d")]
    assert sched.n_waiting == 0


# --------------------------------------------------------------------------- #
# PageAllocator
# --------------------------------------------------------------------------- #
def test_page_allocator_all_or_nothing_and_exhaustion():
    a = PageAllocator(4)
    assert a.alloc(3) == [0, 1, 2]
    assert a.alloc(2) is None  # never a partial grant
    assert a.n_free == 1  # ... and the failed alloc took nothing
    assert a.alloc(1) == [3]
    assert a.alloc(1) is None and a.n_used == 4
    assert a.alloc(0) == []  # zero-page requests always fit (ssm/swa archs)


def test_page_allocator_free_reclaims_whole_set_lowest_first():
    a = PageAllocator(6)
    first = a.alloc(3)
    second = a.alloc(2)
    a.free(first)  # the whole set comes back at once — no fragmentation
    assert a.n_free == 4
    assert a.alloc(4) == [0, 1, 2, 5]  # deterministic lowest-first reuse
    a.free(second + [0, 1, 2, 5])
    assert a.n_free == 6


def test_page_allocator_extend_and_double_free():
    a = PageAllocator(4)
    pages = a.alloc(2)
    assert a.extend(pages, 1) == [0, 1, 2] and pages == [0, 1, 2]
    assert a.extend(pages, 2) is None and pages == [0, 1, 2]  # all-or-nothing
    a.free(pages)
    with pytest.raises(ValueError):
        a.free([0])  # double free
    with pytest.raises(ValueError):
        a.free([99])  # out of range


def test_page_allocator_duplicate_free_rejected():
    """Regression (PR 5): the boolean-owned allocator validated the WHOLE
    list before mutating, so ``free([p, p])`` passed the ownership check
    twice and pushed ``p`` onto the free list twice — a later ``alloc``
    then granted the same physical page to two slots (silent KV aliasing).
    The refcounted allocator rejects duplicates within a call BEFORE any
    mutation, so the failed call leaves the allocator untouched."""
    a = PageAllocator(4)
    pages = a.alloc(2)
    with pytest.raises(ValueError):
        a.free([pages[0], pages[0]])
    # the rejected call mutated NOTHING
    assert a.n_free == 2 and a.refcount(pages[0]) == 1
    a.free(pages)
    assert a.n_free == 4
    # and the old failure mode is structurally impossible now: disjoint
    # grants can never alias a physical page
    g1, g2 = a.alloc(2), a.alloc(2)
    assert not set(g1) & set(g2)


def test_page_allocator_refcount_share_and_last_reader_release():
    a = PageAllocator(4)
    pages = a.alloc(2)  # refcount 1 each
    assert all(a.acquire(p) for p in pages)  # a second reader per page
    assert a.n_used == 2  # a shared page is counted ONCE
    a.free(pages)  # first reader releases...
    assert a.n_used == 2 and a.n_free == 2  # ...pages stay referenced
    a.free(pages)  # last reader releases
    assert a.n_used == 0 and a.n_free == 4
    with pytest.raises(ValueError):
        a.free([pages[0]])  # refcount already 0


def test_page_allocator_acquire_revives_cached_page():
    """A released page (refcount 0, back on the free list, contents intact)
    can be revived by a new reader — the warm-prefix-cache mechanism — and
    while revived it is NOT grantable to writers."""
    a = PageAllocator(2)
    (p,) = a.alloc(1)
    a.free([p])  # cached
    assert a.acquire(p)
    assert a.refcount(p) == 1 and a.n_free == 1
    assert a.alloc(2) is None  # the revived page cannot be re-granted
    a.free([p])
    assert a.n_free == 2


def test_page_allocator_peak_tracks_every_alloc_site():
    """``peak_used`` is raised inside alloc() AND acquire() — the only two
    operations that can grow usage — and ``reset_peak`` re-arms to CURRENT
    usage so held allocations stay observed across a counter reset."""
    a = PageAllocator(8)
    g = a.alloc(5)
    a.free(g)
    assert a.peak_used == 5
    a.reset_peak()
    assert a.peak_used == 0
    g = a.alloc(3)
    a.reset_peak()  # pages still held: the reset must NOT lose them
    assert a.peak_used == 3
    a.free([g[0]])  # cached now
    assert a.acquire(g[0])  # revive raises usage again
    extra = a.alloc(2)
    assert a.peak_used == 5
    a.free(g + extra)


def test_page_allocator_rollback_peak_on_failed_reservation():
    """A failed all-or-nothing reservation that revived cached pages must
    be able to restore the high-water mark after rolling its refs back —
    otherwise retried head-of-queue admissions report phantom peaks."""
    a = PageAllocator(4)
    g = a.alloc(2)
    a.free(g)  # two cached pages
    a.reset_peak()
    assert a.peak_used == 0
    peak0 = a.peak_used
    assert a.acquire(g[0])  # revive raises usage (and the peak) to 1
    assert a.peak_used == 1
    assert a.alloc(4) is None  # the reservation's tail cannot fit
    a.free([g[0]])  # roll the reference back...
    a.rollback_peak(peak0)  # ...and the phantom peak with it
    assert a.peak_used == 0
    with pytest.raises(ValueError):
        a.rollback_peak(3)  # the mark can only be restored, never raised
    b = a.alloc(2)
    with pytest.raises(ValueError):
        a.rollback_peak(1)  # refs NOT rolled back (n_used == 2 > 1)
    a.free(b)


# --------------------------------------------------------------------------- #
# PrefixIndex
# --------------------------------------------------------------------------- #
def test_prefix_index_match_register_drop():
    idx = PrefixIndex(4)
    prompt = np.arange(12, dtype=np.int32)
    idx.register(prompt, [7, 3, 9])
    assert idx.match(prompt) == [7, 3, 9]
    # only FULL pages participate: a 10-token prompt covers two pages
    assert idx.match(prompt[:10]) == [7, 3]
    # keys hash the ENTIRE prefix, not the page's own tokens: divergence
    # inside page 1 kills pages 1 and 2 even though page 2's tokens match
    other = prompt.copy()
    other[5] = 99
    assert idx.match(other) == [7]
    # re-granting a page for writing drops its entry; the chain stops there
    idx.drop_pages([3])
    assert idx.match(prompt) == [7]
    idx.register(prompt, [7, 5, 9])  # re-register the hole with a new page
    assert idx.match(prompt) == [7, 5, 9]
    idx.clear()
    assert idx.match(prompt) == [] and len(idx) == 0


def test_prefix_index_first_registration_wins():
    idx = PrefixIndex(2)
    prompt = np.arange(4, dtype=np.int32)
    idx.register(prompt, [1, 2])
    idx.register(prompt, [5, 6])  # duplicate content elsewhere: keep first
    assert idx.match(prompt) == [1, 2]


def test_scheduler_page_gated_admission_queues_fifo():
    """Admission is gated on PAGES through the reserve hook: a big
    head-of-queue request waits (strict FIFO — never bypassed by a smaller
    one behind it), and its pages+slot are reserved together or not at
    all.  ``None`` is the ONLY exhaustion signal; an empty grant admits."""
    need = {"big": 3, "small": 1, "none": 0}
    pages = PageAllocator(4)
    sched = Scheduler(
        SlotAllocator(4),
        reserve=lambda r: pages.alloc(need[r]),
        release_grant=pages.free,
    )
    sched.enqueue("small")
    sched.enqueue("big")
    sched.enqueue("small")
    placed = sched.admit()
    # small (1 page) + big (3 pages) fill the pool; the second small queues
    assert [r for _, r in placed] == ["small", "big"]
    assert sched.n_waiting == 1 and pages.n_free == 0
    assert sched.admit() == []  # page exhaustion queues rather than crashes
    # an EMPTY grant is a real admission, not exhaustion: zero-page
    # requests admit even with the pool full
    sched.enqueue("none")
    assert sched.n_waiting == 2
    sched.release(1)  # big finishes -> its WHOLE page set is reclaimed
    assert pages.n_free == 3
    assert [r for _, r in sched.admit()] == ["small", "none"]
    assert sched.slot_pages[1] == [1]  # lowest freed page, recycled
    assert sched.slot_pages[2] == []  # the zero-page grant


# --------------------------------------------------------------------------- #
# Engine-level slot lifecycle
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def small_model():
    from repro.configs.registry import get_arch
    from repro.models.model import build_model

    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_engine_exhaustion_queues_and_drains(small_model):
    from repro.serving import Engine, Request

    cfg, model, params = small_model
    rng = np.random.default_rng(0)
    # decode_block=1: this test pins down the PER-TOKEN slot lifecycle
    # (admission counts between individual decode steps); fused-block
    # cadence is covered by tests/test_engine_parity.py
    eng = Engine(model, params, n_slots=2, max_len=16, decode_block=1)
    reqs = [
        eng.submit(
            Request(
                prompt=rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32),
                max_new_tokens=3,
            )
        )
        for _ in range(5)
    ]
    assert eng.n_waiting == 5  # nothing admitted until step()
    eng.step()
    assert eng.n_active == 2 and eng.n_waiting == 3
    while eng.has_work:
        eng.step()
    assert all(len(r.tokens) == 3 for r in reqs)
    assert eng.n_active == 0 and eng.n_waiting == 0
    # all slots returned to the pool
    assert eng.scheduler.allocator.n_free == 2


def test_engine_no_cross_slot_leakage_after_reuse(small_model):
    """A request admitted into a RECYCLED slot must produce exactly what it
    produces in a fresh engine: the previous occupant's cache rows are fully
    overwritten at admission."""
    from repro.serving import Engine, Request

    cfg, model, params = small_model
    rng = np.random.default_rng(3)
    pa = rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32)
    pb = rng.integers(0, cfg.vocab, size=(5,)).astype(np.int32)

    fresh = Engine(model, params, n_slots=1, max_len=16)
    solo = fresh.submit(Request(prompt=pb, max_new_tokens=6))
    while fresh.has_work:
        fresh.step()

    eng = Engine(model, params, n_slots=1, max_len=16)
    first = eng.submit(Request(prompt=pa, max_new_tokens=7))
    reused = eng.submit(Request(prompt=pb, max_new_tokens=6))
    while eng.has_work:
        eng.step()
    assert len(first.tokens) == 7
    # same single slot, second occupant: identical to the solo run
    assert reused.tokens == solo.tokens


def test_engine_rejects_oversized_request(small_model):
    from repro.serving import Engine, Request

    cfg, model, params = small_model
    eng = Engine(model, params, n_slots=1, max_len=8)
    with pytest.raises(ValueError):
        eng.submit(Request(prompt=np.arange(6, dtype=np.int32), max_new_tokens=4))


# --------------------------------------------------------------------------- #
# Engine-level PAGED pool lifecycle
# --------------------------------------------------------------------------- #
def test_paged_engine_page_exhaustion_queues_and_drains(small_model):
    """Slots outnumber the page budget: admission is page-gated, the overflow
    request queues (never crashes, never drops), and every request still
    completes once pages recycle."""
    from repro.serving import Engine, Request

    cfg, model, params = small_model
    rng = np.random.default_rng(0)
    # each request needs ceil((4 + 3) / 4) = 2 pages; 3 pages admit ONE
    # request at a time even though two slots are free
    eng = Engine(model, params, n_slots=2, max_len=16, page_size=4, kv_pages=3,
                 decode_block=1)
    reqs = [
        eng.submit(
            Request(
                prompt=rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32),
                max_new_tokens=3,
            )
        )
        for _ in range(3)
    ]
    eng.step()
    assert eng.n_active == 1 and eng.n_waiting == 2  # page-gated, not slot-gated
    assert eng.pages_in_use == 2
    while eng.has_work:
        eng.step()
    assert all(len(r.tokens) == 3 for r in reqs)
    assert eng.pages_in_use == 0 and eng.page_pool.n_free == 3
    assert eng.peak_active == 1


def test_paged_engine_oversized_for_pool_rejected(small_model):
    from repro.serving import Engine, Request

    cfg, model, params = small_model
    eng = Engine(model, params, n_slots=1, max_len=16, page_size=4, kv_pages=2)
    with pytest.raises(ValueError):  # needs 3 pages, pool holds 2: livelock guard
        eng.submit(Request(prompt=np.arange(8, dtype=np.int32), max_new_tokens=4))


def test_paged_engine_no_leakage_through_recycled_pages(small_model):
    """A request admitted into RECYCLED pages (and a recycled slot) must match
    its fresh-engine run exactly: prefill fully overwrites every allocated
    page and the freed slot's block-table row is compacted back to trash."""
    from repro.serving import Engine, Request

    cfg, model, params = small_model
    rng = np.random.default_rng(3)
    pa = rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32)
    pb = rng.integers(0, cfg.vocab, size=(5,)).astype(np.int32)

    fresh = Engine(model, params, n_slots=1, max_len=16, page_size=4)
    solo = fresh.submit(Request(prompt=pb, max_new_tokens=6))
    while fresh.has_work:
        fresh.step()

    eng = Engine(model, params, n_slots=1, max_len=16, page_size=4)
    first = eng.submit(Request(prompt=pa, max_new_tokens=7))
    reused = eng.submit(Request(prompt=pb, max_new_tokens=6))
    while eng.has_work:
        eng.step()
    assert len(first.tokens) == 7
    assert reused.tokens == solo.tokens


def test_paged_engine_block_table_compaction_on_reuse(small_model):
    """The block-table row of a freed slot is all-trash until reuse, and the
    reused slot's fresh pages are written DENSELY from entry 0."""
    from repro.serving import Engine, Request

    cfg, model, params = small_model
    eng = Engine(model, params, n_slots=1, max_len=16, page_size=4, decode_block=1)
    trash = eng.kv_pages
    assert (eng._bt == trash).all()  # pristine table points at trash
    r = eng.submit(Request(prompt=np.arange(5, dtype=np.int32), max_new_tokens=4))
    eng.step()  # prefill + 1 decode: still mid-stream (decode_block=1)
    need = eng._page_need(r)  # ceil(9/4) = 3
    row = eng._bt[0]
    assert (row[:need] != trash).all() and (row[need:] == trash).all()
    while eng.has_work:
        eng.step()
    assert (eng._bt == trash).all()  # compacted back on release
    r2 = eng.submit(Request(prompt=np.arange(10, dtype=np.int32), max_new_tokens=6))
    eng.step()
    need2 = eng._page_need(r2)  # ceil(16/4) = 4
    row = eng._bt[0]
    assert (row[:need2] != trash).all() and (row[need2:] == trash).all()
    while eng.has_work:
        eng.step()


def test_paged_engine_memory_accounting(small_model):
    """kv_bytes_in_use tracks ALLOCATED pages, not worst-case capacity, and a
    leaner page pool really shrinks the device footprint at equal max_len."""
    from repro.serving import Engine, Request

    cfg, model, params = small_model
    full = Engine(model, params, n_slots=2, max_len=16)
    paged = Engine(model, params, n_slots=2, max_len=16, page_size=4, kv_pages=4,
                   decode_block=1)
    # flat pool: committed up front, in_use == capacity always
    assert full.kv_bytes_in_use == full.kv_bytes_capacity > 0
    # half the token capacity (4 * 4 vs 2 * 16) + one trash page
    assert paged.kv_bytes_capacity < full.kv_bytes_capacity
    assert paged.kv_bytes_in_use == 0
    r = paged.submit(Request(prompt=np.arange(5, dtype=np.int32), max_new_tokens=3))
    paged.step()
    assert paged.pages_in_use == 2  # ceil((5 + 3) / 4)
    assert paged.kv_bytes_in_use == 2 * paged._bytes_per_page
    while paged.has_work:
        paged.step()
    assert paged.kv_bytes_in_use == 0 and paged.peak_pages_in_use == 2
    assert len(r.tokens) == 3


# --------------------------------------------------------------------------- #
# Zero-page paged archs (mamba state / SWA rings stay slot-resident)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("arch_id", ["mamba2-130m", "h2o-danube-1.8b"])
def test_paged_engine_zero_page_request_full_lifecycle(arch_id):
    """Archs with nothing paged (mamba conv/state, SWA rings) run the paged
    engine with ``page_need == 0``: every admission reserves the EMPTY page
    list — ``alloc(0) == []``, which must never be confused with the
    ``None`` exhaustion signal.  Audit trail for that confusion: the
    scheduler's admit loop breaks only on ``grant is None`` (an empty
    grant admits), ``slot_pages`` holds the empty grant like any other,
    and the engine's free path releases it without touching the allocator.
    A 1-page pool (maximal page pressure for anyone who DID need pages)
    must therefore never gate these archs: admission stays slot-gated and
    the full admit -> decode -> free lifecycle completes."""
    from repro.configs.registry import get_arch
    from repro.models.model import build_model
    from repro.serving import Engine, Request

    cfg = get_arch(arch_id, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    eng = Engine(
        model, params, n_slots=2, max_len=16, page_size=4, kv_pages=1,
        decode_block=1,
    )
    assert not eng._has_pages  # nothing paged for this family
    reqs = [
        eng.submit(
            Request(
                prompt=rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32),
                max_new_tokens=3,
            )
        )
        for _ in range(3)
    ]
    eng.step()
    # slot-gated (2 slots), never page-gated: zero-page grants always fit
    assert eng.n_active == 2 and eng.n_waiting == 1
    assert all(g.pages == [] for g in eng.scheduler.slot_pages.values())
    assert eng.pages_in_use == 0 and eng.kv_bytes_in_use == eng._bytes_resident
    while eng.has_work:
        eng.step()
    assert all(len(r.tokens) == 3 for r in reqs)
    assert eng.pages_in_use == 0 and eng.peak_pages_in_use == 0
    assert eng.scheduler.allocator.n_free == 2


# --------------------------------------------------------------------------- #
# Allocation-peak accounting (kv_bytes_peak honesty)
# --------------------------------------------------------------------------- #
def test_paged_engine_peak_observed_across_chunked_prefill(small_model):
    """Regression (PR 5): peaks were engine-side state refreshed on the
    admission path of step() and zeroed outright by reset_counters() — a
    request mid-chunked-prefill at a warmup boundary kept its pages
    allocated while ``peak_pages_in_use`` reported 0 until the NEXT
    admission, under-reporting ``kv_bytes_peak``.  The allocator now owns
    the high-water mark (raised at every allocation-changing site) and a
    reset re-arms to CURRENT usage."""
    from repro.serving import Engine, Request

    cfg, model, params = small_model
    eng = Engine(
        model, params, n_slots=2, max_len=16, page_size=4, prefill_chunk=3,
        decode_block=1,
    )
    r = eng.submit(Request(prompt=np.arange(10, dtype=np.int32), max_new_tokens=2))
    eng.step()  # admission + FIRST chunk only: no decode ran, pages held
    assert eng.prefill_chunks == 1 and eng.n_active == 1
    assert eng.pages_in_use == 3  # ceil((10 + 2) / 4), reserved up front
    assert eng.peak_pages_in_use == 3
    eng.reset_counters()  # warmup boundary mid-prefill
    assert eng.peak_pages_in_use == 3  # held allocation stays observed
    assert eng.peak_active == 1
    assert eng.kv_bytes_peak == eng._bytes_resident + 3 * eng._bytes_per_page
    while eng.has_work:
        eng.step()
    assert len(r.tokens) == 2
    assert eng.peak_pages_in_use == 3 and eng.pages_in_use == 0


# --------------------------------------------------------------------------- #
# Shared-prefix refcount lifecycle (engine level)
# --------------------------------------------------------------------------- #
def test_engine_shared_prefix_refcount_lifecycle(small_model):
    """Shared pages are counted once while mapped by many slots, survive
    the donor's release (the follower still reads them), return to the
    free list only after the LAST reader releases, and remain matchable
    as a warm cache afterwards — with emitted tokens identical to a fresh
    unshared engine throughout."""
    from repro.serving import Engine, Request

    cfg, model, params = small_model
    rng = np.random.default_rng(5)
    sys = rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32)  # 2 full pages
    pa = np.concatenate([sys, rng.integers(0, cfg.vocab, size=(2,)).astype(np.int32)])
    pb = np.concatenate([sys, rng.integers(0, cfg.vocab, size=(3,)).astype(np.int32)])

    # references from an unshared paged engine, run solo
    refs = []
    for p, s in ((pa, 4), (pb, 5)):
        fresh = Engine(model, params, n_slots=1, max_len=16, page_size=4)
        r = fresh.submit(Request(prompt=p, max_new_tokens=s))
        while fresh.has_work:
            fresh.step()
        refs.append(r.tokens)

    eng = Engine(
        model, params, n_slots=2, max_len=16, page_size=4, kv_pages=8,
        share_prefix=True, decode_block=1,
    )
    donor = eng.submit(Request(prompt=pa, max_new_tokens=4))  # needs 4 pages
    eng.step()  # donor prefilled + registered
    assert eng.pages_in_use == 4
    follower = eng.submit(Request(prompt=pb, max_new_tokens=5))  # needs 4
    eng.step()
    # follower mapped the 2 sys pages read-only, allocated only 2 fresh:
    # 6 distinct pages — not 8 — back 8 pages of logical table entries
    assert eng.shared_page_hits == 2 and eng.shared_admissions == 1
    assert eng.pages_in_use == 6
    shared = [g for g in eng.scheduler.slot_pages.values() if g.n_shared == 2]
    assert len(shared) == 1
    for p in shared[0].pages[:2]:
        assert eng.page_pool.refcount(p) == 2  # donor + follower
    while not donor.done:
        eng.step()
    # donor finished (smaller budget) but the shared pages are NOT
    # recycled: the follower still reads them
    assert donor.done and not follower.done
    for p in shared[0].pages[:2]:
        assert eng.page_pool.refcount(p) == 1
    assert eng.pages_in_use == 4  # 2 shared + follower's 2 private
    while eng.has_work:
        eng.step()
    assert eng.pages_in_use == 0 and eng.page_pool.n_free == 8
    assert donor.tokens == refs[0] and follower.tokens == refs[1]

    # warm cache: the freed pages still match until a writer re-grants them
    late = eng.submit(Request(prompt=pb, max_new_tokens=5))
    while eng.has_work:
        eng.step()
    assert eng.shared_admissions == 2  # matched CACHED pages (revived)
    assert late.tokens == refs[1]


def test_engine_shared_reserve_rollback_is_atomic(small_model):
    """A queued request that MATCHES prefix pages but cannot fit its tail
    rolls back every acquired reference (the donor's refcounts return to
    1) and queues; once the donor releases, the retry admits off the warm
    cache and the tokens still match the unshared reference."""
    from repro.serving import Engine, Request

    cfg, model, params = small_model
    rng = np.random.default_rng(11)
    sys = rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32)
    pa = np.concatenate([sys, rng.integers(0, cfg.vocab, size=(2,)).astype(np.int32)])
    pb = np.concatenate([sys, rng.integers(0, cfg.vocab, size=(3,)).astype(np.int32)])
    ref = Engine(model, params, n_slots=1, max_len=16, page_size=4)
    r = ref.submit(Request(prompt=pb, max_new_tokens=5))
    while ref.has_work:
        ref.step()

    # pool of 4: the donor (4 pages) fills it; the follower matches the 2
    # sys pages but its 2-page tail cannot fit -> the reservation fails
    # and must roll back BOTH acquired references atomically
    eng = Engine(
        model, params, n_slots=2, max_len=16, page_size=4, kv_pages=4,
        share_prefix=True, decode_block=1,
    )
    donor = eng.submit(Request(prompt=pa, max_new_tokens=4))  # needs 4
    eng.step()  # donor prefilled + registered (4 pages live)
    donor_pages = list(eng.scheduler.slot_pages[0].pages)
    follower = eng.submit(Request(prompt=pb, max_new_tokens=5))  # needs 4
    eng.step()  # follower's reservation fails this step (0 pages free)
    assert eng.n_waiting == 1 and not donor.done
    # the failed match took one ref on each sys page and gave both back
    assert all(eng.page_pool.refcount(p) == 1 for p in donor_pages)
    assert eng.pages_in_use == 4 and eng.peak_pages_in_use == 4
    while eng.has_work:
        eng.step()
    assert follower.tokens == r.tokens
    assert eng.shared_admissions == 1  # the retry matched the warm cache
    assert eng.pages_in_use == 0


def test_engine_shared_cow_degrades_when_fork_page_cannot_fit(small_model):
    """Livelock regression: the COW fork wants one page BEYOND the
    request's declared footprint, but ``submit`` only guarantees
    ``need <= kv_pages`` — a fully-covered prompt whose need equals the
    whole pool would retry the identical failing reservation forever.
    The reservation must instead degrade (un-share the boundary page and
    prefill it) and admit at exactly ``need`` pages."""
    from repro.serving import Engine, Request

    cfg, model, params = small_model
    rng = np.random.default_rng(13)
    prompt = rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32)  # 2 pages
    eng = Engine(
        model, params, n_slots=2, max_len=12, page_size=4, kv_pages=3,
        share_prefix=True, decode_block=1,
    )
    first = eng.submit(Request(prompt=prompt, max_new_tokens=4))  # need == 3 == pool
    while eng.has_work:
        eng.step()
    # identical prompt, fully covered by the cached pages: a fork would
    # need 4 pages; the degraded reservation shares page 0, re-prefills
    # page 1, and must terminate
    again = eng.submit(Request(prompt=prompt, max_new_tokens=4))
    for _ in range(64):
        if not eng.has_work:
            break
        eng.step()
    assert again.done, "fully-covered prompt livelocked at need == kv_pages"
    assert again.tokens == first.tokens
    assert eng.cow_forks == 0 and eng.shared_admissions == 1
    assert eng.shared_page_hits == 1  # page 0 shared; boundary page re-prefilled
    assert eng.pages_in_use == 0


def test_engine_degraded_reservation_failure_restores_peak(small_model):
    """Regression: when the COW degrade pops the ONLY acquired page and
    the retry alloc still fails, the failure branch must still restore
    the high-water mark the revive raised — otherwise the head-of-queue
    retry reports a phantom page peak every step."""
    from repro.serving import Engine, Request

    cfg, model, params = small_model
    rng = np.random.default_rng(17)
    px = rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32)  # 1 full page
    eng = Engine(
        model, params, n_slots=3, max_len=12, page_size=4, kv_pages=4,
        share_prefix=True, decode_block=1,
    )
    # blocker takes pages 0-1 and stays live; the donor takes 2-3,
    # finishes fast, and leaves px's page cached + indexed at page 2
    blocker = eng.submit(
        Request(prompt=rng.integers(0, cfg.vocab, size=(3,)).astype(np.int32),
                max_new_tokens=5)  # ceil((3 + 5) / 4) = 2 pages, stays live
    )
    eng.step()
    donor = eng.submit(Request(prompt=px, max_new_tokens=2))
    while not donor.done:
        eng.step()
    assert not blocker.done and eng.pages_in_use == 2
    eng.page_pool.reset_peak()
    assert eng.peak_pages_in_use == 2
    # fully-covered follower, need 3: fork wants 3 fresh (1 free after the
    # revive), the degrade retry wants 3 fresh (2 free) — both fail, and
    # the revived page must NOT linger in the peak
    follower = eng.submit(Request(prompt=px, max_new_tokens=8))
    eng.step()
    assert not follower.done and eng.n_waiting == 1
    assert eng.pages_in_use == 2
    assert eng.peak_pages_in_use == 2  # no phantom page from the revive
    while eng.has_work:
        eng.step()
    assert follower.done and eng.pages_in_use == 0


# --------------------------------------------------------------------------- #
# PageAllocator warm cache (LRU eviction, budget, clean-first grants)
# --------------------------------------------------------------------------- #
def test_page_allocator_clean_first_then_lru_eviction():
    """``alloc`` spends never-indexed free pages before evicting cached
    entries, and evicts least-recently-used first — announcing every
    eviction through ``on_evict`` BEFORE the writer sees the page."""
    evicted = []
    a = PageAllocator(4, on_evict=evicted.extend)
    g = a.alloc(2)  # pages [0, 1] — a chain, head first
    a.mark_indexed(g)
    a.free(g)  # both cached; the chain TAIL (page 1) is the older entry
    # clean supply [2, 3] covers this grant: nothing evicted
    assert a.alloc(2) == [2, 3]
    assert evicted == [] and a.evictions == 0 and a.n_cached == 2
    # clean supply exhausted: the grant must evict, LRU (chain tail) first
    assert a.alloc(2) == [1, 0]
    assert evicted == [1, 0] and a.evictions == 2 and a.n_cached == 0


def test_page_allocator_lru_recency_refresh():
    """Re-marking a cached page moves it to the most-recently-used slot,
    so the OTHER entries are the ones a short grant evicts — and recency
    is chain-aware: within one call, earlier-listed pages outlive later
    ones (a chained index loses everything below a missing page)."""
    evicted = []
    a = PageAllocator(3, on_evict=evicted.extend)
    g = a.alloc(3)
    a.mark_indexed(g)
    a.free(g)  # eviction order (oldest first): 2, 1, 0
    a.mark_indexed([2])  # refresh the tail: order now 1, 0, 2
    assert a.alloc(1) == [1]
    assert evicted == [1]


def test_page_allocator_cache_budget_sweeps_on_release():
    """``cache_budget`` caps resident cached entries: the excess is
    swept eagerly when the last reader releases, LRU first."""
    evicted = []
    a = PageAllocator(4, cache_budget=2, on_evict=evicted.extend)
    g = a.alloc(4)
    a.mark_indexed(g)
    a.free(g)  # 4 cached > budget 2: sweep the two oldest (the chain tail)
    assert evicted == [3, 2] and a.evictions == 2
    assert a.n_cached == 2 and a.n_free == 4  # swept pages stay free
    with pytest.raises(ValueError):
        PageAllocator(2, cache_budget=-1)


def test_page_allocator_budget_zero_disables_warm_cache():
    a = PageAllocator(2, cache_budget=0)
    g = a.alloc(1)
    a.mark_indexed(g)
    a.free(g)  # swept immediately
    assert a.n_cached == 0 and a.evictions == 1


def test_page_allocator_flush_cache_is_silent():
    """``flush_cache`` (owner-initiated reset) forgets every entry
    without firing ``on_evict`` or counting evictions — the counter
    stays a cache-pressure metric."""
    evicted = []
    a = PageAllocator(2, on_evict=evicted.extend)
    g = a.alloc(2)
    a.mark_indexed(g)
    a.free(g)
    assert a.n_cached == 2
    a.flush_cache()
    assert a.n_cached == 0 and a.evictions == 0 and evicted == []
    assert a.alloc(2) == [0, 1]  # plain clean pages again


def test_page_allocator_mark_indexed_validates_and_caches_ref0():
    a = PageAllocator(2)
    with pytest.raises(ValueError):
        a.mark_indexed([2])
    g = a.alloc(1)
    a.mark_indexed(g)  # live page: indexed but not yet cached
    assert a.n_cached == 0
    a.free(g)  # ...cached the moment the last reader leaves
    assert a.n_cached == 1
    assert a.acquire(g[0])  # revive: live again, off the cache
    assert a.n_cached == 0
    a.free(g)
    assert a.n_cached == 1  # still indexed: re-cached on re-release


def test_page_allocator_inert_without_mark_indexed():
    """With ``mark_indexed`` never called the allocator is byte-for-byte
    the PR-5 one: pure lowest-id-first reuse, no evictions, no cache."""
    a = PageAllocator(3)
    g = a.alloc(3)
    a.free(g)
    assert a.alloc(2) == [0, 1]
    assert a.evictions == 0 and a.n_cached == 0


def test_scheduler_same_batch_match_then_reserve_ordering(small_model):
    """Several admissions landing in one ``Engine.step`` must respect
    the match-then-reserve window: a later request in the same placement
    batch may NOT be granted (as writer) a cached refcount-0 page an
    earlier request just matched.  The matcher's ``acquire`` pulls the
    page off the free list inside its own reservation, so the writer
    behind it queues instead of stealing the storage."""
    from repro.serving import Engine, Request

    cfg, model, params = small_model
    rng = np.random.default_rng(23)
    px = rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32)  # 1 full page
    eng = Engine(
        model, params, n_slots=3, max_len=16, page_size=4, kv_pages=4,
        share_prefix=True, decode_block=1,
    )
    donor = eng.submit(Request(prompt=px, max_new_tokens=2))  # pages [0, 1]
    while eng.has_work:
        eng.step()
    assert donor.done and eng.pages_in_use == 0
    assert eng.prefix_cached_pages == 1  # px's page 0 is warm
    # one step admits BOTH: the matcher (head of queue) revives page 0
    # read-only; the writer behind it wants 2 fresh pages but only one
    # clean page remains — it must queue, NOT evict/steal page 0
    follow = np.concatenate([px, rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32)])
    matcher = eng.submit(Request(prompt=follow, max_new_tokens=4))  # need 3
    writer = eng.submit(
        Request(prompt=rng.integers(0, cfg.vocab, size=(5,)).astype(np.int32),
                max_new_tokens=2)  # need 2 > 1 clean page left
    )
    eng.step()
    assert eng.shared_admissions == 1 and not matcher.done
    assert eng.n_waiting == 1  # the writer queued behind the match
    assert eng.prefix_evictions == 0  # page 0 was never re-granted
    while eng.has_work:
        eng.step()
    assert matcher.done and writer.done
    # determinism cross-check: the matcher saw exactly the donor's bytes
    cold = Engine(
        model, params, n_slots=1, max_len=16, page_size=4, kv_pages=4,
        prefill_chunk=4,
    )
    ref = cold.run([Request(prompt=follow.copy(), max_new_tokens=4)])[0]
    assert matcher.tokens == ref.tokens
