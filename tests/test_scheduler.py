"""Unit tests for the serving slot allocator / scheduler, plus engine-level
slot-lifecycle properties (exhaustion queues, reuse, no cache leakage)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.scheduler import Scheduler, SlotAllocator


# --------------------------------------------------------------------------- #
# SlotAllocator
# --------------------------------------------------------------------------- #
def test_allocator_exhaustion_returns_none():
    a = SlotAllocator(2)
    assert a.alloc() == 0 and a.alloc() == 1
    assert a.alloc() is None  # exhaustion is a soft condition, not an error
    assert a.n_free == 0 and a.n_active == 2


def test_allocator_free_and_reuse_lowest_first():
    a = SlotAllocator(3)
    s = [a.alloc() for _ in range(3)]
    assert s == [0, 1, 2]
    a.free(1)
    a.free(0)
    # deterministic reuse order: lowest free id first
    assert a.alloc() == 0
    assert a.alloc() == 1
    assert a.alloc() is None


def test_allocator_double_free_rejected():
    a = SlotAllocator(2)
    slot = a.alloc()
    a.free(slot)
    with pytest.raises(ValueError):
        a.free(slot)
    with pytest.raises(ValueError):
        a.free(99)


def test_allocator_bad_size_rejected():
    with pytest.raises(ValueError):
        SlotAllocator(0)


# --------------------------------------------------------------------------- #
# Scheduler
# --------------------------------------------------------------------------- #
def test_scheduler_fifo_admission_and_queueing():
    sched = Scheduler(SlotAllocator(2))
    for name in ("a", "b", "c", "d"):
        sched.enqueue(name)
    placed = sched.admit()
    assert [(s, r) for s, r in placed] == [(0, "a"), (1, "b")]
    assert sched.n_waiting == 2  # exhaustion queues rather than crashes
    assert sched.admit() == []  # no free slots -> nothing admitted
    sched.release(0)
    assert sched.admit() == [(0, "c")]  # freed slot reused, FIFO order kept
    sched.release(1)
    sched.release(0)
    assert sched.admit() == [(0, "d")]
    assert sched.n_waiting == 0


# --------------------------------------------------------------------------- #
# Engine-level slot lifecycle
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def small_model():
    from repro.configs.registry import get_arch
    from repro.models.model import build_model

    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_engine_exhaustion_queues_and_drains(small_model):
    from repro.serving import Engine, Request

    cfg, model, params = small_model
    rng = np.random.default_rng(0)
    # decode_block=1: this test pins down the PER-TOKEN slot lifecycle
    # (admission counts between individual decode steps); fused-block
    # cadence is covered by tests/test_engine_parity.py
    eng = Engine(model, params, n_slots=2, max_len=16, decode_block=1)
    reqs = [
        eng.submit(
            Request(
                prompt=rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32),
                max_new_tokens=3,
            )
        )
        for _ in range(5)
    ]
    assert eng.n_waiting == 5  # nothing admitted until step()
    eng.step()
    assert eng.n_active == 2 and eng.n_waiting == 3
    while eng.has_work:
        eng.step()
    assert all(len(r.tokens) == 3 for r in reqs)
    assert eng.n_active == 0 and eng.n_waiting == 0
    # all slots returned to the pool
    assert eng.scheduler.allocator.n_free == 2


def test_engine_no_cross_slot_leakage_after_reuse(small_model):
    """A request admitted into a RECYCLED slot must produce exactly what it
    produces in a fresh engine: the previous occupant's cache rows are fully
    overwritten at admission."""
    from repro.serving import Engine, Request

    cfg, model, params = small_model
    rng = np.random.default_rng(3)
    pa = rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32)
    pb = rng.integers(0, cfg.vocab, size=(5,)).astype(np.int32)

    fresh = Engine(model, params, n_slots=1, max_len=16)
    solo = fresh.submit(Request(prompt=pb, max_new_tokens=6))
    while fresh.has_work:
        fresh.step()

    eng = Engine(model, params, n_slots=1, max_len=16)
    first = eng.submit(Request(prompt=pa, max_new_tokens=7))
    reused = eng.submit(Request(prompt=pb, max_new_tokens=6))
    while eng.has_work:
        eng.step()
    assert len(first.tokens) == 7
    # same single slot, second occupant: identical to the solo run
    assert reused.tokens == solo.tokens


def test_engine_rejects_oversized_request(small_model):
    from repro.serving import Engine, Request

    cfg, model, params = small_model
    eng = Engine(model, params, n_slots=1, max_len=8)
    with pytest.raises(ValueError):
        eng.submit(Request(prompt=np.arange(6, dtype=np.int32), max_new_tokens=4))
