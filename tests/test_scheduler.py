"""Unit tests for the serving slot/page allocators and scheduler, plus
engine-level lifecycle properties (exhaustion queues, reuse, no cache
leakage) for both the flat and the paged KV pool."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.scheduler import PageAllocator, Scheduler, SlotAllocator


# --------------------------------------------------------------------------- #
# SlotAllocator
# --------------------------------------------------------------------------- #
def test_allocator_exhaustion_returns_none():
    a = SlotAllocator(2)
    assert a.alloc() == 0 and a.alloc() == 1
    assert a.alloc() is None  # exhaustion is a soft condition, not an error
    assert a.n_free == 0 and a.n_active == 2


def test_allocator_free_and_reuse_lowest_first():
    a = SlotAllocator(3)
    s = [a.alloc() for _ in range(3)]
    assert s == [0, 1, 2]
    a.free(1)
    a.free(0)
    # deterministic reuse order: lowest free id first
    assert a.alloc() == 0
    assert a.alloc() == 1
    assert a.alloc() is None


def test_allocator_double_free_rejected():
    a = SlotAllocator(2)
    slot = a.alloc()
    a.free(slot)
    with pytest.raises(ValueError):
        a.free(slot)
    with pytest.raises(ValueError):
        a.free(99)


def test_allocator_bad_size_rejected():
    with pytest.raises(ValueError):
        SlotAllocator(0)


# --------------------------------------------------------------------------- #
# Scheduler
# --------------------------------------------------------------------------- #
def test_scheduler_fifo_admission_and_queueing():
    sched = Scheduler(SlotAllocator(2))
    for name in ("a", "b", "c", "d"):
        sched.enqueue(name)
    placed = sched.admit()
    assert [(s, r) for s, r in placed] == [(0, "a"), (1, "b")]
    assert sched.n_waiting == 2  # exhaustion queues rather than crashes
    assert sched.admit() == []  # no free slots -> nothing admitted
    sched.release(0)
    assert sched.admit() == [(0, "c")]  # freed slot reused, FIFO order kept
    sched.release(1)
    sched.release(0)
    assert sched.admit() == [(0, "d")]
    assert sched.n_waiting == 0


# --------------------------------------------------------------------------- #
# PageAllocator
# --------------------------------------------------------------------------- #
def test_page_allocator_all_or_nothing_and_exhaustion():
    a = PageAllocator(4)
    assert a.alloc(3) == [0, 1, 2]
    assert a.alloc(2) is None  # never a partial grant
    assert a.n_free == 1  # ... and the failed alloc took nothing
    assert a.alloc(1) == [3]
    assert a.alloc(1) is None and a.n_used == 4
    assert a.alloc(0) == []  # zero-page requests always fit (ssm/swa archs)


def test_page_allocator_free_reclaims_whole_set_lowest_first():
    a = PageAllocator(6)
    first = a.alloc(3)
    second = a.alloc(2)
    a.free(first)  # the whole set comes back at once — no fragmentation
    assert a.n_free == 4
    assert a.alloc(4) == [0, 1, 2, 5]  # deterministic lowest-first reuse
    a.free(second + [0, 1, 2, 5])
    assert a.n_free == 6


def test_page_allocator_extend_and_double_free():
    a = PageAllocator(4)
    pages = a.alloc(2)
    assert a.extend(pages, 1) == [0, 1, 2] and pages == [0, 1, 2]
    assert a.extend(pages, 2) is None and pages == [0, 1, 2]  # all-or-nothing
    a.free(pages)
    with pytest.raises(ValueError):
        a.free([0])  # double free
    with pytest.raises(ValueError):
        a.free([99])  # out of range


def test_scheduler_page_gated_admission_queues_fifo():
    """Admission is gated on PAGES: a big head-of-queue request waits (strict
    FIFO — never bypassed by a smaller one behind it), and its pages+slot are
    reserved together or not at all."""
    need = {"big": 3, "small": 1}
    sched = Scheduler(
        SlotAllocator(4), pages=PageAllocator(4), page_need=lambda r: need[r]
    )
    sched.enqueue("small")
    sched.enqueue("big")
    sched.enqueue("small")
    placed = sched.admit()
    # small (1 page) + big (3 pages) fill the pool; the second small queues
    assert [r for _, r in placed] == ["small", "big"]
    assert sched.n_waiting == 1 and sched.pages.n_free == 0
    assert sched.admit() == []  # page exhaustion queues rather than crashes
    sched.release(1)  # big finishes -> its WHOLE page set is reclaimed
    assert sched.pages.n_free == 3
    assert [r for _, r in sched.admit()] == ["small"]
    assert sched.slot_pages[1] == [1]  # lowest freed page, recycled


# --------------------------------------------------------------------------- #
# Engine-level slot lifecycle
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def small_model():
    from repro.configs.registry import get_arch
    from repro.models.model import build_model

    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_engine_exhaustion_queues_and_drains(small_model):
    from repro.serving import Engine, Request

    cfg, model, params = small_model
    rng = np.random.default_rng(0)
    # decode_block=1: this test pins down the PER-TOKEN slot lifecycle
    # (admission counts between individual decode steps); fused-block
    # cadence is covered by tests/test_engine_parity.py
    eng = Engine(model, params, n_slots=2, max_len=16, decode_block=1)
    reqs = [
        eng.submit(
            Request(
                prompt=rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32),
                max_new_tokens=3,
            )
        )
        for _ in range(5)
    ]
    assert eng.n_waiting == 5  # nothing admitted until step()
    eng.step()
    assert eng.n_active == 2 and eng.n_waiting == 3
    while eng.has_work:
        eng.step()
    assert all(len(r.tokens) == 3 for r in reqs)
    assert eng.n_active == 0 and eng.n_waiting == 0
    # all slots returned to the pool
    assert eng.scheduler.allocator.n_free == 2


def test_engine_no_cross_slot_leakage_after_reuse(small_model):
    """A request admitted into a RECYCLED slot must produce exactly what it
    produces in a fresh engine: the previous occupant's cache rows are fully
    overwritten at admission."""
    from repro.serving import Engine, Request

    cfg, model, params = small_model
    rng = np.random.default_rng(3)
    pa = rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32)
    pb = rng.integers(0, cfg.vocab, size=(5,)).astype(np.int32)

    fresh = Engine(model, params, n_slots=1, max_len=16)
    solo = fresh.submit(Request(prompt=pb, max_new_tokens=6))
    while fresh.has_work:
        fresh.step()

    eng = Engine(model, params, n_slots=1, max_len=16)
    first = eng.submit(Request(prompt=pa, max_new_tokens=7))
    reused = eng.submit(Request(prompt=pb, max_new_tokens=6))
    while eng.has_work:
        eng.step()
    assert len(first.tokens) == 7
    # same single slot, second occupant: identical to the solo run
    assert reused.tokens == solo.tokens


def test_engine_rejects_oversized_request(small_model):
    from repro.serving import Engine, Request

    cfg, model, params = small_model
    eng = Engine(model, params, n_slots=1, max_len=8)
    with pytest.raises(ValueError):
        eng.submit(Request(prompt=np.arange(6, dtype=np.int32), max_new_tokens=4))


# --------------------------------------------------------------------------- #
# Engine-level PAGED pool lifecycle
# --------------------------------------------------------------------------- #
def test_paged_engine_page_exhaustion_queues_and_drains(small_model):
    """Slots outnumber the page budget: admission is page-gated, the overflow
    request queues (never crashes, never drops), and every request still
    completes once pages recycle."""
    from repro.serving import Engine, Request

    cfg, model, params = small_model
    rng = np.random.default_rng(0)
    # each request needs ceil((4 + 3) / 4) = 2 pages; 3 pages admit ONE
    # request at a time even though two slots are free
    eng = Engine(model, params, n_slots=2, max_len=16, page_size=4, kv_pages=3,
                 decode_block=1)
    reqs = [
        eng.submit(
            Request(
                prompt=rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32),
                max_new_tokens=3,
            )
        )
        for _ in range(3)
    ]
    eng.step()
    assert eng.n_active == 1 and eng.n_waiting == 2  # page-gated, not slot-gated
    assert eng.pages_in_use == 2
    while eng.has_work:
        eng.step()
    assert all(len(r.tokens) == 3 for r in reqs)
    assert eng.pages_in_use == 0 and eng.scheduler.pages.n_free == 3
    assert eng.peak_active == 1


def test_paged_engine_oversized_for_pool_rejected(small_model):
    from repro.serving import Engine, Request

    cfg, model, params = small_model
    eng = Engine(model, params, n_slots=1, max_len=16, page_size=4, kv_pages=2)
    with pytest.raises(ValueError):  # needs 3 pages, pool holds 2: livelock guard
        eng.submit(Request(prompt=np.arange(8, dtype=np.int32), max_new_tokens=4))


def test_paged_engine_no_leakage_through_recycled_pages(small_model):
    """A request admitted into RECYCLED pages (and a recycled slot) must match
    its fresh-engine run exactly: prefill fully overwrites every allocated
    page and the freed slot's block-table row is compacted back to trash."""
    from repro.serving import Engine, Request

    cfg, model, params = small_model
    rng = np.random.default_rng(3)
    pa = rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32)
    pb = rng.integers(0, cfg.vocab, size=(5,)).astype(np.int32)

    fresh = Engine(model, params, n_slots=1, max_len=16, page_size=4)
    solo = fresh.submit(Request(prompt=pb, max_new_tokens=6))
    while fresh.has_work:
        fresh.step()

    eng = Engine(model, params, n_slots=1, max_len=16, page_size=4)
    first = eng.submit(Request(prompt=pa, max_new_tokens=7))
    reused = eng.submit(Request(prompt=pb, max_new_tokens=6))
    while eng.has_work:
        eng.step()
    assert len(first.tokens) == 7
    assert reused.tokens == solo.tokens


def test_paged_engine_block_table_compaction_on_reuse(small_model):
    """The block-table row of a freed slot is all-trash until reuse, and the
    reused slot's fresh pages are written DENSELY from entry 0."""
    from repro.serving import Engine, Request

    cfg, model, params = small_model
    eng = Engine(model, params, n_slots=1, max_len=16, page_size=4, decode_block=1)
    trash = eng.kv_pages
    assert (eng._bt == trash).all()  # pristine table points at trash
    r = eng.submit(Request(prompt=np.arange(5, dtype=np.int32), max_new_tokens=4))
    eng.step()  # prefill + 1 decode: still mid-stream (decode_block=1)
    need = eng._page_need(r)  # ceil(9/4) = 3
    row = eng._bt[0]
    assert (row[:need] != trash).all() and (row[need:] == trash).all()
    while eng.has_work:
        eng.step()
    assert (eng._bt == trash).all()  # compacted back on release
    r2 = eng.submit(Request(prompt=np.arange(10, dtype=np.int32), max_new_tokens=6))
    eng.step()
    need2 = eng._page_need(r2)  # ceil(16/4) = 4
    row = eng._bt[0]
    assert (row[:need2] != trash).all() and (row[need2:] == trash).all()
    while eng.has_work:
        eng.step()


def test_paged_engine_memory_accounting(small_model):
    """kv_bytes_in_use tracks ALLOCATED pages, not worst-case capacity, and a
    leaner page pool really shrinks the device footprint at equal max_len."""
    from repro.serving import Engine, Request

    cfg, model, params = small_model
    full = Engine(model, params, n_slots=2, max_len=16)
    paged = Engine(model, params, n_slots=2, max_len=16, page_size=4, kv_pages=4,
                   decode_block=1)
    # flat pool: committed up front, in_use == capacity always
    assert full.kv_bytes_in_use == full.kv_bytes_capacity > 0
    # half the token capacity (4 * 4 vs 2 * 16) + one trash page
    assert paged.kv_bytes_capacity < full.kv_bytes_capacity
    assert paged.kv_bytes_in_use == 0
    r = paged.submit(Request(prompt=np.arange(5, dtype=np.int32), max_new_tokens=3))
    paged.step()
    assert paged.pages_in_use == 2  # ceil((5 + 3) / 4)
    assert paged.kv_bytes_in_use == 2 * paged._bytes_per_page
    while paged.has_work:
        paged.step()
    assert paged.kv_bytes_in_use == 0 and paged.peak_pages_in_use == 2
    assert len(r.tokens) == 3
