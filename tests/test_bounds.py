"""Theorem 3.2 / Lemma 3.1 property tests (hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    certify_head,
    rsi_factors,
    softmax_jacobian,
    softmax_perturbation_bound,
)


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    C=st.integers(2, 24),
    scale=st.floats(0.1, 20.0),
)
def test_lemma_3_1_jacobian_row_sums(seed, C, scale):
    """Row sums of |J_sigma| equal 2*s_i(1-s_i) and are <= 1/2."""
    u = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (C,))) * scale
    J = np.asarray(softmax_jacobian(jnp.asarray(u)))
    s = np.asarray(jax.nn.softmax(jnp.asarray(u)))
    row_sums = np.abs(J).sum(axis=1)
    np.testing.assert_allclose(row_sums, 2 * s * (1 - s), atol=1e-5)
    assert (row_sums <= 0.5 + 1e-6).all()
    # Jacobian structure: diag(s) - s s^T
    np.testing.assert_allclose(J, np.diag(s) - np.outer(s, s), atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    C=st.integers(3, 16),
    D=st.integers(8, 64),
    k_frac=st.floats(0.2, 0.9),
)
def test_theorem_3_2_bound_holds(seed, C, D, k_frac):
    """||softmax(W~h+b) - softmax(Wh+b)||_inf <= 1/2 R ||W-W~||_2 for random
    W, low-rank W~, and a batch of feature vectors with ||h|| <= R."""
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    W = jax.random.normal(keys[0], (C, D))
    b = jax.random.normal(keys[1], (C,))
    k = max(1, int(k_frac * min(C, D)))
    A, B = rsi_factors(W, k, 2, keys[2])
    W_approx = A @ B
    h = jax.random.normal(keys[3], (32, D))
    R = float(jnp.max(jnp.linalg.norm(h, axis=-1)))
    spec_err = float(jnp.linalg.svd(W - W_approx, compute_uv=False)[0])

    p = jax.nn.softmax(h @ W.T + b, axis=-1)
    p2 = jax.nn.softmax(h @ W_approx.T + b, axis=-1)
    lhs = float(jnp.max(jnp.abs(p - p2)))
    rhs = float(softmax_perturbation_bound(spec_err, R))
    assert lhs <= rhs + 1e-5, (lhs, rhs)


def test_certificate_end_to_end():
    key = jax.random.PRNGKey(0)
    C, D, k = 10, 64, 4
    W = jax.random.normal(key, (C, D)) * 0.3
    A, B = rsi_factors(W, k, 3, jax.random.PRNGKey(1))
    calib = jax.random.normal(jax.random.PRNGKey(2), (128, D))
    cert = certify_head(W, A @ B, calib, jax.random.PRNGKey(3), rank=k, q=3)
    assert cert.prob_deviation_bound >= 0.5 * cert.spectral_error * 0  # sanity
    # the empirical deviation on calibration data must respect the bound
    p = jax.nn.softmax(calib @ W.T, axis=-1)
    p2 = jax.nn.softmax(calib @ (A @ B).T, axis=-1)
    emp = float(jnp.max(jnp.abs(p - p2)))
    assert emp <= cert.prob_deviation_bound + 1e-4
    # top-1 stability logic
    assert cert.guarantees_top1_stability(margin=2 * cert.prob_deviation_bound + 0.1)
    assert not cert.guarantees_top1_stability(margin=0.0)
