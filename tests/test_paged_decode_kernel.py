"""Paged (block-table) flash-decode kernel suite.

Same contract as tests/test_decode_kernel.py, interpret mode on CPU: the
Pallas kernel that gathers K/V through a block table must match the
gather-einsum oracle — which itself must be BIT-identical to the flat
dense reference when the pages reassemble the same cache — across GQA
ratios, ragged ``n_valid`` crossing page boundaries, fully-masked rows,
and the dispatch routing that picks the kernel by shape/platform.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import paged_decode_attention_pallas
from repro.runtime import dispatch
from repro.runtime.dispatch import DECODE_MIN_SEQ, DispatchConfig, use_dispatch

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5), jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


def _rand(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) / shape[-1] ** 0.25).astype(dtype)


def _paged_inputs(B, n_tbl, page, KV, G, hd, dtype, seed=0, poison=1e4):
    """A flat cache and its paged twin: pages placed at PERMUTED physical
    ids (so tests catch any reliance on contiguity), plus a trailing trash
    page full of poison."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    S = n_tbl * page
    q = _rand(ks[0], (B, 1, KV * G, hd), dtype)
    flat_k = _rand(ks[1], (B, S, KV, hd), dtype)
    flat_v = _rand(ks[2], (B, S, KV, hd), dtype)
    P = B * n_tbl + 1
    rng = np.random.default_rng(seed)
    bt = rng.permutation(P - 1).reshape(B, n_tbl).astype(np.int32)
    k_pool = np.full((P, page, KV, hd), poison, np.float32).astype(dtype)
    v_pool = np.full((P, page, KV, hd), poison, np.float32).astype(dtype)
    for b in range(B):
        for j in range(n_tbl):
            k_pool[bt[b, j]] = np.asarray(flat_k)[b, j * page : (j + 1) * page]
            v_pool[bt[b, j]] = np.asarray(flat_v)[b, j * page : (j + 1) * page]
    return q, flat_k, flat_v, jnp.asarray(k_pool), jnp.asarray(v_pool), jnp.asarray(bt)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("G", [1, 4, 8])  # GQA ratio H/KV
def test_paged_kernel_gqa_ratios(G, dtype):
    B, n_tbl, page, KV, hd = 2, 4, 16, 2, 16
    q, fk, fv, kp, vp, bt = _paged_inputs(B, n_tbl, page, KV, G, hd, dtype)
    n_valid = jnp.array([n_tbl * page, n_tbl * page // 2], jnp.int32)
    got = paged_decode_attention_pallas(q, kp, vp, bt, n_valid, interpret=True)
    want = ref.paged_decode_attention_ref(q, kp, vp, bt, n_valid)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **TOL[dtype]
    )


def test_paged_ref_bit_identical_to_flat():
    """The gather oracle reassembles EXACTLY the flat cache: outputs are
    bit-identical to the dense flat reference — the property that makes the
    paged engine's greedy tokens match the flat engine's."""
    B, n_tbl, page, KV, G, hd = 3, 4, 8, 2, 4, 16
    q, fk, fv, kp, vp, bt = _paged_inputs(B, n_tbl, page, KV, G, hd, jnp.float32, seed=1)
    S = n_tbl * page
    n_valid = jnp.array([S, 11, 27], jnp.int32)
    valid = jnp.arange(S)[None, :] < n_valid[:, None]
    flat = ref.decode_attention_ref(q, fk, fv, valid)
    paged = ref.paged_decode_attention_ref(q, kp, vp, bt, n_valid)
    np.testing.assert_array_equal(np.asarray(paged), np.asarray(flat))


@pytest.mark.parametrize("n_valid_vals", [(1, 17, 48, 5), (16, 32, 33, 31)])
def test_paged_kernel_ragged_n_valid_crosses_pages(n_valid_vals):
    """Ragged per-slot validity, including boundaries INSIDE and exactly AT
    page edges: poison beyond each slot's valid prefix (and in the trash
    page every unallocated table entry points at) must never leak."""
    B, n_tbl, page, KV, G, hd = 4, 3, 16, 2, 4, 16  # S = 48
    q, fk, fv, kp, vp, bt = _paged_inputs(B, n_tbl, page, KV, G, hd, jnp.float32, seed=2)
    S = n_tbl * page
    n_valid = jnp.array(n_valid_vals, jnp.int32)
    # poison the invalid tail of every slot's pages, flat-and-paged alike
    valid = jnp.arange(S)[None, :] < n_valid[:, None]
    kp_host, vp_host = np.array(kp), np.array(vp)
    for b in range(B):
        for j in range(n_tbl):
            keep = np.asarray(valid)[b, j * page : (j + 1) * page]
            kp_host[int(bt[b, j])][~keep] = 1e4
            vp_host[int(bt[b, j])][~keep] = 1e4
    got = paged_decode_attention_pallas(
        q, jnp.asarray(kp_host), jnp.asarray(vp_host), bt, n_valid, interpret=True
    )
    assert bool(jnp.all(jnp.isfinite(got)))
    want = ref.decode_attention_ref(q, fk, fv, valid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL[jnp.float32])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_fully_masked_rows_are_zero(dtype):
    """A slot with n_valid == 0 (every table entry on trash) produces ZEROS
    from both the kernel and the gather oracle; live rows are untouched."""
    B, n_tbl, page, KV, G, hd = 3, 2, 8, 2, 2, 8
    q, fk, fv, kp, vp, bt = _paged_inputs(B, n_tbl, page, KV, G, hd, dtype, seed=3)
    trash = kp.shape[0] - 1
    bt = bt.at[0].set(trash).at[2].set(trash)  # dead slots point at trash
    n_valid = jnp.array([0, 7, 0], jnp.int32)
    for got in (
        ref.paged_decode_attention_ref(q, kp, vp, bt, n_valid),
        paged_decode_attention_pallas(q, kp, vp, bt, n_valid, interpret=True),
    ):
        got = np.asarray(got, np.float32)
        assert np.isfinite(got).all()
        np.testing.assert_array_equal(got[0], np.zeros_like(got[0]))
        np.testing.assert_array_equal(got[2], np.zeros_like(got[2]))
        assert np.abs(got[1]).sum() > 0


# --------------------------------------------------------------------------- #
# dispatch routing
# --------------------------------------------------------------------------- #
def test_choose_paged_decode_path_auto_table():
    q_shape = (4, 1, 8, 64)
    pool = (64, 64, 2, 64)  # page = 64
    cfg = DispatchConfig()
    deep, shallow = 32, 1  # 32 * 64 = 2048 logical >= DECODE_MIN_SEQ > 64
    assert dispatch.choose_paged_decode_path(q_shape, pool, deep, config=cfg, platform="tpu") == "pallas"
    assert dispatch.choose_paged_decode_path(q_shape, pool, shallow, config=cfg, platform="tpu") == "xla"
    assert dispatch.choose_paged_decode_path(q_shape, pool, deep, config=cfg, platform="cpu") == "xla"
    assert shallow * pool[1] < DECODE_MIN_SEQ <= deep * pool[1]
    pinned = DispatchConfig(backend="pallas")
    assert dispatch.choose_paged_decode_path(q_shape, pool, shallow, config=pinned, platform="cpu") == "pallas"
    per_op = DispatchConfig(overrides=(("paged_decode_attention", "xla"),))
    assert dispatch.choose_paged_decode_path(q_shape, pool, deep, config=per_op, platform="tpu") == "xla"


def test_paged_dispatch_entry_counts_and_matches():
    B, n_tbl, page, KV, G, hd = 2, 4, 8, 2, 4, 16
    q, fk, fv, kp, vp, bt = _paged_inputs(B, n_tbl, page, KV, G, hd, jnp.float32, seed=5)
    n_valid = jnp.array([32, 11], jnp.int32)
    dispatch.reset_counters()
    with use_dispatch(backend="pallas"):
        got = dispatch.paged_decode_attention(q, kp, vp, bt, n_valid)
    want = ref.paged_decode_attention_ref(q, kp, vp, bt, n_valid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)
    hits = dispatch.counters_by_path()
    assert hits.get(("paged_decode_attention", "pallas"), 0) >= 1


def test_paged_engine_decode_runs_through_dispatch_counter():
    """End-to-end: a paged fused engine block records paged_decode_attention
    sites (and the flat op is NOT used for the paged pool)."""
    from repro.configs.registry import get_arch
    from repro.models.model import build_model
    from repro.serving import Engine, Request

    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dispatch.reset_counters()
    eng = Engine(model, params, n_slots=2, max_len=16, decode_block=4, page_size=4)
    eng.submit(Request(prompt=np.arange(4, dtype=np.int32), max_new_tokens=5))
    while eng.has_work:
        eng.step()
    hits = dispatch.counters_by_path()
    assert hits.get(("paged_decode_attention", "xla"), 0) >= 1  # CPU auto -> gather
    assert hits.get(("decode_attention", "xla"), 0) == 0
