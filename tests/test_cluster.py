"""Replicated serving cluster: failure detection, failover, recovery.

Covers the cluster's acceptance contract:
  * pure-level routing (least-loaded total order) and failover backoff
    (capped exponential, deterministic jitter)
  * heartbeat-miss detection (hang fault), kill and slow/straggler faults
  * retry-budget exhaustion -> structured ``replica_lost`` rejection
  * probation rejoin state machine and ``restart_replica``
  * cross-replica resume for every chunk-capable arch

Bit-exactness is asserted PER COMPUTE PATH (the same contract the
``--trace failover`` benchmark gates): an unfailed request must match the
single-engine replay exactly; a failed-over request must have a
bit-identical credited prefix, and a resumed tail bit-identical to what a
fresh engine emits for that continuation.  The uninterrupted replay may
legitimately diverge from a resumed tail at an argmax near-tie, because
prefill-written and decode-written KV differ in low-order bits.
"""

import time

import jax
import numpy as np
import pytest

from repro.analysis import sanitize
from repro.configs.registry import ARCH_IDS, get_arch
from repro.data.synthetic import modality_extras
from repro.models.model import build_model
from repro.runtime.fault_tolerance import (
    FaultInjector,
    ReplicaKilled,
    StepWatchdog,
)
from repro.serving import (
    Cluster,
    Engine,
    FailoverBudget,
    Request,
    RoutingPolicy,
)

MAX_LEN = 32
ENG_KW = dict(
    n_slots=2, max_len=MAX_LEN, page_size=4, prefill_chunk=4,
    decode_block=2, share_prefix=True,
)


@pytest.fixture(autouse=True)
def _sanitizer_clean():
    """Under REPRO_SANITIZE=1 every guarded-attribute access in these
    tests is checked live; a violation recorded by ANY thread during the
    test fails it here (raising inside a replica thread would just look
    like one more replica death to the failover machinery)."""
    sanitize.reset()
    yield
    sanitize.check()


# --------------------------------------------------------------------------- #
# pure level: routing + backoff
# --------------------------------------------------------------------------- #
def test_routing_policy_least_loaded_total_order():
    pol = RoutingPolicy()
    # least queue depth dominates
    assert pol.pick([(0, 3, 0), (1, 1, 99)]) == 1
    # depth tie -> least pages
    assert pol.pick([(0, 2, 8), (1, 2, 3)]) == 1
    # full tie -> lowest id (deterministic routing for a fixed trace)
    assert pol.pick([(2, 1, 4), (0, 1, 4), (1, 1, 4)]) == 0
    with pytest.raises(ValueError):
        pol.pick([])


def test_failover_budget_backoff_deterministic_capped():
    # base 0 (the default) never sleeps: unit tests stay instant
    assert FailoverBudget().backoff_ms(0) == 0.0
    assert FailoverBudget().backoff_ms(5, salt=7) == 0.0

    b = FailoverBudget(max_failovers=3, base_ms=10.0, cap_ms=50.0)
    for attempt in range(6):
        for salt in (0, 1, 17):
            raw = min(10.0 * 2.0 ** attempt, 50.0)
            d = b.backoff_ms(attempt, salt=salt)
            # deterministic: same (attempt, salt) -> same delay
            assert d == b.backoff_ms(attempt, salt=salt)
            # jitter keeps the delay in [raw/2, raw], under the cap
            assert raw / 2 <= d <= raw <= 50.0
    # different salts actually spread (thundering-herd jitter is real)
    ds = {b.backoff_ms(2, salt=s) for s in range(8)}
    assert len(ds) > 1


# --------------------------------------------------------------------------- #
# shared fixtures / helpers (one reduced llama for every cluster test)
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def llama():
    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _factory(model, params):
    def make(_rid: int) -> Engine:
        return Engine(model, params, **ENG_KW)

    return make


def _warm(eng, cfg, seed=123):
    """Compile the engine's programs, then reseed its watchdog with
    post-compile step times so the cluster's adaptive heartbeat deadline
    reflects steady-state speed, not XLA's first-trace latency.

    Warmup must cover every shape a FAILOVER can later trigger: resumed
    prompts (``prompt + emitted``) land on every partial-chunk residue
    mod ``page_size``, and a fresh compile mid-run is a multi-second
    stall the tightened heartbeat deadline would misread as a death."""
    rng = np.random.default_rng(seed)

    def mk(length):
        return Request(
            prompt=rng.integers(0, cfg.vocab, size=(length,)).astype(np.int32),
            max_new_tokens=8, extras=modality_extras(cfg, rng),
        )

    for length in (5, 6, 7, 8):  # chunk residues 1, 2, 3 and full-chunk
        eng.run([mk(length)])
    # prompts <= prefill_chunk ride the MONOLITHIC grouped-prefill program
    # (bucketed (G, P) shapes) — cover both group sizes of it too
    eng.run([mk(4)])
    eng.run([mk(4) for _ in range(eng.n_slots)])
    eng.run([mk(6) for _ in range(eng.n_slots)])  # full decode group
    eng.watchdog = StepWatchdog()  # drop compile-time spikes
    eng.run([mk(6)])
    eng.reset_prefix_cache()
    eng.reset_counters()


def _trace(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [
        dict(
            prompt=rng.integers(
                0, cfg.vocab, size=(int(rng.integers(4, 7)),)
            ).astype(np.int32),
            max_new=int(rng.integers(8, 12)),
        )
        for _ in range(n)
    ]


def _build(trace, cfg, seed=0):
    return [
        Request(
            prompt=t["prompt"].copy(), max_new_tokens=t["max_new"],
            extras=modality_extras(cfg, np.random.default_rng(seed + i)),
        )
        for i, t in enumerate(trace)
    ]


def _reference(trace, cfg, model, params, seed=0):
    eng = Engine(model, params, **ENG_KW)
    _warm(eng, cfg)
    reqs = _build(trace, cfg, seed)
    eng.run(reqs)
    assert all(r.status == "ok" for r in reqs)
    return eng, [list(r.tokens) for r in reqs]


def _check_streams(clu, reqs, refs, trace, cfg, replay_eng, seed=0):
    """The per-compute-path token contract (see module docstring)."""
    n_failed_over = 0
    resume_points = clu.stats()["resume_points"]  # locked snapshot
    for i, r in enumerate(reqs):
        assert r.status == "ok", f"req {i}: {r.status} ({r.rejected})"
        got = list(r.tokens)
        assert len(got) == trace[i]["max_new"]
        splits = resume_points.get(r.uid)
        if not splits:
            assert got == refs[i], f"unfailed req {i} diverged from replay"
            continue
        n_failed_over += 1
        assert got[: splits[0]] == refs[i][: splits[0]], (
            f"req {i}: credited prefix not bit-identical"
        )
        bounds = list(splits) + [len(got)]
        for j, k in enumerate(splits):
            end = bounds[j + 1]
            if end <= k:
                continue  # replica died before the resume emitted anything
            cont = Request(
                prompt=np.concatenate(
                    [trace[i]["prompt"], np.asarray(got[:k], np.int32)]
                ),
                max_new_tokens=trace[i]["max_new"] - k,
                extras=modality_extras(cfg, np.random.default_rng(seed + i)),
            )
            replay_eng.reset_prefix_cache()
            replay_eng.run([cont])
            assert got[k:end] == list(cont.tokens)[: end - k], (
                f"req {i}: resumed tail diverged from the continuation replay"
            )
    return n_failed_over


def _drive_to_healthy(clu, rid, timeout_s=10.0):
    """Poll the monitor until replica ``rid`` rejoins the router."""
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        clu.check_health()
        if clu.replicas[rid].state == "healthy":
            return
        time.sleep(0.01)
    pytest.fail(
        f"replica {rid} never rejoined (state={clu.replicas[rid].state})"
    )


# --------------------------------------------------------------------------- #
# kill fault: failover + restart
# --------------------------------------------------------------------------- #
def test_cluster_kill_failover_and_restart(llama):
    cfg, model, params = llama
    trace = _trace(cfg, 8, seed=1)
    replay_eng, refs = _reference(trace, cfg, model, params, seed=0)

    inj = FaultInjector(kill_replica=(0, 5))
    clu = Cluster(
        _factory(model, params), 2, heartbeat_ms=500.0,
        budget=FailoverBudget(max_failovers=3),
        injector=inj, probation_s=0.05, straggler_min_s=10.0,
    )
    try:
        for rep in clu.replicas:
            _warm(rep.eng, cfg)
        reqs = _build(trace, cfg, seed=0)
        clu.run(reqs, timeout_s=120.0)

        assert inj.fired.get("kill_replica") == 1
        rep0 = clu.replicas[0]
        with rep0.health_lock:
            step_error = rep0.step_error
        assert isinstance(step_error, ReplicaKilled)
        assert rep0.state == "dead"
        assert not rep0.thread_alive  # the thread genuinely died
        stats = clu.stats()
        assert stats["replica_deaths"] >= 1
        assert stats["failovers"] >= 1
        assert stats["exhausted"] == 0
        n_failed = _check_streams(clu, reqs, refs, trace, cfg, replay_eng)
        assert n_failed >= 1  # the kill landed on live work

        # a killed replica needs a rebuilt engine; it rejoins via probation
        inj.kill_replica = None  # disarm before the fresh engine steps
        with pytest.raises(RuntimeError):
            clu.restart_replica(1)  # live replicas must not be rebuilt
        clu.restart_replica(0)
        assert clu.replicas[0].thread_alive
        _drive_to_healthy(clu, 0)
        assert clu.stats()["rejoins"] >= 1

        # the restarted fleet serves again
        more = _build(_trace(cfg, 2, seed=9), cfg, seed=50)
        clu.run(more, timeout_s=120.0)
        assert all(r.status == "ok" for r in more)
    finally:
        clu.close()


# --------------------------------------------------------------------------- #
# hang fault: heartbeat-miss detection
# --------------------------------------------------------------------------- #
def test_cluster_hang_heartbeat_miss_failover(llama):
    cfg, model, params = llama
    trace = _trace(cfg, 6, seed=2)
    replay_eng, refs = _reference(trace, cfg, model, params, seed=0)

    inj = FaultInjector(hang_replica=(0, 4), hang_s=2.0)
    clu = Cluster(
        _factory(model, params), 2, heartbeat_ms=500.0,
        budget=FailoverBudget(max_failovers=3),
        injector=inj, probation_s=0.05, straggler_min_s=10.0,
    )
    try:
        for rep in clu.replicas:
            _warm(rep.eng, cfg)
        reqs = _build(trace, cfg, seed=0)
        clu.run(reqs, timeout_s=120.0)

        assert inj.fired.get("hang_replica") == 1
        # no exception was raised: ONLY the silent heartbeat caught this
        stats = clu.stats()
        assert stats["heartbeat_misses"] >= 1
        assert stats["replica_deaths"] >= 1
        assert stats["failovers"] >= 1
        assert stats["exhausted"] == 0
        n_failed = _check_streams(clu, reqs, refs, trace, cfg, replay_eng)
        assert n_failed >= 1
        # the hung thread survived; once the hang ends it drains and can
        # walk probation back to healthy
        assert clu.replicas[0].thread_alive
        _drive_to_healthy(clu, 0)
        assert clu.stats()["rejoins"] >= 1
    finally:
        clu.close()


# --------------------------------------------------------------------------- #
# slow fault: watchdog straggler detection
# --------------------------------------------------------------------------- #
def test_cluster_slow_replica_straggler_death(llama):
    cfg, model, params = llama
    trace = _trace(cfg, 6, seed=3)
    replay_eng, refs = _reference(trace, cfg, model, params, seed=0)

    # the slowdown happens INSIDE engine steps (the engine-level fault),
    # so the watchdog times it; heartbeat_ms is huge so the ONLY death
    # signal is the straggler flag above the absolute floor.  The window
    # is armed AFTER warmup, relative to the step index warmup reached.
    eng_inj = FaultInjector(slow_ms=400.0)

    def make(rid: int) -> Engine:
        eng = Engine(model, params, **ENG_KW)
        if rid == 0:
            eng.injector = eng_inj
        return eng

    clu = Cluster(
        make, 2, heartbeat_ms=5000.0,
        budget=FailoverBudget(max_failovers=3),
        probation_s=0.05, straggler_min_s=0.05,
    )
    try:
        for rep in clu.replicas:
            _warm(rep.eng, cfg)
        base = clu.replicas[0].eng._step_idx
        eng_inj.slow_steps = (base + 3, base + 7)
        reqs = _build(trace, cfg, seed=0)
        clu.run(reqs, timeout_s=120.0)

        assert eng_inj.fired.get("slow_step", 0) >= 1
        assert clu.replicas[0].eng.straggler_flags >= 1
        stats = clu.stats()
        assert stats["heartbeat_misses"] == 0  # straggler path, not heartbeat
        assert stats["replica_deaths"] >= 1
        assert stats["exhausted"] == 0
        _check_streams(clu, reqs, refs, trace, cfg, replay_eng)
    finally:
        clu.close()


# --------------------------------------------------------------------------- #
# retry-budget exhaustion -> structured replica_lost rejection
# --------------------------------------------------------------------------- #
def test_cluster_budget_exhaustion_structured_rejection(llama):
    cfg, model, params = llama
    trace = _trace(cfg, 2, seed=4)
    inj = FaultInjector(kill_replica=(0, 3))
    clu = Cluster(
        _factory(model, params), 1,
        budget=FailoverBudget(max_failovers=0),
        injector=inj, straggler_min_s=10.0,
    )
    try:
        reqs = _build(trace, cfg, seed=0)
        clu.run(reqs, timeout_s=120.0)
        assert inj.fired.get("kill_replica") == 1
        stats = clu.stats()
        assert stats["exhausted"] >= 1
        assert stats["failovers"] == 0  # zero budget: no re-enqueue happened
        for r in reqs:
            # nothing vanishes: every root lands terminal with a reason
            assert r.status == "shed"
            assert r.rejected is not None
            assert r.rejected.reason == "replica_lost"
            assert r.rejected.uid == r.uid
    finally:
        clu.close()


# --------------------------------------------------------------------------- #
# probation state machine (monitor driven manually)
# --------------------------------------------------------------------------- #
def test_cluster_probation_rejoin_state_machine(llama):
    cfg, model, params = llama
    clu = Cluster(
        _factory(model, params), 1, heartbeat_ms=50.0,
        cold_grace_s=0.05, probation_s=0.1, straggler_min_s=10.0,
    )
    try:
        clu.start()
        rep = clu.replicas[0]
        deadline = time.monotonic() + 5.0
        while rep.state == "healthy" and time.monotonic() < deadline:
            # simulate a wedged device: the beat stops
            with rep.health_lock:
                rep.last_beat = time.monotonic() - 1.0
            clu.check_health()
        assert rep.state == "dead"
        assert clu.stats()["heartbeat_misses"] >= 1
        with rep.health_lock:
            assert rep.state_cmd == "drain"

        # the thread drains (nothing held) and beats while parked ->
        # probation; a clean probation window -> healthy again
        deadline = time.monotonic() + 5.0
        while rep.state == "dead" and time.monotonic() < deadline:
            clu.check_health()
            time.sleep(0.01)
        assert rep.state == "probation"
        with rep.health_lock:
            assert rep.drained
        t_probation = time.monotonic()
        _drive_to_healthy(clu, 0)
        assert time.monotonic() - t_probation >= clu.probation_s * 0.5
        assert clu.stats()["rejoins"] == 1
        with rep.health_lock:
            assert rep.state_cmd == "run"
    finally:
        clu.close()


# --------------------------------------------------------------------------- #
# cross-replica resume: every chunk-capable arch
# --------------------------------------------------------------------------- #
CHUNK_ARCHS = [
    a for a in ARCH_IDS
    if get_arch(a, reduced=True).family in ("dense", "moe")
    and get_arch(a, reduced=True).sliding_window is None
]


@pytest.mark.parametrize("arch_id", CHUNK_ARCHS)
def test_cross_replica_resume_bit_exact(arch_id):
    """export_inflight() on replica A -> submit on replica B: the credited
    prefix is bit-identical to the undisturbed stream, and the resumed
    tail is bit-identical to any fresh engine serving that continuation —
    for every arch the chunked-prefill (rematerialization) path supports."""
    cfg = get_arch(arch_id, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, size=(6,)).astype(np.int32)
    extras = modality_extras(cfg, rng)
    steps = 8
    kw = dict(n_slots=2, max_len=MAX_LEN, page_size=4, prefill_chunk=4,
              decode_block=2)

    ref_eng = Engine(model, params, **kw)
    ref = ref_eng.run(
        [Request(prompt=prompt.copy(), max_new_tokens=steps, extras=extras)]
    )[0]
    refs = list(ref.tokens)
    assert len(refs) == steps

    # replica A serves, then "dies": export carries the work out
    eng_a = Engine(model, params, **kw)
    r = eng_a.submit(
        Request(prompt=prompt.copy(), max_new_tokens=steps, extras=extras)
    )
    guard = 0
    while len(r.tokens) < 3 and guard < 64:
        eng_a.step()
        guard += 1
    assert 0 < len(r.tokens) < steps, "export must happen mid-decode"
    conts = eng_a.export_inflight()
    assert len(conts) == 1 and eng_a.exported == 1
    assert not eng_a.has_work
    if eng_a.paged:
        assert eng_a.pages_in_use == 0  # no orphaned pages after export

    emitted = list(r.tokens)
    assert emitted == refs[: len(emitted)], "credited prefix diverged"

    # replica B resumes the continuation; the engine folds the tail back
    # into the root request's stream
    eng_b = Engine(model, params, **kw)
    eng_b.submit(conts[0])
    while eng_b.has_work:
        eng_b.step()
    assert r.status == "ok"
    assert len(r.tokens) == steps
    tail = list(r.tokens)[len(emitted):]

    # any fresh engine serving the same continuation emits the same tail
    replay = Request(
        prompt=np.concatenate([prompt, np.asarray(emitted, np.int32)]),
        max_new_tokens=steps - len(emitted), extras=extras,
    )
    eng_c = Engine(model, params, **kw)
    eng_c.run([replay])
    assert tail == list(replay.tokens), (
        f"{arch_id}: resumed tail diverged from the continuation replay"
    )
