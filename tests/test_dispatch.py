"""Unit tests for the unified kernel-dispatch runtime.

Covers the auto selection table (shape/backend -> chosen path), the
use_dispatch context manager + per-site hit counters, ValueError input
validation on the Pallas kernels, and allclose agreement between the stacked
fused path and the XLA fallback.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lowrank import apply_linear, lowrank_params
from repro.kernels.lowrank_matmul import (
    DEFAULT_VMEM_LIMIT,
    fits_fused,
    fused_vmem_bytes,
    lowrank_matmul_batched_pallas,
    lowrank_matmul_pallas,
)
from repro.runtime import dispatch
from repro.runtime.dispatch import (
    PATH_DENSE,
    PATH_FUSED,
    PATH_FUSED_BATCHED,
    PATH_TWO_GEMM,
    DispatchConfig,
    choose_lowrank_path,
    use_dispatch,
)


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# --------------------------------------------------------------------------- #
# selection table
# --------------------------------------------------------------------------- #
class TestSelectionTable:
    def test_auto_cpu_small_rank_is_two_gemm(self):
        cfg = DispatchConfig()
        got = choose_lowrank_path((64, 96), (96, 8), (8, 40), jnp.float32,
                                  config=cfg, platform="cpu")
        assert got == PATH_TWO_GEMM

    def test_auto_tpu_fitting_shape_is_fused(self):
        cfg = DispatchConfig()
        got = choose_lowrank_path((64, 96), (96, 8), (8, 40), jnp.float32,
                                  config=cfg, platform="tpu")
        assert got == PATH_FUSED

    def test_auto_tpu_stacked_is_fused_batched(self):
        cfg = DispatchConfig()
        got = choose_lowrank_path((4, 64, 96), (4, 96, 8), (4, 8, 40),
                                  jnp.float32, config=cfg, platform="tpu")
        assert got == PATH_FUSED_BATCHED

    def test_over_breakeven_rank_with_big_batch_rematerializes_dense(self):
        # r=90 >= break_even(96, 40) and M >= dense_min_tokens -> dense remat
        cfg = DispatchConfig()
        got = choose_lowrank_path((4096, 96), (96, 90), (90, 40), jnp.float32,
                                  config=cfg, platform="cpu")
        assert got == PATH_DENSE
        # small token batch does not amortize the remat
        got = choose_lowrank_path((64, 96), (96, 90), (90, 40), jnp.float32,
                                  config=cfg, platform="cpu")
        assert got == PATH_TWO_GEMM

    def test_forced_pallas_respects_vmem_budget(self):
        cfg = DispatchConfig(backend="pallas")
        # r x N residency alone exceeds the budget at bf16 -> two-GEMM even
        # when Pallas is pinned
        assert not fits_fused(4096, 16384, jnp.bfloat16)
        got = choose_lowrank_path((64, 8192), (8192, 4096), (4096, 16384),
                                  jnp.bfloat16, config=cfg, platform="tpu")
        assert got == PATH_TWO_GEMM

    def test_vmem_budget_is_dtype_aware(self):
        r, n = 512, 8192
        assert fused_vmem_bytes(r, n, jnp.float32) > fused_vmem_bytes(r, n, jnp.bfloat16)
        # a shape can fit at bf16 but not at fp32
        ok16 = fits_fused(256, 4096, jnp.bfloat16)
        ok32 = fits_fused(256, 4096, jnp.float32, limit=fused_vmem_bytes(256, 4096, jnp.bfloat16))
        assert ok16 and not ok32

    def test_reference_backend_pins_two_gemm(self):
        cfg = DispatchConfig(backend="reference")
        got = choose_lowrank_path((8192, 96), (96, 90), (90, 40), jnp.float32,
                                  config=cfg, platform="tpu")
        assert got == PATH_TWO_GEMM

    def test_per_op_override(self):
        cfg = DispatchConfig(backend="pallas", overrides=(("lowrank_matmul", "xla"),))
        got = choose_lowrank_path((64, 96), (96, 8), (8, 40), jnp.float32,
                                  config=cfg, platform="tpu")
        assert got == PATH_TWO_GEMM

    def test_invalid_backend_rejected(self):
        with pytest.raises(ValueError):
            DispatchConfig(backend="cuda")
        with pytest.raises(ValueError):
            DispatchConfig(overrides=(("not_an_op", "xla"),))

    def test_from_arch_reads_kernels_field(self):
        from repro.configs.registry import get_arch

        cfg = get_arch("llama3.2-1b", reduced=True)
        assert DispatchConfig.from_arch(cfg).backend == cfg.kernels == "auto"

    def test_use_pallas_alias_folds_into_kernels(self):
        import dataclasses

        from repro.configs.registry import get_arch

        cfg = dataclasses.replace(get_arch("llama3.2-1b", reduced=True), use_pallas=True)
        assert cfg.kernels == "pallas"
        assert DispatchConfig.from_arch(cfg).backend == "pallas"


# --------------------------------------------------------------------------- #
# context manager + counters
# --------------------------------------------------------------------------- #
class TestContextAndCounters:
    def test_use_dispatch_nests_and_restores(self):
        base = dispatch.active_dispatch()
        with use_dispatch(backend="xla") as outer:
            assert dispatch.active_dispatch() is outer
            with use_dispatch(backend="pallas") as inner:
                assert dispatch.active_dispatch() is inner
            assert dispatch.active_dispatch() is outer
        assert dispatch.active_dispatch() == base

    def test_counters_record_selected_path(self):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        x, A, B = _rand(ks[0], (16, 32)), _rand(ks[1], (32, 4)), _rand(ks[2], (4, 24))
        dispatch.reset_counters()
        with use_dispatch(backend="xla"):
            apply_linear(lowrank_params(A, B), x)
        agg = dispatch.counters_by_path()
        assert agg == {("lowrank_matmul", PATH_TWO_GEMM): 1}

        dispatch.reset_counters()
        with use_dispatch(backend="pallas"):
            apply_linear(lowrank_params(A, B), x)
            apply_linear(lowrank_params(A, B), x)  # same site sig -> same key
        assert dispatch.counters() == {
            ("lowrank_matmul", PATH_FUSED, (1, 16, 32, 4, 24)): 2
        }

    def test_dense_linears_are_counted_too(self):
        dispatch.reset_counters()
        w = _rand(jax.random.PRNGKey(1), (32, 8))
        x = _rand(jax.random.PRNGKey(2), (4, 32))
        apply_linear(w, x)
        assert dispatch.counters_by_path() == {("dense", "xla"): 1}


# --------------------------------------------------------------------------- #
# kernel input validation (satellite: bare asserts -> ValueError)
# --------------------------------------------------------------------------- #
class TestKernelValidation:
    def test_shape_mismatch_raises_value_error(self):
        x = jnp.zeros((8, 16))
        A = jnp.zeros((17, 4))  # K mismatch
        B = jnp.zeros((4, 8))
        with pytest.raises(ValueError, match="contraction dim"):
            lowrank_matmul_pallas(x, A, B, interpret=True)
        with pytest.raises(ValueError, match="A rank"):
            lowrank_matmul_pallas(jnp.zeros((8, 16)), jnp.zeros((16, 4)),
                                  jnp.zeros((5, 8)), interpret=True)

    def test_residency_violation_raises_value_error(self):
        x = jnp.zeros((8, 16), jnp.bfloat16)
        A = jnp.zeros((16, 4096), jnp.bfloat16)
        B = jnp.zeros((4096, 16384), jnp.bfloat16)
        with pytest.raises(ValueError, match="VMEM"):
            lowrank_matmul_pallas(x, A, B, interpret=True)

    def test_batched_stack_mismatch_raises(self):
        with pytest.raises(ValueError, match="stack dims"):
            lowrank_matmul_batched_pallas(
                jnp.zeros((2, 8, 16)), jnp.zeros((3, 16, 4)), jnp.zeros((3, 4, 8)),
                interpret=True,
            )

    def test_validation_survives_python_O(self):
        # the old bare asserts vanished under `python -O`; ValueError must not
        import subprocess
        import sys

        code = (
            "import jax.numpy as jnp\n"
            "from repro.kernels.lowrank_matmul import lowrank_matmul_pallas\n"
            "try:\n"
            "    lowrank_matmul_pallas(jnp.zeros((8, 16)), jnp.zeros((17, 4)),"
            " jnp.zeros((4, 8)), interpret=True)\n"
            "except ValueError:\n"
            "    print('RAISED')\n"
        )
        out = subprocess.run(
            [sys.executable, "-O", "-c", code],
            capture_output=True, text=True, env=_env_with_src(),
        )
        assert "RAISED" in out.stdout, (out.stdout, out.stderr)


def _env_with_src():
    import os

    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get("PYTHONPATH", "")
    return env


# --------------------------------------------------------------------------- #
# stacked fused path == fallback path
# --------------------------------------------------------------------------- #
class TestStackedFusedAllclose:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_stacked_factored_params_fused_vs_fallback(self, dtype):
        L, M, K, r, N = 5, 33, 96, 8, 72
        ks = jax.random.split(jax.random.PRNGKey(7), 3)
        x = _rand(ks[0], (L, M, K), dtype)
        A = _rand(ks[1], (L, K, r), dtype)
        B = _rand(ks[2], (L, r, N), dtype)
        p = lowrank_params(A, B)
        with use_dispatch(backend="xla"):
            want = apply_linear(p, x)
        with use_dispatch(backend="pallas"):
            got = apply_linear(p, x)
        tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=tol, atol=tol,
        )

    def test_double_stacked_expert_factors(self):
        # (L, E, ...) leading dims all flatten into one batched launch
        L, E, C, K, r, N = 2, 3, 16, 48, 4, 32
        ks = jax.random.split(jax.random.PRNGKey(8), 3)
        x = _rand(ks[0], (L, E, C, K))
        A = _rand(ks[1], (L, E, K, r))
        B = _rand(ks[2], (L, E, r, N))
        p = lowrank_params(A, B)
        dispatch.reset_counters()
        with use_dispatch(backend="pallas"):
            got = apply_linear(p, x)
        assert dispatch.counters_by_path() == {
            ("lowrank_matmul", PATH_FUSED_BATCHED): 1
        }
        with use_dispatch(backend="xla"):
            want = apply_linear(p, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize(
        "x_shape,out_shape",
        [
            ((2, 3, 5, 16), (2, 3, 5, 8)),  # extra inner dims
            ((2, 16), (2, 8)),              # no inner M dim at all
            ((2, 2, 5, 16), (2, 2, 5, 8)),  # inner dim coincides with stack
        ],
    )
    def test_fallback_paths_canonicalize_stacked_layouts(self, x_shape, out_shape):
        # regression: bare jnp.matmul broadcasting crashed on extra inner
        # dims and silently misaligned an inner batch dim against the stack
        ks = jax.random.split(jax.random.PRNGKey(11), 3)
        x = _rand(ks[0], x_shape)
        p = lowrank_params(_rand(ks[1], (2, 16, 4)), _rand(ks[2], (2, 4, 8)))
        with use_dispatch(backend="xla"):
            want = apply_linear(p, x)
        with use_dispatch(backend="pallas"):
            got = apply_linear(p, x)
        assert want.shape == got.shape == out_shape
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_ops_wrapper_accepts_stacked_factors(self):
        from repro.kernels import ops, ref

        ks = jax.random.split(jax.random.PRNGKey(9), 3)
        x = _rand(ks[0], (3, 10, 64))
        A = _rand(ks[1], (3, 64, 8))
        B = _rand(ks[2], (3, 8, 40))
        got = ops.lowrank_matmul(x, A, B)
        want = jnp.stack([ref.lowrank_matmul_ref(x[i], A[i], B[i]) for i in range(3)])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------- #
# fused rank floor: sliced tiers can carry rank-1 factors
# --------------------------------------------------------------------------- #
class TestFusedMinRank:
    def test_rank_below_floor_never_takes_fused_path(self):
        # a tier sliced to rank 1 (core.lowrank.slice_rank with a tiny
        # fraction) must fall back: the fused kernel's rank tile would be
        # ~all padding
        cfg = DispatchConfig(fused_min_rank=4)
        below = choose_lowrank_path((64, 96), (96, 2), (2, 40), jnp.float32,
                                    config=cfg, platform="tpu")
        assert below == PATH_TWO_GEMM
        at = choose_lowrank_path((64, 96), (96, 4), (4, 40), jnp.float32,
                                 config=cfg, platform="tpu")
        assert at == PATH_FUSED
        # the floor binds even when Pallas is pinned explicitly
        pinned = DispatchConfig(backend="pallas", fused_min_rank=4)
        forced = choose_lowrank_path((64, 96), (96, 2), (2, 40), jnp.float32,
                                     config=pinned, platform="tpu")
        assert forced == PATH_TWO_GEMM

    def test_default_floor_only_excludes_degenerate_ranks(self):
        cfg = DispatchConfig()
        assert cfg.fused_min_rank == 2
        got = choose_lowrank_path((64, 96), (96, 1), (1, 40), jnp.float32,
                                  config=cfg, platform="tpu")
        assert got == PATH_TWO_GEMM
