"""Substrate tests: optimizer, train step, checkpoint, fault tolerance, data."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_arch
from repro.models.model import build_model
from repro.train import optimizer as opt_mod
from repro.train.train_step import TrainState, init_train_state, make_train_step
from repro.checkpoint import checkpointer as ckpt
from repro.runtime.fault_tolerance import (
    ElasticReshard,
    RetryableStep,
    StepWatchdog,
    TrainLoopRunner,
)
from repro.data.synthetic import SyntheticLM, markov_tokens


def quadratic_loss(params, _batch):
    return jnp.sum(jnp.square(params["w"] - 3.0)) + jnp.square(params["b"] + 1.0)[0]


@pytest.mark.parametrize("opt_name", ["adamw", "adafactor", "sgdm"])
def test_optimizer_converges_quadratic(opt_name):
    opt = {
        "adamw": opt_mod.adamw(opt_mod.constant_schedule(0.1), weight_decay=0.0),
        # adafactor's RMS-normalized updates need a decaying lr to settle
        "adafactor": opt_mod.adafactor(opt_mod.linear_schedule(0.5, 1, 300)),
        "sgdm": opt_mod.sgdm(opt_mod.constant_schedule(0.05)),
    }[opt_name]
    params = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((1,))}
    state = opt.init(params)
    step = jnp.zeros((), jnp.int32)
    for i in range(300):
        grads = jax.grad(quadratic_loss)(params, None)
        updates, state = opt.update(grads, state, params, step + i)
        params = opt_mod.apply_updates(params, updates)
    assert float(quadratic_loss(params, None)) < 1e-2


def test_train_step_reduces_loss():
    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    opt = opt_mod.adamw(opt_mod.cosine_schedule(3e-3, 10, 200), weight_decay=0.01)
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(model, opt))
    data = SyntheticLM(cfg, batch=8, seq=32, seed=0)
    losses = []
    for i in range(30):
        batch = jax.tree_util.tree_map(jnp.asarray, data.at_step(i))
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses


def test_grad_accumulation_matches_full_batch():
    cfg = get_arch("mamba2-130m", reduced=True)
    model = build_model(cfg)
    opt = opt_mod.sgdm(opt_mod.constant_schedule(0.1), momentum=0.0)
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    data = SyntheticLM(cfg, batch=8, seq=16, seed=1)
    batch = jax.tree_util.tree_map(jnp.asarray, data.at_step(0))
    s1, m1 = jax.jit(make_train_step(model, opt, accum_steps=1))(state, batch)
    s2, m2 = jax.jit(make_train_step(model, opt, accum_steps=4))(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    l1 = jax.tree_util.tree_leaves(s1.params)
    l2 = jax.tree_util.tree_leaves(s2.params)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=2e-2, rtol=2e-2
        )


def test_checkpoint_roundtrip_and_atomicity():
    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    opt = opt_mod.adamw(opt_mod.constant_schedule(1e-3))
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(state, d, 7, extra={"arch": cfg.name})
        # stale tmp dir from a "crashed" save must be ignored + cleaned
        os.makedirs(os.path.join(d, "step_9.tmp"), exist_ok=True)
        assert ckpt.latest_step(d) == 7
        restored, manifest = ckpt.restore(state, d)
        assert manifest["extra"]["arch"] == cfg.name
        for a, b in zip(jax.tree_util.tree_leaves(state), jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_detects_corruption():
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(state, d, 1)
        leaf = os.path.join(d, "step_1", "leaf_00000.npy.zst")
        with open(leaf, "wb") as f:
            f.write(ckpt._Codec.compress(b"\x00" * 64, ckpt._Codec.default()))
        with pytest.raises(IOError):
            ckpt.restore(state, d)


def test_async_checkpointer_retention():
    state = {"w": jnp.ones((8,))}
    with tempfile.TemporaryDirectory() as d:
        c = ckpt.Checkpointer(d, keep=2)
        for s in (1, 2, 3, 4):
            c.save_async(state, s)
        c.wait()
        steps = sorted(
            int(x.split("_")[1]) for x in os.listdir(d) if x.startswith("step_")
        )
        assert steps == [3, 4]


def test_restart_resumes_identically():
    """Crash at step 5, restore from checkpoint at 4, resume -> same state as
    an uninterrupted run (determinism of data + step)."""
    cfg = get_arch("mamba2-130m", reduced=True)
    model = build_model(cfg)
    opt = opt_mod.adamw(opt_mod.constant_schedule(1e-3))
    data = SyntheticLM(cfg, batch=4, seq=16, seed=3)
    step_fn = jax.jit(make_train_step(model, opt))

    def fresh():
        return init_train_state(model, opt, jax.random.PRNGKey(0))

    with tempfile.TemporaryDirectory() as d:
        c = ckpt.Checkpointer(d, keep=3)
        runner = TrainLoopRunner(step_fn, data.at_step, c, save_every=2)
        # uninterrupted reference
        ref_state, _ = TrainLoopRunner(step_fn, data.at_step, None, save_every=10**9).run(
            fresh(), 8, shard_fn=lambda b: jax.tree_util.tree_map(jnp.asarray, b)
        )
        # interrupted run
        with pytest.raises(RuntimeError):
            runner.run(
                fresh(),
                8,
                shard_fn=lambda b: jax.tree_util.tree_map(jnp.asarray, b),
                fail_at=lambda s: s == 5,
            )
        c.wait()
        last = ckpt.latest_step(d)
        assert last == 4
        restored, _ = ckpt.restore(fresh(), d)
        resumed, _ = TrainLoopRunner(step_fn, data.at_step, None, save_every=10**9).run(
            restored,
            8,
            shard_fn=lambda b: jax.tree_util.tree_map(jnp.asarray, b),
            start_step=last,
        )
        for a, b in zip(
            jax.tree_util.tree_leaves(ref_state.params),
            jax.tree_util.tree_leaves(resumed.params),
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_watchdog_flags_stragglers():
    w = StepWatchdog(straggler_factor=2.0)
    for i in range(10):
        w.observe(i, 1.0)
    assert w.observe(10, 5.0) is True
    assert 10 in w.straggler_steps
    assert w.observe(11, 1.1) is False


def test_retryable_step():
    calls = {"n": 0}

    def flaky(x):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("link flap")
        return x + 1

    r = RetryableStep(flaky, max_retries=3)
    assert r(1) == 2
    assert r.total_retries == 2


def test_data_determinism():
    a = markov_tokens(0, 5, 4, 16, 1000)
    b = markov_tokens(0, 5, 4, 16, 1000)
    c = markov_tokens(0, 6, 4, 16, 1000)
    np.testing.assert_array_equal(a, b)
    assert (a != c).any()
    assert a.min() >= 0 and a.max() < 1000
