"""End-to-end serving driver: compress a pretrained-style model with RSI and
serve batched requests through prefill + greedy decode.

    PYTHONPATH=src python examples/compress_and_serve.py [--alpha 0.3] [--q 4]

What it shows:
  * dense vs compressed parameter counts and per-token agreement;
  * q=1 (RSVD) vs q=4 (RSI) divergence from the dense model's generations —
    the serving-level analogue of Table 4.1;
  * batched-request throughput through the same ModelApi the production
    launcher uses.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.core import CompressionPolicy, compress_tree, spectralize_params
from repro.data.synthetic import SyntheticLM
from repro.models.model import build_model
from repro.train.serve_step import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--alpha", type=float, default=0.3)
    ap.add_argument("--q", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # simulate pretrained weights (slow-decay spectra) — the paper's regime
    params = spectralize_params(params, jax.random.PRNGKey(9))
    n_dense = sum(x.size for x in jax.tree_util.tree_leaves(params))

    data = SyntheticLM(cfg, batch=args.batch, seq=args.prompt_len, kind="serve")
    batch = {k: jnp.asarray(v) for k, v in data.at_step(0).items()}
    max_len = args.prompt_len + args.gen

    t0 = time.time()
    ref = np.asarray(greedy_generate(model, params, batch, steps=args.gen, max_len=max_len))
    t_dense = time.time() - t0

    print(f"dense: {n_dense/1e6:.2f}M params, {args.batch*args.gen/t_dense:.1f} tok/s")
    for q in (1, args.q):
        policy = CompressionPolicy(alpha=args.alpha, q=q, min_dim=32)
        cp, _, rep = compress_tree(params, policy, jax.random.PRNGKey(1))
        t0 = time.time()
        out = np.asarray(greedy_generate(model, cp, batch, steps=args.gen, max_len=max_len))
        dt = time.time() - t0
        agree = float((out == ref).mean())
        print(
            f"alpha={args.alpha} q={q}: ratio={rep.ratio:.3f}, "
            f"{args.batch*args.gen/dt:.1f} tok/s, token agreement vs dense = {agree:.1%}"
        )


if __name__ == "__main__":
    main()
