"""Quickstart: RSI in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

1. Build a weight matrix with the slow-decay spectrum of a pretrained layer.
2. Compress with RSVD (q=1) vs RSI (q=4) — watch the normalized error drop.
3. Compress a whole (reduced llama) model's pytree with one call.
4. Certify the compressed classifier head with the paper's Theorem 3.2.
"""

import jax
import jax.numpy as jnp

from repro.core import (
    CompressionPolicy,
    certify_head,
    compress_tree,
    normalized_error,
    rsi,
    rsi_factors,
    synth_spectrum_matrix,
    vgg_like_spectrum,
)
from repro.configs.registry import get_arch
from repro.models.model import build_model

# --- 1. a "pretrained-like" matrix -----------------------------------------
C, D, k = 512, 2048, 64
spectrum = vgg_like_spectrum(C)
W = synth_spectrum_matrix(jax.random.PRNGKey(0), C, D, spectrum)
print(f"W: {C}x{D}, slow-decay spectrum (s1={float(spectrum[0]):.1f}, "
      f"s_{k+1}={float(spectrum[k]):.3f})")

# --- 2. RSVD vs RSI ---------------------------------------------------------
for q in (1, 2, 4):
    res = rsi(W, k, q, jax.random.PRNGKey(1))
    err = normalized_error(W, res.U, res.S, res.Vt, float(spectrum[k]), jax.random.PRNGKey(2))
    label = "RSVD" if q == 1 else f"RSI q={q}"
    print(f"  {label:9s} normalized spectral error = {float(err):.3f}  (optimal = 1.0)")

A, B = rsi_factors(W, k, 4, jax.random.PRNGKey(1))
print(f"  factored: {W.size:,} params -> {A.size + B.size:,} "
      f"({(A.size + B.size) / W.size:.1%})")

# --- 3. whole-model compression ---------------------------------------------
cfg = get_arch("llama3.2-1b", reduced=True)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(3))
policy = CompressionPolicy(alpha=0.3, q=4, min_dim=32)
new_params, _, report = compress_tree(params, policy, jax.random.PRNGKey(4))
print(f"model: {report.summary()}")

# --- 4. Theorem 3.2 certificate ---------------------------------------------
head = synth_spectrum_matrix(jax.random.PRNGKey(5), 10, 256, vgg_like_spectrum(10) * 0.05)
A2, B2 = rsi_factors(head, 6, 4, jax.random.PRNGKey(6))
calib = jax.random.normal(jax.random.PRNGKey(7), (256, 256))
calib = calib / jnp.linalg.norm(calib, axis=-1, keepdims=True) * 3.0
cert = certify_head(head, A2 @ B2, calib, jax.random.PRNGKey(8), rank=6, q=4)
print(
    f"certificate: ||W-W~||_2={cert.spectral_error:.4f}, R={cert.feature_radius:.2f} "
    f"=> max class-probability deviation <= {cert.prob_deviation_bound:.4f}"
)
