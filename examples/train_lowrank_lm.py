"""Training driver: train an LM for a few hundred steps, with optional
RSI-compressed (low-rank) parameterization from step 0.

    PYTHONPATH=src python examples/train_lowrank_lm.py               # reduced (CPU-sized)
    PYTHONPATH=src python examples/train_lowrank_lm.py --steps 300   # longer run
    PYTHONPATH=src python examples/train_lowrank_lm.py --full        # real mamba2-130m cfg

Demonstrates that the factored {a, b} parameter trees produced by
core/compress are TRAINABLE (gradients flow through apply_linear), i.e. the
framework supports low-rank-native training, not just post-hoc compression —
with checkpoint/restart via the production launcher machinery.
"""

import argparse

from repro.launch import train as train_cli


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--alpha", type=float, default=0.5)
    args = ap.parse_args()

    argv = [
        "--arch", "mamba2-130m",
        "--steps", str(args.steps),
        "--batch", "8",
        "--seq", "64",
        "--ckpt-dir", "/tmp/rsi_lowrank_train",
        "--save-every", "50",
        "--compress-alpha", str(args.alpha),
        "--compress-q", "4",
    ]
    if not args.full:
        argv.append("--reduced")
    state, metrics = train_cli.main(argv)
    assert float(metrics["loss"]) < 7.0, "training diverged"
    print("low-rank training OK")


if __name__ == "__main__":
    main()
