"""RSI + LoRA (paper §4 closing suggestion): compress the backbone with RSI,
then adapt with LoRA-style low-rank deltas on top of the FROZEN factored
weights — efficiency gains from both directions.

    PYTHONPATH=src python examples/rsi_plus_lora.py

Implementation: every compressed linear W ~= A·B stays frozen; a trainable
delta (lora_a (d_in,r) · lora_b (r,d_out), r << rank) is added.  Only the
adapters (and norms/biases) receive gradients.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.core import CompressionPolicy, compress_tree
from repro.core.lowrank import is_lowrank
from repro.data.synthetic import SyntheticLM
from repro.models.model import build_model
from repro.train import optimizer as opt_mod
from repro.train.train_step import softmax_xent

LORA_RANK = 4


def add_lora(params, key):
    """Attach zero-init LoRA adapters to every factored linear."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=is_lowrank
    )
    out = []
    for path, leaf in leaves:
        if is_lowrank(leaf):
            key, k1 = jax.random.split(key)
            d_in, d_out = leaf["a"].shape[-2], leaf["b"].shape[-1]
            lead = leaf["a"].shape[:-2]
            la = jax.random.normal(k1, lead + (d_in, LORA_RANK), jnp.float32) * 0.01
            lb = jnp.zeros(lead + (LORA_RANK, d_out), jnp.float32)
            leaf = dict(leaf, lora_a=la.astype(leaf["a"].dtype), lora_b=lb.astype(leaf["b"].dtype))
        out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def lora_merge(params):
    """Fold adapters into the factored weights for serving: stack the ranks."""
    def merge(leaf):
        if isinstance(leaf, dict) and "lora_a" in leaf:
            a = jnp.concatenate([leaf["a"], leaf["lora_a"]], axis=-1)
            b = jnp.concatenate([leaf["b"], leaf["lora_b"]], axis=-2)
            return {"a": a, "b": b}
        return leaf
    return jax.tree_util.tree_map(merge, params, is_leaf=lambda x: isinstance(x, dict) and "a" in x)


def main():
    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    params, _, rep = compress_tree(
        params, CompressionPolicy(alpha=0.4, q=4, min_dim=32), jax.random.PRNGKey(1)
    )
    print("backbone:", rep.summary())
    params = add_lora(params, jax.random.PRNGKey(2))

    trainable = lambda path: any("lora" in str(getattr(p, "key", "")) for p in path)
    data = SyntheticLM(cfg, batch=8, seq=32, seed=0)
    opt = opt_mod.adamw(opt_mod.constant_schedule(5e-3), weight_decay=0.0)

    # merged-apply: model sees {"a","b"} with lora ranks stacked in
    def loss_fn(p, batch):
        logits, _ = model.forward(lora_merge(p), batch)
        return softmax_xent(logits, batch["targets"], real_vocab=cfg.vocab)

    state = opt.init(params)

    @jax.jit
    def step(params, state, i, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        # freeze everything except LoRA adapters
        grads = jax.tree_util.tree_map_with_path(
            lambda path, g: g if trainable(path) else jnp.zeros_like(g), grads
        )
        updates, state = opt.update(grads, state, params, i)
        return opt_mod.apply_updates(params, updates), state, loss

    losses = []
    for i in range(40):
        batch = jax.tree_util.tree_map(jnp.asarray, data.at_step(i))
        params, state, loss = step(params, state, jnp.int32(i), batch)
        losses.append(float(loss))
        if i % 10 == 0:
            print(f"step {i:3d} adapter-only loss {losses[-1]:.4f}")
    assert np.mean(losses[-5:]) < np.mean(losses[:5]), "LoRA adaptation did not learn"
    n_train = sum(
        l.size for path, l in jax.tree_util.tree_flatten_with_path(params)[0] if trainable(path)
    )
    n_total = sum(l.size for l in jax.tree_util.tree_leaves(params))
    print(f"trainable adapter params: {n_train:,} / {n_total:,} ({n_train/n_total:.2%})")
    print("RSI + LoRA OK")


if __name__ == "__main__":
    main()
