"""Continuous-batching serving demo: RSI-compressed model under live traffic.

    PYTHONPATH=src python examples/continuous_serving.py [--alpha 0.3] [--q 4]
    PYTHONPATH=src python examples/continuous_serving.py --paged

What it shows:
  * requests with DIFFERENT prompt lengths, output budgets and sampling
    params (greedy / temperature / top-k) sharing one slotted KV-cache pool;
  * slot exhaustion queueing and mid-stream admission: more requests than
    slots, so finished sequences hand their slot to waiting ones;
  * the greedy-parity contract: a greedy request served under continuous
    batching emits exactly the tokens the reference ``greedy_generate``
    produces for that prompt alone;
  * RSI compression (the paper's Alg 3.1) as a serving lever: the same
    engine drives the compressed checkpoint;
  * with ``--paged``: the PAGED KV pool — fixed-size pages + per-slot block
    tables at HALF the flat pool's capacity, admission gated on actual page
    need, one long prompt prefilled in chunks interleaved with the running
    decodes — same tokens, fewer resident bytes;
  * with ``--shared``: system-prompt traffic over the paged pool with
    refcounted copy-on-write PREFIX SHARING — every request repeats the
    same leading prompt pages, which are prefilled once, mapped read-only
    into each follower's block table (counted once in the page
    accounting), and recycled only after their last reader finishes —
    same tokens again, and strictly fewer pages than the unshared run;
  * with ``--sessions``: the SESSION CACHE — a 3-turn conversation whose
    every follow-up prompt extends the previous reply, matching the pages
    the previous turn's decode filled (registered at slot release, LRU
    warm cache) so each turn re-prefills only its new user tokens.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.core import CompressionPolicy, compress_tree, spectralize_params
from repro.models.model import build_model
from repro.serving import Engine, Request, SamplingParams
from repro.train.serve_step import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--alpha", type=float, default=0.3)
    ap.add_argument("--q", type=int, default=4)
    ap.add_argument("--n-slots", type=int, default=3)
    ap.add_argument("--n-requests", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--paged", action="store_true",
                    help="serve from a paged KV pool at half the flat "
                    "capacity, with one long prompt chunk-prefilled")
    ap.add_argument("--shared", action="store_true",
                    help="system-prompt traffic over the paged pool with "
                    "copy-on-write prefix sharing (implies --paged)")
    ap.add_argument("--sessions", action="store_true",
                    help="after the shared run, drive a 3-turn conversation "
                    "through the warm session cache: each follow-up prompt "
                    "extends the previous reply and skips its re-prefill "
                    "(implies --shared)")
    args = ap.parse_args()
    if args.sessions:
        args.shared = True
    if args.shared:
        args.paged = True

    cfg = get_arch("llama3.2-1b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    # simulate pretrained weights (slow-decay spectra) — the paper's regime
    params = spectralize_params(params, jax.random.PRNGKey(9))
    if args.alpha > 0:
        params, _, rep = compress_tree(
            params, CompressionPolicy(alpha=args.alpha, q=args.q, min_dim=16),
            jax.random.PRNGKey(1),
        )
        print(f"[compress] {rep.summary()}")

    rng = np.random.default_rng(args.seed)
    max_len = 64 if args.shared else 48  # room for the 16-token system prompt
    # --shared: every request opens with the same 16-token system prompt
    # (two full 8-token pages) followed by its own suffix
    sys_prompt = rng.integers(0, cfg.vocab, size=(16,)) if args.shared else None
    reqs = []
    for i in range(args.n_requests):
        # mixed workload: even requests greedy, odd requests sampled
        sp = (
            SamplingParams()
            if i % 2 == 0
            else SamplingParams(temperature=0.8, top_k=40, seed=100 + i)
        )
        prompt = rng.integers(0, cfg.vocab, size=(int(rng.integers(4, 17)),))
        if sys_prompt is not None:
            prompt = np.concatenate([sys_prompt, prompt])
        reqs.append(
            Request(
                prompt=prompt,
                max_new_tokens=int(rng.integers(6, 20)),
                sampling=sp,
            )
        )

    paged_kw = {}
    if args.paged:
        # half the flat pool's token capacity, 8-token pages, and prompts
        # longer than 12 tokens prefilled in chunks between decode blocks
        paged_kw = dict(page_size=8,
                        kv_pages=args.n_slots * max_len // (2 * 8),
                        prefill_chunk=12,
                        share_prefix=args.shared)
        reqs.append(Request(  # a long prompt that chunk-prefills
            prompt=rng.integers(0, cfg.vocab, size=(30,)), max_new_tokens=8,
        ))
    eng = Engine(model, params, n_slots=args.n_slots, max_len=max_len, **paged_kw)
    t0 = time.time()
    done = eng.run(reqs)
    dt = time.time() - t0
    n_tok = sum(len(r.tokens) for r in done)
    print(
        f"[engine] {len(done)} requests ({args.n_slots} slots), {n_tok} tokens "
        f"in {dt:.2f}s ({n_tok/dt:.1f} tok/s, {eng.steps} shared decode steps)"
    )
    if args.paged:
        print(
            f"[paged] {eng.kv_pages} pages of {eng.page_size} tokens — "
            f"half the flat pool's {args.n_slots}x{max_len}-token reservation "
            f"({eng.kv_bytes_capacity} B pool, peak {eng.peak_pages_in_use} "
            f"pages / {eng.kv_bytes_peak} B resident, "
            f"{eng.prefill_chunks} prefill chunks interleaved)"
        )
    if args.shared:
        print(
            f"[shared] {eng.shared_page_hits} prefix pages mapped read-only "
            f"across {eng.shared_admissions} admissions "
            f"({eng.cow_forks} copy-on-write forks) — the system prompt's "
            f"pages were prefilled once and counted once"
        )
    for r in sorted(done, key=lambda r: r.uid):
        kind = "greedy" if r.sampling.temperature == 0 else (
            f"T={r.sampling.temperature} k={r.sampling.top_k}"
        )
        print(
            f"  req {r.uid}: prompt {r.prompt.size:2d} -> {len(r.tokens):2d} tokens "
            f"[{kind:12s}] latency {r.latency*1e3:6.0f}ms  {r.tokens[:8]}"
        )

    # greedy-parity spot check against the reference decode loop
    g = next(r for r in done if r.sampling.temperature == 0)
    ref = np.asarray(
        greedy_generate(
            model, params, {"tokens": jnp.asarray(g.prompt[None])},
            steps=g.max_new_tokens, max_len=max_len,
        )
    )[0].tolist()
    assert g.tokens == ref, (g.tokens, ref)
    print(f"[parity] request {g.uid} matches greedy_generate exactly: OK")

    if args.sessions:
        # a 3-turn conversation on the WARM engine: turn t+1's prompt is
        # turn t's prompt + reply + new user tokens, so it matches the
        # pages turn t's decode filled and prefills only the new suffix
        print("[sessions] 3-turn conversation through the warm session cache:")
        ctx = np.concatenate([sys_prompt, rng.integers(0, cfg.vocab, size=(5,))])
        for turn in range(3):
            r = eng.run([Request(prompt=ctx.copy(), max_new_tokens=8)])[0]
            print(
                f"  turn {turn}: prompt {ctx.size:2d} tokens, re-prefilled "
                f"{ctx.size - r.prefill_skipped:2d} (skipped "
                f"{r.prefill_skipped:2d} via matched pages), "
                f"ttft {r.ttft*1e3:4.0f}ms -> reply {r.tokens}"
            )
            ctx = np.concatenate(
                [ctx, np.asarray(r.tokens, np.int64),
                 rng.integers(0, cfg.vocab, size=(4,))]
            )


if __name__ == "__main__":
    main()
